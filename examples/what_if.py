"""Scenario simulation end to end: generate a what-if family, score a
placement grid in one dispatch, pick the min–max robust placement — then go
multi-objective (one dispatch returns the latency-F, network-movement, and
occupancy grids, §3.1), extract the Pareto front those grids already hold
(repro.search), and finally replay a generated trace (diurnal load, bursts,
a degrade, a device loss) through the real StreamingEngine and watch
modeled-vs-observed drift.

Run:  PYTHONPATH=src python examples/what_if.py
"""

import numpy as np

from repro.core import (ObjectiveSet, latency, network_movement,
                        scenario_robust_search, uniform_placement)
from repro.search import ObjectiveScales, pareto_front, scalarize
from repro.sim import (BatchedEvaluator, ScenarioConfig, pack_fleets,
                       pack_placements, replay_trace, scenario_batch)
from repro.core.placement import random_placement
from repro.streaming.engine import StreamingEngine
from repro.streaming.operators import (StreamGraph, filter_op, map_op,
                                       source, window_agg)

rng = np.random.default_rng(0)

# ---- the job: a real executable pipeline ---------------------------------
ops = [
    source(),
    map_op("normalize", lambda r: (r - r.mean()) / (r.std() + 1e-9)),
    filter_op("threshold", lambda r: r[:, 0] > -0.5, selectivity=0.7),
    window_agg("window_mean", window=4),
]
sg = StreamGraph(ops, [(0, 1), (1, 2), (2, 3)])

# ---- a family of 8 what-if worlds: random geo-fleets + workload traces ---
cfg = ScenarioConfig(n_regions=(3, 4), devices_per_region=(3, 5),
                     trace_len=24, base_rate=128.0,
                     degrade_prob=0.1, loss_prob=0.05)
scens = scenario_batch(rng, 8, cfg, graph=sg.meta)
v = scens[0].n_devices
print(f"family: {len(scens)} fleets × {v} devices, graph {sg.meta}")

# ---- batched what-if grid: 8 × 256 candidates in ONE dispatch ------------
xs = [random_placement(sg.meta.n_ops, np.ones((sg.meta.n_ops, v), bool),
                       rng, 0.5) for _ in range(256)]
ev = BatchedEvaluator(sg.meta)
grid = np.asarray(ev.score_grid(pack_placements(xs),
                                pack_fleets([s.fleet for s in scens])))
print(f"grid {grid.shape}: best-per-world F = {grid.min(axis=1).round(3)}")

# ---- min–max robust placement vs per-world optimum ------------------------
res = scenario_robust_search(sg.meta, scens, rng, n_candidates=256)
uni = uniform_placement(sg.meta.n_ops, np.ones((sg.meta.n_ops, v), bool))
worst_uni = max(latency(sg.meta, s.fleet, uni) for s in scens)
print(f"robust placement: worst-case F {res.F:.4f} "
      f"(uniform placement: {worst_uni:.4f})")

# ---- multi-objective: trade worst-case F against WAN bytes moved ---------
obj = ObjectiveSet.from_weights(latency_f=1.0, network_movement=0.002,
                                occupancy_max=0.05)
multi = ev.score_grid(pack_placements(xs),
                      pack_fleets([s.fleet for s in scens]),
                      objectives=obj)  # every grid + scalarization, ONE dispatch
print(f"objective grids {tuple(multi.names)}, each {multi.scalarized.shape}")
res_m = scenario_robust_search(sg.meta, scens, rng, n_candidates=256,
                               objectives=obj)
moved = max(network_movement(sg.meta, s.fleet, res.x) for s in scens)
moved_m = max(network_movement(sg.meta, s.fleet, res_m.x) for s in scens)
print(f"robust F-only placement moves {moved:.1f} bytes worst-case; "
      f"multi-objective placement {moved_m:.1f} "
      f"(scalarized worst-case {res_m.F:.4f})")

# ---- Pareto front: the trade-off menu one dispatch already holds ----------
# The weighted sum above is ONE point per weight vector; the per-objective
# grids hold the whole non-dominated front.  scenario="worst" extracts it
# over the worst-case-per-objective envelope of the 8 what-if worlds.
front = pareto_front(multi, scenario="worst")
print(f"Pareto front: {len(front)} of {len(xs)} candidates are "
      f"non-dominated over {multi.names}")
for k, vals in list(front)[:5]:
    print(f"  candidate {k:3d}: F={vals[0]:.4f}  "
          f"WAN-bytes={vals[1]:9.1f}  occupancy={vals[2]:.4f}")
# normalized scalarization: fit per-objective scales from the sampled grids
# so equal weights mean "each objective matters equally", not raw units
scales = ObjectiveScales.fit(multi)
k_eq = int(np.argmin(scalarize(front.values, np.ones(3), scales)))
print(f"equal-weight choice on NORMALIZED axes: candidate "
      f"{int(front.indices[k_eq])} (scales: "
      + ", ".join(f"{n}≈{s:.3g}" for n, s in zip(scales.names, scales.scale))
      + ")")

# ---- replay one world's trace through the real engine --------------------
s = scens[0]
eng = StreamingEngine(sg, s.fleet, res.x.copy())
rep = replay_trace(eng, s.trace, rng, name=s.name)
d = rep.drift()
print(f"replayed {len(rep.steps)} ticks "
      f"({rep.n_degrades} degrades, {rep.n_removes} removals); "
      f"fleet {v} → {eng.fleet.n_devices} devices")
print(f"modeled-vs-observed drift: ratio_rel_std={d['ratio_rel_std']:.3f} "
      f"over {d['n_ticks']} ticks")
