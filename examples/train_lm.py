"""End-to-end training driver example: train a reduced LM for a few hundred
steps on the quality-checked synthetic stream, with checkpointing.

(The paper's kind is streaming/serving infrastructure, so serve_stream.py is
the primary end-to-end driver; this shows the training path of the same
framework.  Scale --steps/--width up on real hardware.)

Run:  PYTHONPATH=src python examples/train_lm.py
"""

import tempfile

from repro.configs import get_smoke_config
from repro.launch.train import run_training

cfg = get_smoke_config("granite_8b").replace(
    n_layers=4, d_model=128, d_ff=256)  # ~13M params: CPU-friendly
with tempfile.TemporaryDirectory() as ckpt:
    out = run_training(
        cfg,
        steps=200,
        global_batch=8,
        seq_len=64,
        lr=1e-3,
        dq_fraction=0.25,       # quality-check a quarter of the stream
        ckpt_dir=ckpt,
        ckpt_every=50,
        log_every=20,
    )
first, last = out["losses"][0][1], out["losses"][-1][1]
print(f"\nloss {first:.3f} -> {last:.3f} over {out['final_step']} steps")
assert last < first
