"""Geo-distributed streaming analytics end to end.

A 3-region fleet (12 heterogeneous devices, WAN links between regions) runs
a real streaming DAG — ingest → clean → quality-check → LM scoring →
windowed aggregation — where the LM-scoring operator is an actual (reduced)
olmo model from the zoo.  The paper's cost model places every operator
fractionally; then a straggler appears and the runtime re-optimizes.

Run:  PYTHONPATH=src python examples/geo_placement.py
"""

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import (CostConfig, DQCoupling, ExplicitFleet,
                        PlacementProblem, greedy_transfer, latency,
                        uniform_placement)
from repro.models.api import build_model
from repro.streaming.engine import StreamingEngine
from repro.streaming.operators import (StreamGraph, map_op, model_op,
                                       quality_op, source, window_agg)

# ---- fleet: 3 regions × 4 devices, WAN between regions -------------------
rng = np.random.default_rng(0)
n_dev, n_regions = 12, 3
region = np.repeat(np.arange(n_regions), n_dev // n_regions)
wan = np.array([[0.02, 1.5, 2.5],
                [1.5, 0.02, 1.0],
                [2.5, 1.0, 0.02]])
com = wan[np.ix_(region, region)] + rng.uniform(0, 0.05, (n_dev, n_dev))
com = (com + com.T) / 2
np.fill_diagonal(com, 0.0)
speed = np.where(region == 0, 2.0, 1.0)  # region 0 has fast accelerators
fleet = ExplicitFleet(com_cost=com, speed=speed, region=region)

# ---- the analytics job ----------------------------------------------------
cfg = get_smoke_config("olmo_1b")
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
ops = [
    source("ingest"),
    map_op("clean", lambda r: np.clip(r, 0, cfg.vocab - 1), work=0.5),
    quality_op("dq_check", threshold=0.4, work=2.0),
    model_op("lm_score", model, params, cfg, work=50.0),
    window_agg("window_mean", window=8, work=0.5),
]
g = StreamGraph(ops, [(0, 1), (1, 2), (2, 3), (3, 4)])

# ---- cost-model-driven placement ------------------------------------------
caps = DQCoupling(cap0=np.full(n_dev, 1.0), load=np.full(n_dev, 0.05))
prob = PlacementProblem(g.meta, fleet,
                        CostConfig(alpha=0.002, include_compute=True),
                        beta=1.0, dq=caps)
uni = uniform_placement(g.meta.n_ops, prob.availability())
res = greedy_transfer(prob)
print(f"uniform placement F = {prob.score(uni, 0.0):.4f}")
print(f"optimized placement F = {res.F:.4f}  (dq={res.dq_fraction:.2f})")

# ---- run the stream --------------------------------------------------------
eng = StreamingEngine(g, fleet, res.x, alpha=0.002, device_speed=speed)
for batch_id in range(3):
    batch = rng.integers(0, cfg.vocab, (256, 32)).astype(float)
    batch[rng.random(256) < 0.05] = -1  # sensor dropouts
    t0 = time.perf_counter()
    rep = eng.run_batch(batch)
    print(f"batch {batch_id}: rows_in={rep.rows_in} -> "
          f"{rep.rows_out} modeled_latency={rep.modeled_latency:.4f} "
          f"wall={rep.wall_s*1e3:.0f}ms")

# ---- straggler: region-1 device slows 10× — re-optimize -------------------
slow_dev = 5
print(f"\ndevice {slow_dev} degrades 10x (straggler)...")
before = latency(g.meta, eng.fleet, eng.x, eng.cfg)
res2 = eng.degrade_and_replace(slow_dev, 10.0, beta=1.0)
print(f"re-optimized: F={res2.F:.4f}; mass on straggler "
      f"{eng.x[:, slow_dev].sum():.3f} (was {res.x[:, slow_dev].sum():.3f})")
rep = eng.run_batch(rng.integers(0, cfg.vocab, (256, 32)).astype(float))
print(f"post-mitigation batch: modeled_latency={rep.modeled_latency:.4f}")

# ---- elastic: lose a device entirely ---------------------------------------
print(f"\ndevice 11 fails — elastic down-scale...")
eng.remove_device(11, beta=1.0)
rep = eng.run_batch(rng.integers(0, cfg.vocab, (256, 32)).astype(float))
print(f"11-device fleet: modeled_latency={rep.modeled_latency:.4f} "
      f"rows_out={rep.rows_out}")
