"""Quickstart: the paper's cost model in 60 lines.

1. Reproduce the §3 worked example exactly.
2. Let the optimizers find a better placement under capacity constraints.
3. Show the data-quality trade-off (eq. 8) flipping with β.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (DQCoupling, ExplicitFleet, PlacementProblem,
                        greedy_transfer, latency, linear_graph, objective_F,
                        projected_gradient)

# ---- 1. the paper's worked example --------------------------------------
graph = linear_graph([1.0, 1.5, 1.0])  # 3 operators, s0=1, s1=1.5
fleet = ExplicitFleet(com_cost=np.array([  # paper Table 3 (GBps → cost)
    [0.0, 1.5, 2.0],
    [1.5, 0.0, 1.0],
    [2.0, 1.0, 0.0],
]))
x_paper = np.array([  # paper Table 4
    [0.8, 0.2, 0.0],
    [0.7, 0.0, 0.3],
    [0.3, 0.4, 0.3],
])
lat = latency(graph, fleet, x_paper)
print(f"paper placement latency      : {lat:.2f}   (paper: 1.74)")
print(f"F(beta=1, DQ=0.5)            : {objective_F(lat, 0.5, 1.0):.2f}"
      "   (paper: 1.16)")

x_mod = x_paper.copy()
x_mod[2] = [0.0, 0.4, 0.6]
lat2 = latency(graph, fleet, x_mod)
print(f"modified plan latency        : {lat2:.2f}   (paper: 2.37)")
print(f"beta=1: {objective_F(lat, .5, 1):.3f} vs {objective_F(lat2, 1, 1):.3f}"
      "  -> modification NOT worth it")
print(f"beta=2: {objective_F(lat, .5, 2):.2f} vs {objective_F(lat2, 1, 2):.2f}"
      "   -> now it IS (the paper's flip)")

# ---- 2. optimize the placement ------------------------------------------
# capacity 1.2 per device (quality checks eat 0.2·DQ) forces real spreading
prob = PlacementProblem(graph, fleet, beta=1.0,
                        dq=DQCoupling(cap0=np.full(3, 1.2),
                                      load=np.full(3, 0.2)))
greedy = greedy_transfer(prob)
pg = projected_gradient(prob, steps=150)
print(f"\noptimized (greedy)           : F={greedy.F:.3f} "
      f"dq={greedy.dq_fraction:.2f}")
print(f"optimized (autodiff, beyond-paper): F={pg.F:.3f} "
      f"dq={pg.dq_fraction:.2f}")
print("placement (rows=operators, cols=devices):")
print(np.round(pg.x, 2))
