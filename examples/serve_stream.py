"""Streaming inference service (the paper's kind: serve a small model with
batched requests) — prefill+decode waves with KV caches, reporting
throughput, latency and the paper's quality-adjusted objective F.

Run:  PYTHONPATH=src python examples/serve_stream.py
"""

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import ServeStats, serve_wave
from repro.models.api import build_model
from repro.streaming.quality import dq_latency_model, quality_scores

cfg = get_smoke_config("qwen3_32b")  # reduced same-family config
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

stats = ServeStats()
waves, batch, prompt_len, gen = 4, 8, 32, 24
print(f"serving {waves} waves x {batch} requests "
      f"(prompt {prompt_len}, gen {gen}) with {cfg.name}...")
all_outputs = []
for w in range(waves):
    prompts = rng.integers(0, cfg.vocab, (batch, prompt_len), dtype=np.int32)
    out, stats = serve_wave(model, cfg, params, prompts, gen, stats=stats)
    all_outputs.append(out)
s = stats.summary()
print(s)

# data-quality scoring of the generated streams (paper §3.1): DQ_fraction
# of outputs get scored; eq. 8 prices the latency/quality trade
outputs = np.concatenate(all_outputs)
for dq_fraction in (0.0, 0.5, 1.0):
    n_checked = int(len(outputs) * dq_fraction)
    scores = quality_scores(outputs[:n_checked]) if n_checked else np.array([])
    lat = s["decode_s"] / s["tokens_out"]
    for beta in (1.0, 2.0):
        F = dq_latency_model(lat, dq_fraction, beta)
        print(f"DQ_fraction={dq_fraction:.1f} beta={beta}: "
              f"F={F*1e3:.3f} ms/token"
              + (f" (mean quality {scores.mean():.2f})" if n_checked else ""))
