"""Serving-layer gates (BENCH_serve.json): coalescing must be free-of-error
and the sharing must actually pay.

The repro.serve claims this benchmark records and gates:

  * **parity**: served scores are BITWISE identical to direct dedicated
    ``score_grid`` calls — across interleaved tenants, mixed row counts,
    scalar AND per-scenario dq, different β, and multi-objective raw
    grids (padding rows never leak);
  * **throughput**: a warm service answers ≥10⁴ mixed-shape queries/s on
    one host (submit → drain → poll, everything included) by coalescing
    them into a handful of padded super-batch dispatches;
  * **sharing speedup**: the same mixed multi-tenant workload served ≥5×
    faster than per-tenant dedicated ``BatchedEvaluator`` instances built
    in isolated executable caches (each paying its own JIT — exactly what
    naive per-tenant serving does);
  * **admission**: with a tight p99 budget the service degrades/rejects
    (typed verdicts, non-zero counts) and the observed warm dispatch p99
    stays within the pricing-model resolution of the budget;
  * **cache accounting**: per-bucket recompile counts and the process
    executable-cache hit rate are reported, and a warm repeat of the
    whole workload adds ZERO recompiles.

Usage:
  python -m benchmarks.bench_serve            # full sizes
  python -m benchmarks.bench_serve --smoke    # small sizes (CI)
  python -m benchmarks.bench_serve --check    # exit 1 on a failed gate
"""

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core import ExplicitFleet, ObjectiveSet, random_dag, \
    random_placement
from repro.serve import (AdmissionConfig, Degraded, QueryResult, Rejected,
                         WhatIfQuery, WhatIfService)
from repro.sim import BatchedEvaluator, fresh_cache, pack_fleets

OUT_PATH = Path("BENCH_serve.json")

MIN_QPS = 1e4
MIN_SPEEDUP = 5.0
# observed-p99 vs budget slack: quantile estimation is a factor-of-growth
# (2×) resolution instrument, and the budget binds PREDICTED time
P99_SLACK = 4.0

FULL = dict(n_ops=5, n_dev=8, n_scen=2, n_tenants=8, n_queries=1000,
            rows_lo=2, rows_hi=16, chunk=2048)
SMOKE = dict(n_ops=5, n_dev=8, n_scen=2, n_tenants=4, n_queries=200,
             rows_lo=2, rows_hi=16, chunk=1024)

OBJ2 = ObjectiveSet.from_weights(latency_f=1.0, network_movement=0.05)


def _workload(cfg, seed=0):
    rng = np.random.default_rng(seed)
    g = random_dag(cfg["n_ops"], edge_prob=0.6, rng=rng)
    fleets = []
    for _ in range(cfg["n_scen"]):
        com = rng.uniform(0.1, 3.0, (cfg["n_dev"], cfg["n_dev"]))
        com = (com + com.T) / 2
        np.fill_diagonal(com, 0.0)
        fleets.append(ExplicitFleet(com_cost=com))
    coms = np.asarray(pack_fleets(fleets))

    def placements(n):
        return np.stack([
            random_placement(cfg["n_ops"],
                             np.ones((cfg["n_ops"], cfg["n_dev"]), bool),
                             rng)
            for _ in range(n)]).astype(np.float32)

    queries = []
    for i in range(cfg["n_queries"]):
        rows = int(rng.integers(cfg["rows_lo"], cfg["rows_hi"] + 1))
        dq = (rng.uniform(0.0, 0.8, cfg["n_scen"]) if i % 5 == 0
              else float(rng.uniform(0.0, 0.8)))
        queries.append((f"tenant{i % cfg['n_tenants']}", placements(rows),
                        dq, float(rng.uniform(0.0, 2.0))))
    return g, coms, placements, queries


def _serve_all(svc, fid, queries):
    """submit → drain → poll for every tenant; returns {query_id: result}
    and the tickets in submission order."""
    tickets = [svc.submit(t, fid, WhatIfQuery(kind="score", placements=x,
                                              dq=dq, beta=beta))
               for t, x, dq, beta in queries]
    svc.drain()
    results = {}
    for t in {q[0] for q in queries}:
        for m in svc.poll(t):
            if isinstance(m, QueryResult):
                results[m.query_id] = m
    return tickets, results


# -- gate 1: bitwise parity across the whole heterogeneous mix ----------------

def _parity_row(cfg) -> dict:
    g, coms, placements, queries = _workload(cfg, seed=1)
    svc = WhatIfService(g, admission=AdmissionConfig(p99_budget_s=1e6),
                        max_chunk_rows=64)   # force multi-chunk streaming
    fid = svc.register_fleet("shared", coms)
    sample = queries[:40]
    tickets, results = _serve_all(svc, fid, sample)
    ev = BatchedEvaluator.shared(g)
    checked, bitwise = 0, True
    for (t, x, dq, beta), tk in zip(sample, tickets):
        direct = np.asarray(ev.score_grid(x, coms, dq=dq, beta=beta),
                            dtype=np.float32)
        got = results[tk.query_id].scores
        bitwise &= got.shape == direct.shape \
            and bool(np.array_equal(got, direct))
        checked += 1
    # multi-objective raw-grid parity on top
    fid_m = svc.register_fleet("shared", coms, objectives=OBJ2)
    x = placements(9)
    tk = svc.submit("m", fid_m, WhatIfQuery(kind="score", placements=x))
    svc.drain()
    res = [m for m in svc.poll("m") if isinstance(m, QueryResult)][0]
    raw = ev.score_grid(x, coms, objectives=OBJ2)
    multi_ok = all(
        np.array_equal(res.grids[n], np.asarray(raw.grids[n], np.float32))
        for n in OBJ2.names)
    return dict(name="parity", queries_checked=checked,
                bitwise_scores=bool(bitwise),
                bitwise_multi_grids=bool(multi_ok),
                ok=bool(bitwise and multi_ok))


# -- gate 2: warm mixed-shape throughput --------------------------------------

def _throughput_row(cfg) -> dict:
    g, coms, _, queries = _workload(cfg, seed=2)
    svc = WhatIfService(g, admission=AdmissionConfig(p99_budget_s=1e6),
                        max_chunk_rows=cfg["chunk"])
    fid = svc.register_fleet("shared", coms)
    _serve_all(svc, fid, queries)        # warm pass: compiles every bucket
    t0 = time.perf_counter()
    tickets, results = _serve_all(svc, fid, queries)
    seconds = time.perf_counter() - t0
    qps = len(queries) / seconds
    snap = svc.stats.snapshot()
    return dict(name="throughput", queries=len(queries),
                completed=len(results), seconds=seconds, qps=qps,
                min_qps=MIN_QPS,
                dispatches=sum(b["dispatches"] for b in snap["buckets"]),
                buckets=snap["buckets"],
                ok=bool(qps >= MIN_QPS and len(results) == len(queries)))


# -- gate 3: sharing speedup vs per-tenant dedicated evaluators ---------------

def _speedup_row(cfg) -> dict:
    g, coms, _, queries = _workload(cfg, seed=4)
    by_tenant = {}
    for t, x, dq, beta in queries:
        by_tenant.setdefault(t, []).append((x, dq, beta))

    # baseline: every tenant owns a dedicated evaluator in an ISOLATED
    # executable cache — each pays its own JIT, like naive per-tenant
    # serving (shape-bucketed the same way, to isolate the sharing effect)
    t0 = time.perf_counter()
    for t, qs in by_tenant.items():
        with fresh_cache():
            ev = BatchedEvaluator(g)
            for x, dq, beta in qs:
                np.asarray(ev.score_grid(x, coms, dq=dq, beta=beta))
    baseline_s = time.perf_counter() - t0

    with fresh_cache():                      # serve pays its OWN compiles
        svc = WhatIfService(g, admission=AdmissionConfig(p99_budget_s=1e6),
                            max_chunk_rows=cfg["chunk"])
        fid = svc.register_fleet("shared", coms)
        t0 = time.perf_counter()
        _, results = _serve_all(svc, fid, queries)
        serve_s = time.perf_counter() - t0
    speedup = baseline_s / serve_s
    return dict(name="sharing_speedup", baseline_s=baseline_s,
                serve_s=serve_s, speedup=speedup,
                min_speedup=MIN_SPEEDUP, tenants=len(by_tenant),
                queries=len(queries),
                ok=bool(speedup >= MIN_SPEEDUP
                        and len(results) == len(queries)))


# -- gate 4: admission bounds the tail ----------------------------------------

def _admission_row(cfg) -> dict:
    g, coms, placements, _ = _workload(cfg, seed=5)
    with fresh_cache():
        svc = WhatIfService(g, admission=AdmissionConfig(p99_budget_s=1e6),
                            max_chunk_rows=cfg["chunk"])
        fid = svc.register_fleet("shared", coms)
        # calibrate the pricer on real dispatches
        for _ in range(3):
            svc.submit("warm", fid, WhatIfQuery(kind="score",
                                                placements=placements(256)))
            svc.drain()
        svc.poll("warm")
        budget = svc._fleets[fid].pricer.price_s(cfg["n_scen"], 256) * 1.5
        svc.admission = AdmissionConfig(p99_budget_s=budget, min_rows=16)
        verdicts = {"admitted": 0, "degraded": 0, "rejected": 0}
        for i in range(40):
            v = svc.submit("flood", fid, WhatIfQuery(
                kind="score", placements=placements(512)))
            if isinstance(v, Rejected):
                verdicts["rejected"] += 1
            elif isinstance(v.admission, Degraded):
                verdicts["degraded"] += 1
            else:
                verdicts["admitted"] += 1
            if i % 8 == 7:
                svc.drain()                    # let the backlog clear
        svc.drain()
        svc.poll("flood")
        warm_p99 = max((b.p99_warm() for b in svc.stats.buckets()
                        if b.warm > 0), default=float("nan"))
        snap = svc.stats.snapshot()
    controlled = verdicts["degraded"] + verdicts["rejected"] > 0
    bounded = bool(np.isfinite(warm_p99) and warm_p99 <= budget * P99_SLACK)
    return dict(name="admission", budget_s=budget, warm_p99_s=warm_p99,
                p99_slack=P99_SLACK, verdicts=verdicts,
                buckets=snap["buckets"],
                ok=bool(controlled and bounded))


# -- gate 5: executable-cache accounting + zero warm recompiles ---------------

def _cache_row(cfg) -> dict:
    # a graph this process has never seen, so the pass below is truly cold
    g, coms, _, queries = _workload(cfg, seed=6)
    with fresh_cache() as cache:
        # two independently-built evaluators over the SAME graph content:
        # instance 2 must resolve instance 1's jitted callables (the
        # cross-instance sharing the process-wide cache exists for)
        ev1 = BatchedEvaluator(g)
        misses_after_first = cache.stats()["misses"]
        ev2 = BatchedEvaluator(g)
        stats = cache.stats()
        cross_instance_hits = stats["hits"]

        svc = WhatIfService(g, admission=AdmissionConfig(p99_budget_s=1e6),
                            max_chunk_rows=cfg["chunk"])
        fid = svc.register_fleet("shared", coms)
        _serve_all(svc, fid, queries)          # cold pass compiles
        cold_recompiles = sum(b.recompiles for b in svc.stats.buckets())
        _serve_all(svc, fid, queries)          # warm repeat
        snap = svc.stats.snapshot()
        warm_recompiles = sum(
            b["recompiles"] for b in snap["buckets"]) - cold_recompiles
        stats = cache.stats()
    return dict(name="cache_accounting",
                executable_cache=stats,
                cross_instance_hits=cross_instance_hits,
                cold_recompiles=cold_recompiles,
                per_bucket=[{k: b[k] for k in
                             ("bucket", "dispatches", "recompiles",
                              "warm_dispatches", "p50", "p99")}
                            for b in snap["buckets"]],
                warm_repeat_recompiles=warm_recompiles,
                ok=bool(warm_recompiles == 0
                        and cross_instance_hits >= misses_after_first
                        and cold_recompiles > 0
                        and stats["hit_rate"] > 0.0))


def run(smoke: bool = False) -> list[str]:
    cfg = SMOKE if smoke else FULL
    rows = [_parity_row(cfg), _throughput_row(cfg), _speedup_row(cfg),
            _admission_row(cfg), _cache_row(cfg)]
    report = {"smoke": smoke, "rows": rows,
              "all_ok": all(r["ok"] for r in rows)}
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    out = []
    for r in rows:
        if r["name"] == "parity":
            out.append(f"serve_parity,bitwise={r['bitwise_scores']},"
                       f"multi={r['bitwise_multi_grids']},ok={r['ok']}")
        elif r["name"] == "throughput":
            out.append(f"serve_throughput,{r['qps']:.0f}qps,"
                       f"gate>={MIN_QPS:.0f},ok={r['ok']}")
        elif r["name"] == "sharing_speedup":
            out.append(f"serve_speedup,{r['speedup']:.1f}x,"
                       f"gate>={MIN_SPEEDUP:.0f}x,ok={r['ok']}")
        elif r["name"] == "admission":
            v = r["verdicts"]
            out.append(f"serve_admission,p99={r['warm_p99_s'] * 1e3:.1f}ms,"
                       f"budget={r['budget_s'] * 1e3:.1f}ms,"
                       f"degraded={v['degraded']},rejected={v['rejected']},"
                       f"ok={r['ok']}")
        else:
            st = r["executable_cache"]
            out.append(f"serve_cache,hit_rate={st['hit_rate']:.2f},"
                       f"warm_recompiles={r['warm_repeat_recompiles']},"
                       f"ok={r['ok']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every serving gate holds: bitwise "
                         "parity with direct score_grid, ≥1e4 mixed-shape "
                         "queries/s, ≥5× over per-tenant dedicated "
                         "evaluators, admission-bounded p99, zero warm "
                         "recompiles")
    ns = ap.parse_args()
    for line in run(smoke=ns.smoke):
        print(line)
    if ns.check:
        report = json.loads(OUT_PATH.read_text())
        if not report["all_ok"]:
            bad = [r["name"] for r in report["rows"] if not r["ok"]]
            print(f"FAILED gates: {bad}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
