"""Benchmark harness — one module per paper table/figure + the roofline
reader.  Prints ``name,us_per_call,derived`` CSV (see DESIGN.md §7).

  python -m benchmarks.run            # all
  python -m benchmarks.run paper dq   # substring filter
"""

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_analysis, bench_belief, bench_dq_tradeoff,
                            bench_geo_calibration, bench_kernels, bench_obs,
                            bench_optimizers, bench_paper_example,
                            bench_roofline, bench_scaling, bench_scenarios,
                            bench_search, bench_serve, bench_structured)
    suites = [
        ("paper_example", bench_paper_example.run),
        ("dq_tradeoff", bench_dq_tradeoff.run),
        ("optimizers", bench_optimizers.run),
        ("scaling", bench_scaling.run),
        ("scenarios", bench_scenarios.run),
        ("structured", bench_structured.run),
        ("search", bench_search.run),
        ("serve", bench_serve.run),
        ("obs", bench_obs.run),
        ("analysis", bench_analysis.run),
        ("kernels", bench_kernels.run),
        ("geo_calibration", bench_geo_calibration.run),
        ("belief", bench_belief.run),
        ("roofline", bench_roofline.run),
    ]
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if filters and not any(f in name for f in filters):
            continue
        try:
            for row in fn():
                print(row)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},NaN,FAILED")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
