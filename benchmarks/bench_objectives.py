"""Multi-objective what-if scoring: ONE score_grid dispatch returning every
§3.1 objective grid (latency-F, both network movements, both occupancy
reductions) vs one single-objective dispatch per objective, on both scenario
representations.

The tentpole claims this benchmark records (BENCH_objectives.json):

  * the fused multi-objective dispatch is at least as fast as running the
    same objectives as separate single-objective dispatches (they share the
    scenario lax.map, the edge-endpoint gathers, and the dispatch overhead)
    — the CI ``--check`` gate;
  * the structured path scores all objectives — including the
    degrade-weighted region-mass quadratic form of network movement — at
    V = 131 072 without ever materializing an (S, V, V) array, far past
    where the dense pack stops being representable.

Usage:
  python -m benchmarks.bench_objectives            # full sweep (V to 131072)
  python -m benchmarks.bench_objectives --smoke    # tiny V (CI)
  python -m benchmarks.bench_objectives --check    # exit 1 if the fused
                                                   # dispatch is slower
"""

import argparse
import json
import sys
from pathlib import Path

import numpy as np

from repro.core import ObjectiveSet, OBJECTIVES
from repro.core.graph import linear_graph
from repro.core.placement import random_placement
from repro.obs import bench as obench
from repro.sim import (BatchedEvaluator, ScenarioConfig, pack_fleets,
                       pack_placements, pack_speeds, region_fleet_family)

OUT_PATH = Path("BENCH_objectives.json")

N_OPS = 12
N_SCENARIOS = 4
N_REGIONS = 8
BYTES_F32 = 4

OBJECTIVE_WEIGHTS = {"latency_f": 1.0, "network_movement": 0.001,
                     "network_movement_cost": 0.01, "occupancy_max": 0.1,
                     "occupancy_imbalance": 0.1}
BETA, DQ = 0.5, 0.3

# (V, n_placements): P shrinks as V grows to bound the (P, E, V) working set
FULL_SWEEP = [(1024, 64), (16384, 32), (131072, 8)]
SMOKE_SWEEP = [(1024, 32)]
DENSE_MAX_V = 1024  # past this the (S, V, V) pack dwarfs memory


def _time(f, n=5):
    """(median seconds, last result) — median over n reps so one noisy CI
    rep can't flip the --check gate (shared harness: repro.obs.bench)."""
    t = obench.measure(f, n=n, block=False)
    return t.seconds, t.result


def _instance(rng, v: int, n_placements: int):
    cfg = ScenarioConfig(n_regions=(N_REGIONS, N_REGIONS),
                         explicit_fleet=False, outage_prob=0.1,
                         straggler_prob=0.05)
    fam = region_fleet_family(rng, N_SCENARIOS, cfg, n_devices=v)
    # payloads make every objective non-degenerate (work=0 ⇒ occupancy ≡ 0)
    g = linear_graph([float(s) for s in rng.uniform(0.5, 1.5, N_OPS)],
                     out_bytes=2.0, work=0.3)
    avail = np.ones((N_OPS, v), dtype=bool)
    xs = [random_placement(N_OPS, avail, rng, 0.5)
          for _ in range(n_placements)]
    return g, fam, pack_placements(xs), xs


def _bench_path(ev, placements, pack, obj_set, speed=None):
    """(fused_s, separate_s, fused_result): one multi-objective dispatch vs
    one single-objective dispatch per objective."""
    fused_s, res = _time(lambda: {
        name: np.asarray(g) for name, g in ev.score_grid(
            placements, pack, dq=DQ, beta=BETA, objectives=obj_set,
            speed=speed).grids.items()})
    separate_s = 0.0
    for name in obj_set.names:
        single = ObjectiveSet.of(name)
        s, _ = _time(lambda: np.asarray(ev.score_grid(
            placements, pack, dq=DQ, beta=BETA, objectives=single,
            speed=speed).scalarized))
        separate_s += s
    return fused_s, separate_s, res


def run(smoke: bool = False) -> list[str]:
    rng = np.random.default_rng(0)
    sweep = SMOKE_SWEEP if smoke else FULL_SWEEP
    obj_set = ObjectiveSet.from_weights(**OBJECTIVE_WEIGHTS)
    rows, out_rows = [], []

    for v, n_placements in sweep:
        g, fam, placements, xs = _instance(rng, v, n_placements)
        n_cells = N_SCENARIOS * n_placements * len(obj_set.names)
        ev = BatchedEvaluator(g)
        fused_s, separate_s, grids = _bench_path(ev, placements, fam, obj_set)
        # oracle spot-check on the smallest V (pure waste at 10⁵ devices,
        # where the scalar oracle itself is the slow path)
        if v == sweep[0][0]:
            for name in obj_set.names:
                want = OBJECTIVES[name].scalar(g, fam.fleet(0), xs[0],
                                               DQ, BETA, ev.cfg)
                err = abs(grids[name][0, 0] - want) / max(abs(want), 1e-12)
                if err > 1e-4:
                    raise AssertionError(f"{name} grid disagrees with "
                                         f"oracle: rel {err}")
        row = {
            "representation": "structured",
            "V": v, "R": N_REGIONS, "S": N_SCENARIOS, "P": n_placements,
            "objectives": list(obj_set.names),
            "seconds_fused": fused_s,
            "seconds_separate_dispatches": separate_s,
            "fused_speedup": separate_s / fused_s,
            "objective_cells_per_second": n_cells / fused_s,
            "scenario_state_bytes":
                N_SCENARIOS * (N_REGIONS * N_REGIONS + v) * BYTES_F32,
        }
        rows.append(row)
        out_rows.append(
            f"structured_multi_V{v},{fused_s * 1e3:.2f}ms,"
            f"fused_speedup={row['fused_speedup']:.2f}x")

        if v <= DENSE_MAX_V:
            fleets = fam.fleets()
            coms, speeds = pack_fleets(fleets), pack_speeds(fleets)
            fused_s, separate_s, _ = _bench_path(ev, placements, coms,
                                                 obj_set, speed=speeds)
            rows.append({
                "representation": "dense",
                "V": v, "S": N_SCENARIOS, "P": n_placements,
                "objectives": list(obj_set.names),
                "seconds_fused": fused_s,
                "seconds_separate_dispatches": separate_s,
                "fused_speedup": separate_s / fused_s,
                "objective_cells_per_second": n_cells / fused_s,
                "scenario_state_bytes": N_SCENARIOS * v * v * BYTES_F32,
            })
            out_rows.append(
                f"dense_multi_V{v},{fused_s * 1e3:.2f}ms,"
                f"fused_speedup={rows[-1]['fused_speedup']:.2f}x")

    report = {
        "n_ops": N_OPS,
        "n_scenarios": N_SCENARIOS,
        "n_regions": N_REGIONS,
        "weights": OBJECTIVE_WEIGHTS,
        "smoke": smoke,
        "rows": rows,
        "min_fused_speedup": min(r["fused_speedup"] for r in rows),
        "max_structured_V": max(r["V"] for r in rows
                                if r["representation"] == "structured"),
    }
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return out_rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny V sweep for CI")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless the fused multi-objective dispatch "
                         "is at least as fast as separate dispatches")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
    if args.check:
        report = json.loads(OUT_PATH.read_text())
        speedup = report["min_fused_speedup"]
        # 0.8x tolerance: catch real regressions (sharing the scenario map
        # and gathers should win outright), not CI timer noise
        if speedup < 0.8:
            print(f"CHECK FAILED: fused multi-objective dispatch slower "
                  f"than separate dispatches (min speedup {speedup:.2f}x "
                  f"< 0.8x)", file=sys.stderr)
            sys.exit(1)
        if not report["smoke"] and report["max_structured_V"] < 131072:
            print(f"CHECK FAILED: structured sweep stopped at "
                  f"V={report['max_structured_V']} < 131072",
                  file=sys.stderr)
            sys.exit(1)
        print(f"check OK: min fused speedup {speedup:.2f}x, structured V "
              f"up to {report['max_structured_V']}")


if __name__ == "__main__":
    main()
