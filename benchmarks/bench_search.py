"""Batched search vs the seed's scalar-loop searchers at matched candidate
counts (BENCH_search.json).

The search-subsystem claims this benchmark records:

  * the batched searchers (``repro.search``) return the same argmin as the
    seed scalar loops on fixed-seed problems — ≤1e-5 relative objective
    difference after exact re-scoring — while issuing O(dispatches) instead
    of O(candidates) evaluator calls (the ``dispatches`` column vs the
    ``evals`` column);
  * at matched candidate counts the batched random/exhaustive searchers are
    faster than the scalar loop — the CI ``--check`` gate — on BOTH
    scenario representations: a dense ExplicitFleet problem and a
    structured RegionFleet problem at V = 131 072 (full sweep), where the
    engine packs an S=1 RegionFleetFamily and never materializes V×V;
  * greedy descent runs one dispatch per (operator, round) instead of one
    scalar score per move (reported, not gated: on tiny instances its
    per-dispatch overhead can tie the scalar loop).

Usage:
  python -m benchmarks.bench_search            # full sweep (V to 131072)
  python -m benchmarks.bench_search --smoke    # tiny V (CI)
  python -m benchmarks.bench_search --check    # exit 1 on slower-than-scalar
                                               # or argmin mismatch
"""

import argparse
import json
import math
import sys
from pathlib import Path

import numpy as np

from repro.core import (ExplicitFleet, PlacementProblem, RegionFleet,
                        linear_graph)
from repro.core.optimizers import DQCoupling, OptResult, _dq_grid
from repro.core.placement import random_placement, uniform_placement
from repro.obs import bench as obench
from repro.obs import jaxhooks, perfbridge
from repro.search import (BatchedProblem, exhaustive_search, greedy_transfer,
                          random_search)

OUT_PATH = Path("BENCH_search.json")

N_OPS = 8
BETA = 1.0

# (V_dense, P_random, V_structured)
FULL = dict(v_dense=64, p_random=512, v_structured=131072, p_structured=32,
            greedy_v=16)
# smoke sizes keep the scalar side several × the batched side so the CI
# gate has margin against runner noise (V=16/P=128 measured only ~1.4× on
# idle hardware; scalar scoring scales with V while the dispatch does not)
SMOKE = dict(v_dense=64, p_random=384, v_structured=4096, p_structured=64,
             greedy_v=8)


def _time(f):
    """One-shot (seconds, result) via the shared harness
    (:func:`repro.obs.bench.time_once`); results here are host-side
    OptResults, so no extra block is needed."""
    return obench.time_once(f, block=False)


def _timed_batched(run_b):
    """Time a WARM batched searcher, surfacing recompiles inside the timed
    region (should be 0 — a nonzero count is a silent shape-bucket miss
    the telemetry layer exists to catch)."""
    snap = jaxhooks.snapshot()
    seconds, res = obench.time_once(run_b, block=False)
    n_rec, _ = snap.delta()
    return seconds, res, n_rec


def _dense_problem(rng, v: int, coupling: bool = True) -> PlacementProblem:
    com = rng.uniform(0.1, 3.0, (v, v))
    com = (com + com.T) / 2.0
    np.fill_diagonal(com, 0.0)
    g = linear_graph([float(s) for s in rng.uniform(0.5, 1.5, N_OPS)])
    dq = DQCoupling(cap0=np.full(v, max(2.0 * N_OPS / v, 0.5)),
                    load=np.full(v, 0.1)) if coupling else None
    return PlacementProblem(g, ExplicitFleet(com_cost=com), beta=BETA, dq=dq)


def _structured_problem(rng, v: int, r: int = 16) -> PlacementProblem:
    region = np.sort(rng.integers(0, r, v))
    inter = rng.uniform(0.5, 3.0, (r, r))
    inter = (inter + inter.T) / 2.0
    np.fill_diagonal(inter, 0.05)
    g = linear_graph([float(s) for s in rng.uniform(0.5, 1.5, N_OPS)])
    return PlacementProblem(g, RegionFleet(region=region, inter=inter),
                            beta=BETA)


# -- seed-faithful scalar-loop references -------------------------------------

def _scalar_random_search(prob, rng, n_candidates: int) -> OptResult:
    """The seed loop: one exact prob.score per (candidate, dq)."""
    avail = prob.availability()
    n_ops, _ = avail.shape
    dqs = _dq_grid(prob)
    best_F, best_x, best_dq, evals = math.inf, None, 0.0, 0
    for x in [uniform_placement(n_ops, avail)] + [
            random_placement(n_ops, avail, rng, 0.5)
            for _ in range(n_candidates)]:
        for dq in dqs:
            f = prob.score(x, dq)
            evals += 1
            if f < best_F:
                best_F, best_x, best_dq = f, x, dq
    return OptResult.of(prob, best_x, best_dq, [best_F], evals)


def _scalar_greedy(prob, deltas=(0.4, 0.2, 0.1, 0.05),
                   max_rounds: int = 60) -> OptResult:
    """The seed greedy: per-move prob.score calls."""
    avail = prob.availability()
    n_ops, _ = avail.shape
    x = uniform_placement(n_ops, avail)
    dq, evals = 0.0, 1
    best = prob.score(x, dq)
    for delta in deltas:
        for _ in range(max_rounds):
            improved = False
            for dq_cand in _dq_grid(prob, include=(dq,)):
                f = prob.score(x, dq_cand)
                evals += 1
                if f < best - 1e-12:
                    best, dq, improved = f, dq_cand, True
            for i in range(n_ops):
                idx = np.flatnonzero(avail[i])
                best_move, best_f = None, best
                for u in idx:
                    if x[i, u] < delta - 1e-12:
                        continue
                    for v in idx:
                        if v == u:
                            continue
                        x[i, u] -= delta
                        x[i, v] += delta
                        f = prob.score(x, dq)
                        evals += 1
                        x[i, u] += delta
                        x[i, v] -= delta
                        if f < best_f - 1e-12:
                            best_f, best_move = f, (u, v)
                if best_move is not None:
                    u, v = best_move
                    x[i, u] -= delta
                    x[i, v] += delta
                    best, improved = best_f, True
            if not improved:
                break
    return OptResult.of(prob, x, dq, [best], evals)


def _scalar_exhaustive(prob, granularity: int) -> OptResult:
    import itertools

    from repro.search.candidates import _per_op_rows
    avail = prob.availability()
    best_F, best_x, best_dq, evals = math.inf, None, 0.0, 0
    dqs = _dq_grid(prob)
    for rows in itertools.product(*_per_op_rows(avail, granularity)):
        x = np.stack(rows)
        for dq in dqs:
            f = prob.score(x, dq)
            evals += 1
            if f < best_F:
                best_F, best_x, best_dq = f, x, dq
    return OptResult.of(prob, best_x, best_dq, [best_F], evals)


def _rel_diff(a: float, b: float) -> float:
    return abs(a - b) / max(abs(a), abs(b), 1e-12)


def _row(name, scalar_s, batched_s, res_scalar, res_batched, gated,
         n_recompiles=0, **extra):
    return dict(name=name, seconds_scalar=scalar_s, seconds_batched=batched_s,
                speedup=scalar_s / max(batched_s, 1e-12),
                evals=res_batched.evals, dispatches=res_batched.dispatches,
                F_scalar=res_scalar.F, F_batched=res_batched.F,
                rel_objective_diff=_rel_diff(res_scalar.F, res_batched.F),
                gated=gated, n_recompiles=n_recompiles, **extra)


def _hlo_fields(eng: BatchedProblem, n_placements: int) -> dict:
    """repro.perf bridge: FLOPs/bytes/roofline of ONE dense grid dispatch
    at this benchmark's warmed shape (pads to the searcher's bucket)."""
    from repro.sim.batched import pack_placements

    bucket = 1 << max(n_placements - 1, 0).bit_length()
    avail = eng.prob.availability()
    xs = [uniform_placement(avail.shape[0], avail)] * bucket
    placements = pack_placements(xs)
    f = lambda: eng._ev._jit_grid(placements, eng._pack, 0.0, 0.0)
    t = obench.measure(f, n=3)
    rec = perfbridge.hlo_record(eng._ev._jit_grid,
                                args=(placements, eng._pack, 0.0, 0.0),
                                measured_s=t.seconds,
                                compile_snapshot=None)
    return dict(hlo_flops=rec["hlo_flops"],
                roofline_fraction=rec["roofline_fraction"],
                grid_dispatch_s=t.seconds)


def run(smoke: bool = False) -> list[str]:
    cfg = SMOKE if smoke else FULL
    rows, out = [], []

    # Every batched searcher is timed against a WARM engine (one warm call
    # first, same shapes): the claim under test is steady-state dispatch
    # cost at matched candidate counts, not one-time jit compilation — the
    # same convention the other benches use (warm call inside _time).

    # -- random search, dense representation, matched candidates -------------
    rng = np.random.default_rng(0)
    prob = _dense_problem(rng, cfg["v_dense"])
    eng = BatchedProblem(prob)
    run_b = lambda: random_search(prob, np.random.default_rng(7),
                                  n_candidates=cfg["p_random"], engine=eng)
    run_b()  # warm (jit compile per bucket shape)
    bs, rb, n_rec = _timed_batched(run_b)
    ss, rs = _time(lambda: _scalar_random_search(
        prob, np.random.default_rng(7), cfg["p_random"]))
    rows.append(_row("random_dense", ss, bs, rs, rb, gated=True,
                     n_recompiles=n_rec, V=cfg["v_dense"],
                     candidates=cfg["p_random"],
                     **_hlo_fields(eng, cfg["p_random"])))

    # -- random search, structured representation (V to 131072) --------------
    prob_s = _structured_problem(rng, cfg["v_structured"])
    eng_s = BatchedProblem(prob_s)
    run_b = lambda: random_search(
        prob_s, np.random.default_rng(7), n_candidates=cfg["p_structured"],
        batch=cfg["p_structured"], engine=eng_s)
    run_b()  # warm
    bs, rb, n_rec = _timed_batched(run_b)
    ss, rs = _time(lambda: _scalar_random_search(
        prob_s, np.random.default_rng(7), cfg["p_structured"]))
    rows.append(_row("random_structured", ss, bs, rs, rb, gated=True,
                     n_recompiles=n_rec, V=cfg["v_structured"], candidates=cfg["p_structured"]))

    # -- exhaustive oracle, matched enumeration ------------------------------
    prob_e = _dense_problem(np.random.default_rng(3), 3, coupling=True)
    prob_e = PlacementProblem(linear_graph([1.0, 1.5, 1.0]),
                              prob_e.fleet, beta=BETA, dq=prob_e.dq)
    eng_e = BatchedProblem(prob_e)
    run_b = lambda: exhaustive_search(prob_e, granularity=4, engine=eng_e)
    run_b()  # warm
    bs, rb, n_rec = _timed_batched(run_b)
    ss, rs = _time(lambda: _scalar_exhaustive(prob_e, granularity=4))
    rows.append(_row("exhaustive", ss, bs, rs, rb, gated=True,
                     n_recompiles=n_rec, V=3, candidates=rb.evals))

    # -- greedy descent (reported, not gated) --------------------------------
    prob_g = _dense_problem(np.random.default_rng(5), cfg["greedy_v"])
    eng_g = BatchedProblem(prob_g)
    run_b = lambda: greedy_transfer(prob_g, engine=eng_g)
    run_b()  # warm
    bs, rb, n_rec = _timed_batched(run_b)
    ss, rs = _time(lambda: _scalar_greedy(prob_g))
    rows.append(_row("greedy_dense", ss, bs, rs, rb, gated=False,
                     n_recompiles=n_rec, V=cfg["greedy_v"], candidates=rb.evals))

    for r in rows:
        out.append(f"search_{r['name']},{r['seconds_batched'] * 1e3:.2f}ms,"
                   f"speedup={r['speedup']:.2f}x,"
                   f"dispatches={r['dispatches']},evals={r['evals']},"
                   f"rel_diff={r['rel_objective_diff']:.2e}")

    gated = [r for r in rows if r["gated"]]
    report = {
        "n_ops": N_OPS,
        "beta": BETA,
        "smoke": smoke,
        "rows": rows,
        "min_gated_speedup": min(r["speedup"] for r in gated),
        "max_gated_rel_diff": max(r["rel_objective_diff"] for r in gated),
        "max_structured_V": max(r["V"] for r in rows
                                if "structured" in r["name"]),
    }
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny V sweep (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every gated batched searcher beats "
                         "the scalar loop at equal candidates AND matches "
                         "its argmin objective to ≤1e-5 relative")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
    if args.check:
        report = json.loads(OUT_PATH.read_text())
        ok = True
        if report["min_gated_speedup"] < 1.0:
            print(f"CHECK FAILED: batched searcher slower than the scalar "
                  f"loop at equal candidates (min speedup "
                  f"{report['min_gated_speedup']:.2f}x < 1.0x)",
                  file=sys.stderr)
            ok = False
        if report["max_gated_rel_diff"] > 1e-5:
            print(f"CHECK FAILED: batched argmin disagrees with the scalar "
                  f"loop (rel objective diff "
                  f"{report['max_gated_rel_diff']:.2e} > 1e-5)",
                  file=sys.stderr)
            ok = False
        if not report["smoke"] and report["max_structured_V"] < 131072:
            print(f"CHECK FAILED: structured sweep stopped at "
                  f"V={report['max_structured_V']} < 131072", file=sys.stderr)
            ok = False
        if not ok:
            sys.exit(1)
        print(f"check OK: min gated speedup "
              f"{report['min_gated_speedup']:.2f}x, max rel diff "
              f"{report['max_gated_rel_diff']:.2e}, structured V up to "
              f"{report['max_structured_V']}")


if __name__ == "__main__":
    main()
