"""DQ/latency trade-off sweep (the paper's §3 flip, as a β curve): for each
β the optimizer picks (placement, DQ_fraction); report chosen DQ and F."""

import numpy as np

from repro.core import (DQCoupling, ExplicitFleet, PlacementProblem,
                        greedy_transfer, linear_graph)

COM = np.array([[0.0, 1.5, 2.0], [1.5, 0.0, 1.0], [2.0, 1.0, 0.0]])


def run() -> list[str]:
    g = linear_graph([1.0, 1.5, 1.0])
    fleet = ExplicitFleet(com_cost=COM)
    # quality checks eat capacity on device 0 (the well-connected one)
    dq = DQCoupling(cap0=np.array([1.2, 1.2, 1.4]),
                    load=np.array([0.6, 0.1, 0.0]))
    rows = []
    prev_dq = -1.0
    for beta in (0.0, 0.5, 1.0, 2.0, 4.0):
        prob = PlacementProblem(g, fleet, beta=beta, dq=dq)
        res = greedy_transfer(prob)
        rows.append(f"dq_tradeoff_beta{beta},0.0,"
                    f"dq={res.dq_fraction:.2f};F={res.F:.4f};"
                    f"latency={res.latency:.4f}")
        assert res.dq_fraction >= prev_dq - 1e-9, "DQ must rise with beta"
        prev_dq = res.dq_fraction
    return rows
