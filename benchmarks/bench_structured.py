"""Structured vs dense what-if scoring: score_grid over RegionFleetFamily
scenario families across a device-count sweep, against the dense (S, V, V)
path at the V both can run.

The tentpole claim this benchmark records (BENCH_structured.json):

  * the structured path's scenario state is O(S·(R² + V)) — it completes a
    V = 131 072 grid without ever allocating an (S, V, V) array, far past
    where the dense pack stops being representable;
  * at the largest V both paths can run, the structured path holds ≥10×
    less memory for the scenario family (``memory_headroom_vs_dense``) and
    is at least as fast per candidate (the CI ``--check`` gate).

Usage:
  python -m benchmarks.bench_structured            # full sweep
  python -m benchmarks.bench_structured --smoke    # tiny V (CI)
  python -m benchmarks.bench_structured --check    # exit 1 if structured
                                                   # slower than dense
"""

import argparse
import json
import sys
from pathlib import Path

import numpy as np

from repro.core import latency, objective_F
from repro.core.graph import linear_graph
from repro.core.placement import random_placement
from repro.obs import bench as obench
from repro.sim import (BatchedEvaluator, ScenarioConfig, pack_fleets,
                       pack_placements, region_fleet_family)

OUT_PATH = Path("BENCH_structured.json")

N_OPS = 12
N_SCENARIOS = 4
N_REGIONS = 8
BYTES_F32 = 4

# (V, n_placements): P shrinks as V grows to bound the (P, E, V) working set
FULL_SWEEP = [(1024, 64), (16384, 32), (131072, 8)]
# smoke V sits well above the dense/structured crossover (~300 devices on
# CPU: below it the dense E·V² matmul is too small for the structured
# path's scatter/gather overhead to pay off) so the CI speed gate has a
# several-x margin, not a coin flip
SMOKE_SWEEP = [(1024, 32)]
# dense (S, V, V) packs: 1024² · 4 scenarios ≈ 17 MB — past a few thousand
# devices the pack alone dwarfs memory, which is the point of this bench
FULL_DENSE_MAX_V = 1024
SMOKE_DENSE_MAX_V = 1024


def _time(f, n=5):
    """(median seconds, last result) — median over n reps so one noisy CI
    rep can't flip the --check gate; the result feeds the oracle spot-check
    without an extra dispatch (shared harness: repro.obs.bench)."""
    t = obench.measure(f, n=n, block=False)
    return t.seconds, t.result


def _instance(rng, v: int, n_placements: int):
    cfg = ScenarioConfig(n_regions=(N_REGIONS, N_REGIONS),
                         explicit_fleet=False, outage_prob=0.1,
                         straggler_prob=0.05)
    fam = region_fleet_family(rng, N_SCENARIOS, cfg, n_devices=v)
    g = linear_graph([float(s) for s in rng.uniform(0.5, 1.5, N_OPS)])
    avail = np.ones((N_OPS, v), dtype=bool)
    xs = [random_placement(N_OPS, avail, rng, 0.5)
          for _ in range(n_placements)]
    return g, fam, pack_placements(xs), xs


def _state_bytes_structured(v: int) -> int:
    """Resident scenario-family state: (S, R, R) inter + (S, V) degrade."""
    return N_SCENARIOS * (N_REGIONS * N_REGIONS + v) * BYTES_F32


def _state_bytes_dense(v: int) -> int:
    """Resident scenario-family state: the (S, V, V) com stack."""
    return N_SCENARIOS * v * v * BYTES_F32


def _peak_bytes(v: int, p: int, e: int, dense: bool) -> int:
    """Analytic peak estimate: scenario state + placements + the per-scenario
    (P, E, V) endpoint working set lax.map keeps live (3 dense operands /
    4 structured plus the (P, E, R) masses)."""
    placements = p * N_OPS * v * BYTES_F32
    if dense:
        return _state_bytes_dense(v) + placements + 3 * p * e * v * BYTES_F32
    return (_state_bytes_structured(v) + placements
            + 4 * p * e * v * BYTES_F32
            + p * e * N_REGIONS * BYTES_F32)


def run(smoke: bool = False) -> list[str]:
    rng = np.random.default_rng(0)
    sweep = SMOKE_SWEEP if smoke else FULL_SWEEP
    dense_max_v = SMOKE_DENSE_MAX_V if smoke else FULL_DENSE_MAX_V
    structured_rows, dense_rows, out_rows = [], [], []
    common = None  # largest V where both paths ran

    for v, n_placements in sweep:
        g, fam, placements, xs = _instance(rng, v, n_placements)
        n_cand = N_SCENARIOS * n_placements
        ev = BatchedEvaluator(g)
        s_struct, grid = _time(lambda: np.asarray(
            ev.score_grid(placements, fam, dq=0.3, beta=0.5)))
        # spot-check the oracle on the smallest V (cheap there, pure waste
        # at 10⁵ devices where the oracle itself is the slow path)
        if v == sweep[0][0]:
            want = objective_F(latency(g, fam.fleet(0), xs[0]), 0.3, 0.5)
            err = abs(grid[0, 0] - want) / max(abs(want), 1e-12)
            if err > 1e-4:
                raise AssertionError(
                    f"structured grid disagrees with oracle: rel {err}")
        row = {
            "V": v, "R": N_REGIONS, "S": N_SCENARIOS, "P": n_placements,
            "E": g.n_edges,
            "seconds_per_grid": s_struct,
            "candidates_per_second": n_cand / s_struct,
            "scenario_state_bytes": _state_bytes_structured(v),
            "peak_bytes_est": _peak_bytes(v, n_placements, g.n_edges,
                                          dense=False),
        }
        structured_rows.append(row)
        out_rows.append(
            f"structured_grid_V{v},{s_struct / n_cand * 1e6:.2f},"
            f"cands_per_s={n_cand / s_struct:.0f}")

        if v <= dense_max_v:
            coms = pack_fleets(fam.fleets())
            s_dense, _ = _time(lambda: np.asarray(
                ev.score_grid(placements, coms, dq=0.3, beta=0.5)))
            dense_rows.append({
                "V": v, "S": N_SCENARIOS, "P": n_placements,
                "seconds_per_grid": s_dense,
                "candidates_per_second": n_cand / s_dense,
                "scenario_state_bytes": _state_bytes_dense(v),
                "peak_bytes_est": _peak_bytes(v, n_placements, g.n_edges,
                                              dense=True),
            })
            out_rows.append(
                f"dense_grid_V{v},{s_dense / n_cand * 1e6:.2f},"
                f"cands_per_s={n_cand / s_dense:.0f}")
            common = (v, s_struct, s_dense)

    report = {
        "n_ops": N_OPS,
        "n_scenarios": N_SCENARIOS,
        "n_regions": N_REGIONS,
        "smoke": smoke,
        "structured": structured_rows,
        "dense": dense_rows,
    }
    if common is not None:
        v, s_struct, s_dense = common
        report["largest_common_V"] = v
        report["memory_headroom_vs_dense"] = (
            _state_bytes_dense(v) / _state_bytes_structured(v))
        report["peak_headroom_vs_dense"] = (
            _peak_bytes(v, dict(sweep)[v], N_OPS - 1, True)
            / _peak_bytes(v, dict(sweep)[v], N_OPS - 1, False))
        report["structured_speedup_at_common_V"] = s_dense / s_struct
        out_rows.append(
            f"structured_headroom_V{v},0.00,"
            f"mem_headroom={report['memory_headroom_vs_dense']:.0f}x;"
            f"speedup={report['structured_speedup_at_common_V']:.1f}x")
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return out_rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny V sweep for CI")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless structured ≥ dense speed and ≥10× "
                         "memory headroom at the common V")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
    if args.check:
        report = json.loads(OUT_PATH.read_text())
        speedup = report.get("structured_speedup_at_common_V", 0.0)
        headroom = report.get("memory_headroom_vs_dense", 0.0)
        # 0.8x tolerance: the gate catches real regressions (the structured
        # path sits at several-x above the crossover V), not CI timer noise
        if speedup < 0.8:
            print(f"CHECK FAILED: structured path slower than dense at equal "
                  f"V (speedup {speedup:.2f}x < 0.8x)", file=sys.stderr)
            sys.exit(1)
        if headroom < 10.0:
            print(f"CHECK FAILED: memory headroom {headroom:.1f}x < 10x",
                  file=sys.stderr)
            sys.exit(1)
        print(f"check OK: speedup {speedup:.2f}x, headroom {headroom:.0f}x")


if __name__ == "__main__":
    main()
