"""Static-analysis & sanitizer gates (BENCH_analysis.json): the linter
holds the shipped tree clean and the runtime sanitizer is invisible.

The repro.analysis claims this benchmark records and gates:

  * **lint_clean**: ``repro.analysis.lint_paths(["src"])`` reports ZERO
    errors — the tree satisfies its own trace-safety/numerics invariants
    (the CI ``lint`` job enforces the same through the real CLI);
  * **sanitizer_overhead**: with the sanitizer ENABLED (NaN guards,
    domain checks, retrace budget armed), the ``BatchedProblem.
    score_batch`` hot loop costs within 5% of the disabled default;
  * **numerics**: enabling the sanitizer changes nothing — bitwise-
    identical (P, D) score grids, identical argmin, equal dispatch
    counts (checks only READ values the computation already produced);
  * **detection**: the guards actually fire — NaN candidates, mis-shaped
    batches, out-of-domain dq, and a blown retrace budget each raise a
    typed ``AnalysisError`` carrying the offending rule/bucket.

Usage:
  python -m benchmarks.bench_analysis            # full loop sizes
  python -m benchmarks.bench_analysis --smoke    # small sizes (CI)
  python -m benchmarks.bench_analysis --check    # exit 1 on a failed gate
"""

import argparse
import gc
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.analysis import AnalysisError, lint_paths, sanitize
from repro.core import ExplicitFleet, PlacementProblem, linear_graph
from repro.obs import bench as obench
from repro.search import BatchedProblem

OUT_PATH = Path("BENCH_analysis.json")
SRC_DIR = Path(__file__).resolve().parent.parent / "src"

MAX_ENABLED_OVERHEAD = 0.05

FULL = dict(v=64, p=256, loop_reps=40, samples=11)
SMOKE = dict(v=32, p=256, loop_reps=30, samples=11)


def _dense_problem(rng, v: int) -> PlacementProblem:
    com = rng.uniform(0.1, 3.0, (v, v))
    com = (com + com.T) / 2.0
    np.fill_diagonal(com, 0.0)
    g = linear_graph([float(s) for s in rng.uniform(0.5, 1.5, 8)])
    return PlacementProblem(g, ExplicitFleet(com_cost=com), beta=1.0)


def _inputs(cfg):
    rng = np.random.default_rng(0)
    prob = _dense_problem(rng, cfg["v"])
    xs = rng.dirichlet(np.ones(cfg["v"]), size=(cfg["p"], 8))
    dqs = np.linspace(0.0, 0.8, 5)
    return prob, xs, dqs


# -- gate 1: the shipped tree lints clean -------------------------------------

def _lint_row(cfg) -> dict:
    report = lint_paths([SRC_DIR])
    c = report["counts"]
    return dict(name="lint_clean", files_checked=report["files_checked"],
                errors=c["error"], warnings=c["warning"],
                suppressed=c["suppressed"],
                ok=bool(c["error"] == 0 and report["files_checked"] > 0))


# -- gate 2: sanitizer-enabled overhead on the score_batch hot loop -----------

def _overhead_row(cfg) -> dict:
    """Attributed within-run overhead: every sanitizer code path the
    enabled hot loop executes (``check_dq``, the output NaN guard) is
    wrapped with an accumulating timer, and the gate is

        t_sanitizer / (t_loop_enabled - t_sanitizer) < 5%.

    Both numerator and denominator come from the SAME run, so the
    estimate is immune to the multi-second clock/contention drift that
    swamps A/B block medians on sub-ms calls (observed ±15% per pair on
    a ~2% true effect).  Attribution still catches structural costs, not
    just check arithmetic: a check that forces an early device sync
    blocks inside its own ``np.asarray`` and lands in the numerator.
    The un-wrapped residue (two ``state()`` reads and their branches) is
    bounded well below the timer-wrapper overhead already counted
    against the sanitizer.
    """
    prob, xs, dqs = _inputs(cfg)
    eng = BatchedProblem(prob)
    eng.score_batch(xs, dqs)  # warm (jit compile at this bucket)
    assert not sanitize.enabled()

    reps = cfg["samples"] * cfg["loop_reps"]
    acc = [0.0]
    orig_dq = sanitize.check_dq
    orig_finite = sanitize.check_finite
    orig_guard = BatchedProblem._guard_outputs

    def timed_dq(dq, **kw):
        t0 = time.perf_counter()
        orig_dq(dq, **kw)
        acc[0] += time.perf_counter() - t0

    def timed_finite(name, arr, **kw):
        # covers score_grid's output guard (sim/batched.py).  Wait for
        # device compute BEFORE the timer: both arms pay that wait (the
        # disabled arm blocks at np.concatenate instead), so only the
        # guard's marginal work — host transfer + isnan scan — is
        # sanitizer cost
        arr = jax.block_until_ready(arr)
        t0 = time.perf_counter()
        orig_finite(name, arr, **kw)
        acc[0] += time.perf_counter() - t0

    def timed_guard(self, lat, rest):
        t0 = time.perf_counter()
        orig_guard(self, lat, rest)
        acc[0] += time.perf_counter() - t0

    gc.disable()
    try:
        sanitize.check_dq = timed_dq
        sanitize.check_finite = timed_finite
        BatchedProblem._guard_outputs = timed_guard
        sanitize.enable(retrace_budget=64)
        total, _ = obench.time_once(
            lambda: [eng.score_batch(xs, dqs) for _ in range(reps)],
            block=False)
    finally:
        sanitize.check_dq = orig_dq
        sanitize.check_finite = orig_finite
        BatchedProblem._guard_outputs = orig_guard
        sanitize.disable()
        gc.enable()

    t_checks = acc[0]
    overhead = t_checks / max(total - t_checks, 1e-12)
    return dict(name="sanitizer_overhead", seconds_enabled=total,
                seconds_sanitizer=t_checks, reps=reps, overhead=overhead,
                max_overhead=MAX_ENABLED_OVERHEAD,
                ok=bool(overhead < MAX_ENABLED_OVERHEAD))


# -- gate 3: enabling the sanitizer never changes numerics --------------------

def _numerics_row(cfg) -> dict:
    prob, xs, dqs = _inputs(cfg)
    eng_off = BatchedProblem(prob)
    scores_off = eng_off.score_batch(xs, dqs)
    with sanitize.sanitized(retrace_budget=64):
        eng_on = BatchedProblem(prob)
        scores_on = eng_on.score_batch(xs, dqs)
    bitwise = bool(np.array_equal(scores_off, scores_on))
    argmin_eq = bool(np.argmin(scores_off) == np.argmin(scores_on))
    return dict(name="numerics",
                bitwise_equal_scores=bitwise,
                argmin_equal=argmin_eq,
                dispatches_disabled=eng_off.dispatches,
                dispatches_enabled=eng_on.dispatches,
                ok=bool(bitwise and argmin_eq
                        and eng_on.dispatches == eng_off.dispatches))


# -- gate 4: the guards actually fire -----------------------------------------

def _detection_row(cfg) -> dict:
    prob, xs, dqs = _inputs(cfg)

    def trips(fn, want_rule):
        try:
            fn()
        except AnalysisError as e:
            return e.rule == want_rule
        return False

    bad_nan = xs.copy()
    bad_nan[0, 0, 0] = np.nan
    with sanitize.sanitized(retrace_budget=64):
        nan_ok = trips(lambda: BatchedProblem(prob).score_batch(bad_nan, dqs),
                       "nan-guard")
        dq_ok = trips(lambda: BatchedProblem(prob).score_batch(
            xs, np.array([0.2, 1.5])), "dq-domain")
    shape_ok = trips(lambda: BatchedProblem(prob).score_batch(
        xs[:, :4, :], dqs), "score-batch-domain")  # always-on, no enable
    with sanitize.sanitized(retrace_budget=0):
        budget_ok = trips(lambda: BatchedProblem(prob).score_batch(xs, dqs),
                          "no-silent-retrace")
    return dict(name="detection", nan_detected=nan_ok,
                dq_domain_detected=dq_ok, shape_detected=shape_ok,
                retrace_budget_detected=budget_ok,
                ok=bool(nan_ok and dq_ok and shape_ok and budget_ok))


def run(smoke: bool = False) -> list[str]:
    cfg = SMOKE if smoke else FULL
    rows = [_lint_row(cfg), _overhead_row(cfg), _numerics_row(cfg),
            _detection_row(cfg)]
    report = {"smoke": smoke, "rows": rows,
              "all_ok": all(r["ok"] for r in rows)}
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    out = []
    for r in rows:
        if r["name"] == "lint_clean":
            out.append(f"analysis_lint,{r['files_checked']}files,"
                       f"errors={r['errors']},suppressed={r['suppressed']},"
                       f"ok={r['ok']}")
        elif r["name"] == "sanitizer_overhead":
            out.append(f"analysis_overhead,{r['overhead'] * 100:.2f}%,"
                       f"gate<{MAX_ENABLED_OVERHEAD * 100:.0f}%,"
                       f"ok={r['ok']}")
        elif r["name"] == "numerics":
            out.append(f"analysis_numerics,"
                       f"bitwise={r['bitwise_equal_scores']},"
                       f"dispatches={r['dispatches_enabled']}=="
                       f"{r['dispatches_disabled']},ok={r['ok']}")
        else:
            out.append(f"analysis_detection,nan={r['nan_detected']},"
                       f"dq={r['dq_domain_detected']},"
                       f"shape={r['shape_detected']},"
                       f"budget={r['retrace_budget_detected']},ok={r['ok']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small loop sizes (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every gate holds: src/ lints "
                         "clean, sanitizer-enabled overhead <5%, "
                         "bitwise-identical numerics, all guards fire")
    ns = ap.parse_args()
    for line in run(smoke=ns.smoke):
        print(line)
    if ns.check:
        report = json.loads(OUT_PATH.read_text())
        if not report["all_ok"]:
            bad = [r["name"] for r in report["rows"] if not r["ok"]]
            print(f"FAILED gates: {bad}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
