"""Massive-parallelism scaling of the cost model itself: evaluation
latency vs (operators × devices), explicit vs region-structured fleets —
the paper's fleet sizes (10⁵ devices) must be scorable interactively for
any optimizer to work at that scale."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (RegionFleet, ExplicitFleet, latency, make_latency_fn,
                        random_dag, random_placement)
from repro.obs import bench as obench


def _time(f, n=5):
    """Mean microseconds per warm call (shared harness: repro.obs.bench;
    results are host floats, so no device block)."""
    return obench.measure(f, n=n, block=False).mean_s * 1e6


def _time_once(f):
    """One cold call in microseconds (compile cost included by design)."""
    return obench.time_once(f, block=False)[0] * 1e6


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    for n_ops, n_dev in [(10, 256), (20, 4096), (50, 65536)]:
        g = random_dag(n_ops, 0.3, rng)
        n_regions = max(n_dev // 256, 1)
        region = np.repeat(np.arange(n_regions), n_dev // n_regions)
        inter = rng.uniform(0.5, 2.0, (n_regions, n_regions))
        inter = (inter + inter.T) / 2
        fleet = RegionFleet(region=region, inter=inter)
        x = random_placement(n_ops, np.ones((n_ops, n_dev), bool), rng,
                             sparsity=0.9)
        us_np = (_time_once(lambda: latency(g, fleet, x)) if n_dev > 10000
                 else _time(lambda: latency(g, fleet, x)))
        # per-size compile is the quantity under measurement here
        lat_fn = jax.jit(make_latency_fn(g, fleet))  # repro: ignore[no-silent-retrace]
        xj = jnp.asarray(x)
        us_jax = _time(lambda: float(lat_fn(xj)))
        # batched candidate scoring (what the optimizers lean on)
        batched = jax.jit(jax.vmap(make_latency_fn(g, fleet)))  # repro: ignore[no-silent-retrace]
        xs = jnp.asarray(np.stack([x] * 32))
        us_batch = _time(lambda: np.asarray(batched(xs)).sum()) / 32
        rows.append(
            f"costmodel_scaling_ops{n_ops}_dev{n_dev},{us_np:.1f},"
            f"jax_us={us_jax:.1f};batched_per_candidate_us={us_batch:.1f}")
    return rows
