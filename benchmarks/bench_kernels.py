"""Kernel benches: edge-latency backend races + interpret-mode micro rows.

The edge-latency section races the three dispatch routes per shape —
jitted XLA einsum, the V-blocked Pallas kernel at the fixed default
``(block_edges=128, block_v=512)``, and the same kernel at the
autotuner's pick — dense at V ∈ {256, 1024, 4096} and structured at
V = 131 072 (smoke: {256, 1024} / 16 384), recording parity against the
XLA route and per-region recompile counts (``repro.obs.bench`` wraps each
timed region in a CompileSnapshot).

The gated claims (BENCH_kernels.json, ``--check``):

  * the autotuned config is no worse than the fixed default in every race
    (≥0.9× within CI timer tolerance);
  * every WARM timed region recompiles exactly zero times — the decision
    table plus module-level jitted wrappers with static block args mean a
    stable shape never rebuilds its executable;
  * both Pallas routes match the XLA einsum to ≤1e-4 relative.

On this CPU-only container the Pallas routes run in interpret mode, where
per-grid-step Python overhead dominates — exactly the regime the autotune
model's cpu step-overhead term prices, so the tuned config (fewer, larger
tiles) must win or tie.  Compiled-mode absolute numbers are out of scope
here; the roofline analysis covers that story.

Usage:
  python -m benchmarks.bench_kernels            # full sweep
  python -m benchmarks.bench_kernels --smoke    # small V (CI)
  python -m benchmarks.bench_kernels --check    # exit 1 on gate failure
"""

import argparse
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ops, ref
from repro.kernels.dispatch import backend_name, resolve_flags
from repro.kernels.edge_latency import (edge_latency_pallas,
                                        edge_latency_structured_pallas)
from repro.obs import bench as obench

OUT_PATH = Path("BENCH_kernels.json")

# dense races: B placement rows × E edges against one shared (V, V) com
DENSE_FULL_V = (256, 1024, 4096)
DENSE_SMOKE_V = (256, 1024)
DENSE_B, DENSE_E = 4, 24
# structured races: R-region factorization at fleet sizes where a (V, V)
# com no longer exists
STRUCT_FULL_V = (131072,)
STRUCT_SMOKE_V = (16384,)
STRUCT_B, STRUCT_E, STRUCT_R = 2, 12, 8

FIXED = autotune.KernelConfig(block_edges=128, block_v=512)
N_REPS = 5
# the gate catches real regressions (a mis-ranked config costs whole grid
# steps, 2x+), not CI timer noise — the small-V races are genuine ties
# whose median ratio wanders ±10% on a loaded CPU runner
SPEEDUP_TOL = 0.85
PARITY_TOL = 1e-4


def _time(f):
    return obench.measure(f, n=N_REPS)


def _rel_err(got, want) -> float:
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    return float(np.abs(got - want).max() / max(np.abs(want).max(), 1e-12))


def _race_entry(kind, V, E, B, R, xla_t, fixed_t, tuned_t, tuned_cfg,
                parity_fixed, parity_tuned):
    return {
        "kind": kind, "V": V, "E": E, "B": B, "R": R,
        "xla": xla_t.row(), "pallas_fixed": fixed_t.row(),
        "pallas_tuned": tuned_t.row(),
        "fixed_config": {"block_edges": FIXED.block_edges,
                         "block_v": FIXED.block_v},
        "tuned_config": {"block_edges": tuned_cfg.block_edges,
                         "block_v": tuned_cfg.block_v},
        "tuned_vs_fixed_speedup": fixed_t.seconds / tuned_t.seconds,
        "parity_fixed_vs_xla": parity_fixed,
        "parity_tuned_vs_xla": parity_tuned,
    }


def _dense_races(rng, sweep, interpret: bool, backend: str):
    races, rows = [], []
    xla = jax.jit(lambda xi, xj, com: jnp.max(
        xi * jnp.einsum("buv,bev->beu", com, xj), axis=-1))
    for V in sweep:
        xi = jnp.asarray(rng.standard_normal((DENSE_B, DENSE_E, V)),
                         jnp.float32)
        xj = jnp.asarray(rng.standard_normal((DENSE_B, DENSE_E, V)),
                         jnp.float32)
        com = jnp.asarray(rng.standard_normal((1, V, V)), jnp.float32)
        tuned = autotune.get_config("dense", DENSE_B, DENSE_E, V,
                                    com_batch=1, backend=backend)
        xla_t = _time(lambda: xla(xi, xj, com))
        fixed_t = _time(lambda: edge_latency_pallas(
            xi, xj, com, block_edges=FIXED.block_edges,
            block_v=FIXED.block_v, interpret=interpret))
        tuned_t = _time(lambda: edge_latency_pallas(
            xi, xj, com, block_edges=tuned.block_edges,
            block_v=tuned.block_v, interpret=interpret))
        races.append(_race_entry(
            "dense", V, DENSE_E, DENSE_B, None, xla_t, fixed_t, tuned_t,
            tuned, _rel_err(fixed_t.result, xla_t.result),
            _rel_err(tuned_t.result, xla_t.result)))
        rows.append(f"edge_latency_dense_V{V},{tuned_t.seconds * 1e6:.0f},"
                    f"tuned_be{tuned.block_edges}_bv{tuned.block_v};"
                    f"vs_fixed={races[-1]['tuned_vs_fixed_speedup']:.2f}x;"
                    f"vs_xla={xla_t.seconds / tuned_t.seconds:.2f}x")
    return races, rows


def _structured_races(rng, sweep, interpret: bool, backend: str):
    races, rows = [], []
    xla = jax.jit(lambda xi, xj, mass, a, corr: jnp.max(
        xi * (jnp.einsum("ber,bru->beu", mass, a) + corr * xj), axis=-1))
    for V in sweep:
        xi = jnp.asarray(rng.standard_normal((STRUCT_B, STRUCT_E, V)),
                         jnp.float32)
        xj = jnp.asarray(rng.standard_normal((STRUCT_B, STRUCT_E, V)),
                         jnp.float32)
        mass = jnp.asarray(rng.standard_normal((STRUCT_B, STRUCT_E,
                                                STRUCT_R)), jnp.float32)
        a = jnp.asarray(rng.standard_normal((1, STRUCT_R, V)), jnp.float32)
        corr = jnp.asarray(rng.standard_normal((1, 1, V)), jnp.float32)
        tuned = autotune.get_config("structured", STRUCT_B, STRUCT_E, V,
                                    STRUCT_R, com_batch=1, backend=backend)
        xla_t = _time(lambda: xla(xi, xj, mass, a, corr))
        fixed_t = _time(lambda: edge_latency_structured_pallas(
            xi, xj, mass, a, corr, block_edges=FIXED.block_edges,
            block_v=FIXED.block_v, interpret=interpret))
        tuned_t = _time(lambda: edge_latency_structured_pallas(
            xi, xj, mass, a, corr, block_edges=tuned.block_edges,
            block_v=tuned.block_v, interpret=interpret))
        races.append(_race_entry(
            "structured", V, STRUCT_E, STRUCT_B, STRUCT_R, xla_t, fixed_t,
            tuned_t, tuned, _rel_err(fixed_t.result, xla_t.result),
            _rel_err(tuned_t.result, xla_t.result)))
        rows.append(
            f"edge_latency_structured_V{V},{tuned_t.seconds * 1e6:.0f},"
            f"tuned_be{tuned.block_edges}_bv{tuned.block_v};"
            f"vs_fixed={races[-1]['tuned_vs_fixed_speedup']:.2f}x;"
            f"vs_xla={xla_t.seconds / tuned_t.seconds:.2f}x")
    return races, rows


def _micro_rows() -> list[str]:
    """Interpret-mode correctness deltas + XLA-reference timings for the
    non-edge kernels (flash attention, SSD scan, rmsnorm)."""
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, D = 2, 256, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ref_fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, True))
    us = obench.measure(lambda: ref_fn(q, k, v), n=N_REPS).mean_s * 1e6
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    err = float(jnp.abs(out - ref.flash_attention_ref(q, k, v, True)).max())
    rows.append(f"kernel_flash_attention,{us:.0f},"
                f"interpret_vs_oracle_maxerr={err:.2e};shape={B}x{S}x{H}x{D}")

    b, L, Hs, P, N = 2, 128, 8, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(ks[0], (b, L, Hs, P))
    Bm = jax.random.normal(ks[1], (b, L, N)) * 0.5
    Cm = jax.random.normal(ks[2], (b, L, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, L, Hs))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[4], (Hs,)) * 0.3)
    Dm = jax.random.normal(ks[5], (Hs,))
    ref_fn = jax.jit(lambda *a: ref.ssd_ref(*a)[0])
    us = obench.measure(lambda: ref_fn(x, Bm, Cm, dt, A, Dm),
                        n=N_REPS).mean_s * 1e6
    y = ops.ssd_scan(x, Bm, Cm, dt, A, Dm, chunk=32, interpret=True)
    err = float(jnp.abs(y - ref.ssd_ref(x, Bm, Cm, dt, A, Dm)[0]).max())
    rows.append(f"kernel_ssd_scan,{us:.0f},"
                f"interpret_vs_oracle_maxerr={err:.2e};shape={b}x{L}x{Hs}x{P}")

    xw = jax.random.normal(jax.random.PRNGKey(2), (1024, 512))
    w = jax.random.normal(jax.random.PRNGKey(3), (512,))
    ref_fn = jax.jit(lambda x, w: ref.rmsnorm_ref(x, w))
    us = obench.measure(lambda: ref_fn(xw, w), n=N_REPS).mean_s * 1e6
    err = float(jnp.abs(ops.rmsnorm(xw, w, interpret=True)
                        - ref.rmsnorm_ref(xw, w)).max())
    rows.append(f"kernel_rmsnorm,{us:.0f},interpret_vs_oracle_maxerr={err:.2e}")
    return rows


def run(smoke: bool = False) -> list[str]:
    rng = np.random.default_rng(0)
    backend = backend_name()
    _, interpret = resolve_flags(use_pallas=True)
    dense_sweep = DENSE_SMOKE_V if smoke else DENSE_FULL_V
    struct_sweep = STRUCT_SMOKE_V if smoke else STRUCT_FULL_V
    autotune.clear_table()  # race against THIS run's decisions, not a
    #                         table warmed by an earlier import
    d_races, d_rows = _dense_races(rng, dense_sweep, interpret, backend)
    s_races, s_rows = _structured_races(rng, struct_sweep, interpret,
                                        backend)
    report = {
        "smoke": smoke,
        "backend": backend,
        "interpret": interpret,
        "races": d_races + s_races,
        "autotune_table": autotune.table_rows(),
    }
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return d_rows + s_rows + _micro_rows()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small V sweep for CI")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless tuned ≥ fixed, zero warm "
                         "recompiles, and Pallas ≡ XLA parity")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
    if args.check:
        report = json.loads(OUT_PATH.read_text())
        failed = False
        for race in report["races"]:
            tag = f"{race['kind']} V={race['V']}"
            if race["tuned_vs_fixed_speedup"] < SPEEDUP_TOL:
                print(f"CHECK FAILED: {tag}: autotuned config slower than "
                      f"fixed default "
                      f"({race['tuned_vs_fixed_speedup']:.2f}x "
                      f"< {SPEEDUP_TOL}x)", file=sys.stderr)
                failed = True
            for route in ("xla", "pallas_fixed", "pallas_tuned"):
                n = race[route]["n_recompiles"]
                if n != 0:
                    print(f"CHECK FAILED: {tag}: {route} recompiled {n}x "
                          f"in the warm timed region", file=sys.stderr)
                    failed = True
            for parity in ("parity_fixed_vs_xla", "parity_tuned_vs_xla"):
                if race[parity] > PARITY_TOL:
                    print(f"CHECK FAILED: {tag}: {parity} "
                          f"{race[parity]:.2e} > {PARITY_TOL}",
                          file=sys.stderr)
                    failed = True
        if failed:
            sys.exit(1)
        worst = min(r["tuned_vs_fixed_speedup"] for r in report["races"])
        print(f"check OK: {len(report['races'])} races, tuned ≥ "
              f"{worst:.2f}x fixed, zero warm recompiles")


if __name__ == "__main__":
    main()
