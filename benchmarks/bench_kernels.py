"""Kernel micro-bench: interpret-mode correctness deltas + XLA-reference
timings on CPU (real TPU timings are out of scope in this container — the
roofline analysis covers the performance story)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.obs import bench as obench


def _time(f, n=3):
    """Mean microseconds per call (shared harness: repro.obs.bench)."""
    return obench.measure(f, n=n).mean_s * 1e6


def run() -> list[str]:
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, D = 2, 256, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ref_fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, True))
    us = _time(lambda: ref_fn(q, k, v))
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    err = float(jnp.abs(out - ref.flash_attention_ref(q, k, v, True)).max())
    rows.append(f"kernel_flash_attention,{us:.0f},"
                f"interpret_vs_oracle_maxerr={err:.2e};shape={B}x{S}x{H}x{D}")

    b, L, Hs, P, N = 2, 128, 8, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(ks[0], (b, L, Hs, P))
    Bm = jax.random.normal(ks[1], (b, L, N)) * 0.5
    Cm = jax.random.normal(ks[2], (b, L, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, L, Hs))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[4], (Hs,)) * 0.3)
    Dm = jax.random.normal(ks[5], (Hs,))
    ref_fn = jax.jit(lambda *a: ref.ssd_ref(*a)[0])
    us = _time(lambda: ref_fn(x, Bm, Cm, dt, A, Dm))
    y = ops.ssd_scan(x, Bm, Cm, dt, A, Dm, chunk=32, interpret=True)
    err = float(jnp.abs(y - ref.ssd_ref(x, Bm, Cm, dt, A, Dm)[0]).max())
    rows.append(f"kernel_ssd_scan,{us:.0f},"
                f"interpret_vs_oracle_maxerr={err:.2e};shape={b}x{L}x{Hs}x{P}")

    xw = jax.random.normal(jax.random.PRNGKey(2), (1024, 512))
    w = jax.random.normal(jax.random.PRNGKey(3), (512,))
    ref_fn = jax.jit(lambda x, w: ref.rmsnorm_ref(x, w))
    us = _time(lambda: ref_fn(xw, w))
    err = float(jnp.abs(ops.rmsnorm(xw, w, interpret=True)
                        - ref.rmsnorm_ref(xw, w)).max())
    rows.append(f"kernel_rmsnorm,{us:.0f},interpret_vs_oracle_maxerr={err:.2e}")
    return rows
