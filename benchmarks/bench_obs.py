"""Telemetry-layer gates (BENCH_obs.json): the observability subsystem must
be free when off and invisible when on.

The repro.obs claims this benchmark records and gates:

  * **disabled overhead**: with the default (disabled) registry, the
    instrumented ``BatchedProblem.score_batch`` hot loop — the bench_search
    inner loop — costs within 5% of a control where every ``obs`` call site
    is stubbed out entirely (the guard is ONE attribute read per dispatch);
  * **numerics invariance**: enabling telemetry changes nothing the science
    depends on — a fixed-seed search returns a BITWISE-identical argmin,
    equal objective, and the exact same dispatch count (instrumentation
    only reads already-computed values: no rng draws, no extra dispatches);
  * **trace validity**: a telemetry-enabled closed-loop adaptive run
    exports a Chrome-trace/Perfetto JSONL (``BENCH_obs.trace.jsonl``, the
    CI artifact) that passes the schema check ``repro.obs.load_trace``
    enforces — spans from sim/search/adapt/streaming plus drift/regret
    counter timelines;
  * **perf bridge**: ``repro.obs.perfbridge.hlo_record`` on the dense
    score-grid dispatch yields finite ``hlo_flops`` / ``roofline_fraction``
    / ``n_recompiles`` — the fields BENCH_search.json rows now carry.

Usage:
  python -m benchmarks.bench_obs            # full loop sizes
  python -m benchmarks.bench_obs --smoke    # small sizes (CI)
  python -m benchmarks.bench_obs --check    # exit 1 on a failed gate
"""

import argparse
import gc
import json
import statistics
import sys
from pathlib import Path

import numpy as np

from repro import obs
from repro.core import ExplicitFleet, PlacementProblem, linear_graph
from repro.obs import bench as obench
from repro.obs import perfbridge
from repro.obs.spans import _fresh_trace
from repro.search import BatchedProblem, random_search

OUT_PATH = Path("BENCH_obs.json")
TRACE_PATH = Path("BENCH_obs.trace.jsonl")

MAX_DISABLED_OVERHEAD = 0.05

FULL = dict(v=64, p=256, loop_reps=40, samples=11)
SMOKE = dict(v=24, p=128, loop_reps=30, samples=11)


def _dense_problem(rng, v: int) -> PlacementProblem:
    com = rng.uniform(0.1, 3.0, (v, v))
    com = (com + com.T) / 2.0
    np.fill_diagonal(com, 0.0)
    g = linear_graph([float(s) for s in rng.uniform(0.5, 1.5, 8)])
    return PlacementProblem(g, ExplicitFleet(com_cost=com), beta=1.0)


# -- gate 1: disabled-registry overhead on the score_batch hot loop -----------

class _StubObs:
    """A zero-instrumentation control: what the call sites would cost if
    the telemetry layer did not exist at all."""

    class _NullSpan:
        __slots__ = ()

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            return False

        def sync(self, value):
            return value

    class _Registry:
        __slots__ = ()
        enabled = False

    _span = _NullSpan()
    _registry = _Registry()

    @classmethod
    def span(cls, name, **args):
        return cls._span

    @classmethod
    def registry(cls):
        return cls._registry

    @staticmethod
    def counter_sample(name, value, **more):
        return None


def _hot_loop_once(eng, xs, dqs, reps: int) -> float:
    """Wall time of `reps` warm score_batch calls (one sample)."""
    t, _ = obench.time_once(
        lambda: [eng.score_batch(xs, dqs) for _ in range(reps)],
        block=False)
    return t


def _overhead_row(cfg) -> dict:
    import repro.search.engine as engine_mod
    import repro.sim.batched as batched_mod

    rng = np.random.default_rng(0)
    prob = _dense_problem(rng, cfg["v"])
    xs = rng.dirichlet(np.ones(cfg["v"]), size=(cfg["p"], 8))
    dqs = np.linspace(0.0, 0.8, 5)

    eng = BatchedProblem(prob)
    eng.score_batch(xs, dqs)  # warm (jit compile at this bucket)
    assert not obs.enabled()

    # INTERLEAVED A/B samples: back-to-back measurement of the two variants
    # is order-biased (frequency scaling, cache warmup) by far more than
    # the effect under test — alternate them and compare medians
    saved = (engine_mod.obs, batched_mod.obs)
    disabled_ts, stub_ts = [], []
    gc.disable()  # a GC pause inside one 10ms sample dwarfs the effect
    try:
        for _ in range(cfg["samples"]):
            engine_mod.obs, batched_mod.obs = saved
            disabled_ts.append(_hot_loop_once(eng, xs, dqs,
                                              cfg["loop_reps"]))
            # the control: same loop with every obs call site stubbed out
            engine_mod.obs = batched_mod.obs = _StubObs
            stub_ts.append(_hot_loop_once(eng, xs, dqs, cfg["loop_reps"]))
    finally:
        gc.enable()
        engine_mod.obs, batched_mod.obs = saved

    disabled_s = statistics.median(disabled_ts)
    stub_s = statistics.median(stub_ts)
    # per-pair ratios: adjacent samples share thermal/frequency state, so
    # their ratio cancels the drift that medians-of-absolutes keep
    overhead = statistics.median(
        d / max(s, 1e-12) for d, s in zip(disabled_ts, stub_ts)) - 1.0
    return dict(name="disabled_overhead", seconds_disabled=disabled_s,
                seconds_stubbed=stub_s, overhead=overhead,
                max_overhead=MAX_DISABLED_OVERHEAD,
                ok=bool(overhead < MAX_DISABLED_OVERHEAD))


# -- gate 2: enabling telemetry never changes numerics ------------------------

def _solve(cfg):
    prob = _dense_problem(np.random.default_rng(1), cfg["v"])
    eng = BatchedProblem(prob)
    res = random_search(prob, np.random.default_rng(7),
                        n_candidates=cfg["p"], engine=eng)
    return res, eng.dispatches, eng.evals


def _numerics_row(cfg) -> dict:
    res_off, disp_off, evals_off = _solve(cfg)
    saved = obs.registry()
    obs.set_registry(obs.MetricsRegistry(enabled=False))
    try:
        with _fresh_trace():
            obs.enable()
            res_on, disp_on, evals_on = _solve(cfg)
            n_events = len(obs.trace_events())
            n_metrics = len(obs.registry().snapshot())
    finally:
        obs.disable()
        obs.set_registry(saved)
    bitwise = bool(np.array_equal(res_on.x, res_off.x)
                   and res_on.F == res_off.F
                   and res_on.dq_fraction == res_off.dq_fraction)
    return dict(name="numerics_invariance",
                dispatches_disabled=disp_off, dispatches_enabled=disp_on,
                evals_disabled=evals_off, evals_enabled=evals_on,
                bitwise_equal_argmin=bitwise,
                trace_events_recorded=n_events,
                metrics_recorded=n_metrics,
                ok=bool(bitwise and disp_on == disp_off
                        and evals_on == evals_off and n_events > 0))


# -- gate 3: a telemetry-enabled adaptive run exports a valid trace -----------

def _trace_row(cfg) -> dict:
    from repro.adapt.controller import AdaptiveConfig, run_adaptive
    from repro.sim.scenarios import ScenarioConfig, random_trace
    from repro.streaming.engine import StreamingEngine
    from repro.streaming.operators import (StreamGraph, filter_op, map_op,
                                           source)

    rng = np.random.default_rng(2)
    sg = StreamGraph(
        [source(),
         map_op("normalize", lambda r: (r - r.mean()) / (r.std() + 1e-9)),
         filter_op("threshold", lambda r: r[:, 0] > -0.5, selectivity=0.7)],
        [(0, 1), (1, 2)])
    n_ops = sg.meta.n_ops
    fleet = ExplicitFleet(com_cost=rng.uniform(1, 5, (4, 4))
                          * (1 - np.eye(4)), speed=np.ones(4))
    eng = StreamingEngine(sg, fleet, np.full((n_ops, 4), 0.25),
                          observed="work")
    scen = ScenarioConfig(trace_len=16, base_rate=48.0, degrade_prob=0.2,
                          selectivity_drift_std=0.15)
    trace = random_trace(rng, 4, scen, n_ops=n_ops)

    saved = obs.registry()
    obs.set_registry(obs.MetricsRegistry(enabled=False))
    try:
        with _fresh_trace():
            obs.enable()
            run_adaptive(eng, trace, np.random.default_rng(3),
                         AdaptiveConfig(window=3, cooldown=2))
            n_written = obs.export_trace(TRACE_PATH)
    finally:
        obs.disable()
        obs.set_registry(saved)
    events = obs.load_trace(TRACE_PATH)  # raises on schema violation
    names = {e["name"] for e in events}
    # the cross-subsystem claim: one run shows up in ALL the layers
    expected = {"engine.run_batch", "engine.true_latency", "adapt.F"}
    return dict(name="perfetto_trace", path=str(TRACE_PATH),
                n_events=n_written,
                span_names=sorted(names),
                ok=bool(n_written > 0 and len(events) == n_written
                        and expected <= names))


# -- gate 4: the perf bridge yields the BENCH_search HLO fields ---------------

def _hlo_row(cfg) -> dict:
    from repro.core.placement import uniform_placement
    from repro.sim.batched import pack_placements

    prob = _dense_problem(np.random.default_rng(4), cfg["v"])
    eng = BatchedProblem(prob)
    avail = prob.availability()
    xs = [uniform_placement(avail.shape[0], avail)] * cfg["p"]
    placements = pack_placements(xs)
    f = lambda: eng._ev._jit_grid(placements, eng._pack, 0.0, 0.0)
    t = obench.measure(f, n=3)
    rec = perfbridge.hlo_record(eng._ev._jit_grid,
                                args=(placements, eng._pack, 0.0, 0.0),
                                measured_s=t.seconds)
    fields = ("hlo_flops", "roofline_fraction", "n_recompiles")
    finite = all(rec.get(k) is not None and np.isfinite(rec[k])
                 for k in ("hlo_flops", "roofline_fraction"))
    return dict(name="hlo_bridge", measured_s=t.seconds,
                hlo_flops=rec["hlo_flops"], hlo_bytes=rec["hlo_bytes"],
                roofline_fraction=rec["roofline_fraction"],
                n_recompiles=t.n_recompiles,
                ok=bool(finite and rec["hlo_flops"] > 0
                        and all(k in rec or k == "n_recompiles"
                                for k in fields)))


def run(smoke: bool = False) -> list[str]:
    cfg = SMOKE if smoke else FULL
    rows = [_overhead_row(cfg), _numerics_row(cfg), _trace_row(cfg),
            _hlo_row(cfg)]
    report = {"smoke": smoke, "rows": rows,
              "all_ok": all(r["ok"] for r in rows)}
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    out = []
    for r in rows:
        if r["name"] == "disabled_overhead":
            out.append(f"obs_disabled_overhead,{r['overhead'] * 100:.2f}%,"
                       f"gate<{MAX_DISABLED_OVERHEAD * 100:.0f}%,"
                       f"ok={r['ok']}")
        elif r["name"] == "numerics_invariance":
            out.append(f"obs_numerics,bitwise={r['bitwise_equal_argmin']},"
                       f"dispatches={r['dispatches_enabled']}=="
                       f"{r['dispatches_disabled']},ok={r['ok']}")
        elif r["name"] == "perfetto_trace":
            out.append(f"obs_trace,{r['n_events']}events,"
                       f"{TRACE_PATH},ok={r['ok']}")
        else:
            out.append(f"obs_hlo_bridge,flops={r['hlo_flops']:.3g},"
                       f"roofline_fraction={r['roofline_fraction']:.3g},"
                       f"recompiles={r['n_recompiles']},ok={r['ok']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small loop sizes (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every telemetry gate holds: "
                         "disabled overhead <5%, bitwise-identical "
                         "numerics when enabled, schema-valid Perfetto "
                         "export, finite HLO bridge fields")
    ns = ap.parse_args()
    for line in run(smoke=ns.smoke):
        print(line)
    if ns.check:
        report = json.loads(OUT_PATH.read_text())
        if not report["all_ok"]:
            bad = [r["name"] for r in report["rows"] if not r["ok"]]
            print(f"FAILED gates: {bad}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
