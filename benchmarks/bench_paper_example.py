"""Paper §3 worked example (Tables 3–4): correctness + evaluation speed."""

import numpy as np

from repro.core import ExplicitFleet, latency, linear_graph, objective_F
from repro.obs import bench as obench

COM = np.array([[0.0, 1.5, 2.0], [1.5, 0.0, 1.0], [2.0, 1.0, 0.0]])
X0 = np.array([[0.8, 0.2, 0.0], [0.7, 0.0, 0.3], [0.3, 0.4, 0.3]])
X1 = np.array([[0.8, 0.2, 0.0], [0.7, 0.0, 0.3], [0.0, 0.4, 0.6]])


def run() -> list[str]:
    g = linear_graph([1.0, 1.5, 1.0])
    fleet = ExplicitFleet(com_cost=COM)
    lat0 = latency(g, fleet, X0)
    lat1 = latency(g, fleet, X1)
    assert abs(lat0 - 1.74) < 1e-12 and abs(lat1 - 2.37) < 1e-12
    vals = {
        "latency_paper_plan": lat0,
        "latency_modified_plan": lat1,
        "F_beta1": (objective_F(lat0, 0.5, 1.0), objective_F(lat1, 1.0, 1.0)),
        "F_beta2": (objective_F(lat0, 0.5, 2.0), objective_F(lat1, 1.0, 2.0)),
    }
    n = 2000
    t = obench.measure(lambda: latency(g, fleet, X0), n=n, warmup=1,
                       block=False)
    us = t.mean_s * 1e6
    rows = [f"paper_example_eval,{us:.2f},latency0={lat0:.4f};latency1={lat1:.4f}"]
    rows.append(
        "paper_example_F,%0.2f,F(b1)=%.4f/%.4f;F(b2)=%.4f/%.4f" % (
            us, vals["F_beta1"][0], vals["F_beta1"][1],
            vals["F_beta2"][0], vals["F_beta2"][1]))
    return rows
