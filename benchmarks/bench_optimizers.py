"""Placement-optimizer comparison (paper §2 tractability: the problems are
NP-hard, so the deliverable is heuristic quality-vs-time) on a geo fleet."""

import numpy as np

from repro.core import (CostConfig, DQCoupling, ExplicitFleet,
                        PlacementProblem, greedy_transfer, projected_gradient,
                        random_dag, random_search, simulated_annealing,
                        uniform_placement)
from repro.obs import bench as obench


def _instance(seed=0, n_ops=8, n_dev=8, n_regions=3):
    rng = np.random.default_rng(seed)
    g = random_dag(n_ops, 0.4, rng)
    region = rng.integers(0, n_regions, n_dev)
    base = rng.uniform(1.0, 3.0, (n_regions, n_regions))
    base = (base + base.T) / 2
    com = base[np.ix_(region, region)] + rng.uniform(0, 0.1, (n_dev, n_dev))
    com = (com + com.T) / 2
    np.fill_diagonal(com, 0.0)
    fleet = ExplicitFleet(com_cost=com)
    dq = DQCoupling(cap0=np.full(n_dev, 1.6 * n_ops / n_dev),
                    load=np.full(n_dev, 0.1))
    return PlacementProblem(g, fleet, CostConfig(alpha=0.005), beta=1.0,
                            dq=dq)


def run() -> list[str]:
    prob = _instance()
    rng = np.random.default_rng(1)
    uni_F = prob.score(uniform_placement(prob.graph.n_ops,
                                         prob.availability()), 0.0)
    rows = [f"optimizer_uniform_baseline,0.0,F={uni_F:.4f}"]
    for name, fn in [
        ("greedy", lambda: greedy_transfer(prob)),
        ("simulated_annealing", lambda: simulated_annealing(prob, rng,
                                                            steps=3000)),
        ("projected_gradient", lambda: projected_gradient(prob, steps=200)),
        ("random_search", lambda: random_search(prob, rng,
                                                n_candidates=1024)),
    ]:
        seconds, res = obench.time_once(fn, block=False)
        dt = seconds * 1e6
        rows.append(
            f"optimizer_{name},{dt:.0f},F={res.F:.4f};dq={res.dq_fraction:.2f};"
            f"improvement_vs_uniform={(uni_F - res.F) / uni_F:.1%};"
            f"evals={res.evals};dispatches={res.dispatches}")
    return rows
