"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON artifacts.

  PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun]
prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""

import argparse
import json
from pathlib import Path


def load(dir_path, mesh=None, variant="baseline"):
    recs = []
    for p in sorted(Path(dir_path).glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("skipped"):
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        if variant and r.get("variant") != variant:
            continue
        recs.append(r)
    return recs


def dryrun_table(recs) -> str:
    out = ["| cell | mesh | chips | compile s | peak GB/chip | fits 16GB | "
           "HLO GFLOP/chip | wire GB/chip | collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        m = r["memory"]
        c = r["collectives"]
        counts = "+".join(f"{k.split('-')[-1]}:{v}"
                          for k, v in sorted(c["counts"].items()))
        out.append(
            f"| {r['arch']}/{r['shape']} | {r['mesh']} | {r['chips']} "
            f"| {r.get('compile_s', 0):.0f} "
            f"| {m['peak_bytes'] / 1e9:.2f} "
            f"| {'Y' if m['peak_bytes'] < 16 * 2**30 else 'N'} "
            f"| {r['hlo_flops_per_device'] / 1e9:,.0f} "
            f"| {c['total_wire_bytes'] / 1e9:.1f} | {counts} |")
    return "\n".join(out)


def roofline_table(recs) -> str:
    out = ["| cell | compute s | memory s (xla / tpu-adj) | collective s "
           "(xla / tpu-adj) | dominant | MODEL/HLO flops | mfu bound "
           "(tpu-adj) |",
           "|---|---|---|---|---|---|---|"]
    for r in recs:
        rf = r["roofline"]
        ka = r.get("kernel_adjusted", {})
        mfu = r.get("mfu_bound_tpu_adjusted", rf.get("mfu_bound", 0))
        out.append(
            f"| {r['arch']}/{r['shape']} | {rf['compute_s']:.4f} "
            f"| {rf['memory_s']:.3f} / {ka.get('memory_s', rf['memory_s']):.3f} "
            f"| {rf['collective_s']:.3f} / "
            f"{ka.get('collective_s', rf['collective_s']):.3f} "
            f"| {rf['dominant']} | {rf['useful_fraction']:.3f} "
            f"| {mfu:.4f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    single = load(args.dir, mesh="single", variant=args.variant)
    multi = load(args.dir, mesh="multi", variant=args.variant)
    print("### Dry-run (single pod, 256 chips)\n")
    print(dryrun_table(single))
    print("\n### Dry-run (multi-pod, 2×256 = 512 chips)\n")
    print(dryrun_table(multi))
    print("\n### Roofline (single pod)\n")
    print(roofline_table(single))


if __name__ == "__main__":
    main()
