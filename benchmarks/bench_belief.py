"""Learned cost priors + belief uncertainty vs the blind PR 5 controller
(BENCH_belief.json).

The belief-layer claims this benchmark records and gates:

  * **cold start**: on never-observed fleets whose slow speed tier is
    degraded from tick 0, the belief controller (ridge prior trained on
    replay tuples from OTHER fleets, posterior sampling for robust
    selection) accrues ≥20% lower cumulative true-F regret than the blind
    adaptive controller — regret measured against the best hindsight
    oracle floor either run found, so oracle rng luck cannot decide;
  * **sparse observation**: with placement mass concentrated on two
    slow-tier devices (4 of 6 devices never observed), the belief
    controller's regret is STRICTLY lower — the prior prices the risky
    tier before any window fills;
  * **bitwise parity**: ``use_belief=True`` alone (no prior, no sampling,
    no probing) reproduces the legacy RegretReport bitwise — the belief
    state is passive bookkeeping until its knobs are turned;
  * **dispatch budget**: prior training rides replay for free and probing
    rides the reoptimize batch, so the belief path adds at most ONE extra
    search dispatch per run (the initial prior adaptation).

Usage:
  python -m benchmarks.bench_belief            # full sweep
  python -m benchmarks.bench_belief --smoke    # fewer seeds, short traces
  python -m benchmarks.bench_belief --check    # exit 1 on a failed gate
"""

import argparse
import dataclasses
import json
import sys
from pathlib import Path

import numpy as np

from repro.adapt import AdaptiveConfig, run_adaptive
from repro.belief import fit_prior, speed_percentile
from repro.core.calibration import ReplayWindow
from repro.core.devices import ExplicitFleet
from repro.core.placement import uniform_placement
from repro.obs import bench as obench
from repro.sim import (ScenarioConfig, merge_tuples, replay_trace,
                       scenario_batch, training_tuples)
from repro.sim.scenarios import TraceEvent
from repro.streaming.engine import StreamingEngine
from repro.streaming.operators import StreamGraph, filter_op, map_op, source

OUT_PATH = Path("BENCH_belief.json")

FULL = dict(seeds=5, trace_len=64)
SMOKE = dict(seeds=3, trace_len=32)

FACTOR = 8.0  # slow-tier slowdown planted in every evaluation world

SCENARIO = ScenarioConfig(trace_len=8, base_rate=32.0, n_regions=(3, 3),
                          devices_per_region=(2, 2))
BLIND = AdaptiveConfig(window=3, cooldown=2, drift_threshold=0.3,
                       amortize_ticks=20.0, n_candidates=32,
                       oracle_candidates=16)
BELIEF = dataclasses.replace(BLIND, use_belief=True, belief_sampling=True)


def _stream_graph() -> StreamGraph:
    ops = [
        source(),
        map_op("normalize", lambda r: (r - r.mean()) / (r.std() + 1e-9)),
        filter_op("threshold", lambda r: r[:, 0] > -0.5, selectivity=0.7),
    ]
    return StreamGraph(ops, [(0, 1), (1, 2)])


def _engine(seed: int) -> StreamingEngine:
    rng = np.random.default_rng(seed)
    sg = _stream_graph()
    s = scenario_batch(rng, 1, SCENARIO, graph=sg.meta)[0]
    x = uniform_placement(sg.meta.n_ops,
                          np.ones((sg.meta.n_ops, s.n_devices), bool))
    return StreamingEngine(sg, s.fleet, x, observed="work")


def _snapshot_fleet(fleet) -> ExplicitFleet:
    return ExplicitFleet(
        com_cost=np.asarray(fleet.com_matrix(), dtype=np.float64).copy(),
        speed=np.asarray(fleet.effective_speed(), dtype=np.float64).copy(),
        region=np.asarray(fleet.region).copy())


def _slow_tier(fleet) -> np.ndarray:
    pct = speed_percentile(np.asarray(fleet.effective_speed()))
    return np.flatnonzero(pct < 1.0 / 3.0)


def _rate_ticks(t0: int, n: int, rate: float = 32.0) -> list[TraceEvent]:
    return [TraceEvent(t=t0 + k, kind="rate", rate=rate) for k in range(n)]


def _slow_tier_trace(fleet, n_ticks: int) -> list[TraceEvent]:
    events = [TraceEvent(t=0, kind="degrade", rate=0.0, device=int(u),
                         factor=FACTOR)
              for u in _slow_tier(fleet)]
    return events + _rate_ticks(0, n_ticks)


def _train_prior(seeds=(10, 11, 12)):
    """Fit the ridge prior on the (placement, fleet, observed-cost) tuples
    replay traces of DISJOINT training fleets generate for free."""
    parts = []
    for seed in seeds:
        eng = _engine(seed)
        base = _snapshot_fleet(eng.fleet)
        trace = _slow_tier_trace(eng.fleet, n_ticks=6)
        rep = replay_trace(eng, trace, np.random.default_rng(seed))
        window = ReplayWindow.from_report(rep, eng.x)
        parts.append(training_tuples(eng.graph.meta, base, window))
    corpus = merge_tuples(parts)
    return fit_prior(device_features=corpus.device_features,
                     device_log_degrade=corpus.device_log_degrade,
                     device_weights=corpus.device_weights)


def _cold_start_engine(seed: int) -> StreamingEngine:
    """Uniform seed placement, slow tier degraded from tick 0."""
    return _engine(seed)


def _sparse_engine(seed: int) -> StreamingEngine:
    """Sparse observation: ALL placement mass on the two slow-tier devices
    (the rest of the fleet is never observed), which then degrade — the
    blind controller must discover the world through a 2-device keyhole
    while the prior already priced the whole tier."""
    eng = _engine(seed)
    slow = _slow_tier(eng.fleet)
    x0 = np.zeros_like(eng.x)
    x0[:, int(slow[0])] = 0.7
    x0[:, int(slow[1 % len(slow)])] += 0.3
    eng.x = x0
    return eng


def _compare_family(name: str, make_engine, prior, seeds: int,
                    trace_len: int) -> list[dict]:
    """Blind vs belief on the same worlds; regret per seed is measured
    against the shared hindsight floor min(cum_oracle) of the pair (each
    run's oracle consumes a different rng stream — comparing each policy
    to its own oracle would reward oracle luck, not the policy)."""
    rows = []
    for seed in range(seeds):
        reports, secs = {}, {}
        for policy, cfg, pr in (("blind", BLIND, None),
                                ("belief", BELIEF, prior)):
            eng = make_engine(seed)
            trace = _slow_tier_trace(eng.fleet, n_ticks=trace_len)
            secs[policy], reports[policy] = obench.time_once(
                lambda: run_adaptive(eng, trace,
                                     np.random.default_rng(seed + 50),
                                     cfg, name=f"{name}{seed}", prior=pr),
                block=False)
        floor = min(r.cum_oracle for r in reports.values())
        row = dict(family=name, seed=seed, oracle_floor=floor)
        for policy, rep in reports.items():
            row[policy] = dict(seconds=secs[policy],
                               regret=rep.cum_adaptive - floor,
                               **rep.summary())
        rows.append(row)
    return rows


def _bitwise_parity() -> bool:
    """use_belief=True with every belief knob off reproduces the legacy
    controller's RegretReport bitwise on an outage trace."""
    passive = dataclasses.replace(BLIND, use_belief=True)
    reps = []
    for cfg in (BLIND, passive):
        eng = _engine(0)
        region = int(np.asarray(eng.fleet.region)[0])
        trace = (_rate_ticks(0, 4)
                 + [TraceEvent(t=4, kind="outage", rate=0.0, device=region,
                               factor=32.0)]
                 + _rate_ticks(4, 14)
                 + [TraceEvent(t=18, kind="recover", rate=0.0, device=region,
                               factor=32.0)]
                 + _rate_ticks(18, 4))
        reps.append(run_adaptive(eng, trace, np.random.default_rng(1), cfg))
    a, b = reps
    return (a.reconfig_ticks == b.reconfig_ticks
            and a.refit_ticks == b.refit_ticks
            and a.controller_dispatches == b.controller_dispatches
            and a.final_com_scale == b.final_com_scale
            and np.array_equal(a.f_adaptive, b.f_adaptive)
            and np.array_equal(a.f_static, b.f_static)
            and np.array_equal(a.f_oracle, b.f_oracle)
            and np.array_equal(a.reconfig_costs, b.reconfig_costs)
            and np.array_equal(a.drift, b.drift, equal_nan=True))


def _totals(rows: list[dict]) -> dict:
    return {policy: sum(r[policy]["regret"] for r in rows)
            for policy in ("blind", "belief")}


def run(smoke: bool = False) -> list[str]:
    cfg = SMOKE if smoke else FULL
    out = []

    prior = _train_prior()
    cold = _compare_family("cold_start", _cold_start_engine, prior,
                           cfg["seeds"], cfg["trace_len"])
    sparse = _compare_family("sparse", _sparse_engine, prior,
                             cfg["seeds"], cfg["trace_len"])
    parity = _bitwise_parity()

    cold_tot, sparse_tot = _totals(cold), _totals(sparse)
    # the belief path's only extra search dispatch is the initial prior
    # adaptation: dispatches − refits ≤ 1 on every belief run
    extra_dispatches = max(
        r["belief"]["controller_dispatches"] - r["belief"]["n_refits"]
        for r in cold + sparse)

    report = {
        "smoke": smoke,
        "factor": FACTOR,
        "controller": {"window": BLIND.window, "cooldown": BLIND.cooldown,
                       "drift_threshold": BLIND.drift_threshold,
                       "amortize_ticks": BLIND.amortize_ticks,
                       "n_candidates": BLIND.n_candidates,
                       "robust_scenarios": BLIND.robust_scenarios},
        "prior": {"n_device_samples": prior.n_device_samples,
                  "device_residual_var": prior.device_residual_var},
        "cold_start": cold,
        "sparse": sparse,
        "cold_start_regret": cold_tot,
        "sparse_regret": sparse_tot,
        "cold_start_ratio": cold_tot["belief"] / max(cold_tot["blind"],
                                                     1e-12),
        "bitwise_parity": parity,
        "max_extra_dispatches": extra_dispatches,
    }
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")

    for fam, rows, tot in (("cold_start", cold, cold_tot),
                           ("sparse", sparse, sparse_tot)):
        out.append(f"belief_{fam},blind={tot['blind']:.1f},"
                   f"belief={tot['belief']:.1f},"
                   f"ratio={tot['belief'] / max(tot['blind'], 1e-12):.3f}")
        for r in rows:
            out.append(
                f"belief_{fam}_{r['seed']},"
                f"{r['belief']['seconds'] * 1e3:.0f}ms,"
                f"blind_regret={r['blind']['regret']:.1f},"
                f"belief_regret={r['belief']['regret']:.1f},"
                f"belief_reconfigs={r['belief']['n_reconfigs']},"
                f"belief_dispatches="
                f"{r['belief']['controller_dispatches']}")
    out.append(f"belief_parity,bitwise={parity}")
    out.append(f"belief_dispatch_budget,max_extra={extra_dispatches}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer seeds, short traces (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless the belief controller beats the "
                         "blind one ≥20%% on cold start, strictly on sparse "
                         "traces, reproduces the legacy report bitwise with "
                         "uncertainty off, and adds ≤1 extra dispatch")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
    if args.check:
        report = json.loads(OUT_PATH.read_text())
        ok = True
        cold = report["cold_start_regret"]
        if not cold["belief"] <= 0.8 * cold["blind"]:
            print(f"CHECK FAILED: cold-start belief regret "
                  f"{cold['belief']:.1f} is not ≥20% below blind "
                  f"{cold['blind']:.1f}", file=sys.stderr)
            ok = False
        sparse = report["sparse_regret"]
        if not sparse["belief"] < sparse["blind"]:
            print(f"CHECK FAILED: sparse-observation belief regret "
                  f"{sparse['belief']:.1f} is not strictly below blind "
                  f"{sparse['blind']:.1f}", file=sys.stderr)
            ok = False
        if not report["bitwise_parity"]:
            print("CHECK FAILED: use_belief=True with uncertainty off does "
                  "not reproduce the legacy RegretReport bitwise",
                  file=sys.stderr)
            ok = False
        if report["max_extra_dispatches"] > 1:
            print(f"CHECK FAILED: belief path adds "
                  f"{report['max_extra_dispatches']} extra dispatches "
                  f"(> 1) — training/probing must ride existing batches",
                  file=sys.stderr)
            ok = False
        if not ok:
            sys.exit(1)
        print(f"check OK: cold-start regret ratio "
              f"{report['cold_start_ratio']:.3f} (≤ 0.8), sparse "
              f"{sparse['belief']:.1f} < {sparse['blind']:.1f}, bitwise "
              f"parity, ≤ {report['max_extra_dispatches']} extra dispatch")


if __name__ == "__main__":
    main()
