"""Scenario-simulation throughput: (scenario × placement) grids scored by
the batched evaluator vs looping the scalar ``latency()`` path, plus the
Pallas edge-latency kernel variant.  Writes BENCH_scenarios.json with
candidates-scored-per-second and the batched-vs-scalar speedup (the ISSUE's
≥10× acceptance gate)."""

import json
from pathlib import Path

import numpy as np

from repro.core import latency, objective_F, random_placement
from repro.obs import bench as obench
from repro.sim import (BatchedEvaluator, ScenarioConfig, pack_fleets,
                       pack_placements, scenario_batch)

OUT_PATH = Path("BENCH_scenarios.json")


def _time(f, n=5):
    """Mean seconds per warm call (shared harness: repro.obs.bench)."""
    return obench.measure(f, n=n, block=False).mean_s


def run() -> list[str]:
    rng = np.random.default_rng(0)
    cfg = ScenarioConfig(n_ops=(12, 12), n_regions=(4, 4),
                         devices_per_region=(8, 8))
    n_scenarios, n_placements = 8, 128
    scens = scenario_batch(rng, n_scenarios, cfg)
    g = scens[0].graph
    v = scens[0].n_devices
    xs = [random_placement(g.n_ops, np.ones((g.n_ops, v), bool), rng, 0.5)
          for _ in range(n_placements)]
    coms = pack_fleets([s.fleet for s in scens])
    P = pack_placements(xs)
    n_cand = n_scenarios * n_placements

    ev = BatchedEvaluator(g)
    s_batched = _time(lambda: np.asarray(ev.score_grid(P, coms, dq=0.3,
                                                       beta=0.5)))
    evp = BatchedEvaluator(g, use_pallas=True, interpret=True)
    s_pallas = _time(lambda: np.asarray(evp.score_grid(P, coms, dq=0.3,
                                                       beta=0.5)),
                     n=2)

    # scalar reference: python loop over a subset, extrapolated per-candidate
    sub = 32
    pairs = [(scens[k % n_scenarios].fleet, xs[k % n_placements])
             for k in range(sub)]

    def scalar_loop():
        for fleet, x in pairs:
            objective_F(latency(g, fleet, x), 0.3, 0.5)

    s_scalar_per = _time(scalar_loop, n=2) / sub

    batched_per = s_batched / n_cand
    speedup = s_scalar_per / batched_per
    pallas_per = s_pallas / n_cand
    report = {
        "n_scenarios": n_scenarios,
        "n_placements": n_placements,
        "n_candidates": n_cand,
        "n_ops": g.n_ops,
        "n_devices": v,
        "candidates_per_second": 1.0 / batched_per,
        "batched_us_per_candidate": batched_per * 1e6,
        "pallas_interpret_us_per_candidate": pallas_per * 1e6,
        "scalar_us_per_candidate": s_scalar_per * 1e6,
        "batched_vs_scalar_speedup": speedup,
    }
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    return [
        f"scenarios_grid_{n_scenarios}x{n_placements}_dev{v},"
        f"{batched_per * 1e6:.2f},"
        f"cands_per_s={1.0 / batched_per:.0f};speedup_vs_scalar={speedup:.1f}",
        f"scenarios_scalar_loop_dev{v},{s_scalar_per * 1e6:.2f},per_candidate",
        f"scenarios_pallas_interpret_dev{v},{pallas_per * 1e6:.2f},"
        f"per_candidate",
    ]
