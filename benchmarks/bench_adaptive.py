"""Closed-loop adaptive replay vs static placement on drifting traces
(BENCH_adaptive.json).

The adaptive-controller claims this benchmark records and gates:

  * **regret**: over a family of drifting scenarios (Markov time-correlated
    whole-region outages, permanent stragglers, selectivity drift, device
    losses), the controller's cumulative true F — INCLUDING its
    reconfiguration charges — beats holding the seed placement static
    (aggregate over the fixed seed set; a per-tick oracle is reported as
    the hindsight floor);
  * **refit generalization**: `repro.core.calibration.refit_from_replay`
    fit on the first half of an observation window reduces normalized
    modeled-vs-observed drift on the HELD-OUT second half (the refit
    explains the world, not the sample);
  * **dispatch scaling**: controller search dispatches are O(adaptations),
    not O(ticks) — doubling the trace length must not double dispatches
    unless the world drifted twice as often.

Usage:
  python -m benchmarks.bench_adaptive            # full sweep
  python -m benchmarks.bench_adaptive --smoke    # short traces (CI)
  python -m benchmarks.bench_adaptive --check    # exit 1 on a failed gate
"""

import argparse
import json
import sys
from pathlib import Path

import numpy as np

from repro.adapt import AdaptiveConfig, run_adaptive
from repro.core.calibration import (ReplayWindow, normalized_drift,
                                    refit_from_replay)
from repro.core.costmodel import latency
from repro.core.placement import uniform_placement
from repro.obs import bench as obench
from repro.sim import ScenarioConfig, scenario_batch
from repro.sim.scenarios import random_trace
from repro.streaming.engine import StreamingEngine
from repro.streaming.operators import StreamGraph, filter_op, map_op, source

OUT_PATH = Path("BENCH_adaptive.json")

FULL = dict(seeds=5, trace_len=64)
SMOKE = dict(seeds=3, trace_len=32)

CONTROLLER = AdaptiveConfig(window=4, cooldown=2, drift_threshold=0.5,
                            amortize_ticks=5.0)


def _stream_graph() -> StreamGraph:
    ops = [
        source(),
        map_op("normalize", lambda r: (r - r.mean()) / (r.std() + 1e-9)),
        filter_op("threshold", lambda r: r[:, 0] > -0.5, selectivity=0.7),
    ]
    return StreamGraph(ops, [(0, 1), (1, 2)])


def _drifting_scenario(seed: int, trace_len: int):
    """One drifting world: geo-fleet + trace with Markov region outages
    (geometric ~8-tick dwell), stragglers, selectivity drift, rare losses."""
    rng = np.random.default_rng(seed)
    sg = _stream_graph()
    cfg = ScenarioConfig(trace_len=trace_len, base_rate=64.0,
                         n_regions=(3, 3), devices_per_region=(2, 3),
                         degrade_prob=0.06, loss_prob=0.01,
                         outage_on_prob=0.05, outage_off_prob=0.06,
                         selectivity_drift_std=0.10)
    s = scenario_batch(rng, 1, cfg, graph=sg.meta)[0]
    trace = random_trace(rng, s.n_devices, cfg,
                         n_regions=int(np.asarray(s.fleet.region).max()) + 1,
                         n_ops=sg.meta.n_ops)
    x0 = uniform_placement(sg.meta.n_ops,
                           np.ones((sg.meta.n_ops, s.n_devices), bool))
    eng = StreamingEngine(sg, s.fleet, x0, observed="work")
    return eng, trace


def _run_family(seeds: int, trace_len: int) -> list[dict]:
    rows = []
    for seed in range(seeds):
        eng, trace = _drifting_scenario(seed, trace_len)
        seconds, rep = obench.time_once(
            lambda: run_adaptive(eng, trace,
                                 np.random.default_rng(seed + 100),
                                 CONTROLLER, name=f"drift{seed}"),
            block=False)
        rows.append(dict(seed=seed, seconds=seconds, **rep.summary()))
    return rows


def _heldout_refit() -> dict:
    """Fit on the first half of a drifted window, measure drift on the
    held-out second half: the believed fleet is healthy, the true world
    carries region-scale degrades the belief has never seen."""
    from repro.core.devices import ExplicitFleet

    rng = np.random.default_rng(7)
    sg = _stream_graph()
    cfg = ScenarioConfig(trace_len=1, n_regions=(3, 3),
                         devices_per_region=(2, 3))
    s = scenario_batch(rng, 1, cfg, graph=sg.meta)[0]
    believed = ExplicitFleet(
        com_cost=np.asarray(s.fleet.com_matrix()).copy(),
        speed=np.asarray(s.fleet.effective_speed()).copy(),
        region=np.asarray(s.fleet.region).copy())
    x0 = uniform_placement(sg.meta.n_ops,
                           np.ones((sg.meta.n_ops, s.n_devices), bool))
    eng = StreamingEngine(sg, s.fleet, x0, observed="work")
    # the true world drifts away from the belief: one straggler + a
    # whole-region slowdown
    eng.apply_event("degrade", 0, factor=6.0, reoptimize=False)
    eng.apply_event("outage", int(np.asarray(eng.fleet.region).max()),
                    factor=16.0, reoptimize=False)
    rates, busy, obs, rin, rout = [], [], [], [], []
    for t in range(16):
        rate = 48.0 + 24.0 * (t % 4)
        rep = eng.run_batch(rng.normal(size=(int(rate), 4)))
        rates.append(rate)
        busy.append(rep.device_busy.copy())
        obs.append(rep.true_latency)
        rin.append(rep.op_rows_in.copy())
        rout.append(rep.op_rows_out.copy())
    half = 8
    fit_win = ReplayWindow(rates=np.array(rates[:half]),
                           busy=np.stack(busy[:half]),
                           observed_latency=np.array(obs[:half]),
                           xs=x0,
                           op_rows_in=np.stack(rin[:half]),
                           op_rows_out=np.stack(rout[:half]))
    refit = refit_from_replay(sg.meta, believed, fit_win)
    heldout_obs = np.array(obs[half:])
    pre_mod = np.array([latency(sg.meta, believed, x0)] * (16 - half))
    post_mod = refit.com_scale * np.array(
        [latency(refit.graph, refit.fleet, x0)] * (16 - half))
    return dict(pre_drift_heldout=normalized_drift(heldout_obs, pre_mod),
                post_drift_heldout=normalized_drift(heldout_obs, post_mod),
                com_scale=refit.com_scale,
                max_degrade=float(refit.degrade.max()))


def run(smoke: bool = False) -> list[str]:
    cfg = SMOKE if smoke else FULL
    out = []

    family = _run_family(cfg["seeds"], cfg["trace_len"])
    tot_static = sum(r["cum_static"] for r in family)
    tot_adaptive = sum(r["cum_adaptive"] for r in family)
    tot_oracle = sum(r["cum_oracle"] for r in family)

    # dispatch scaling: the same world family at double the horizon
    long_family = _run_family(cfg["seeds"], 2 * cfg["trace_len"])
    scaling = []
    for short, long in zip(family, long_family):
        for r in (short, long):
            adaptations = r["n_refits"] + r["n_reconfigs"]
            scaling.append(dict(
                seed=r["seed"], ticks=r["n_ticks"],
                dispatches=r["controller_dispatches"],
                adaptations=adaptations,
                dispatches_per_adaptation=r["controller_dispatches"]
                / max(adaptations, 1)))

    heldout = _heldout_refit()

    report = {
        "smoke": smoke,
        "controller": {"window": CONTROLLER.window,
                       "cooldown": CONTROLLER.cooldown,
                       "drift_threshold": CONTROLLER.drift_threshold,
                       "amortize_ticks": CONTROLLER.amortize_ticks,
                       "n_candidates": CONTROLLER.n_candidates,
                       "robust_scenarios": CONTROLLER.robust_scenarios},
        "family": family,
        "total_static": tot_static,
        "total_adaptive": tot_adaptive,
        "total_oracle": tot_oracle,
        "adaptive_over_static": tot_adaptive / tot_static,
        "heldout_refit": heldout,
        "dispatch_scaling": scaling,
        "max_dispatches_per_adaptation": max(
            r["dispatches_per_adaptation"] for r in scaling),
        "max_dispatch_tick_fraction": max(
            r["dispatches"] / r["ticks"] for r in scaling),
    }
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")

    out.append(f"adaptive_regret_total,{tot_adaptive:.1f},"
               f"static={tot_static:.1f},oracle={tot_oracle:.1f},"
               f"ratio={tot_adaptive / tot_static:.3f}")
    for r in family:
        out.append(f"adaptive_{r['seed']},{r['seconds'] * 1e3:.0f}ms,"
                   f"static={r['cum_static']:.1f},"
                   f"adaptive={r['cum_adaptive']:.1f},"
                   f"oracle={r['cum_oracle']:.1f},"
                   f"refits={r['n_refits']},reconfigs={r['n_reconfigs']},"
                   f"dispatches={r['controller_dispatches']}")
    out.append(f"heldout_refit,pre={heldout['pre_drift_heldout']:.3f},"
               f"post={heldout['post_drift_heldout']:.3f}")
    out.append(f"dispatch_scaling,max_per_adaptation="
               f"{report['max_dispatches_per_adaptation']:.2f},"
               f"max_tick_fraction="
               f"{report['max_dispatch_tick_fraction']:.3f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short traces, fewer seeds (CI)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless adaptive beats static in aggregate, "
                         "the refit generalizes to held-out ticks, and "
                         "dispatches scale with adaptations (not ticks)")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
    if args.check:
        report = json.loads(OUT_PATH.read_text())
        ok = True
        if report["total_adaptive"] > report["total_static"]:
            print(f"CHECK FAILED: adaptive cumulative F "
                  f"{report['total_adaptive']:.1f} exceeds static "
                  f"{report['total_static']:.1f} on the drifting-trace "
                  f"family", file=sys.stderr)
            ok = False
        ho = report["heldout_refit"]
        if not ho["post_drift_heldout"] < ho["pre_drift_heldout"]:
            print(f"CHECK FAILED: refit does not reduce held-out drift "
                  f"(pre {ho['pre_drift_heldout']:.3f} → post "
                  f"{ho['post_drift_heldout']:.3f})", file=sys.stderr)
            ok = False
        if report["max_dispatches_per_adaptation"] > 3.0:
            print(f"CHECK FAILED: "
                  f"{report['max_dispatches_per_adaptation']:.2f} dispatches "
                  f"per adaptation (> 3) — dispatch count is not "
                  f"O(reconfigs)", file=sys.stderr)
            ok = False
        if report["max_dispatch_tick_fraction"] > 0.5:
            print(f"CHECK FAILED: dispatches reach "
                  f"{report['max_dispatch_tick_fraction']:.2f} of tick "
                  f"count — O(ticks), not O(reconfigs)", file=sys.stderr)
            ok = False
        if not ok:
            sys.exit(1)
        print(f"check OK: adaptive/static = "
              f"{report['adaptive_over_static']:.3f}, held-out drift "
              f"{ho['pre_drift_heldout']:.3f} → "
              f"{ho['post_drift_heldout']:.3f}, ≤ "
              f"{report['max_dispatches_per_adaptation']:.2f} "
              f"dispatches/adaptation")


if __name__ == "__main__":
    main()
