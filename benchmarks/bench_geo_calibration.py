"""Geo-calibration: the paper's cost model priced from COMPILED artifacts.

Closes the loop between the two halves of the system: the multi-pod dry-run
artifact gives the measured per-step collective wire bytes; the cost model
prices that traffic on the two link classes of the production fleet (ICI
within a pod, DCI between pods) and answers the paper's question — *where
should the replicas be placed?* — for the training dataflow:

  * single-pod   (256 chips, all traffic on ICI)
  * multi-pod DP (512 chips, gradient exchange crosses DCI)

reporting per-step communication seconds and the throughput-equivalent
break-even DCI bandwidth.  This is `repro.core.calibration` +
`repro.core.autoshard` fed by real compiled numbers instead of napkin math.
"""

import json
from pathlib import Path

from repro.core.autoshard import Layout, estimate_layout
from repro.core.devices import DCI_GBPS, ICI_GBPS

_EXP = Path(__file__).resolve().parents[1] / "experiments"
DRYRUN_DIR = (_EXP / "dryrun_final") if (_EXP / "dryrun_final").exists() \
    else (_EXP / "dryrun")


def run() -> list[str]:
    rows = []
    arch = "granite_8b"
    recs = {}
    for mesh in ("single", "multi"):
        p = DRYRUN_DIR / f"{arch}__train_4k__{mesh}.json"
        if p.exists():
            recs[mesh] = json.loads(p.read_text())
    if len(recs) < 2:
        return ["geo_calibration,0.0,missing dry-run artifacts"]

    # measured per-chip wire bytes; the multi-pod pod-axis share is the
    # traffic whose replica groups span pods (approx: multi − single deltas)
    w_single = recs["single"]["collectives"]["total_wire_bytes"]
    w_multi = recs["multi"]["collectives"]["total_wire_bytes"]
    pod_axis_bytes = max(w_multi - w_single / 2, 0.0)  # per-chip, crossing DCI
    ici_s = w_single / (ICI_GBPS * 1e9)
    dci_s = pod_axis_bytes / (DCI_GBPS * 1e9)
    rows.append(
        f"geo_calibration_measured,0.0,single_pod_comm_s={ici_s:.3f};"
        f"multi_pod_pod_axis_s={dci_s:.3f};"
        f"dci_link_assumed_GBps={DCI_GBPS}")

    # analytic cross-check (autoshard) at the same scale
    single = estimate_layout(Layout(dp=16, tp=16), n_layers=36, d_model=4096,
                             d_ff=14336, vocab=49152, seq=4096,
                             global_batch=256, n_params=8.25e9)
    multi = estimate_layout(Layout(dp=32, tp=16, pods=2), n_layers=36,
                            d_model=4096, d_ff=14336, vocab=49152, seq=4096,
                            global_batch=512, n_params=8.25e9)
    # break-even DCI bandwidth: inter-pod gradient exchange no slower than
    # the single-pod step's collective term
    grad_bytes = 8.25e9 * 2.0 / 16  # bf16, per model shard
    breakeven = grad_bytes / 16 / max(single.collective_s, 1e-9) / 1e9
    rows.append(
        f"geo_calibration_analytic,0.0,"
        f"single_collective_s={single.collective_s:.3f};"
        f"multi_dci_s={multi.dci_collective_s:.3f};"
        f"breakeven_dci_GBps={breakeven:.2f}")
    verdict = ("multi_pod_DP_viable" if multi.dci_collective_s
               <= max(multi.compute_s, multi.memory_s)
               else "keep_pods_independent")
    rows.append(f"geo_calibration_verdict,0.0,{verdict}")
    return rows
