"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (baseline, single-pod — per the assignment
the roofline table is single-pod; multi-pod rows are reported in §Dry-run)
and emits one row per cell with the three terms, dominant bottleneck, and
MODEL_FLOPS/HLO_FLOPs."""

import json
from pathlib import Path

_EXP = Path(__file__).resolve().parents[1] / "experiments"
DRYRUN_DIR = (_EXP / "dryrun_final") if (_EXP / "dryrun_final").exists() \
    else (_EXP / "dryrun")


def load_records(mesh: str = "single", variant: str = "baseline"):
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("skipped"):
            continue
        if r.get("mesh") != mesh or r.get("variant") != variant:
            continue
        recs.append(r)
    return recs


def run() -> list[str]:
    recs = load_records()
    if not recs:
        return ["roofline_table,0.0,no dry-run artifacts — run "
                "python -m repro.launch.dryrun --sweep first"]
    rows = []
    for r in recs:
        rf = r["roofline"]
        rows.append(
            f"roofline_{r['arch']}_{r['shape']},0.0,"
            f"compute_s={rf['compute_s']:.4f};memory_s={rf['memory_s']:.4f};"
            f"collective_s={rf['collective_s']:.4f};dom={rf['dominant']};"
            f"useful={rf['useful_fraction']:.3f};"
            f"mfu_bound={rf['mfu_bound']:.4f};"
            f"peakGB={r['memory']['peak_bytes'] / 1e9:.2f};"
            f"fits={r['memory']['fits_16GB']}")
    return rows
