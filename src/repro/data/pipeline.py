"""Streaming data pipeline: deterministic synthetic corpus, resumable
cursors, data-quality hooks, double-buffered prefetch.

The corpus is a stateless hash of (seed, position) so any batch is
reproducible from its cursor alone — that makes checkpoint/restart exact
(the cursor is part of the train state) and lets elastic rescaling re-slice
the stream without coordination.

Data quality (the paper's ``DQ_fraction``): a configurable fraction of each
batch is passed through quality scoring (repro.streaming.quality); low
quality rows get masked out of the loss (``loss_mask``), implementing the
paper's "rate the quality / ignore misleading outputs" semantics in the
training path.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

__all__ = ["PipelineConfig", "TokenStream", "Prefetcher"]


@dataclasses.dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    dq_fraction: float = 0.0  # share of rows quality-checked per batch
    dq_missing_rate: float = 0.01  # synthetic corruption rate (sentinel -1)
    pad_id: int = 0


def _hash_tokens(seed: int, start: int, n: int, vocab: int) -> np.ndarray:
    """SplitMix64-style stateless generator — position-addressable stream."""
    idx = (np.arange(start, start + n, dtype=np.uint64)
           + np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15))
    z = idx
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    z = z ^ (z >> np.uint64(31))
    return (z % np.uint64(vocab)).astype(np.int32)


class TokenStream:
    """Resumable batch iterator.  state = (cursor,) — one integer."""

    def __init__(self, cfg: PipelineConfig, cursor: int = 0):
        self.cfg = cfg
        self.cursor = int(cursor)

    def state(self) -> dict:
        return {"cursor": self.cursor, "seed": self.cfg.seed}

    @classmethod
    def from_state(cls, cfg: PipelineConfig, state: dict) -> "TokenStream":
        if state.get("seed", cfg.seed) != cfg.seed:
            raise ValueError("checkpoint seed mismatch")
        return cls(cfg, cursor=state["cursor"])

    def next_batch(self) -> dict:
        cfg = self.cfg
        n = cfg.global_batch * (cfg.seq_len + 1)
        flat = _hash_tokens(cfg.seed, self.cursor, n, cfg.vocab)
        self.cursor += n
        arr = flat.reshape(cfg.global_batch, cfg.seq_len + 1)
        tokens = arr[:, :-1].copy()
        labels = arr[:, 1:].copy()
        batch = {"tokens": tokens, "labels": labels}
        if cfg.dq_fraction > 0.0:
            batch = self._apply_quality(batch)
        # cursor AFTER this batch — consumers checkpoint the cursor of the
        # batch they actually TRAINED on, not the prefetcher's read-ahead
        # position (a resume would otherwise skip prefetched batches)
        batch["_cursor"] = self.cursor
        return batch

    def _apply_quality(self, batch: dict) -> dict:
        """Corrupt a synthetic share of rows, then quality-score the
        configured DQ_fraction and mask low-quality rows from the loss."""
        cfg = self.cfg
        rng = np.random.default_rng(self.cursor)  # deterministic per batch
        tokens = batch["tokens"]
        B = tokens.shape[0]
        # synthetic corruption (sensor dropouts → sentinel id)
        corrupt = rng.random(B) < cfg.dq_missing_rate
        tokens = tokens.copy()
        tokens[corrupt, ::2] = -1  # half the row drops out
        checked = rng.random(B) < cfg.dq_fraction
        from repro.streaming.quality import quality_scores
        scores = quality_scores(tokens, missing_sentinel=-1)
        # unchecked rows are presumed fine (score forced to 1); clean rows
        # score ≈0.95+, half-missing rows ≈0.6 — threshold between them
        scores = np.where(checked, scores, 1.0)
        loss_mask = (scores >= 0.8).astype(np.float32)
        tokens = np.where(tokens < 0, cfg.pad_id, tokens)
        return {
            "tokens": tokens,
            "labels": batch["labels"],
            "loss_mask": np.broadcast_to(loss_mask[:, None],
                                         batch["labels"].shape).copy(),
        }


class Prefetcher:
    """Double-buffered host-side prefetch — overlaps batch synthesis /
    quality checks with device compute (the compute/comm-overlap trick at
    the data layer)."""

    def __init__(self, stream: TokenStream, depth: int = 2):
        self.stream = stream
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.stream.next_batch()
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2)
