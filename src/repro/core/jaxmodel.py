"""Differentiable / vectorized JAX twin of the paper cost model.

Why a twin: the paper's optimization problems are NP-hard ILPs (§2.3.2);
practical instruments are heuristics.  Because the cost model is a chain of
matmuls + maxes, writing it in JAX gives us (a) a *projected-gradient*
placement optimizer via autodiff over a temperature-smoothed latency
(beyond-paper, see optimizers.py), and (b) vectorized batch scoring of
thousands of candidate placements at once (`vmap`) for the SA/greedy search
and the massive-parallelism scaling bench.

Hard mode (``temp=0``) matches :mod:`repro.core.costmodel` to float32
precision — asserted by property tests.

The graph structure is static Python; only ``x`` (and optionally the com
matrix) are traced, so every builder here returns a jit-compatible closure.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.devices import ExplicitFleet, RegionFleet
from repro.core.graph import OpGraph

__all__ = ["SmoothConfig", "make_latency_fn", "make_objective_fn"]


@dataclasses.dataclass(frozen=True)
class SmoothConfig:
    """temp=0 ⇒ hard max (paper-exact); temp>0 ⇒ logsumexp smoothing.
    link_eps smooths the enabledLinks indicator: nz(x) ≈ x/(x+eps)."""

    alpha: float = 0.0
    temp: float = 0.0
    link_eps: float = 1e-4


def _smax(v: jnp.ndarray, temp: float, axis=None) -> jnp.ndarray:
    if temp <= 0.0:
        return jnp.max(v, axis=axis)
    return temp * jax.nn.logsumexp(v / temp, axis=axis)


def _soft_nz(x: jnp.ndarray, eps: float, hard: bool) -> jnp.ndarray:
    if hard:
        return (x > 0).astype(x.dtype)
    return x / (x + eps)


def _edge_latency(x_i, x_j, s_i, com_times, cfg: SmoothConfig):
    per_u = x_i * s_i * com_times(x_j)
    base = _smax(per_u, cfg.temp)
    if cfg.alpha:
        nz_i = _soft_nz(x_i, cfg.link_eps, cfg.temp <= 0.0)
        nz_j = _soft_nz(x_j, cfg.link_eps, cfg.temp <= 0.0)
        links = nz_i.sum() * nz_j.sum() - (nz_i * nz_j).sum()
        base = base + cfg.alpha * links
    return base


def make_latency_fn(graph: OpGraph, fleet: ExplicitFleet | RegionFleet,
                    cfg: SmoothConfig = SmoothConfig()):
    """Returns jit'able ``lat(x) -> scalar`` for (n_ops, V) placements.

    The critical-path DP is unrolled over the (static) topo order; with
    temp>0 the max over parents is also smoothed so the whole objective is
    C¹ — suitable for jax.grad.
    """
    sel = [op.selectivity for op in graph.operators]

    if isinstance(fleet, RegionFleet):
        region = jnp.asarray(fleet.region)
        # index in numpy BEFORE tracing: a traced inter[region] gather gets
        # constant-folded per edge — minutes of XLA time at 10⁵ devices
        inter_dev = jnp.asarray(fleet.inter[fleet.region])  # (V, R)
        diag = jnp.asarray(np.diag(fleet.inter)[fleet.region])
        self_cost = fleet.self_cost

        def com_times(x_j):
            mass = jax.ops.segment_sum(x_j, region, num_segments=fleet.n_regions)
            return inter_dev @ mass + (self_cost - diag) * x_j
    else:
        com = jnp.asarray(fleet.com_cost)

        def com_times(x_j):
            return com @ x_j

    def lat(x: jnp.ndarray) -> jnp.ndarray:
        elat = {}
        for e, (i, j) in enumerate(graph.edges):
            elat[e] = _edge_latency(x[i], x[j], sel[i], com_times, cfg)
        dist: dict[int, jnp.ndarray] = {}
        zero = jnp.asarray(0.0, dtype=x.dtype)
        for i in graph.topo_order:
            incoming = [dist[ip] + elat[e] for ip, e in graph.in_edges(i)]
            if incoming:
                dist[i] = _smax(jnp.stack(incoming), cfg.temp, axis=0)
            else:
                dist[i] = zero
        sinks = [dist[s] for s in graph.sinks]
        return _smax(jnp.stack(sinks), cfg.temp, axis=0) if sinks else zero

    return lat


def make_objective_fn(graph: OpGraph, fleet: ExplicitFleet | RegionFleet,
                      beta: float, cfg: SmoothConfig = SmoothConfig()):
    """``obj(x, dq_fraction) -> F`` (paper eq. 8), differentiable in both."""
    lat = make_latency_fn(graph, fleet, cfg)

    def obj(x: jnp.ndarray, dq_fraction: jnp.ndarray) -> jnp.ndarray:
        return lat(x) / (1.0 + beta * dq_fraction)

    return obj


@partial(jax.jit, static_argnames=("n_candidates",))
def _noop(n_candidates: int):  # pragma: no cover - keep jax imported hot
    return n_candidates
