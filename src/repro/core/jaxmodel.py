"""Differentiable / vectorized JAX twin of the paper cost model.

Why a twin: the paper's optimization problems are NP-hard ILPs (§2.3.2);
practical instruments are heuristics.  Because the cost model is a chain of
matmuls + maxes, writing it in JAX gives us (a) a *projected-gradient*
placement optimizer via autodiff over a temperature-smoothed latency
(beyond-paper, see optimizers.py), and (b) vectorized batch scoring of
thousands of candidate placements at once (`vmap`) for the SA/greedy search
and the massive-parallelism scaling bench.

Hard mode (``temp=0``) matches :mod:`repro.core.costmodel` to float32
precision — asserted by property tests.

The graph structure is static Python; only ``x`` (and optionally the com
matrix) are traced, so every builder here returns a jit-compatible closure.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.devices import ExplicitFleet, RegionFleet
from repro.core.graph import OpGraph

__all__ = ["SmoothConfig", "make_latency_fn", "make_objective_fn",
           "make_edge_latencies_com_fn", "make_latency_com_fn",
           "make_edge_latencies_region_fn", "make_latency_region_fn",
           "critical_path_dp"]


@dataclasses.dataclass(frozen=True)
class SmoothConfig:
    """temp=0 ⇒ hard max (paper-exact); temp>0 ⇒ logsumexp smoothing.
    link_eps smooths the enabledLinks indicator: nz(x) ≈ x/(x+eps)."""

    alpha: float = 0.0
    temp: float = 0.0
    link_eps: float = 1e-4


def _smax(v: jnp.ndarray, temp: float, axis=None) -> jnp.ndarray:
    if temp <= 0.0:
        return jnp.max(v, axis=axis)
    return temp * jax.nn.logsumexp(v / temp, axis=axis)


def _soft_nz(x: jnp.ndarray, eps: float, hard: bool) -> jnp.ndarray:
    if hard:
        return (x > 0).astype(x.dtype)
    return x / (x + eps)


def _edge_latency(x_i, x_j, s_i, com_times, cfg: SmoothConfig):
    per_u = x_i * s_i * com_times(x_j)
    base = _smax(per_u, cfg.temp)
    if cfg.alpha:
        nz_i = _soft_nz(x_i, cfg.link_eps, cfg.temp <= 0.0)
        nz_j = _soft_nz(x_j, cfg.link_eps, cfg.temp <= 0.0)
        links = nz_i.sum() * nz_j.sum() - (nz_i * nz_j).sum()
        base = base + cfg.alpha * links
    return base


def make_latency_fn(graph: OpGraph, fleet: ExplicitFleet | RegionFleet,
                    cfg: SmoothConfig = SmoothConfig()):
    """Returns jit'able ``lat(x) -> scalar`` for (n_ops, V) placements.

    The critical-path DP is unrolled over the (static) topo order; with
    temp>0 the max over parents is also smoothed so the whole objective is
    C¹ — suitable for jax.grad.
    """
    sel = [op.selectivity for op in graph.operators]

    if isinstance(fleet, RegionFleet):
        region = jnp.asarray(fleet.region)
        d = fleet.degrade_or_ones()
        # index in numpy BEFORE tracing: a traced inter[region] gather gets
        # constant-folded per edge — minutes of XLA time at 10⁵ devices
        inter_dev = jnp.asarray(fleet.inter[fleet.region] * d[:, None])  # (V, R)
        # u==v is priced at d²·inter[r,r] by the matvec; correct to self_cost
        corr = jnp.asarray(
            fleet.self_cost - d * d * np.diag(fleet.inter)[fleet.region])
        d_j = jnp.asarray(d)

        def com_times(x_j):
            mass = jax.ops.segment_sum(d_j * x_j, region,
                                       num_segments=fleet.n_regions)
            return inter_dev @ mass + corr * x_j
    else:
        com = jnp.asarray(fleet.com_cost)

        def com_times(x_j):
            return com @ x_j

    def lat(x: jnp.ndarray) -> jnp.ndarray:
        elat = {}
        for e, (i, j) in enumerate(graph.edges):
            elat[e] = _edge_latency(x[i], x[j], sel[i], com_times, cfg)
        dist: dict[int, jnp.ndarray] = {}
        zero = jnp.asarray(0.0, dtype=x.dtype)
        for i in graph.topo_order:
            incoming = [dist[ip] + elat[e] for ip, e in graph.in_edges(i)]
            if incoming:
                dist[i] = _smax(jnp.stack(incoming), cfg.temp, axis=0)
            else:
                dist[i] = zero
        sinks = [dist[s] for s in graph.sinks]
        return _smax(jnp.stack(sinks), cfg.temp, axis=0) if sinks else zero

    return lat


def make_objective_fn(graph: OpGraph, fleet: ExplicitFleet | RegionFleet,
                      beta: float, cfg: SmoothConfig = SmoothConfig()):
    """``obj(x, dq_fraction) -> F`` (paper eq. 8), differentiable in both."""
    lat = make_latency_fn(graph, fleet, cfg)

    def obj(x: jnp.ndarray, dq_fraction: jnp.ndarray) -> jnp.ndarray:
        return lat(x) / (1.0 + beta * dq_fraction)

    return obj


# -- batched what-if APIs (the com matrix itself is traced) -------------------
#
# make_latency_fn closes over ONE fleet; the scenario-simulation subsystem
# (repro.sim) instead scores placements against *families* of fleets, so the
# communication matrix must be an argument: vmap over (x, com) pairs scores a
# (scenario × placement) grid in one dispatch.  Edge math is vectorized over
# E (gather endpoint rows, one einsum, one row-max) rather than unrolled
# per-edge — that is what the Pallas kernel in kernels/edge_latency.py fuses.

def _edge_arrays(graph: OpGraph):
    src = np.array([i for i, _ in graph.edges], dtype=np.int64)
    dst = np.array([j for _, j in graph.edges], dtype=np.int64)
    sel = np.array([graph.operators[i].selectivity for i, _ in graph.edges])
    return src, dst, sel


def make_edge_latencies_com_fn(graph: OpGraph, cfg: SmoothConfig = SmoothConfig(),
                               nz_eps: float = 0.0):
    """Returns ``elat(x, com) -> (E,)`` with both placement AND com traced.

    Hard-max only (this is the what-if scorer, not the gradient path);
    matches :func:`repro.core.costmodel.edge_latencies` on an ExplicitFleet
    with ``com_cost == com``.  ``nz_eps`` mirrors CostConfig.nz_eps for the
    enabledLinks indicator.
    """
    src, dst, sel = _edge_arrays(graph)
    src_j = jnp.asarray(src)
    dst_j = jnp.asarray(dst)
    sel_j = jnp.asarray(sel)
    alpha = cfg.alpha

    def elat(x: jnp.ndarray, com: jnp.ndarray) -> jnp.ndarray:
        x_i = x[src_j] * sel_j[:, None]           # (E, V)
        x_j = x[dst_j]                            # (E, V)
        t = jnp.einsum("uv,ev->eu", com, x_j)     # (E, V)
        out = jnp.max(x_i * t, axis=1)            # (E,)
        if alpha:
            nz = (x > nz_eps).astype(x.dtype)  # hard indicator, paper-exact
            counts = nz.sum(axis=1)               # (n_ops,)
            both = (nz[src_j] * nz[dst_j]).sum(axis=1)
            out = out + alpha * (counts[src_j] * counts[dst_j] - both)
        return out

    return elat


def critical_path_dp(graph: OpGraph, elat: jnp.ndarray) -> jnp.ndarray:
    """(..., E) edge latencies → (...,) critical-path latency.

    The DP unrolls over the static topo order with whatever leading batch
    shape ``elat`` carries — the single implementation shared by the scalar
    com-fn below and the batched evaluator (repro.sim.batched), so the
    oracle-matching max/DP semantics live in exactly one place.
    """
    zero = jnp.zeros(elat.shape[:-1], dtype=elat.dtype)
    dist: dict[int, jnp.ndarray] = {}
    for i in graph.topo_order:
        incoming = [dist[ip] + elat[..., e] for ip, e in graph.in_edges(i)]
        dist[i] = jnp.max(jnp.stack(incoming), axis=0) if incoming else zero
    sinks = graph.sinks
    return jnp.max(jnp.stack([dist[s] for s in sinks]), axis=0) \
        if sinks else zero


def make_latency_com_fn(graph: OpGraph, cfg: SmoothConfig = SmoothConfig(),
                        nz_eps: float = 0.0):
    """Returns ``lat(x, com) -> scalar``: critical-path DP over the traced
    com matrix.  vmap/jit-compatible twin of costmodel.latency for scenario
    batching (repro.sim.batched vmaps it)."""
    elat_fn = make_edge_latencies_com_fn(graph, cfg, nz_eps)

    def lat(x: jnp.ndarray, com: jnp.ndarray) -> jnp.ndarray:
        return critical_path_dp(graph, elat_fn(x, com))

    return lat


# -- structured (RegionFleet) batched APIs ------------------------------------
#
# The dense com-traced twins above need the (V, V) matrix as an operand —
# fine for scenario batches of modest V, hopeless at the 10⁵-device fleets
# the paper targets.  These twins generalize the segment-sum ``com_times``
# closure of make_latency_fn into argument-taking functions: the *region
# assignment* is static (a what-if family shares the fleet layout) while the
# (R, R) inter matrix and (V,) per-device degrade multipliers are traced —
# so vmapping over (inter, degrade) pairs scores a whole RegionFleetFamily
# without ever materializing an (S, V, V) tensor.  Per edge the math is
#
#   t_u = d_u · Σ_r inter[r_u, r] · mass_r  +  (self_cost − d_u²·inter[r_u,r_u])·x_{j,u}
#   mass_r = Σ_{v ∈ region r} d_v · x_{j,v}
#
# i.e. O(E·(V·R + R²)) work and O(E·V) memory — linear in V.

def _region_factors(inter: jnp.ndarray, degrade: jnp.ndarray,
                    region_ix: jnp.ndarray, self_cost: float):
    """The structured pricing rule, factored once for every consumer
    (this module's region twin, the batched evaluator's Pallas precompute):

        a[r, u]  = degrade_u · inter[region_u, r]                  (R, V)
        corr[u]  = self_cost − degrade_u² · inter[r_u, r_u]        (V,)

    so ``t = mass @ a + corr·x_j`` prices one scenario's per-device transfer
    times.  vmap over (inter, degrade) pairs for a whole family."""
    a = degrade[None, :] * inter.T[:, region_ix]             # (R, V)
    corr = self_cost - degrade * degrade * jnp.diag(inter)[region_ix]
    return a, corr


def make_edge_latencies_region_fn(graph: OpGraph, region: np.ndarray,
                                  n_regions: int, self_cost: float = 0.0,
                                  cfg: SmoothConfig = SmoothConfig(),
                                  nz_eps: float = 0.0):
    """Returns ``elat(x, inter, degrade) -> (E,)`` — the structured twin of
    :func:`make_edge_latencies_com_fn`.

    ``region``/``n_regions``/``self_cost`` are static family structure;
    ``inter`` (R, R) and ``degrade`` (V,) are traced per-scenario state.
    Hard-max only; matches the numpy oracle on the equivalent RegionFleet.
    """
    src, dst, sel = _edge_arrays(graph)
    src_j = jnp.asarray(src)
    dst_j = jnp.asarray(dst)
    sel_j = jnp.asarray(sel)
    region_ix = jnp.asarray(np.asarray(region, dtype=np.int64))
    alpha = cfg.alpha
    n_edges = graph.n_edges

    def elat(x: jnp.ndarray, inter: jnp.ndarray,
             degrade: jnp.ndarray) -> jnp.ndarray:
        x_i = x[src_j] * sel_j[:, None]                  # (E, V)
        x_j = x[dst_j]                                   # (E, V)
        dj = degrade[None, :] * x_j                      # (E, V)
        mass = jnp.zeros((n_edges, n_regions), x.dtype)  # (E, R)
        mass = mass.at[:, region_ix].add(dj)             # segment sum over V
        a, corr = _region_factors(inter, degrade, region_ix, self_cost)
        t = mass @ a.astype(x.dtype) + corr.astype(x.dtype)[None, :] * x_j
        out = jnp.max(x_i * t, axis=1)                   # (E,)
        if alpha:
            nz = (x > nz_eps).astype(x.dtype)
            counts = nz.sum(axis=1)
            both = (nz[src_j] * nz[dst_j]).sum(axis=1)
            out = out + alpha * (counts[src_j] * counts[dst_j] - both)
        return out

    return elat


def make_latency_region_fn(graph: OpGraph, region: np.ndarray,
                           n_regions: int, self_cost: float = 0.0,
                           cfg: SmoothConfig = SmoothConfig(),
                           nz_eps: float = 0.0):
    """Returns ``lat(x, inter, degrade) -> scalar``: critical-path DP over
    the structured edge latencies (vmap/jit twin of costmodel.latency on a
    RegionFleet, with the per-scenario state traced)."""
    elat_fn = make_edge_latencies_region_fn(graph, region, n_regions,
                                            self_cost, cfg, nz_eps)

    def lat(x: jnp.ndarray, inter: jnp.ndarray,
            degrade: jnp.ndarray) -> jnp.ndarray:
        return critical_path_dp(graph, elat_fn(x, inter, degrade))

    return lat


@partial(jax.jit, static_argnames=("n_candidates",))
def _noop(n_candidates: int):  # pragma: no cover - keep jax imported hot
    return n_candidates
