"""The paper's cost model (§3), exact reference implementation.

Everything here is float64 numpy — this is the *oracle* used by tests,
benchmarks, and the discrete optimizers.  The differentiable / vectorized
JAX twin lives in :mod:`repro.core.jaxmodel`; a property test asserts the two
agree on random instances.

Paper formulas implemented:

  edgeLat(i→j) = max_{u∈ED_i} { x_{i,u}·s_i·Σ_{v∈ED_j} comCost_{u,v}·x_{j,v} }
                 + α·enabledLinks_{i,j}
  Latency      = max_{paths} Σ_{(i→j)∈path} edgeLat(i→j)
  F            = Latency / (1 + β·DQ_fraction)                       (eq. 8)

plus the §3.1 "trivial through simple sum functions" extensions (network
movement as in [26], device occupancy) and the compute-cost extension used by
auto-sharding (DESIGN.md assumption log).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.devices import ExplicitFleet, RegionFleet
from repro.core.graph import OpGraph

__all__ = [
    "CostConfig",
    "edge_latency",
    "edge_latencies",
    "enabled_links",
    "latency",
    "latency_via_paths",
    "objective_F",
    "network_movement",
    "device_occupancy",
    "node_compute_cost",
]

Fleet = ExplicitFleet | RegionFleet


@dataclasses.dataclass(frozen=True)
class CostConfig:
    """Knobs of the cost model.

    alpha: the paper's network-congestion / connection-overhead factor.
    include_compute: enable the per-operator compute term (extension;
      False ⇒ paper-faithful "communication dominates" assumption).
    nz_eps: threshold under which a fraction counts as zero for
      ``enabledLinks`` (the paper uses exact ``x ≠ 0``).
    """

    alpha: float = 0.0
    include_compute: bool = False
    nz_eps: float = 0.0


def _com_times_x(fleet: Fleet, x_j: np.ndarray) -> np.ndarray:
    """(Σ_v comCost_{u,v} · x_{j,v}) for every u — structured when possible.

    RegionFleet path: comCost_{u,v} = d_u·d_v·inter[r_u, r_v] (u ≠ v), so the
    matvec collapses to a degrade-weighted region mass (segment sum) times
    the (R, R) inter matrix, plus a diagonal correction to self_cost —
    O(V + R²) instead of O(V²)."""
    if isinstance(fleet, RegionFleet):
        diag_r = np.diag(fleet.inter)[fleet.region]
        if fleet.degrade is None:  # healthy fleet — skip the no-op passes
            mass = fleet.region_masses(x_j)  # (R,)
            per_u = fleet.inter[fleet.region] @ mass  # (V,)
            per_u += (fleet.self_cost - diag_r) * x_j
            return per_u
        d = fleet.degrade
        mass = fleet.region_masses(d * x_j)  # (R,)
        per_u = d * (fleet.inter[fleet.region] @ mass)  # (V,)
        # u==v pairs were priced at d_u²·inter[r,r]; correct them to self_cost.
        per_u += (fleet.self_cost - d * d * diag_r) * x_j
        return per_u
    return fleet.com_cost @ x_j


def _effective_speed(fleet: Fleet, n_dev: int) -> np.ndarray:
    """(V,) compute speed with degrade applied — shared by the occupancy
    objective and the compute-cost extension so a straggler is priced slow
    on compute exactly as its links are priced slow (fleet.effective_speed,
    falling back to ones for speed-less fleets)."""
    if fleet.speed is None:
        return np.ones(n_dev)
    return fleet.effective_speed()


def enabled_links(x_i: np.ndarray, x_j: np.ndarray, nz_eps: float = 0.0) -> float:
    """#{(u,v): x_{i,u}≠0, x_{j,v}≠0, u≠v} — devices exchanging data over the net."""
    nz_i = x_i > nz_eps
    nz_j = x_j > nz_eps
    return float(nz_i.sum() * nz_j.sum() - (nz_i & nz_j).sum())


def edge_latency(
    x_i: np.ndarray,
    x_j: np.ndarray,
    s_i: float,
    fleet: Fleet,
    cfg: CostConfig = CostConfig(),
) -> float:
    """Paper edge latency: slowest single-device transfer + α·enabledLinks."""
    per_u = x_i * s_i * _com_times_x(fleet, x_j)
    base = float(per_u.max()) if per_u.size else 0.0
    if cfg.alpha:
        base += cfg.alpha * enabled_links(x_i, x_j, cfg.nz_eps)
    return base


def edge_latencies(graph: OpGraph, fleet: Fleet, x: np.ndarray,
                   cfg: CostConfig = CostConfig()) -> np.ndarray:
    """(E,) edge latency for every edge of the graph."""
    x = np.asarray(x, dtype=np.float64)
    out = np.zeros(graph.n_edges)
    for e, (i, j) in enumerate(graph.edges):
        out[e] = edge_latency(x[i], x[j], graph.operators[i].selectivity, fleet, cfg)
    return out


def node_compute_cost(graph: OpGraph, fleet: Fleet, x: np.ndarray, i: int) -> float:
    """Extension: slowest instance's compute time for operator i.

    ``work_i · rate_i · x_{i,u} / speed_u`` maxed over devices that hold a
    fraction.  rate_i scales work by upstream selectivities.
    """
    op = graph.operators[i]
    if op.work == 0.0:
        return 0.0
    rate = graph.cumulative_rates()[i]
    t = op.work * rate * x[i] / _effective_speed(fleet, x.shape[1])
    return float(t.max())


def latency(graph: OpGraph, fleet: Fleet, x: np.ndarray,
            cfg: CostConfig = CostConfig()) -> float:
    """Critical-path latency by topological DP (== max over explicit paths).

    dist[j] = max_{i∈pred(j)} (dist[i] + edgeLat(i→j)) (+ compute terms when
    the extension is on); answer = max over sinks.
    """
    x = np.asarray(x, dtype=np.float64)
    elat = edge_latencies(graph, fleet, x, cfg)
    dist = np.zeros(graph.n_ops)
    if cfg.include_compute:
        for i in graph.sources:
            dist[i] = node_compute_cost(graph, fleet, x, i)
    for i in graph.topo_order:
        for j, e in graph.out_edges(i):
            cand = dist[i] + elat[e]
            if cfg.include_compute:
                cand += node_compute_cost(graph, fleet, x, j)
            if cand > dist[j]:
                dist[j] = cand
    sinks = graph.sinks
    return float(max(dist[s] for s in sinks)) if sinks else 0.0


def latency_via_paths(graph: OpGraph, fleet: Fleet, x: np.ndarray,
                      cfg: CostConfig = CostConfig()) -> float:
    """Oracle: explicit max over enumerated paths (exponential; tests only)."""
    elat = edge_latencies(graph, fleet, x, cfg)
    paths = graph.edge_paths()
    if not paths:
        return 0.0
    if cfg.include_compute:
        raise NotImplementedError("oracle covers the paper-faithful model only")
    return float(max((sum(elat[e] for e in p) for p in paths), default=0.0))


def objective_F(latency_value: float, dq_fraction: float, beta: float) -> float:
    """Paper eq. (8): quality-aware objective.  β=0 removes DQ from play."""
    if not 0.0 <= dq_fraction <= 1.0:
        raise ValueError(f"DQ_fraction must be in [0,1], got {dq_fraction}")
    if beta < 0.0:
        raise ValueError(f"beta must be ≥ 0, got {beta}")
    return latency_value / (1.0 + beta * dq_fraction)


# -- §3.1 additional objectives ("trivial through simple sum functions") -----

def network_movement(graph: OpGraph, fleet: Fleet, x: np.ndarray,
                     weight_by_cost: bool = False) -> float:
    """Total data moved over the network (as in [26]): Σ_edges Σ_{u≠v}
    rate_i·s_i·bytes_i·x_{i,u}·x_{j,v}, optionally weighted by comCost.

    The bilinear sum factorizes — unweighted it is
    ``(Σ_u x_{i,u})·(Σ_v x_{j,v}) − Σ_u x_{i,u}·x_{j,u}`` (O(V) per edge);
    weighted it routes through :func:`_com_times_x`, so RegionFleets take
    the degrade-weighted segment-sum path (O(V + R²) per edge) and never
    materialize ``com_matrix()``.  The u == v diagonal (data staying local)
    is subtracted explicitly in both forms.
    """
    x = np.asarray(x, dtype=np.float64)
    rates = graph.cumulative_rates()
    if weight_by_cost:
        # per-device self-transfer price: what _com_times_x puts on u == v
        diag = fleet.self_cost if isinstance(fleet, RegionFleet) \
            else np.diag(fleet.com_cost)
    total = 0.0
    for i, j in graph.edges:
        op = graph.operators[i]
        if weight_by_cost:
            pair = x[i] @ _com_times_x(fleet, x[j]) \
                - float(np.sum(x[i] * diag * x[j]))
        else:
            pair = float(x[i].sum() * x[j].sum() - np.sum(x[i] * x[j]))
        total += rates[i] * op.selectivity * op.out_bytes * pair
    return float(total)


def device_occupancy(graph: OpGraph, fleet: Fleet, x: np.ndarray) -> np.ndarray:
    """(V,) total processing time each device is occupied for one unit batch
    per source (§3.1: "total time resources are occupied").

    Speeds are *effective* speeds: a RegionFleet device with a ``degrade``
    multiplier occupies proportionally longer — the compute-side twin of how
    its links are priced ``degrade``× slower."""
    x = np.asarray(x, dtype=np.float64)
    rates = graph.cumulative_rates()
    speed = _effective_speed(fleet, x.shape[1])
    occ = np.zeros(x.shape[1])
    for i, op in enumerate(graph.operators):
        occ += op.work * rates[i] * x[i] / speed
    return occ
