"""The paper's primary contribution: the data-quality-aware cost model for
geo-distributed massively parallel streaming analytics (§3), its optimizers,
and its calibration against compiled TPU artifacts."""

from repro.core.costmodel import (
    CostConfig,
    device_occupancy,
    edge_latencies,
    edge_latency,
    enabled_links,
    latency,
    latency_via_paths,
    network_movement,
    objective_F,
)
from repro.core.objectives import (
    OBJECTIVES,
    ObjectiveGrids,
    ObjectiveSet,
    ObjectiveSpec,
    as_objective_set,
)
from repro.core.devices import (ExplicitFleet, RegionFleet, RegionFleetFamily,
                                fleet_from_tpu_mesh)
from repro.core.graph import Operator, OpGraph, diamond_graph, linear_graph, random_dag
from repro.core.jaxmodel import (
    SmoothConfig,
    make_edge_latencies_com_fn,
    make_edge_latencies_region_fn,
    make_latency_com_fn,
    make_latency_fn,
    make_latency_region_fn,
    make_objective_fn,
)
from repro.core.optimizers import (
    DQCoupling,
    OptResult,
    PlacementProblem,
    exhaustive_search,
    greedy_transfer,
    projected_gradient,
    random_search,
    scenario_robust_search,
    simulated_annealing,
)
from repro.core.placement import (
    random_placement,
    uniform_placement,
    validate_placement,
)

__all__ = [
    "CostConfig", "device_occupancy", "edge_latencies", "edge_latency",
    "enabled_links", "latency", "latency_via_paths", "network_movement",
    "objective_F",
    "OBJECTIVES", "ObjectiveGrids", "ObjectiveSet", "ObjectiveSpec",
    "as_objective_set",
    "ExplicitFleet", "RegionFleet", "RegionFleetFamily", "fleet_from_tpu_mesh",
    "Operator", "OpGraph", "diamond_graph", "linear_graph", "random_dag",
    "SmoothConfig", "make_latency_fn", "make_objective_fn",
    "make_edge_latencies_com_fn", "make_latency_com_fn",
    "make_edge_latencies_region_fn", "make_latency_region_fn",
    "DQCoupling", "OptResult", "PlacementProblem", "exhaustive_search",
    "greedy_transfer", "projected_gradient", "random_search",
    "scenario_robust_search", "simulated_annealing", "random_placement",
    "uniform_placement", "validate_placement",
]
