"""Cost-model-driven sharding selection ("operator configuration", paper §1).

For a given (architecture × input shape × chip budget) we enumerate candidate
parallel layouts (DP×TP factorizations, vocab-parallel loss on/off, remat
policy) and score each with the same three-term roofline the dry-run reports,
**pricing each collective on the link class it rides** — the paper's
geo-heterogeneity: DP traffic that crosses the ``pod`` axis pays DCI rates,
TP traffic inside a pod pays ICI rates, and the step's collective term is the
slowest participant's total (the paper's max-over-devices semantics).

The estimates are analytic (bytes from model dims); the dry-run then verifies
the chosen layout by compiling it and re-deriving the terms from real HLO —
estimate vs. compiled comparisons live in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses

from repro.core.devices import DCI_GBPS, ICI_GBPS, HBM_GBPS, PEAK_BF16_TFLOPS

__all__ = ["Layout", "LayoutEstimate", "candidate_layouts", "estimate_layout",
           "choose_layout"]


@dataclasses.dataclass(frozen=True)
class Layout:
    dp: int  # data-parallel ways (including the pod axis)
    tp: int  # tensor/expert-parallel ways
    pods: int = 1
    vocab_parallel_ce: bool = True
    zero_sharded_opt: bool = True  # optimizer state sharded over dp
    remat: str = "full"  # "full" | "dots" | "none"

    @property
    def chips(self) -> int:
        return self.dp * self.tp


@dataclasses.dataclass
class LayoutEstimate:
    layout: Layout
    compute_s: float
    memory_s: float
    ici_collective_s: float
    dci_collective_s: float

    @property
    def collective_s(self) -> float:
        # DP grad sync can overlap across link classes only partially; be
        # conservative: serialize the two classes (slow path dominates).
        return self.ici_collective_s + self.dci_collective_s

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


def candidate_layouts(chips: int, pods: int = 1,
                      max_tp: int = 64) -> list[Layout]:
    outs = []
    tp = 1
    while tp <= min(chips, max_tp):
        if chips % tp == 0:
            dp = chips // tp
            for vp in (True, False):
                for remat in ("full", "dots"):
                    outs.append(Layout(dp=dp, tp=tp, pods=pods,
                                       vocab_parallel_ce=vp, remat=remat))
        tp *= 2
    return outs


def _ring(n: int) -> float:
    return (n - 1) / n if n > 1 else 0.0


def estimate_layout(
    layout: Layout,
    *,
    n_layers: int,
    d_model: int,
    d_ff: int,
    vocab: int,
    seq: int,
    global_batch: int,
    n_params: float,
    moe_experts: int = 0,
    top_k: int = 2,
    train: bool = True,
    param_bytes: float = 4.0,
) -> LayoutEstimate:
    """Analytic roofline terms for one layout (per-device, bf16 activations)."""
    chips = layout.chips
    local_batch = global_batch / layout.dp
    tokens_local = local_batch * seq
    act = 2.0  # bf16 bytes

    # ---- compute (per device) ----
    n_active = n_params
    if moe_experts:
        # only top_k of the experts' FFN params are active per token
        ffn_params = n_layers * 3 * d_model * d_ff * moe_experts
        n_active = n_params - ffn_params + n_layers * 3 * d_model * d_ff * top_k
    flops_per_token = (6.0 if train else 2.0) * n_active
    # attention flops (quadratic term), causal halves it
    attn_flops_per_token = (6.0 if train else 2.0) * 2 * d_model * seq / 2
    remat_factor = {"full": 4.0 / 3.0, "dots": 7.0 / 6.0, "none": 1.0}[layout.remat]
    if not train:
        remat_factor = 1.0
    flops_dev = (flops_per_token + attn_flops_per_token) * tokens_local * remat_factor / layout.tp
    compute_s = flops_dev / (PEAK_BF16_TFLOPS * 1e12)

    # ---- HBM bytes (per device): params read + grads/opt + activations ----
    params_local = n_params * param_bytes / chips if layout.zero_sharded_opt \
        else n_params * param_bytes / layout.tp
    weight_traffic = n_params * param_bytes / layout.tp  # weights streamed per step
    act_traffic = tokens_local * d_model * act * n_layers * 8 / layout.tp
    opt_traffic = (3.0 if train else 0.0) * n_params * param_bytes / chips
    memory_s = (weight_traffic * (3.0 if train else 1.0) + act_traffic + opt_traffic) / (HBM_GBPS * 1e9)

    # ---- collectives per link class ----
    ici = 0.0
    dci = 0.0
    # TP: Megatron fwd+bwd all-reduces per layer: 4 × act bytes over tp (ICI)
    if layout.tp > 1:
        act_bytes = tokens_local * d_model * act
        per_layer = 4.0 * 2.0 * act_bytes * _ring(layout.tp)
        ici += n_layers * per_layer
        if not layout.vocab_parallel_ce:
            # all-gather full logits
            ici += tokens_local * vocab * act * _ring(layout.tp)
    if moe_experts and layout.tp > 1:
        # token dispatch+return all-to-all, fwd+bwd
        a2a = tokens_local * top_k * d_model * act * _ring(layout.tp)
        ici += 4.0 * a2a
    # DP grad reduce-scatter+all-gather: rides ICI within pod, DCI across pods
    if train and layout.dp > 1:
        grad_bytes = n_params * 2.0 / layout.tp  # bf16 grads
        wire = 2.0 * grad_bytes * _ring(layout.dp)
        if layout.pods > 1:
            intra = layout.dp // layout.pods
            # hierarchical: intra-pod reduce (ICI) + inter-pod exchange (DCI)
            ici += 2.0 * grad_bytes * _ring(intra)
            dci += 2.0 * (grad_bytes / max(intra, 1)) * _ring(layout.pods)
        else:
            ici += wire
    if train and layout.zero_sharded_opt and layout.dp > 1:
        # ZeRO-3 parameter all-gathers (fwd + bwd re-gather) over dp
        ici += 2.0 * (n_params * 2.0 / layout.tp) * _ring(layout.dp)
    ici_s = ici / (ICI_GBPS * 1e9)
    dci_s = dci / (DCI_GBPS * 1e9)
    return LayoutEstimate(layout, compute_s, memory_s, ici_s, dci_s)


def choose_layout(chips: int, pods: int = 1, **model_kwargs) -> LayoutEstimate:
    """argmin step-time over candidates; ties broken toward smaller TP
    (less collective surface) — the paper's optimizer role, analytically."""
    best = None
    for layout in candidate_layouts(chips, pods):
        est = estimate_layout(layout, **model_kwargs)
        if best is None or est.step_time_s < best.step_time_s - 1e-12 or (
                abs(est.step_time_s - best.step_time_s) <= 1e-12
                and layout.tp < best.layout.tp):
            best = est
    return best
