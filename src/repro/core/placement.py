"""Placements: the paper's decision variable ``x_{i,u}``.

A placement is an (n_ops, n_devices) row-stochastic matrix — each operator's
tuples are fractionally partitioned across devices (paper's massive
parallelism).  Availability masks (``available_{i,u}``) force zeros; capacity
bounds cap per-device mass (used by the DQ coupling, see optimizers.py).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "validate_placement",
    "random_placement",
    "uniform_placement",
    "project_rows_to_simplex",
    "project_with_caps",
]


def validate_placement(x: np.ndarray, available: np.ndarray | None = None,
                       atol: float = 1e-6) -> None:
    x = np.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"placement must be 2-D (ops, devices), got {x.shape}")
    if (x < -atol).any():
        raise ValueError("placement has negative fractions")
    rows = x.sum(axis=1)
    if not np.allclose(rows, 1.0, atol=atol):
        bad = np.argmax(np.abs(rows - 1.0))
        raise ValueError(f"row {bad} sums to {rows[bad]}, want 1.0")
    if available is not None and (x[~np.asarray(available, dtype=bool)] > atol).any():
        raise ValueError("placement assigns mass to unavailable (op, device) pairs")


def uniform_placement(n_ops: int, available: np.ndarray) -> np.ndarray:
    """Spread each operator evenly over its available devices."""
    a = np.asarray(available, dtype=np.float64)
    if (a.sum(axis=1) == 0).any():
        raise ValueError("some operator has no available device")
    return a / a.sum(axis=1, keepdims=True)


def random_placement(n_ops: int, available: np.ndarray,
                     rng: np.random.Generator, sparsity: float = 0.0) -> np.ndarray:
    """Dirichlet-random rows restricted to available devices.

    sparsity>0 randomly zeroes that fraction of available slots first (keeps
    at least one), producing the sparse placements real deployments use.
    """
    a = np.asarray(available, dtype=bool).copy()
    n_dev = a.shape[1]
    x = np.zeros((n_ops, n_dev))
    for i in range(n_ops):
        idx = np.flatnonzero(a[i])
        if sparsity > 0.0 and idx.size > 1:
            keep = rng.random(idx.size) >= sparsity
            if not keep.any():
                keep[rng.integers(idx.size)] = True
            idx = idx[keep]
        w = rng.gamma(1.0, 1.0, size=idx.size)
        x[i, idx] = w / w.sum()
    return x


def project_rows_to_simplex(x: np.ndarray, available: np.ndarray | None = None) -> np.ndarray:
    """Euclidean projection of each row onto the probability simplex
    (Duchi et al. 2008), respecting the availability mask."""
    x = np.asarray(x, dtype=np.float64).copy()
    n_ops, n_dev = x.shape
    if available is not None:
        x[~np.asarray(available, dtype=bool)] = -np.inf
    out = np.zeros_like(x)
    for i in range(n_ops):
        row = x[i]
        finite = np.isfinite(row)
        v = row[finite]
        u = np.sort(v)[::-1]
        css = np.cumsum(u)
        rho = np.nonzero(u * np.arange(1, v.size + 1) > (css - 1.0))[0][-1]
        theta = (css[rho] - 1.0) / float(rho + 1)
        out[i, finite] = np.maximum(v - theta, 0.0)
    return out


def project_with_caps(x: np.ndarray, caps: np.ndarray,
                      available: np.ndarray | None = None,
                      iters: int = 50) -> np.ndarray:
    """Approximate projection onto {rows on simplex, column mass ≤ caps}.

    Alternating projection (simplex rows ↔ clip column mass); converges to a
    feasible point when one exists (Σcaps ≥ n_ops).  Used by the DQ-coupled
    optimizer where quality checks eat device capacity (DESIGN.md §2).
    """
    caps = np.asarray(caps, dtype=np.float64)
    y = project_rows_to_simplex(x, available)
    for _ in range(iters):
        col = y.sum(axis=0)
        over = col > caps + 1e-9
        if not over.any():
            break
        scale = np.where(over, caps / np.maximum(col, 1e-12), 1.0)
        y = y * scale[None, :]
        y = project_rows_to_simplex(y, available)
    return y
