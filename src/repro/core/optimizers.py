"""Placement / configuration optimizers driven by the paper's cost model.

The associated placement problems are NP-hard mixed ILPs (paper §2.3.2), so —
like every system the paper surveys — we attack them with heuristics:

  * ``exhaustive_search``   — oracle on tiny discretized instances (tests).
  * ``greedy_transfer``     — deterministic local mass-transfer descent.
  * ``simulated_annealing`` — randomized global search.
  * ``projected_gradient``  — beyond-paper: jax.grad through the smoothed
    cost model (logits reparameterization ⇒ rows live on the simplex by
    construction, availability enforced with a −inf mask).
  * ``random_search``       — batched scoring of N random placements
    (the "massive parallelism" of the *optimizer* itself).

All optimizers jointly handle the paper's DQ_fraction: quality checks eat
device capacity via :class:`DQCoupling` (caps(dq) = cap0 − dq·load), which is
how the worked example's "DQ=1 forces fraction x_{2,0} off device 0" story
becomes a mechanical constraint.

The discrete searchers (exhaustive / greedy / annealing / random) live in
:mod:`repro.search` — the batched three-layer search subsystem — and are
re-exported here with their seed signatures; this module keeps the problem
definitions (:class:`PlacementProblem`, :class:`DQCoupling`,
:class:`OptResult`) and the gradient-based :func:`projected_gradient`.  The
imports stay function-local so core remains importable without the search /
sim layers and the package dependency arrow (search → sim → core) stays
one-directional.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostConfig, latency, objective_F
from repro.core.devices import ExplicitFleet, RegionFleet
from repro.core.graph import OpGraph
from repro.core.jaxmodel import SmoothConfig, make_latency_fn
from repro.core.objectives import ObjectiveSet

__all__ = [
    "DQCoupling",
    "PlacementProblem",
    "OptResult",
    "exhaustive_search",
    "greedy_transfer",
    "simulated_annealing",
    "projected_gradient",
    "random_search",
    "scenario_robust_search",
]

Fleet = ExplicitFleet | RegionFleet


@dataclasses.dataclass(frozen=True)
class DQCoupling:
    """Device capacity as a function of DQ_fraction.

    cap_u(dq) = cap0_u − dq·load_u ; constraint: Σ_i x_{i,u} ≤ cap_u(dq).
    With load=0 the DQ knob is free (latency unaffected — then F strictly
    improves with dq and the optimizer pins dq=1, as eq. 8 dictates).
    """

    cap0: np.ndarray
    load: np.ndarray

    def caps(self, dq: float) -> np.ndarray:
        return np.asarray(self.cap0) - float(dq) * np.asarray(self.load)


@dataclasses.dataclass(frozen=True)
class PlacementProblem:
    """One placement instance.  ``objectives=None`` scores paper eq. (8)'s F
    alone; an :class:`repro.core.objectives.ObjectiveSet` makes ``score``
    the weighted multi-objective scalarization through the exact oracles —
    every discrete optimizer below then minimizes it unchanged (the
    projected-gradient path still descends the smoothed-latency surrogate
    and only *snaps* with the full scalarized score)."""

    graph: OpGraph
    fleet: Fleet
    cost_cfg: CostConfig = CostConfig()
    beta: float = 0.0
    dq: DQCoupling | None = None
    objectives: ObjectiveSet | None = None

    def availability(self) -> np.ndarray:
        return self.fleet.availability(self.graph.n_ops)

    def feasible(self, x: np.ndarray, dq: float, atol: float = 1e-7) -> bool:
        if self.dq is None:
            return True
        return bool((x.sum(axis=0) <= self.dq.caps(dq) + atol).all())

    def score(self, x: np.ndarray, dq: float = 0.0) -> float:
        """Exact weighted objective (∞ if infeasible); F when single-objective."""
        if not self.feasible(x, dq):
            return math.inf
        if self.objectives is not None:
            return self.objectives.scalar_total(self.graph, self.fleet, x,
                                                dq, self.beta, self.cost_cfg)
        lat = latency(self.graph, self.fleet, x, self.cost_cfg)
        return objective_F(lat, dq, self.beta)


@dataclasses.dataclass
class OptResult:
    """``evals`` counts logical candidate evaluations (the seed's unit);
    ``dispatches`` counts jitted device dispatches — the batched searchers'
    O(candidates) → O(dispatches) collapse (0 for scalar-loop paths)."""

    x: np.ndarray
    dq_fraction: float
    F: float
    latency: float
    history: list[float]
    evals: int
    dispatches: int = 0

    @classmethod
    def of(cls, prob: PlacementProblem, x: np.ndarray, dq: float,
           history: list[float], evals: int,
           dispatches: int = 0) -> "OptResult":
        """F is the problem's own score: paper eq. (8) single-objective, or
        the weighted scalarization when the problem carries an ObjectiveSet
        (latency stays the raw critical-path latency either way)."""
        lat = latency(prob.graph, prob.fleet, x, prob.cost_cfg)
        f = objective_F(lat, dq, prob.beta) if prob.objectives is None \
            else prob.objectives.scalar_total(prob.graph, prob.fleet, x, dq,
                                              prob.beta, prob.cost_cfg)
        return cls(x=x, dq_fraction=dq, F=f, latency=lat, history=history,
                   evals=evals, dispatches=dispatches)


def _dq_grid(prob: PlacementProblem, steps: int = 5,
             include: tuple[float, ...] = ()) -> list[float]:
    """DQ candidates: {k/steps} when β > 0, else {0} — ALWAYS containing the
    ``include`` values (the search's incumbent dq_fraction, so re-optimizing
    from a previous result can never regress the dq term just because the
    incumbent is not a grid multiple; see repro.search.candidates.dq_grid)."""
    from repro.search.candidates import dq_grid

    return list(dq_grid(prob.beta, steps=steps, include=include))


# -- batched discrete searchers (implementations in repro.search) -------------

def exhaustive_search(prob: PlacementProblem, granularity: int = 4,
                      max_states: int = 2_000_000) -> OptResult:
    """Enumerate placements on the grid x_{i,·} ∈ {k/granularity} — the
    discrete oracle the heuristics are tested against.  Exponential state
    count; scored in chunked batched dispatches by
    :func:`repro.search.searchers.exhaustive_search` (this is a
    signature-preserving re-export)."""
    from repro.search.searchers import exhaustive_search as impl

    return impl(prob, granularity=granularity, max_states=max_states)


def greedy_transfer(prob: PlacementProblem, x0: np.ndarray | None = None,
                    deltas: tuple[float, ...] = (0.4, 0.2, 0.1, 0.05),
                    max_rounds: int = 60) -> OptResult:
    """Move δ mass between device pairs while it improves exact F.

    Deterministic, paper-style bottleneck chasing; each operator's whole
    transfer neighborhood is scored as one batched dispatch by
    :func:`repro.search.searchers.greedy_transfer` (signature-preserving
    re-export).  DQ is co-optimized on a grid at each δ level."""
    from repro.search.searchers import greedy_transfer as impl

    return impl(prob, x0=x0, deltas=deltas, max_rounds=max_rounds)


def simulated_annealing(prob: PlacementProblem, rng: np.random.Generator,
                        steps: int = 4000, t0: float = 0.5, t1: float = 1e-3,
                        x0: np.ndarray | None = None) -> OptResult:
    """Randomized global search (block-batched Metropolis; implementation in
    :func:`repro.search.searchers.simulated_annealing` — signature-preserving
    re-export; ``steps`` still counts proposals)."""
    from repro.search.searchers import simulated_annealing as impl

    return impl(prob, rng, steps=steps, t0=t0, t1=t1, x0=x0)


# -- projected gradient (JAX autodiff through the smoothed model) -------------

def projected_gradient(prob: PlacementProblem, steps: int = 400,
                       lr: float = 0.05, temps: tuple[float, ...] = (0.1, 0.02, 0.005),
                       cap_penalty: float = 50.0, seed: int = 0) -> OptResult:
    """Beyond-paper optimizer: anneal a logsumexp-smoothed F with Adam on
    softmax logits; availability via −inf mask; caps via quadratic penalty;
    DQ via a sigmoid-parameterized scalar.  Final score is the exact model."""
    avail = prob.availability()
    n_ops, n_dev = avail.shape
    mask = jnp.where(jnp.asarray(avail), 0.0, -jnp.inf)
    key = jax.random.PRNGKey(seed)
    z = 0.01 * jax.random.normal(key, (n_ops, n_dev))
    w = jnp.asarray(-1.0)  # dq = sigmoid(w); starts low
    beta = prob.beta
    caps_cfg = prob.dq
    history, evals = [], 0
    dispatches = 0  # jitted grad_fn dispatches (the shim-path counter the
    # search layer reports; a regression test pins it to steps x len(temps))

    def x_of(z):
        return jax.nn.softmax(z + mask, axis=1)

    for temp in temps:
        lat_fn = make_latency_fn(
            prob.graph, prob.fleet,
            SmoothConfig(alpha=prob.cost_cfg.alpha, temp=temp))

        def loss(params):
            z, w = params
            x = x_of(z)
            dq = jax.nn.sigmoid(w) if beta > 0.0 else 0.0
            f = lat_fn(x) / (1.0 + beta * dq)
            if caps_cfg is not None:
                caps = jnp.asarray(caps_cfg.cap0) - dq * jnp.asarray(caps_cfg.load)
                over = jnp.maximum(x.sum(axis=0) - caps, 0.0)
                f = f + cap_penalty * jnp.sum(over ** 2)
            return f

        # each temperature is a DIFFERENT smoothed program; the per-temp
        # compile is intentional and metered by `dispatches` below
        grad_fn = jax.jit(jax.value_and_grad(loss))  # repro: ignore[no-silent-retrace]
        m = (jnp.zeros_like(z), jnp.zeros_like(w))
        v = (jnp.zeros_like(z), jnp.zeros_like(w))
        params = (z, w)
        for t in range(1, steps + 1):
            val, g = grad_fn(params)
            evals += 1
            dispatches += 1
            m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, m, g)
            v = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ * g_, v, g)
            mhat = jax.tree.map(lambda m_: m_ / (1 - 0.9 ** t), m)
            vhat = jax.tree.map(lambda v_: v_ / (1 - 0.999 ** t), v)
            params = jax.tree.map(
                lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
                params, mhat, vhat)
            history.append(float(val))
        z, w = params
    x = np.asarray(x_of(z), dtype=np.float64)
    x = x / x.sum(axis=1, keepdims=True)
    dq_soft = float(jax.nn.sigmoid(w)) if beta > 0.0 else 0.0
    # snap to the best feasible dq on the grid — which always includes the
    # relaxed optimum itself (exact, not rounded)
    best_dq, best_f = 0.0, math.inf
    for dq in _dq_grid(prob, steps=10, include=(dq_soft,)):
        if prob.dq is not None:
            from repro.core.placement import project_with_caps
            xf = project_with_caps(x, prob.dq.caps(dq), avail)
        else:
            xf = x
        f = prob.score(xf, dq)
        evals += 1
        if f < best_f:
            best_f, best_dq, best_x = f, dq, xf
    return OptResult.of(prob, best_x, best_dq, history, evals,
                        dispatches=dispatches)


# -- scenario-robust search (min–max over a generated what-if family) ---------

def scenario_robust_search(graph: OpGraph, scenarios, rng: np.random.Generator,
                           **kwargs) -> OptResult:
    """Placement minimizing WORST-CASE F over a scenario batch.

    Delegator: the implementation lives in
    :func:`repro.sim.replay.scenario_robust_search` (sim builds on core, so
    the import here stays function-local to keep core importable without
    sim and the package dependency arrow one-directional).
    """
    from repro.sim.replay import scenario_robust_search as impl

    return impl(graph, scenarios, rng, **kwargs)


# -- vectorized random search -------------------------------------------------

def random_search(prob: PlacementProblem, rng: np.random.Generator,
                  n_candidates: int = 2048, sparsity: float = 0.5,
                  batch: int = 256) -> OptResult:
    """Score many random placements in chunked batched dispatches
    (:func:`repro.search.searchers.random_search` — signature-preserving
    re-export; multi-objective problems now select on the weighted
    scalarization, where the seed loop selected on latency-F alone).

    Demonstrates that the JAX cost model evaluates thousands of placements
    per second even for large fleets — the scale knob of the paper's title.
    """
    from repro.search.searchers import random_search as impl

    return impl(prob, rng, n_candidates=n_candidates, sparsity=sparsity,
                batch=batch)
