"""Placement / configuration optimizers driven by the paper's cost model.

The associated placement problems are NP-hard mixed ILPs (paper §2.3.2), so —
like every system the paper surveys — we attack them with heuristics:

  * ``exhaustive_search``   — oracle on tiny discretized instances (tests).
  * ``greedy_transfer``     — deterministic local mass-transfer descent.
  * ``simulated_annealing`` — randomized global search.
  * ``projected_gradient``  — beyond-paper: jax.grad through the smoothed
    cost model (logits reparameterization ⇒ rows live on the simplex by
    construction, availability enforced with a −inf mask).
  * ``random_search``       — vmap-vectorized scoring of N random placements
    (the "massive parallelism" of the *optimizer* itself).

All optimizers jointly handle the paper's DQ_fraction: quality checks eat
device capacity via :class:`DQCoupling` (caps(dq) = cap0 − dq·load), which is
how the worked example's "DQ=1 forces fraction x_{2,0} off device 0" story
becomes a mechanical constraint.
"""

from __future__ import annotations

import dataclasses
import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostConfig, latency, objective_F
from repro.core.devices import ExplicitFleet, RegionFleet
from repro.core.graph import OpGraph
from repro.core.jaxmodel import SmoothConfig, make_latency_fn
from repro.core.objectives import ObjectiveSet
from repro.core.placement import random_placement, uniform_placement

__all__ = [
    "DQCoupling",
    "PlacementProblem",
    "OptResult",
    "exhaustive_search",
    "greedy_transfer",
    "simulated_annealing",
    "projected_gradient",
    "random_search",
    "scenario_robust_search",
]

Fleet = ExplicitFleet | RegionFleet


@dataclasses.dataclass(frozen=True)
class DQCoupling:
    """Device capacity as a function of DQ_fraction.

    cap_u(dq) = cap0_u − dq·load_u ; constraint: Σ_i x_{i,u} ≤ cap_u(dq).
    With load=0 the DQ knob is free (latency unaffected — then F strictly
    improves with dq and the optimizer pins dq=1, as eq. 8 dictates).
    """

    cap0: np.ndarray
    load: np.ndarray

    def caps(self, dq: float) -> np.ndarray:
        return np.asarray(self.cap0) - float(dq) * np.asarray(self.load)


@dataclasses.dataclass(frozen=True)
class PlacementProblem:
    """One placement instance.  ``objectives=None`` scores paper eq. (8)'s F
    alone; an :class:`repro.core.objectives.ObjectiveSet` makes ``score``
    the weighted multi-objective scalarization through the exact oracles —
    every discrete optimizer below then minimizes it unchanged (the
    projected-gradient path still descends the smoothed-latency surrogate
    and only *snaps* with the full scalarized score)."""

    graph: OpGraph
    fleet: Fleet
    cost_cfg: CostConfig = CostConfig()
    beta: float = 0.0
    dq: DQCoupling | None = None
    objectives: ObjectiveSet | None = None

    def availability(self) -> np.ndarray:
        return self.fleet.availability(self.graph.n_ops)

    def feasible(self, x: np.ndarray, dq: float, atol: float = 1e-7) -> bool:
        if self.dq is None:
            return True
        return bool((x.sum(axis=0) <= self.dq.caps(dq) + atol).all())

    def score(self, x: np.ndarray, dq: float = 0.0) -> float:
        """Exact weighted objective (∞ if infeasible); F when single-objective."""
        if not self.feasible(x, dq):
            return math.inf
        if self.objectives is not None:
            return self.objectives.scalar_total(self.graph, self.fleet, x,
                                                dq, self.beta, self.cost_cfg)
        lat = latency(self.graph, self.fleet, x, self.cost_cfg)
        return objective_F(lat, dq, self.beta)


@dataclasses.dataclass
class OptResult:
    x: np.ndarray
    dq_fraction: float
    F: float
    latency: float
    history: list[float]
    evals: int

    @classmethod
    def of(cls, prob: PlacementProblem, x: np.ndarray, dq: float,
           history: list[float], evals: int) -> "OptResult":
        """F is the problem's own score: paper eq. (8) single-objective, or
        the weighted scalarization when the problem carries an ObjectiveSet
        (latency stays the raw critical-path latency either way)."""
        lat = latency(prob.graph, prob.fleet, x, prob.cost_cfg)
        f = objective_F(lat, dq, prob.beta) if prob.objectives is None \
            else prob.objectives.scalar_total(prob.graph, prob.fleet, x, dq,
                                              prob.beta, prob.cost_cfg)
        return cls(x=x, dq_fraction=dq, F=f,
                   latency=lat, history=history, evals=evals)


def _dq_grid(prob: PlacementProblem, steps: int = 5):
    return [0.0] if prob.beta == 0.0 else list(np.linspace(0.0, 1.0, steps + 1))


# -- exhaustive oracle --------------------------------------------------------

def _compositions(total: int, parts: int):
    """All ways to write ``total`` as an ordered sum of ``parts`` ≥0 ints."""
    if parts == 1:
        yield (total,)
        return
    for head in range(total + 1):
        for tail in _compositions(total - head, parts - 1):
            yield (head,) + tail


def exhaustive_search(prob: PlacementProblem, granularity: int = 4,
                      max_states: int = 2_000_000) -> OptResult:
    """Enumerate placements on the grid x_{i,·} ∈ {k/granularity} — the
    discrete oracle the heuristics are tested against.  Exponential."""
    avail = prob.availability()
    n_ops, n_dev = avail.shape
    per_op_choices: list[list[np.ndarray]] = []
    for i in range(n_ops):
        idx = np.flatnonzero(avail[i])
        rows = []
        for comp in _compositions(granularity, idx.size):
            row = np.zeros(n_dev)
            row[idx] = np.asarray(comp) / granularity
            rows.append(row)
        per_op_choices.append(rows)
    n_states = math.prod(len(c) for c in per_op_choices)
    if n_states > max_states:
        raise ValueError(f"search space {n_states} exceeds max_states={max_states}")
    best_F, best_x, best_dq, evals = math.inf, None, 0.0, 0
    dqs = _dq_grid(prob)
    for rows in itertools.product(*per_op_choices):
        x = np.stack(rows)
        for dq in dqs:
            evals += 1
            f = prob.score(x, dq)
            if f < best_F:
                best_F, best_x, best_dq = f, x, dq
    return OptResult.of(prob, best_x, best_dq, [best_F], evals)


# -- greedy local descent -----------------------------------------------------

def greedy_transfer(prob: PlacementProblem, x0: np.ndarray | None = None,
                    deltas: tuple[float, ...] = (0.4, 0.2, 0.1, 0.05),
                    max_rounds: int = 60) -> OptResult:
    """Move δ mass between device pairs while it improves exact F.

    Deterministic, paper-style bottleneck chasing: for every operator try all
    (src→dst) transfers of the current δ; take the best; shrink δ when no
    move helps.  DQ is co-optimized on a grid at each δ level.
    """
    avail = prob.availability()
    n_ops, n_dev = avail.shape
    x = uniform_placement(n_ops, avail) if x0 is None else x0.copy()
    dq = 0.0
    # start from a feasible point under the tightest relevant caps
    if prob.dq is not None:
        from repro.core.placement import project_with_caps
        x = project_with_caps(x, prob.dq.caps(dq), avail)
    best = prob.score(x, dq)
    history, evals = [best], 1
    for delta in deltas:
        for _ in range(max_rounds):
            improved = False
            for dq_cand in _dq_grid(prob):
                f = prob.score(x, dq_cand)
                evals += 1
                if f < best - 1e-12:
                    best, dq, improved = f, dq_cand, True
            for i in range(n_ops):
                idx = np.flatnonzero(avail[i])
                best_move, best_f = None, best
                for u in idx:
                    if x[i, u] < delta - 1e-12:
                        continue
                    for v in idx:
                        if v == u:
                            continue
                        x[i, u] -= delta
                        x[i, v] += delta
                        f = prob.score(x, dq)
                        evals += 1
                        x[i, u] += delta
                        x[i, v] -= delta
                        if f < best_f - 1e-12:
                            best_f, best_move = f, (u, v)
                if best_move is not None:
                    u, v = best_move
                    x[i, u] -= delta
                    x[i, v] += delta
                    best = best_f
                    improved = True
            history.append(best)
            if not improved:
                break
    return OptResult.of(prob, x, dq, history, evals)


# -- simulated annealing ------------------------------------------------------

def simulated_annealing(prob: PlacementProblem, rng: np.random.Generator,
                        steps: int = 4000, t0: float = 0.5, t1: float = 1e-3,
                        x0: np.ndarray | None = None) -> OptResult:
    avail = prob.availability()
    n_ops, n_dev = avail.shape
    x = random_placement(n_ops, avail, rng) if x0 is None else x0.copy()
    dq = 0.0
    if prob.dq is not None:
        from repro.core.placement import project_with_caps
        x = project_with_caps(x, prob.dq.caps(dq), avail)
    cur = prob.score(x, dq)
    best, best_x, best_dq = cur, x.copy(), dq
    history, evals = [cur], 1
    for step in range(steps):
        t = t0 * (t1 / t0) ** (step / max(steps - 1, 1))
        y, ndq = x.copy(), dq
        if prob.beta > 0.0 and rng.random() < 0.15:
            ndq = float(np.clip(dq + rng.choice([-0.2, -0.1, 0.1, 0.2]), 0.0, 1.0))
        else:
            i = rng.integers(n_ops)
            idx = np.flatnonzero(avail[i])
            if idx.size >= 2:
                u, v = rng.choice(idx, size=2, replace=False)
                amt = rng.uniform(0.0, x[i, u])
                y[i, u] -= amt
                y[i, v] += amt
        f = prob.score(y, ndq)
        evals += 1
        if math.isfinite(f) and (f < cur or rng.random() < math.exp(-(f - cur) / max(t, 1e-9))):
            x, dq, cur = y, ndq, f
            if cur < best:
                best, best_x, best_dq = cur, x.copy(), dq
        history.append(best)
    return OptResult.of(prob, best_x, best_dq, history, evals)


# -- projected gradient (JAX autodiff through the smoothed model) -------------

def projected_gradient(prob: PlacementProblem, steps: int = 400,
                       lr: float = 0.05, temps: tuple[float, ...] = (0.1, 0.02, 0.005),
                       cap_penalty: float = 50.0, seed: int = 0) -> OptResult:
    """Beyond-paper optimizer: anneal a logsumexp-smoothed F with Adam on
    softmax logits; availability via −inf mask; caps via quadratic penalty;
    DQ via a sigmoid-parameterized scalar.  Final score is the exact model."""
    avail = prob.availability()
    n_ops, n_dev = avail.shape
    mask = jnp.where(jnp.asarray(avail), 0.0, -jnp.inf)
    key = jax.random.PRNGKey(seed)
    z = 0.01 * jax.random.normal(key, (n_ops, n_dev))
    w = jnp.asarray(-1.0)  # dq = sigmoid(w); starts low
    beta = prob.beta
    caps_cfg = prob.dq
    history, evals = [], 0

    def x_of(z):
        return jax.nn.softmax(z + mask, axis=1)

    for temp in temps:
        lat_fn = make_latency_fn(
            prob.graph, prob.fleet,
            SmoothConfig(alpha=prob.cost_cfg.alpha, temp=temp))

        def loss(params):
            z, w = params
            x = x_of(z)
            dq = jax.nn.sigmoid(w) if beta > 0.0 else 0.0
            f = lat_fn(x) / (1.0 + beta * dq)
            if caps_cfg is not None:
                caps = jnp.asarray(caps_cfg.cap0) - dq * jnp.asarray(caps_cfg.load)
                over = jnp.maximum(x.sum(axis=0) - caps, 0.0)
                f = f + cap_penalty * jnp.sum(over ** 2)
            return f

        grad_fn = jax.jit(jax.value_and_grad(loss))
        m = (jnp.zeros_like(z), jnp.zeros_like(w))
        v = (jnp.zeros_like(z), jnp.zeros_like(w))
        params = (z, w)
        for t in range(1, steps + 1):
            val, g = grad_fn(params)
            evals += 1
            m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, m, g)
            v = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ * g_, v, g)
            mhat = jax.tree.map(lambda m_: m_ / (1 - 0.9 ** t), m)
            vhat = jax.tree.map(lambda v_: v_ / (1 - 0.999 ** t), v)
            params = jax.tree.map(
                lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
                params, mhat, vhat)
            history.append(float(val))
        z, w = params
    x = np.asarray(x_of(z), dtype=np.float64)
    x = x / x.sum(axis=1, keepdims=True)
    dq_candidates = _dq_grid(prob, steps=10)
    dq_soft = float(jax.nn.sigmoid(w)) if beta > 0.0 else 0.0
    # snap to the best feasible dq near the relaxed optimum
    best_dq, best_f = 0.0, math.inf
    for dq in sorted(set(dq_candidates + [round(dq_soft, 2)])):
        if prob.dq is not None:
            from repro.core.placement import project_with_caps
            xf = project_with_caps(x, prob.dq.caps(dq), avail)
        else:
            xf = x
        f = prob.score(xf, dq)
        evals += 1
        if f < best_f:
            best_f, best_dq, best_x = f, dq, xf
    return OptResult.of(prob, best_x, best_dq, history, evals)


# -- scenario-robust search (min–max over a generated what-if family) ---------

def scenario_robust_search(graph: OpGraph, scenarios, rng: np.random.Generator,
                           **kwargs) -> OptResult:
    """Placement minimizing WORST-CASE F over a scenario batch.

    Delegator: the implementation lives in
    :func:`repro.sim.replay.scenario_robust_search` (sim builds on core, so
    the import here stays function-local to keep core importable without
    sim and the package dependency arrow one-directional).
    """
    from repro.sim.replay import scenario_robust_search as impl

    return impl(graph, scenarios, rng, **kwargs)


# -- vectorized random search -------------------------------------------------

def random_search(prob: PlacementProblem, rng: np.random.Generator,
                  n_candidates: int = 2048, sparsity: float = 0.5,
                  batch: int = 256) -> OptResult:
    """Score many random placements with a vmapped hard-max latency fn.

    Demonstrates that the JAX cost model evaluates thousands of placements
    per second even for large fleets — the scale knob of the paper's title.
    """
    avail = prob.availability()
    n_ops, _ = avail.shape
    lat_fn = make_latency_fn(prob.graph, prob.fleet,
                             SmoothConfig(alpha=prob.cost_cfg.alpha, temp=0.0))
    batched = jax.jit(jax.vmap(lat_fn))
    best_F, best_x, best_dq, evals = math.inf, None, 0.0, 0
    dqs = _dq_grid(prob)
    history = []
    # seed with the uniform placement — never return something worse
    uni = uniform_placement(n_ops, avail)
    for dq in dqs:
        f = prob.score(uni, dq)
        evals += 1
        if f < best_F:
            best_F, best_x, best_dq = f, uni, dq
    done = 0
    while done < n_candidates:
        b = min(batch, n_candidates - done)
        xs = np.stack([random_placement(n_ops, avail, rng, sparsity) for _ in range(b)])
        lats = np.asarray(batched(jnp.asarray(xs)))
        for k in range(b):
            for dq in dqs:
                evals += 1
                if not prob.feasible(xs[k], dq):
                    continue
                f = objective_F(float(lats[k]), dq, prob.beta)
                if f < best_F:
                    best_F, best_x, best_dq = f, xs[k], dq
        history.append(best_F)
        done += b
    if best_x is None:  # all infeasible — fall back to uniform
        best_x = uniform_placement(n_ops, avail)
        best_dq = 0.0
    return OptResult.of(prob, best_x, best_dq, history, evals)
