"""Unified multi-objective cost layer (paper §3.1).

The paper's objectives beyond latency — network movement and device
occupancy — compose "trivially through simple sum functions".  Historically
each objective in this repo was hand-kept in up to three twins (scalar numpy
oracle, dense com-traced jnp, structured segment-sum); this module makes the
triple a *spec*: one :class:`ObjectiveSpec` per objective exposing

  * ``scalar``            — the float64 numpy oracle (tests / exact rescoring),
  * ``build_dense``       — a jnp twin over a traced dense ``(V, V)`` com
    matrix: ``f(x, com, speed) -> raw``,
  * ``build_structured``  — a jnp twin over RegionFleetFamily state:
    ``f(x, inter, degrade, speed) -> raw`` (never materializes ``(V, V)``),
  * ``finish``            — the post-map normalization ``(raw, dq, beta) ->
    value`` (only latency-F uses it: paper eq. 8's ``/(1 + β·dq)``), applied
    OUTSIDE the scenario ``lax.map`` so per-scenario dq broadcasts over the
    whole (S, P) grid.

An :class:`ObjectiveSet` bundles specs with scalarization weights; the
batched evaluator (``repro.sim.batched.BatchedEvaluator.score_grid``)
consumes it to return every objective's (S, P) grid plus the weighted
scalarization in ONE jitted dispatch, and the discrete optimizers
(``PlacementProblem.score``, ``robust_placement``,
``scenario_robust_search``) score the same weighted sum through the scalar
oracles — so min–max robust search can trade worst-case F against WAN bytes
moved or device occupancy with one knob.

Objective registry (weights are the caller's unit exchange rates — the
objectives are NOT normalized to a common scale here;
``repro.search.decision.ObjectiveScales`` fits per-objective scales from a
sampled grid when dimensionless weights are wanted, and
``repro.search.decision.pareto_front`` extracts the non-dominated set the
per-objective grids already hold):

  ``latency_f``             paper eq. 8: critical-path latency / (1 + β·dq)
  ``network_movement``      §3.1 [26]: Σ_edges rate·s·bytes·Σ_{u≠v} x_iu·x_jv
  ``network_movement_cost`` the same sum, each (u, v) pair weighted by
                            comCost_{u,v} (WAN bytes priced by link cost)
  ``occupancy_max``         max_u of §3.1 device occupancy (bottleneck box)
  ``occupancy_imbalance``   max_u − mean_u occupancy (load skew, 0 ⇒ even)

Structured network movement collapses to a degrade-weighted region-mass
quadratic form — ``mᵀ·inter·m`` with ``m_r = Σ_{v∈r} degrade_v·x_v`` minus
the u == v diagonal — O(R² + V) per edge, mirroring ``_com_times_x``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import (CostConfig, device_occupancy, latency,
                                  network_movement, objective_F)
from repro.core.devices import ExplicitFleet, RegionFleet
from repro.core.graph import OpGraph
from repro.core.jaxmodel import (SmoothConfig, _edge_arrays,
                                 make_latency_com_fn, make_latency_region_fn)

__all__ = [
    "ObjectiveSpec",
    "ObjectiveSet",
    "ObjectiveGrids",
    "OBJECTIVES",
    "as_objective_set",
]

Fleet = ExplicitFleet | RegionFleet


# -- static per-graph vectors shared by the twins -----------------------------

def _edge_movement_weights(graph: OpGraph) -> np.ndarray:
    """(E,) rate_i·s_i·bytes_i for every edge (i → j) — the §3.1 movement
    weight of one unit of (u ≠ v) placement mass product."""
    rates = graph.cumulative_rates()
    return np.array([rates[i] * graph.operators[i].selectivity
                     * graph.operators[i].out_bytes
                     for i, _ in graph.edges], dtype=np.float64)


def _op_loads(graph: OpGraph) -> np.ndarray:
    """(n_ops,) work_i·rate_i — occupancy seconds per unit placement mass
    at unit speed."""
    rates = graph.cumulative_rates()
    return np.array([op.work * rates[i]
                     for i, op in enumerate(graph.operators)],
                    dtype=np.float64)


def _smooth_cfg(cfg: CostConfig) -> SmoothConfig:
    return SmoothConfig(alpha=cfg.alpha)


# -- latency-F ----------------------------------------------------------------

def _scalar_latency_f(graph, fleet, x, dq, beta, cfg):
    return objective_F(latency(graph, fleet, x, cfg), dq, beta)


def _dense_latency_f(graph: OpGraph, cfg: CostConfig):
    lat = make_latency_com_fn(graph, _smooth_cfg(cfg), nz_eps=cfg.nz_eps)

    def f(x, com, speed):
        return lat(x, com)

    return f


def _structured_latency_f(graph, region, n_regions, self_cost, cfg):
    lat = make_latency_region_fn(graph, region, n_regions, self_cost,
                                 _smooth_cfg(cfg), nz_eps=cfg.nz_eps)

    def f(x, inter, degrade, speed):
        return lat(x, inter, degrade)

    return f


def _finish_latency_f(raw, dq, beta):
    """Paper eq. 8 applied grid-wide: dq broadcasts (scalar or (S, 1))."""
    return raw / (1.0 + beta * dq)


# -- network movement ---------------------------------------------------------

def _make_scalar_movement(weighted: bool):
    def scalar(graph, fleet, x, dq, beta, cfg):
        return network_movement(graph, fleet, x, weight_by_cost=weighted)

    return scalar


def _make_dense_movement(weighted: bool):
    def build(graph: OpGraph, cfg: CostConfig):
        src, dst, _ = _edge_arrays(graph)
        src_j, dst_j = jnp.asarray(src), jnp.asarray(dst)
        w = jnp.asarray(_edge_movement_weights(graph))

        def f(x, com, speed):
            if not weighted:
                tot = x.sum(1)                                 # (n_ops,)
                pair = tot[src_j] * tot[dst_j] \
                    - (x[src_j] * x[dst_j]).sum(1)
                return w.astype(x.dtype) @ pair
            # price each OPERATOR's inbound transfer once (n·V² instead of
            # E·V²), then gather per edge
            op_t = x @ com.T.astype(x.dtype)                   # (n_ops, V)
            diag = jnp.diagonal(com).astype(x.dtype)
            x_i = x[src_j]                                     # (E, V)
            pair = (x_i * op_t[dst_j]).sum(1) \
                - (x_i * diag[None, :] * x[dst_j]).sum(1)
            return w.astype(x.dtype) @ pair

        return f

    return build


def _make_structured_movement(weighted: bool):
    def build(graph, region, n_regions, self_cost, cfg):
        src, dst, _ = _edge_arrays(graph)
        src_j, dst_j = jnp.asarray(src), jnp.asarray(dst)
        w = jnp.asarray(_edge_movement_weights(graph))
        region_ix = jnp.asarray(np.asarray(region, dtype=np.int64))
        n_ops = graph.n_ops

        def f(x, inter, degrade, speed):
            if not weighted:
                tot = x.sum(1)                                 # (n_ops,)
                pair = tot[src_j] * tot[dst_j] \
                    - (x[src_j] * x[dst_j]).sum(1)
                return w.astype(x.dtype) @ pair
            # Σ_{u≠v} d_u·d_v·inter[r_u,r_v]·x_iu·x_jv as a degrade-weighted
            # region-mass quadratic form minus the u == v diagonal — the
            # bilinear twin of _com_times_x's matvec, O(R² + V) per edge
            # with the (n_ops, R) masses segment-summed ONCE per placement
            d = degrade.astype(x.dtype)
            mass = jnp.zeros((n_ops, n_regions), x.dtype)
            mass = mass.at[:, region_ix].add(d[None, :] * x)   # (n_ops, R)
            quad = jnp.einsum("er,rq,eq->e", mass[src_j],
                              inter.astype(x.dtype), mass[dst_j])
            diag = (d * d * jnp.diagonal(inter).astype(x.dtype)[region_ix])
            pair = quad - (x[src_j] * diag[None, :] * x[dst_j]).sum(1)
            return w.astype(x.dtype) @ pair

        return f

    return build


# -- device occupancy ---------------------------------------------------------

def _make_scalar_occupancy(reduce: str):
    def scalar(graph, fleet, x, dq, beta, cfg):
        occ = device_occupancy(graph, fleet, x)
        if reduce == "max":
            return float(occ.max(initial=0.0))
        return float(occ.max(initial=0.0) - (occ.mean() if occ.size else 0.0))

    return scalar


def _occ_reduce(occ: jnp.ndarray, reduce: str) -> jnp.ndarray:
    if reduce == "max":
        return jnp.max(occ)
    return jnp.max(occ) - jnp.mean(occ)


def _make_dense_occupancy(reduce: str):
    def build(graph: OpGraph, cfg: CostConfig):
        wk = jnp.asarray(_op_loads(graph))

        def f(x, com, speed):
            occ = (wk.astype(x.dtype)[:, None] * x).sum(0) \
                / speed.astype(x.dtype)
            return _occ_reduce(occ, reduce)

        return f

    return build


def _make_structured_occupancy(reduce: str):
    def build(graph, region, n_regions, self_cost, cfg):
        wk = jnp.asarray(_op_loads(graph))

        def f(x, inter, degrade, speed):
            # effective speed = speed / degrade (a straggler's compute slows
            # by the same multiplier that prices its links) — degrade is the
            # traced per-scenario operand, speed the nominal vector
            occ = (wk.astype(x.dtype)[:, None] * x).sum(0) \
                * degrade.astype(x.dtype) / speed.astype(x.dtype)
            return _occ_reduce(occ, reduce)

        return f

    return build


# -- the spec and its registry ------------------------------------------------

def _finish_identity(raw, dq, beta):
    return raw


@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    """One §3.1 objective, all representations in one place.

    ``scalar(graph, fleet, x, dq, beta, cfg) -> float`` returns the FINISHED
    value (dq/beta applied where relevant); the batched builders return the
    raw per-instance value and ``finish(raw, dq, beta)`` is applied outside
    the scenario map (dq arrives (S, 1), broadcasting over the (S, P) grid).
    """

    name: str
    scalar: Callable
    build_dense: Callable      # (graph, cfg) -> f(x, com, speed) -> raw
    build_structured: Callable  # (graph, region, R, self_cost, cfg) -> f(x, inter, degrade, speed) -> raw
    finish: Callable = _finish_identity


OBJECTIVES: dict[str, ObjectiveSpec] = {
    spec.name: spec
    for spec in (
        ObjectiveSpec(
            name="latency_f",
            scalar=_scalar_latency_f,
            build_dense=_dense_latency_f,
            build_structured=_structured_latency_f,
            finish=_finish_latency_f,
        ),
        ObjectiveSpec(
            name="network_movement",
            scalar=_make_scalar_movement(False),
            build_dense=_make_dense_movement(False),
            build_structured=_make_structured_movement(False),
        ),
        ObjectiveSpec(
            name="network_movement_cost",
            scalar=_make_scalar_movement(True),
            build_dense=_make_dense_movement(True),
            build_structured=_make_structured_movement(True),
        ),
        ObjectiveSpec(
            name="occupancy_max",
            scalar=_make_scalar_occupancy("max"),
            build_dense=_make_dense_occupancy("max"),
            build_structured=_make_structured_occupancy("max"),
        ),
        ObjectiveSpec(
            name="occupancy_imbalance",
            scalar=_make_scalar_occupancy("imbalance"),
            build_dense=_make_dense_occupancy("imbalance"),
            build_structured=_make_structured_occupancy("imbalance"),
        ),
    )
}


@dataclasses.dataclass(frozen=True)
class ObjectiveSet:
    """Objectives plus scalarization weights — the multi-objective knob.

    Hashable (the batched evaluator caches one jitted grid function per
    set).  Weights are exchange rates between objective units, NOT a convex
    combination: ``scalarized = Σ_k w_k · objective_k``.
    """

    specs: tuple[ObjectiveSpec, ...]
    weights: tuple[float, ...]

    def __post_init__(self):
        if len(self.specs) != len(self.weights):
            raise ValueError(
                f"{len(self.specs)} objectives but {len(self.weights)} weights")
        if not self.specs:
            raise ValueError("ObjectiveSet needs at least one objective")
        names = [s.name for s in self.specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate objectives: {names}")

    @classmethod
    def of(cls, *objectives: str | ObjectiveSpec,
           weights: Iterable[float] | None = None) -> "ObjectiveSet":
        """``ObjectiveSet.of("latency_f", "network_movement")`` — names
        resolve through :data:`OBJECTIVES`; weights default to all-ones."""
        specs = tuple(o if isinstance(o, ObjectiveSpec) else _lookup(o)
                      for o in objectives)
        w = tuple(1.0 for _ in specs) if weights is None \
            else tuple(float(v) for v in weights)
        return cls(specs=specs, weights=w)

    @classmethod
    def from_weights(cls, **name_weights: float) -> "ObjectiveSet":
        """``ObjectiveSet.from_weights(latency_f=1.0, network_movement=0.01)``."""
        return cls.of(*name_weights.keys(),
                      weights=tuple(name_weights.values()))

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(s.name for s in self.specs)

    # -- scalar (float64 oracle) path ----------------------------------------
    def scalar_values(self, graph: OpGraph, fleet: Fleet, x: np.ndarray,
                      dq: float = 0.0, beta: float = 0.0,
                      cfg: CostConfig = CostConfig()) -> dict[str, float]:
        """Every objective's exact value for one placement on one fleet."""
        return {s.name: float(s.scalar(graph, fleet, x, dq, beta, cfg))
                for s in self.specs}

    def scalar_total(self, graph: OpGraph, fleet: Fleet, x: np.ndarray,
                     dq: float = 0.0, beta: float = 0.0,
                     cfg: CostConfig = CostConfig()) -> float:
        """The weighted scalarization through the exact oracles — what
        ``PlacementProblem.score`` minimizes and min–max robust search
        re-scores winners with."""
        vals = self.scalar_values(graph, fleet, x, dq, beta, cfg)
        return float(sum(w * vals[s.name]
                         for s, w in zip(self.specs, self.weights)))


def _lookup(name: str) -> ObjectiveSpec:
    try:
        return OBJECTIVES[name]
    except KeyError:
        raise ValueError(f"unknown objective {name!r}; "
                         f"choose from {sorted(OBJECTIVES)}") from None


def as_objective_set(objectives) -> ObjectiveSet:
    """Coerce user input — an ObjectiveSet, one name/spec, or a sequence of
    names/specs (unit weights) — into an ObjectiveSet."""
    if isinstance(objectives, ObjectiveSet):
        return objectives
    if isinstance(objectives, (str, ObjectiveSpec)):
        return ObjectiveSet.of(objectives)
    return ObjectiveSet.of(*objectives)


@dataclasses.dataclass
class ObjectiveGrids:
    """score_grid's multi-objective result: per-objective (S, P) grids and
    their weighted scalarization, all from ONE jitted dispatch."""

    names: tuple[str, ...]
    grids: dict[str, jax.Array]
    scalarized: jax.Array
    weights: tuple[float, ...]

    def __getitem__(self, name: str) -> jax.Array:
        return self.grids[name]
