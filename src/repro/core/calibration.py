"""Calibrating the cost model from compiled artifacts (DESIGN.md §2).

The paper obtains operator/link metadata by profiling; on TPU we get the same
inputs *statically*: collective traffic from post-SPMD HLO, per-stage compute
from ``cost_analysis()``, link costs from the mesh topology.  The functions
here turn a dry-run artifact into cost-model inputs so placement decisions
price the topology the compiler actually emitted.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.devices import DCI_GBPS, ICI_GBPS, RegionFleet, fleet_from_tpu_mesh
from repro.core.graph import Operator, OpGraph
from repro.perf.hlo import CollectiveStats, parse_collectives

__all__ = ["CalibratedCosts", "calibrate_from_hlo", "stage_graph_for_lm"]


@dataclasses.dataclass
class CalibratedCosts:
    """comCost units: seconds per byte; work units: flop."""

    fleet: RegionFleet
    collectives: CollectiveStats
    bytes_per_step: float  # per-device collective wire bytes
    flops_per_step: float  # per-device HLO flops

    def step_comm_seconds(self, link_gbps: float = ICI_GBPS) -> float:
        return self.bytes_per_step / (link_gbps * 1e9)


def calibrate_from_hlo(hlo_text: str, flops_per_device: float,
                       n_pods: int = 1, chips_per_pod: int = 256) -> CalibratedCosts:
    stats = parse_collectives(hlo_text)
    fleet = fleet_from_tpu_mesh(n_pods=n_pods, chips_per_pod=chips_per_pod,
                                unit_bytes=1.0)
    return CalibratedCosts(
        fleet=fleet,
        collectives=stats,
        bytes_per_step=stats.total_wire_bytes,
        flops_per_step=flops_per_device,
    )


def stage_graph_for_lm(n_layers: int, d_model: int, d_ff: int, vocab: int,
                       seq: int, batch: int, moe_experts: int = 0,
                       top_k: int = 2) -> OpGraph:
    """The train-step dataflow as a paper OpGraph.

    Operators are stages (embed → L×block → head → loss → backward echo);
    selectivity is the bytes-amplification between stages — this is the graph
    auto-sharding scores candidate placements against.  Tuple unit = one
    token's activation row (d_model × 2 bytes bf16).
    """
    tok_bytes = 2.0 * d_model
    ops = [Operator("source", selectivity=1.0, out_bytes=4.0)]  # token ids
    ops.append(Operator("embed", selectivity=1.0, out_bytes=tok_bytes))
    edges = [(0, 1)]
    prev = 1
    for l in range(n_layers):
        amp = 1.0
        if moe_experts:
            # top-k dispatch duplicates tokens k× on the expert axis
            amp = float(top_k)
        ops.append(Operator(f"block{l}", selectivity=amp, out_bytes=tok_bytes,
                            work=1.0))
        edges.append((prev, len(ops) - 1))
        prev = len(ops) - 1
    ops.append(Operator("head", selectivity=vocab / d_model,
                        out_bytes=2.0 * vocab, work=1.0))
    edges.append((prev, len(ops) - 1))
    ops.append(Operator("loss", selectivity=1.0 / vocab, out_bytes=4.0))
    edges.append((len(ops) - 2, len(ops) - 1))
    return OpGraph(ops, edges)
