"""Calibrating the cost model from compiled artifacts (DESIGN.md §2) and —
closing the loop — from OBSERVED replay behavior.

The paper obtains operator/link metadata by profiling; on TPU we get the same
inputs *statically*: collective traffic from post-SPMD HLO, per-stage compute
from ``cost_analysis()``, link costs from the mesh topology.  The functions
here turn a dry-run artifact into cost-model inputs so placement decisions
price the topology the compiler actually emitted.

:func:`refit_from_replay` is the dynamic counterpart: given a window of
replay observations (per-tick rates, per-device busy seconds, an end-to-end
latency signal) it re-fits the *believed* fleet — per-device slowdown
multipliers from the busy series (the §3.1 occupancy model run backwards)
and a global com-cost scale from the latency ratio — so a controller
(:mod:`repro.adapt`) can re-optimize placement against a model that tracks
the drifted world again.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costmodel import CostConfig, latency
from repro.core.devices import (DCI_GBPS, ICI_GBPS, ExplicitFleet,
                                RegionFleet, fleet_from_tpu_mesh)
from repro.core.graph import Operator, OpGraph
from repro.perf.hlo import CollectiveStats, parse_collectives

__all__ = ["CalibratedCosts", "calibrate_from_hlo", "stage_graph_for_lm",
           "ReplayWindow", "ReplayRefit", "fit_work_unit",
           "normalized_drift", "refit_from_replay"]


@dataclasses.dataclass
class CalibratedCosts:
    """comCost units: seconds per byte; work units: flop."""

    fleet: RegionFleet
    collectives: CollectiveStats
    bytes_per_step: float  # per-device collective wire bytes
    flops_per_step: float  # per-device HLO flops

    def step_comm_seconds(self, link_gbps: float = ICI_GBPS) -> float:
        return self.bytes_per_step / (link_gbps * 1e9)


def calibrate_from_hlo(hlo_text: str, flops_per_device: float,
                       n_pods: int = 1, chips_per_pod: int = 256) -> CalibratedCosts:
    stats = parse_collectives(hlo_text)
    fleet = fleet_from_tpu_mesh(n_pods=n_pods, chips_per_pod=chips_per_pod,
                                unit_bytes=1.0)
    return CalibratedCosts(
        fleet=fleet,
        collectives=stats,
        bytes_per_step=stats.total_wire_bytes,
        flops_per_step=flops_per_device,
    )


def stage_graph_for_lm(n_layers: int, d_model: int, d_ff: int, vocab: int,
                       seq: int, batch: int, moe_experts: int = 0,
                       top_k: int = 2) -> OpGraph:
    """The train-step dataflow as a paper OpGraph.

    Operators are stages (embed → L×block → head → loss → backward echo);
    selectivity is the bytes-amplification between stages — this is the graph
    auto-sharding scores candidate placements against.  Tuple unit = one
    token's activation row (d_model × 2 bytes bf16).
    """
    tok_bytes = 2.0 * d_model
    ops = [Operator("source", selectivity=1.0, out_bytes=4.0)]  # token ids
    ops.append(Operator("embed", selectivity=1.0, out_bytes=tok_bytes))
    edges = [(0, 1)]
    prev = 1
    for l in range(n_layers):
        amp = 1.0
        if moe_experts:
            # top-k dispatch duplicates tokens k× on the expert axis
            amp = float(top_k)
        ops.append(Operator(f"block{l}", selectivity=amp, out_bytes=tok_bytes,
                            work=1.0))
        edges.append((prev, len(ops) - 1))
        prev = len(ops) - 1
    ops.append(Operator("head", selectivity=vocab / d_model,
                        out_bytes=2.0 * vocab, work=1.0))
    edges.append((prev, len(ops) - 1))
    ops.append(Operator("loss", selectivity=1.0 / vocab, out_bytes=4.0))
    edges.append((len(ops) - 2, len(ops) - 1))
    return OpGraph(ops, edges)


# -- closed-loop recalibration from replay observations -----------------------

@dataclasses.dataclass
class ReplayWindow:
    """A window of per-tick replay observations, the input of
    :func:`refit_from_replay`.

    Attributes:
      rates: (T,) source rows per tick.
      busy: (T, V) observed per-device busy seconds.
      observed_latency: (T,) end-to-end latency signal per tick (any unit —
        the fit absorbs the unit into ``com_scale``).
      xs: the placement(s) active during the window — (n_ops, V) shared, or
        (T, n_ops, V) per tick.
      op_rows_in / op_rows_out: optional (T, n_ops) per-operator row
        counters (``BatchReport.op_rows_in/out``).  With inputs the busy
        fit predicts load from the rows each operator ACTUALLY processed
        (immune to selectivity drift); with both, the per-operator true
        selectivity is re-fit too.
    """

    rates: np.ndarray
    busy: np.ndarray
    observed_latency: np.ndarray
    xs: np.ndarray
    op_rows_in: np.ndarray | None = None
    op_rows_out: np.ndarray | None = None

    def __post_init__(self):
        self.rates = np.asarray(self.rates, dtype=np.float64)
        self.busy = np.asarray(self.busy, dtype=np.float64)
        self.observed_latency = np.asarray(self.observed_latency,
                                           dtype=np.float64)
        self.xs = np.asarray(self.xs, dtype=np.float64)
        t, v = self.busy.shape
        if self.rates.shape != (t,) or self.observed_latency.shape != (t,):
            raise ValueError(
                f"window shapes disagree: busy {self.busy.shape}, rates "
                f"{self.rates.shape}, observed {self.observed_latency.shape}")
        if self.xs.ndim == 2:
            self.xs = np.broadcast_to(self.xs, (t,) + self.xs.shape)
        if self.xs.shape[0] != t or self.xs.shape[2] != v:
            raise ValueError(f"xs has shape {self.xs.shape}, want "
                             f"({t}, n_ops, {v})")
        n_ops = self.xs.shape[1]
        for name in ("op_rows_in", "op_rows_out"):
            arr = getattr(self, name)
            if arr is not None:
                arr = np.asarray(arr, dtype=np.float64)
                if arr.shape != (t, n_ops):
                    raise ValueError(f"{name} has shape {arr.shape}, want "
                                     f"({t}, {n_ops})")
                setattr(self, name, arr)

    @property
    def n_ticks(self) -> int:
        return self.busy.shape[0]

    @classmethod
    def from_report(cls, report, x: np.ndarray) -> "ReplayWindow":
        """Build a window from a :class:`repro.sim.replay.ReplayReport`
        (its trailing constant-device-count suffix) with the per-tick max
        busy as the latency signal — the observation plain replay has."""
        busy = report.busy_series()
        steps = [s for s in report.steps if s.device_busy is not None]
        tail = steps[len(steps) - busy.shape[0]:]
        return cls(rates=np.array([s.rate for s in tail]),
                   busy=busy,
                   observed_latency=busy.max(axis=1, initial=0.0)
                   if busy.size else np.zeros(busy.shape[0]),
                   xs=np.asarray(x, dtype=np.float64))


def normalized_drift(observed: np.ndarray, modeled: np.ndarray) -> float:
    """RMS of (observed/modeled − 1) over ticks where both are positive —
    0 ⇒ the (unit-calibrated) model matches observation exactly; NaN when
    fewer than 2 ticks carry signal.  This is the trigger signal of the
    adaptive controller: unlike ``ReplayReport.drift``'s scale-free
    ``ratio_rel_std`` it DOES charge a constant offset, because the
    controller maintains its own unit calibration and a persistent offset
    means the calibration is stale."""
    o = np.asarray(observed, dtype=np.float64)
    m = np.asarray(modeled, dtype=np.float64)
    keep = (o > 0) & (m > 0)
    if keep.sum() < 2:
        return float("nan")
    r = o[keep] / m[keep]
    return float(np.sqrt(np.mean((r - 1.0) ** 2)))


@dataclasses.dataclass
class ReplayRefit:
    """Result of :func:`refit_from_replay`.

    ``fleet`` is the recalibrated belief: the input fleet's com costs scaled
    by ``outer(degrade, degrade)`` off-diagonal (structure) times
    ``com_scale`` (units/global drift), with ``speed`` as the new effective
    speeds.  ``graph`` is the belief's operator graph with the re-fit
    selectivities (the input graph unchanged when the window carries no row
    counters).  ``pre_drift``/``post_drift`` are :func:`normalized_drift`
    of the window against the old and new belief — the fit is only adopted
    when it actually explains the window better."""

    com_scale: float
    degrade: np.ndarray  # (V,) per-device slowdown multipliers (1 = healthy)
    speed: np.ndarray    # (V,) re-fitted effective speeds
    sel_scale: np.ndarray  # (n_ops,) selectivity drift estimates (1 = none)
    fleet: ExplicitFleet
    graph: OpGraph
    work_unit: float     # busy-seconds per (work·row) anchoring the fit
    n_ticks: int
    pre_drift: float
    post_drift: float
    # observation evidence behind the estimates: which devices carried busy
    # signal and how much predicted work mass each one processed over the
    # window — the weights a belief layer (repro.belief) uses for its
    # count-weighted posterior updates
    signal: np.ndarray | None = None
    obs_weight: np.ndarray | None = None
    op_obs_weight: np.ndarray | None = None  # (n_ops,) input rows per op
    # posterior slowdown variance AFTER this refit was written into a
    # belief (refit_from_replay(..., belief=...)); None without a belief
    posterior_var: np.ndarray | None = None


def _busy_ratio(graph: OpGraph, fleet, window: ReplayWindow
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-device ``work_unit · slowdown_u`` estimates from the busy series,
    which devices carry signal, and how much evidence each estimate rests on.

    The occupancy model predicts ``busy[t, u] = work_unit · Σ_i
    work_i·rows_i(t)·x_{t,i,u} / speed_u``; with the window's observed
    per-op input rows the prediction is exact under selectivity drift,
    otherwise rows are approximated by ``rate_t · cumulative_rate_i``.

    The returned ``weight`` is the total predicted work mass routed to each
    device over the window — the natural observation count: a device that
    processed 10⁴ work·rows pins its ratio, one that saw a stray 10⁻⁶ of
    mass produces a ratio dominated by quantization noise."""
    if window.op_rows_in is not None:
        wk = np.array([op.work for op in graph.operators])
        rows = window.op_rows_in * wk[None, :]               # (T, n_ops)
    else:
        rates = graph.cumulative_rates()
        wk = np.array([op.work * rates[i]
                       for i, op in enumerate(graph.operators)])
        rows = window.rates[:, None] * wk[None, :]           # (T, n_ops)
    load = np.einsum("ti,tiu->tu", rows, window.xs)
    pred_u = load.sum(axis=0)                                # (V,)
    obs_u = window.busy.sum(axis=0)                          # (V,)
    signal = (pred_u > 1e-12) & (obs_u > 0.0)
    believed_speed = np.asarray(fleet.effective_speed(), dtype=np.float64)
    ratio = np.zeros(window.busy.shape[1])
    # obs/pred = work_unit·slowdown_u/believed_speed_u ⇒ multiply by the
    # believed speed to isolate work_unit·slowdown_u
    ratio[signal] = obs_u[signal] / pred_u[signal] * believed_speed[signal]
    weight = np.where(signal, pred_u, 0.0)
    return ratio, signal, weight


def _weighted_median(values: np.ndarray, weights: np.ndarray) -> float:
    """Median of ``values`` under ``weights`` (lower weighted median): the
    smallest value whose cumulative weight reaches half the total.  Reduces
    to an element of ``values`` (never an interpolation), so one noisy
    near-zero-weight estimate cannot drag the pooled value off the
    well-observed ones."""
    order = np.argsort(values, kind="stable")
    v = values[order]
    w = weights[order]
    total = float(w.sum())
    if total <= 0.0:
        return float(np.median(v))
    k = int(np.searchsorted(np.cumsum(w), 0.5 * total))
    return float(v[min(k, v.size - 1)])


def fit_work_unit(graph: OpGraph, fleet, window: ReplayWindow) -> float:
    """Calibrate the busy-seconds-per-(work·row) unit from a window where
    the fleet belief is trusted (typically the run's first ticks): the
    median per-device ratio.  Anchoring later refits to this constant lets
    them read a UNIFORM busy inflation as real fleet-wide slowdown instead
    of silently renormalizing it away (a whole-region outage where every
    mass-carrying device sits in the region looks uniform).  NaN when no
    device carries signal."""
    ratio, signal, _ = _busy_ratio(graph, fleet, window)
    if not signal.any():
        return float("nan")
    return float(np.median(ratio[signal]))


def _refit_selectivities(graph: OpGraph,
                         window: ReplayWindow) -> tuple[np.ndarray, OpGraph]:
    """(sel_scale, graph') from the window's per-op row counters: operator
    i's observed selectivity is Σ_t out_i / Σ_t in_i (ops with no input
    rows keep their nominal value)."""
    n_ops = graph.n_ops
    scale = np.ones(n_ops)
    if window.op_rows_in is None or window.op_rows_out is None:
        return scale, graph
    tot_in = window.op_rows_in.sum(axis=0)
    tot_out = window.op_rows_out.sum(axis=0)
    for i, op in enumerate(graph.operators):
        if tot_in[i] > 0.0 and op.selectivity > 0.0:
            scale[i] = (tot_out[i] / tot_in[i]) / op.selectivity
    ops = [dataclasses.replace(op,
                               selectivity=float(op.selectivity * scale[i]))
           for i, op in enumerate(graph.operators)]
    return scale, OpGraph(ops, list(graph.edges))


def refit_from_replay(graph: OpGraph, fleet, window: ReplayWindow,
                      cfg: CostConfig = CostConfig(),
                      work_unit: float | None = None,
                      degrade_bounds: tuple[float, float] = (0.05, 1e6),
                      belief=None) -> ReplayRefit:
    """Re-fit the believed fleet (and operator selectivities) from observed
    replay behavior.

    Three estimators, run in sequence so they never double-count:

    1. **selectivities** from the per-op row counters (when the window has
       them): observed out/in rows per operator — the belief graph then
       prices the drifted flow, not the nominal one.
    2. **per-device slowdowns** from the busy series (:func:`_busy_ratio`):
       the per-device ratio of observed to predicted busy, relative to the
       believed effective speed, divided by the work-time unit.  Pass the
       ``work_unit`` calibrated on a trusted window (:func:`fit_work_unit`)
       so uniform fleet-wide slowdowns are read as real; with
       ``work_unit=None`` the window's median device anchors the unit
       (self-calibrating, but blind to uniform shifts).  Devices with no
       mass (no busy signal) keep their believed speed.
    3. **global com scale** from the latency signal, measured against the
       believed model WITH steps 1–2 already applied — the mean
       observed/modeled ratio prices whatever drift the structure cannot
       explain.

    Requires ≥2 ticks (raises ValueError otherwise — the controller guards
    zero/one-tick windows and simply skips the refit).

    ``belief`` (a :class:`repro.belief.BeliefState`) makes the refit WRITE
    its observations into the belief: the per-device slowdown estimates land
    as an observation-count-weighted posterior update (weights = predicted
    work mass per device) and the returned refit carries the belief's
    posterior variance after the write (``posterior_var``).  Adoption of the
    point estimate stays the caller's decision (``belief.commit``).
    """
    if window.n_ticks < 2:
        raise ValueError(f"refit needs ≥2 ticks, got {window.n_ticks}")
    v = window.busy.shape[1]
    if fleet.n_devices != v:
        raise ValueError(f"fleet has {fleet.n_devices} devices, window {v}")
    believed_speed = np.asarray(fleet.effective_speed(), dtype=np.float64)
    sel_scale, graph_fit = _refit_selectivities(graph, window)
    ratio, signal, obs_weight = _busy_ratio(graph_fit, fleet, window)
    anchor = work_unit if work_unit is not None \
        and np.isfinite(work_unit) and work_unit > 0.0 else None
    if anchor is None and signal.any():
        anchor = float(np.median(ratio[signal]))
    degrade = np.ones(v)
    if anchor and anchor > 0.0:
        degrade[signal] = np.clip(ratio[signal] / anchor, *degrade_bounds)
    # region pooling: a device the placement put no mass on emits no busy
    # signal, but fleet failures are region-correlated (outages take whole
    # regions down) — blind devices inherit the pooled estimate of their
    # region-mates that DO carry signal, so the re-optimizer cannot dump
    # mass onto an unobserved device of a struggling region.  The pool is
    # an observation-WEIGHTED median: a region-mate whose "signal" is a
    # stray sliver of mass (near-zero busy samples) contributes a ratio
    # made of quantization noise, and with exactly one well-observed device
    # in the region an unweighted median would average the two — diluting
    # the only real estimate (pinned in tests/test_refit.py).
    region = getattr(fleet, "region", None)
    if region is not None and signal.any() and not signal.all():
        region = np.asarray(region)
        for r in np.unique(region[~signal]):
            sig = (region == r) & signal
            if sig.any():
                degrade[(region == r) & ~signal] = \
                    _weighted_median(degrade[sig], obs_weight[sig])
    speed = believed_speed / degrade
    # structure first: com' = com·d_u·d_v off-diagonal (diag kept)
    com = np.asarray(fleet.com_matrix(), dtype=np.float64)
    com_s = com * np.outer(degrade, degrade)
    np.fill_diagonal(com_s, np.diag(com))
    avail = getattr(fleet, "available", None)
    structured = ExplicitFleet(com_cost=com_s, speed=speed, available=avail,
                               region=getattr(fleet, "region", None))
    modeled0 = np.array([latency(graph, fleet, x, cfg) for x in window.xs])
    modeled1 = np.array([latency(graph_fit, structured, x, cfg)
                         for x in window.xs])
    pre_drift = normalized_drift(window.observed_latency, modeled0)
    keep = (window.observed_latency > 0) & (modeled1 > 0)
    com_scale = float(np.mean(window.observed_latency[keep]
                              / modeled1[keep])) if keep.sum() else 1.0
    if not np.isfinite(com_scale) or com_scale <= 0.0:
        com_scale = 1.0
    # com_scale is a UNIT recalibration, so it scales every entry — the
    # self-cost diagonal included (com_s already carries diag(com))
    refit_fleet = ExplicitFleet(com_cost=com_s * com_scale, speed=speed,
                                available=avail,
                                region=getattr(fleet, "region", None))
    post_drift = normalized_drift(window.observed_latency,
                                  com_scale * modeled1)
    op_obs_weight = None if window.op_rows_in is None \
        else window.op_rows_in.sum(axis=0)
    refit = ReplayRefit(com_scale=com_scale, degrade=degrade, speed=speed,
                        sel_scale=sel_scale, fleet=refit_fleet,
                        graph=graph_fit,
                        work_unit=float(anchor) if anchor else float("nan"),
                        n_ticks=window.n_ticks,
                        pre_drift=pre_drift, post_drift=post_drift,
                        signal=signal, obs_weight=obs_weight,
                        op_obs_weight=op_obs_weight)
    if belief is not None:
        belief.update_from_refit(refit)
        refit = dataclasses.replace(refit,
                                    posterior_var=belief.posterior_var())
    return refit
