"""Device fleets: heterogeneous, geo-distributed compute nodes (paper ``ED``).

Two concrete fleets:

* :class:`ExplicitFleet` — dense ``comCost_{u,v}`` matrix, exactly the paper's
  Table 3 input.  Fine up to a few thousand devices.
* :class:`RegionFleet` — devices grouped into regions (pods / datacenters);
  ``comCost_{u,v} = intra[r]`` if same region else ``inter[r_u, r_v]``.  The
  cost model exploits this structure so evaluation scales to fleets of 10⁵+
  devices (the paper's "massive parallelism" at fleet level) without ever
  materializing the V×V matrix.

``fleet_from_tpu_mesh`` builds a RegionFleet whose link costs mirror the TPU
production mesh (ICI within a pod, DCI between pods) so placement decisions
price the same topology the dry-run compiles against (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "ExplicitFleet",
    "RegionFleet",
    "fleet_from_tpu_mesh",
    "ICI_GBPS",
    "DCI_GBPS",
    "HBM_GBPS",
    "PEAK_BF16_TFLOPS",
]

# TPU v5e hardware constants (per task spec; used by roofline + calibration).
PEAK_BF16_TFLOPS = 197.0  # per chip
HBM_GBPS = 819.0  # per chip
ICI_GBPS = 50.0  # per link
DCI_GBPS = 6.25  # assumed inter-pod (geo) link per chip-pair — the slow WAN tier


@dataclasses.dataclass
class ExplicitFleet:
    """Paper-faithful fleet: dense pairwise communication cost matrix.

    Attributes:
      com_cost: (V, V) — ``comCost_{u,v}``, time per unit data sent u→v.
        Diagonal is normally 0 (local data stays local).
      speed: (V,) relative compute speed (1.0 = nominal).  Only used by the
        compute-cost *extension*; the paper-faithful model ignores it.
      available: (n_ops, V) boolean — paper's ``available_{i,u}``; or None
        meaning every operator may run anywhere.
      region: (V,) int region id per device (informational here).
    """

    com_cost: np.ndarray
    speed: np.ndarray | None = None
    available: np.ndarray | None = None
    region: np.ndarray | None = None

    def __post_init__(self):
        self.com_cost = np.asarray(self.com_cost, dtype=np.float64)
        if self.com_cost.ndim != 2 or self.com_cost.shape[0] != self.com_cost.shape[1]:
            raise ValueError(f"com_cost must be square, got {self.com_cost.shape}")
        v = self.com_cost.shape[0]
        if self.speed is None:
            self.speed = np.ones(v, dtype=np.float64)
        self.speed = np.asarray(self.speed, dtype=np.float64)
        if self.region is None:
            self.region = np.zeros(v, dtype=np.int64)

    @property
    def n_devices(self) -> int:
        return self.com_cost.shape[0]

    def availability(self, n_ops: int) -> np.ndarray:
        if self.available is None:
            return np.ones((n_ops, self.n_devices), dtype=bool)
        a = np.asarray(self.available, dtype=bool)
        if a.shape != (n_ops, self.n_devices):
            raise ValueError(
                f"available has shape {a.shape}, want {(n_ops, self.n_devices)}")
        return a

    def com_matrix(self) -> np.ndarray:
        return self.com_cost

    def degrade_device(self, u: int, factor: float) -> "ExplicitFleet":
        """Model a straggler: all links touching ``u`` get ``factor``× slower
        and its compute speed drops by the same factor (runtime mitigation
        re-optimizes placement against the degraded fleet)."""
        c = self.com_cost.copy()
        c[u, :] *= factor
        c[:, u] *= factor
        np.fill_diagonal(c, np.diag(self.com_cost))
        s = self.speed.copy()
        s[u] /= factor
        return dataclasses.replace(self, com_cost=c, speed=s)

    def without_devices(self, dead: list[int]) -> tuple["ExplicitFleet", np.ndarray]:
        """Elastic down-scale: drop failed devices; returns (fleet, keep_idx)."""
        keep = np.array([u for u in range(self.n_devices) if u not in set(dead)])
        avail = None
        if self.available is not None:
            avail = np.asarray(self.available)[:, keep]
        return (
            ExplicitFleet(
                com_cost=self.com_cost[np.ix_(keep, keep)],
                speed=self.speed[keep],
                available=avail,
                region=self.region[keep],
            ),
            keep,
        )


@dataclasses.dataclass
class RegionFleet:
    """Region-structured fleet for massive device counts.

    ``comCost_{u,v} = inter[region_u, region_v]`` for ``u != v`` and
    ``intra_self`` (default 0) for ``u == v``.  Devices in the same region use
    the diagonal of ``inter`` (the intra-region link cost).
    """

    region: np.ndarray  # (V,) int region ids in [0, R)
    inter: np.ndarray  # (R, R) link cost between regions; diagonal = intra-region
    self_cost: float = 0.0  # u == v
    speed: np.ndarray | None = None
    available: np.ndarray | None = None

    def __post_init__(self):
        self.region = np.asarray(self.region, dtype=np.int64)
        self.inter = np.asarray(self.inter, dtype=np.float64)
        if self.speed is None:
            self.speed = np.ones(self.n_devices, dtype=np.float64)

    @property
    def n_devices(self) -> int:
        return self.region.shape[0]

    @property
    def n_regions(self) -> int:
        return self.inter.shape[0]

    def availability(self, n_ops: int) -> np.ndarray:
        if self.available is None:
            return np.ones((n_ops, self.n_devices), dtype=bool)
        return np.asarray(self.available, dtype=bool)

    def com_matrix(self) -> np.ndarray:
        """Materialize the dense matrix (tests / small fleets only)."""
        c = self.inter[np.ix_(self.region, self.region)].copy()
        np.fill_diagonal(c, self.self_cost)
        return c

    def region_masses(self, x_row: np.ndarray) -> np.ndarray:
        """Σ_{v ∈ region r} x_v — the aggregation the structured model uses."""
        r = np.zeros(self.n_regions, dtype=x_row.dtype)
        np.add.at(r, self.region, x_row)
        return r


def fleet_from_tpu_mesh(
    n_pods: int = 1,
    chips_per_pod: int = 256,
    ici_gbps: float = ICI_GBPS,
    dci_gbps: float = DCI_GBPS,
    unit_bytes: float = 1e9,
) -> RegionFleet:
    """RegionFleet mirroring the production mesh: pods are regions.

    ``comCost`` is seconds per ``unit_bytes`` over the relevant link class:
    intra-pod traffic rides ICI, inter-pod traffic rides the slow DCI tier —
    the paper's geo-distribution heterogeneity, instantiated for TPU fleets.
    """
    region = np.repeat(np.arange(n_pods), chips_per_pod)
    intra = unit_bytes / (ici_gbps * 1e9)
    inter_cost = unit_bytes / (dci_gbps * 1e9)
    inter = np.full((n_pods, n_pods), inter_cost)
    np.fill_diagonal(inter, intra)
    return RegionFleet(region=region, inter=inter, self_cost=0.0)
