"""Device fleets: heterogeneous, geo-distributed compute nodes (paper ``ED``).

Two concrete fleets:

* :class:`ExplicitFleet` — dense ``comCost_{u,v}`` matrix, exactly the paper's
  Table 3 input.  Fine up to a few thousand devices.
* :class:`RegionFleet` — devices grouped into regions (pods / datacenters);
  ``comCost_{u,v} = intra[r]`` if same region else ``inter[r_u, r_v]``.  The
  cost model exploits this structure so evaluation scales to fleets of 10⁵+
  devices (the paper's "massive parallelism" at fleet level) without ever
  materializing the V×V matrix.

``fleet_from_tpu_mesh`` builds a RegionFleet whose link costs mirror the TPU
production mesh (ICI within a pod, DCI between pods) so placement decisions
price the same topology the dry-run compiles against (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "ExplicitFleet",
    "RegionFleet",
    "RegionFleetFamily",
    "fleet_from_tpu_mesh",
    "ICI_GBPS",
    "DCI_GBPS",
    "HBM_GBPS",
    "PEAK_BF16_TFLOPS",
]

# TPU v5e hardware constants (per task spec; used by roofline + calibration).
PEAK_BF16_TFLOPS = 197.0  # per chip
HBM_GBPS = 819.0  # per chip
ICI_GBPS = 50.0  # per link
DCI_GBPS = 6.25  # assumed inter-pod (geo) link per chip-pair — the slow WAN tier


@dataclasses.dataclass
class ExplicitFleet:
    """Paper-faithful fleet: dense pairwise communication cost matrix.

    Attributes:
      com_cost: (V, V) — ``comCost_{u,v}``, time per unit data sent u→v.
        Diagonal is normally 0 (local data stays local).
      speed: (V,) relative compute speed (1.0 = nominal).  Only used by the
        compute-cost *extension*; the paper-faithful model ignores it.
      available: (n_ops, V) boolean — paper's ``available_{i,u}``; or None
        meaning every operator may run anywhere.
      region: (V,) int region id per device (informational here).
    """

    com_cost: np.ndarray
    speed: np.ndarray | None = None
    available: np.ndarray | None = None
    region: np.ndarray | None = None

    def __post_init__(self):
        self.com_cost = np.asarray(self.com_cost, dtype=np.float64)
        if self.com_cost.ndim != 2 or self.com_cost.shape[0] != self.com_cost.shape[1]:
            raise ValueError(f"com_cost must be square, got {self.com_cost.shape}")
        v = self.com_cost.shape[0]
        if self.speed is None:
            self.speed = np.ones(v, dtype=np.float64)
        self.speed = np.asarray(self.speed, dtype=np.float64)
        if self.region is None:
            self.region = np.zeros(v, dtype=np.int64)

    @property
    def n_devices(self) -> int:
        return self.com_cost.shape[0]

    def availability(self, n_ops: int) -> np.ndarray:
        if self.available is None:
            return np.ones((n_ops, self.n_devices), dtype=bool)
        a = np.asarray(self.available, dtype=bool)
        if a.shape != (n_ops, self.n_devices):
            raise ValueError(
                f"available has shape {a.shape}, want {(n_ops, self.n_devices)}")
        return a

    def com_matrix(self) -> np.ndarray:
        return self.com_cost

    def effective_speed(self) -> np.ndarray:
        """(V,) compute speed as priced by the occupancy / compute objectives.

        An ExplicitFleet has no separate degrade state — stragglers are
        folded directly into ``speed`` (see :meth:`degrade_device`)."""
        return self.speed

    def degrade_device(self, u: int, factor: float) -> "ExplicitFleet":
        """Model a straggler: all links touching ``u`` get ``factor``× slower
        and its compute speed drops by the same factor (runtime mitigation
        re-optimizes placement against the degraded fleet)."""
        c = self.com_cost.copy()
        c[u, :] *= factor
        c[:, u] *= factor
        np.fill_diagonal(c, np.diag(self.com_cost))
        s = self.speed.copy()
        s[u] /= factor
        return dataclasses.replace(self, com_cost=c, speed=s)

    def without_devices(self, dead: list[int]) -> tuple["ExplicitFleet", np.ndarray]:
        """Elastic down-scale: drop failed devices; returns (fleet, keep_idx)."""
        keep = np.array([u for u in range(self.n_devices) if u not in set(dead)])
        avail = None
        if self.available is not None:
            avail = np.asarray(self.available)[:, keep]
        return (
            ExplicitFleet(
                com_cost=self.com_cost[np.ix_(keep, keep)],
                speed=self.speed[keep],
                available=avail,
                region=self.region[keep],
            ),
            keep,
        )


@dataclasses.dataclass
class RegionFleet:
    """Region-structured fleet for massive device counts.

    ``comCost_{u,v} = degrade_u · degrade_v · inter[region_u, region_v]`` for
    ``u != v`` and ``self_cost`` (default 0) for ``u == v``.  Devices in the
    same region use the diagonal of ``inter`` (the intra-region link cost).

    ``degrade`` (default all-ones) is the structured straggler/outage model:
    every link touching device ``u`` gets ``degrade_u``× slower — the same
    semantics as ``ExplicitFleet.degrade_device`` but without ever leaving
    the O(R² + V) representation, so what-if families keep 10⁵-device fleets
    structured.
    """

    region: np.ndarray  # (V,) int region ids in [0, R)
    inter: np.ndarray  # (R, R) link cost between regions; diagonal = intra-region
    self_cost: float = 0.0  # u == v
    speed: np.ndarray | None = None
    available: np.ndarray | None = None
    degrade: np.ndarray | None = None  # (V,) per-device link multipliers

    def __post_init__(self):
        self.region = np.asarray(self.region, dtype=np.int64)
        self.inter = np.asarray(self.inter, dtype=np.float64)
        if self.speed is None:
            self.speed = np.ones(self.n_devices, dtype=np.float64)
        if self.degrade is not None:
            self.degrade = np.asarray(self.degrade, dtype=np.float64)
            if self.degrade.shape != (self.n_devices,):
                raise ValueError(
                    f"degrade has shape {self.degrade.shape}, "
                    f"want {(self.n_devices,)}")

    @property
    def n_devices(self) -> int:
        return self.region.shape[0]

    @property
    def n_regions(self) -> int:
        return self.inter.shape[0]

    def availability(self, n_ops: int) -> np.ndarray:
        if self.available is None:
            return np.ones((n_ops, self.n_devices), dtype=bool)
        return np.asarray(self.available, dtype=bool)

    def degrade_or_ones(self) -> np.ndarray:
        if self.degrade is None:
            return np.ones(self.n_devices, dtype=np.float64)
        return self.degrade

    def effective_speed(self) -> np.ndarray:
        """(V,) compute speed with the degrade multiplier applied.

        ``degrade_u`` prices every link touching ``u`` as ``degrade_u``×
        slower; a straggling box is slow on compute too, so the occupancy /
        compute objectives divide its nominal speed by the same multiplier
        (a degrade-2 device occupies 2× longer for the same work)."""
        return self.speed / self.degrade_or_ones()

    def com_matrix(self) -> np.ndarray:
        """Materialize the dense matrix (tests / small fleets only)."""
        c = self.inter[np.ix_(self.region, self.region)].copy()
        if self.degrade is not None:
            c *= np.outer(self.degrade, self.degrade)
        np.fill_diagonal(c, self.self_cost)
        return c

    def region_masses(self, x_row: np.ndarray) -> np.ndarray:
        """Σ_{v ∈ region r} x_v — the aggregation the structured model uses."""
        r = np.zeros(self.n_regions, dtype=x_row.dtype)
        np.add.at(r, self.region, x_row)
        return r

    def degrade_device(self, u: int, factor: float) -> "RegionFleet":
        """Structured straggler: links touching ``u`` get ``factor``× slower
        and, through :meth:`effective_speed`, its compute slows by the same
        factor (mirrors ExplicitFleet.degrade_device without materializing
        the matrix).  The slowdown lives ONLY in ``degrade`` — ``speed``
        stays nominal, so families built from degraded fleets keep one
        shared speed vector and the multiplier is never double-counted."""
        d = self.degrade_or_ones().copy()
        d[u] *= factor
        return dataclasses.replace(self, degrade=d)


@dataclasses.dataclass
class RegionFleetFamily:
    """A packed what-if *family* of RegionFleets sharing one region layout.

    This is the structured counterpart of stacking dense com matrices into
    an (S, V, V) tensor: scenarios share the ``region`` assignment (what-if
    perturbations move link costs and device health, not the fleet layout),
    so the whole family is

      * ``inter``   — (S, R, R) per-scenario inter-region link costs,
      * ``degrade`` — (S, V) per-device link multipliers (stragglers /
        whole-region outages; all-ones ⇒ healthy),

    i.e. O(S·(R² + V)) memory instead of O(S·V²) — the representation the
    batched evaluator's structured path consumes directly, reaching the
    10⁵-device fleets the scalar ``make_latency_fn`` already prices.

    ``S == 1`` families broadcast against a placement batch the same way a
    (1, V, V) dense com does.
    """

    region: np.ndarray  # (V,) shared region assignment
    inter: np.ndarray  # (S, R, R)
    degrade: np.ndarray  # (S, V)
    self_cost: float = 0.0
    speed: np.ndarray | None = None  # (V,) shared or (S, V) per-scenario

    def __post_init__(self):
        self.region = np.asarray(self.region, dtype=np.int64)
        self.inter = np.asarray(self.inter, dtype=np.float64)
        if self.inter.ndim != 3 or self.inter.shape[1] != self.inter.shape[2]:
            raise ValueError(f"inter must be (S, R, R), got {self.inter.shape}")
        if self.degrade is None:
            self.degrade = np.ones((self.n_scenarios, self.n_devices))
        self.degrade = np.asarray(self.degrade, dtype=np.float64)
        if self.degrade.shape != (self.n_scenarios, self.n_devices):
            raise ValueError(
                f"degrade has shape {self.degrade.shape}, "
                f"want {(self.n_scenarios, self.n_devices)}")
        if self.speed is not None:
            self.speed = np.asarray(self.speed, dtype=np.float64)
            if self.speed.shape not in (
                    (self.n_devices,),
                    (self.n_scenarios, self.n_devices)):
                raise ValueError(
                    f"speed has shape {self.speed.shape}, want "
                    f"{(self.n_devices,)} or "
                    f"{(self.n_scenarios, self.n_devices)}")
        if self.region.min(initial=0) < 0 or \
                self.region.max(initial=-1) >= self.n_regions:
            raise ValueError("region ids must lie in [0, n_regions)")

    @property
    def n_scenarios(self) -> int:
        return self.inter.shape[0]

    @property
    def n_devices(self) -> int:
        return self.region.shape[0]

    @property
    def n_regions(self) -> int:
        return self.inter.shape[1]

    @classmethod
    def from_fleets(cls, fleets: list["RegionFleet"]) -> "RegionFleetFamily":
        """Pack RegionFleets that share a region assignment and self_cost.

        Raises ValueError when the fleets don't stack structurally (different
        layouts belong in a dense (S, V, V) pack instead).
        """
        if not fleets:
            raise ValueError("need at least one fleet")
        if not all(isinstance(f, RegionFleet) for f in fleets):
            raise ValueError("all fleets must be RegionFleets")
        first = fleets[0]
        for f in fleets[1:]:
            if f.inter.shape != first.inter.shape \
                    or not np.array_equal(f.region, first.region) \
                    or f.self_cost != first.self_cost:
                raise ValueError(
                    "fleets disagree on region layout / self_cost — "
                    "pack them densely instead")
        # speeds only matter for the compute extension (fleet(s) oracle
        # use), but dropping them would silently mis-price degraded fleets
        # there — keep the shared vector when they agree, stack otherwise
        speeds = np.stack([np.ones(first.n_devices) if f.speed is None
                           else np.asarray(f.speed, dtype=np.float64)
                           for f in fleets])
        speed = speeds[0].copy() if np.allclose(speeds, speeds[0]) else speeds
        return cls(
            region=first.region.copy(),
            inter=np.stack([f.inter for f in fleets]),
            degrade=np.stack([f.degrade_or_ones() for f in fleets]),
            self_cost=first.self_cost,
            speed=speed,
        )

    def speed_or_ones(self) -> np.ndarray:
        """(S, V) nominal speeds, scenario-broadcast when shared."""
        if self.speed is None:
            return np.ones((self.n_scenarios, self.n_devices))
        return np.broadcast_to(self.speed,
                               (self.n_scenarios, self.n_devices))

    def effective_speeds(self) -> np.ndarray:
        """(S, V) per-scenario compute speeds with degrade applied —
        the stacked twin of :meth:`RegionFleet.effective_speed`."""
        return self.speed_or_ones() / self.degrade

    def fleet(self, s: int) -> "RegionFleet":
        """Scenario ``s`` as a standalone RegionFleet (oracle / replay use)."""
        speed = self.speed if self.speed is None or self.speed.ndim == 1 \
            else self.speed[s]
        return RegionFleet(region=self.region, inter=self.inter[s],
                           self_cost=self.self_cost, speed=speed,
                           degrade=self.degrade[s])

    def fleets(self) -> list["RegionFleet"]:
        return [self.fleet(s) for s in range(self.n_scenarios)]

    def com_matrix(self, s: int) -> np.ndarray:
        """Scenario ``s`` materialized densely (tests / small V only)."""
        return self.fleet(s).com_matrix()


def fleet_from_tpu_mesh(
    n_pods: int = 1,
    chips_per_pod: int = 256,
    ici_gbps: float = ICI_GBPS,
    dci_gbps: float = DCI_GBPS,
    unit_bytes: float = 1e9,
) -> RegionFleet:
    """RegionFleet mirroring the production mesh: pods are regions.

    ``comCost`` is seconds per ``unit_bytes`` over the relevant link class:
    intra-pod traffic rides ICI, inter-pod traffic rides the slow DCI tier —
    the paper's geo-distribution heterogeneity, instantiated for TPU fleets.
    """
    region = np.repeat(np.arange(n_pods), chips_per_pod)
    intra = unit_bytes / (ici_gbps * 1e9)
    inter_cost = unit_bytes / (dci_gbps * 1e9)
    inter = np.full((n_pods, n_pods), inter_cost)
    np.fill_diagonal(inter, intra)
    return RegionFleet(region=region, inter=inter, self_cost=0.0)
