"""Operator DAGs for streaming analytics jobs (paper §3, Table 2).

An :class:`OpGraph` is the paper's ``G_op = (V_op, E_op)``: vertices are
operators (a set of pipelined job steps that run on one device class), edges
are data re-distributions (shuffles).  Each operator carries a selectivity
``s_i`` (output tuples per input tuple) and, as an extension used by
auto-sharding (DESIGN.md §2), an optional compute ``work`` and output tuple
size in bytes.

The paper defines total latency over *paths* from a source to the operator
just upstream of a sink; enumerating paths is exponential, so the cost model
evaluates the identical quantity with a topological-order DP (O(V+E)).  Path
enumeration is kept here for oracle tests on small graphs.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["Operator", "OpGraph", "linear_graph", "diamond_graph", "random_dag"]


@dataclasses.dataclass(frozen=True)
class Operator:
    """One vertex of ``G_op``.

    Attributes:
      name: unique operator name.
      selectivity: ``s_i`` — output tuples per input tuple.  Sources have
        ``s=1`` per the paper; sinks' selectivity has no effect.
      out_bytes: average output tuple size (used by the byte-weighted
        network-movement objective of paper §3.1 and by calibration).
      work: abstract compute units per input batch (0 ⇒ paper-faithful
        "execution latency is negligible" assumption).
      dq_eligible: whether data-quality checks may run inside this operator.
    """

    name: str
    selectivity: float = 1.0
    out_bytes: float = 1.0
    work: float = 0.0
    dq_eligible: bool = False


class OpGraph:
    """A DAG of operators with edges representing data shuffling."""

    def __init__(self, operators: Sequence[Operator], edges: Iterable[tuple[int, int]]):
        self.operators = list(operators)
        self.edges = [(int(i), int(j)) for i, j in edges]
        n = len(self.operators)
        names = [op.name for op in self.operators]
        if len(set(names)) != n:
            raise ValueError(f"duplicate operator names: {names}")
        for i, j in self.edges:
            if not (0 <= i < n and 0 <= j < n):
                raise ValueError(f"edge ({i},{j}) out of range for {n} operators")
            if i == j:
                raise ValueError(f"self-loop on operator {i}")
        self._out = [[] for _ in range(n)]
        self._in = [[] for _ in range(n)]
        for e, (i, j) in enumerate(self.edges):
            self._out[i].append((j, e))
            self._in[j].append((i, e))
        self.topo_order = self._toposort()

    # -- structure ---------------------------------------------------------
    @property
    def n_ops(self) -> int:
        return len(self.operators)

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    def successors(self, i: int) -> list[int]:
        return [j for j, _ in self._out[i]]

    def predecessors(self, j: int) -> list[int]:
        return [i for i, _ in self._in[j]]

    def out_edges(self, i: int) -> list[tuple[int, int]]:
        """[(dst, edge_index)] for operator ``i``."""
        return list(self._out[i])

    def in_edges(self, j: int) -> list[tuple[int, int]]:
        return list(self._in[j])

    @property
    def sources(self) -> list[int]:
        return [i for i in range(self.n_ops) if not self._in[i]]

    @property
    def sinks(self) -> list[int]:
        return [i for i in range(self.n_ops) if not self._out[i]]

    def selectivities(self) -> np.ndarray:
        return np.array([op.selectivity for op in self.operators], dtype=np.float64)

    def _toposort(self) -> list[int]:
        n = self.n_ops
        indeg = [len(self._in[i]) for i in range(n)]
        stack = [i for i in range(n) if indeg[i] == 0]
        order: list[int] = []
        while stack:
            i = stack.pop()
            order.append(i)
            for j, _ in self._out[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    stack.append(j)
        if len(order) != n:
            raise ValueError("graph has a cycle — G_op must be a DAG")
        return order

    # -- paths (oracle; exponential — small graphs only) --------------------
    def edge_paths(self) -> list[list[int]]:
        """All source→sink paths, each as a list of *edge indices*.

        Per the paper, a path runs from a source to the operator just
        upstream of a sink; the edge into the sink is the last contributor.
        A source that is also a sink contributes an empty path (no edges).
        """
        paths: list[list[int]] = []

        def walk(i: int, acc: list[int]):
            if not self._out[i]:
                paths.append(list(acc))
                return
            for j, e in self._out[i]:
                acc.append(e)
                walk(j, acc)
                acc.pop()

        for s in self.sources:
            walk(s, [])
        return paths

    # -- cumulative selectivity (input rate scaling per operator) ----------
    def cumulative_rates(self) -> np.ndarray:
        """Relative input rate of each operator w.r.t. unit source rate.

        rate(source)=1; rate(j) = Σ_{i∈pred(j)} rate(i)·s_i.  Used by the
        byte-weighted objectives and by the streaming engine for batch sizing.
        """
        rate = np.zeros(self.n_ops, dtype=np.float64)
        for i in self.topo_order:
            if not self._in[i]:
                rate[i] = 1.0
        for i in self.topo_order:
            for j, _ in self._out[i]:
                rate[j] += rate[i] * self.operators[i].selectivity
        return rate

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"OpGraph(n_ops={self.n_ops}, n_edges={self.n_edges})"


# -- constructors ------------------------------------------------------------

def linear_graph(selectivities: Sequence[float], **op_kwargs) -> OpGraph:
    """Chain 0→1→…→n-1 (the paper's worked-example topology)."""
    ops = [
        Operator(name=f"op{i}", selectivity=float(s), **op_kwargs)
        for i, s in enumerate(selectivities)
    ]
    edges = [(i, i + 1) for i in range(len(ops) - 1)]
    return OpGraph(ops, edges)


def diamond_graph(s_src=1.0, s_left=0.5, s_right=2.0) -> OpGraph:
    """src → {left, right} → sink; exercises multi-path critical-path logic."""
    ops = [
        Operator("src", s_src),
        Operator("left", s_left),
        Operator("right", s_right),
        Operator("sink", 1.0),
    ]
    return OpGraph(ops, [(0, 1), (0, 2), (1, 3), (2, 3)])


def random_dag(n_ops: int, edge_prob: float, rng: np.random.Generator,
               max_selectivity: float = 2.0) -> OpGraph:
    """Random layered DAG (edges only i<j) for property tests and benches."""
    ops = [
        Operator(f"op{i}", float(rng.uniform(0.1, max_selectivity)))
        for i in range(n_ops)
    ]
    edges = []
    for j in range(1, n_ops):
        parents = [i for i in range(j) if rng.random() < edge_prob]
        if not parents:  # keep connected
            parents = [int(rng.integers(0, j))]
        edges.extend((i, j) for i in parents)
    return OpGraph(ops, edges)
