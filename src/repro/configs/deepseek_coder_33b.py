"""DeepSeek-Coder-33B [arXiv:2401.14196; hf]: llama-arch dense,
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense", n_layers=62, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=19200, vocab=32256, norm_type="rmsnorm",
    mlp_kind="swiglu", rope_theta=1e5,
    param_dtype="float32", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="deepseek-coder-33b-smoke", n_layers=2, d_model=64, n_heads=8,
    n_kv_heads=2, d_ff=160, vocab=256, act_dtype="float32")
