"""Snowflake Arctic-480B [hf:Snowflake/snowflake-arctic-base; hf]:
35L d_model=7168 56H (GQA kv=8) MoE 128 experts top-2 (d_ff=4864 each)
+ parallel dense residual MLP, vocab=32000.
bf16 params + 8-bit optimizer states (fits 256×16GB v5e; DESIGN.md §5)."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=4864, vocab=32000, moe_experts=128, moe_top_k=2,
    moe_dense_residual=True, moe_capacity_factor=1.25, moe_group_size=4096,
    norm_type="rmsnorm", mlp_kind="swiglu", rope_theta=1e4,
    param_dtype="bfloat16", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="arctic-480b-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=96, vocab=256, moe_experts=4, moe_group_size=32,
    param_dtype="float32", act_dtype="float32")
