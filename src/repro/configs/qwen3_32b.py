"""Qwen3-32B [hf:Qwen/Qwen3-8B family; hf]: dense with qk_norm,
64L d_model=5120 64H (GQA kv=8, head_dim=128) d_ff=25600 vocab=151936."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120, n_heads=64,
    n_kv_heads=8, d_ff=25600, vocab=151936, head_dim=128, qk_norm=True,
    norm_type="rmsnorm", mlp_kind="swiglu", rope_theta=1e6,
    param_dtype="float32", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="qwen3-32b-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, act_dtype="float32")
