"""Granite-8B-code [arXiv:2405.04324; hf]: llama-arch dense,
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense", n_layers=36, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=14336, vocab=49152, norm_type="rmsnorm",
    mlp_kind="swiglu", rope_theta=1e4,
    param_dtype="float32", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="granite-8b-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab=256, act_dtype="float32")
