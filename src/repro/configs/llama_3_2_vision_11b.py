"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision; unverified]:
40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, gated
cross-attention to image patches every 5 layers.  Vision tower is a STUB:
input_specs() provides precomputed (B, 1601, d_model) patch embeddings."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256, cross_attn_every=5,
    n_image_tokens=1601, norm_type="rmsnorm", mlp_kind="swiglu",
    rope_theta=5e5, param_dtype="float32", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="llama-3.2-vision-11b-smoke", n_layers=4, d_model=64, n_heads=8,
    n_kv_heads=2, d_ff=128, vocab=256, cross_attn_every=2, n_image_tokens=9,
    act_dtype="float32")
