"""Grok-1-314B [hf:xai-org/grok-1; unverified]: 64L d_model=6144 48H
(GQA kv=8) MoE 8 experts top-2 (d_ff=32768) vocab=131072.
bf16 params + 8-bit optimizer states."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=32768, vocab=131072, moe_experts=8, moe_top_k=2,
    moe_capacity_factor=1.25, moe_group_size=4096,
    norm_type="rmsnorm", mlp_kind="swiglu", rope_theta=1e4,
    param_dtype="bfloat16", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="grok-1-314b-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab=256, moe_experts=4, moe_group_size=32,
    param_dtype="float32", act_dtype="float32")
