"""Zamba2-1.2B [arXiv:2411.15242; hf]: hybrid Mamba2 backbone + one SHARED
attention block (single param set) applied every 6 SSM layers.
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000 ssm_state=64.
Sub-quadratic: runs the long_500k cell."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000, ssm_state=64,
    ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    shared_attn_every=6, norm_type="rmsnorm", mlp_kind="swiglu",
    rope_theta=1e4, sub_quadratic=True,
    param_dtype="float32", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="zamba2-1.2b-smoke", n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, ssm_state=8, ssm_head_dim=8, ssm_chunk=8,
    shared_attn_every=2, act_dtype="float32")
