from repro.configs.registry import (
    ALIASES, ARCH_IDS, SHAPES, Shape, get_config, get_smoke_config,
    runnable_cells, shape_skip_reason, skipped_cells,
)

__all__ = ["ALIASES", "ARCH_IDS", "SHAPES", "Shape", "get_config",
           "get_smoke_config", "runnable_cells", "shape_skip_reason",
           "skipped_cells"]
