"""OLMo-1B [arXiv:2402.00838; hf]: dense, non-parametric LayerNorm,
16L d_model=2048 16H (GQA kv=16 = MHA) d_ff=8192 vocab=50304."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=8192, vocab=50304, norm_type="layernorm_nonparam",
    mlp_kind="swiglu", rope_theta=1e4,
    param_dtype="float32", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="olmo-1b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, act_dtype="float32")
