"""Mamba2-1.3B [arXiv:2405.21060; unverified]: SSD state-space model,
48L d_model=2048 (attn-free) vocab=50280, ssm_state=128.
Sub-quadratic: runs the long_500k cell."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048, n_heads=64,
    n_kv_heads=64, d_ff=0, vocab=50280, ssm_state=128, ssm_expand=2,
    ssm_head_dim=64, ssm_conv=4, ssm_chunk=256, norm_type="rmsnorm",
    sub_quadratic=True, param_dtype="float32", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="mamba2-1.3b-smoke", n_layers=2, d_model=64, n_heads=16,
    n_kv_heads=16, vocab=256, ssm_state=16, ssm_head_dim=8, ssm_chunk=8,
    act_dtype="float32")
