"""Architecture & shape registry (assigned pool, see DESIGN.md §4).

Each ``src/repro/configs/<arch>.py`` defines ``CONFIG`` (exact published
dims) and ``SMOKE`` (reduced same-family config for CPU tests).  The four
assigned input shapes are global; ``runnable_cells()`` applies the skip
rules (long_500k ⇒ sub-quadratic archs only).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.api import ModelConfig

ARCH_IDS = [
    "olmo_1b",
    "granite_8b",
    "deepseek_coder_33b",
    "qwen3_32b",
    "mamba2_1_3b",
    "arctic_480b",
    "grok_1_314b",
    "zamba2_1_2b",
    "llama_3_2_vision_11b",
    "whisper_large_v3",
]

# CLI-friendly aliases (--arch olmo-1b etc.)
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def canonical_arch(arch: str) -> str:
    return arch.lower().replace(".", "_").replace("-", "_")


def get_config(arch: str) -> ModelConfig:
    arch = canonical_arch(arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_arch(arch)}")
    return mod.SMOKE


def shape_skip_reason(cfg: ModelConfig, shape: Shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("long_500k requires sub-quadratic sequence mixing; "
                f"{cfg.name} is pure full-attention (skip noted in DESIGN.md)")
    return None


def runnable_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if shape_skip_reason(cfg, shape) is None:
                cells.append((arch, sname))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            r = shape_skip_reason(cfg, shape)
            if r:
                out.append((arch, sname, r))
    return out
