"""Whisper-large-v3 backbone [arXiv:2212.04356; unverified]: enc-dec,
32L(dec)+32L(enc) d_model=1280 20H (kv=20) d_ff=5120 (GELU) vocab=51866.
Conv/mel frontend is a STUB: input_specs() provides precomputed
(B, 1500, d_model) frame embeddings.  Norms simplified to RMSNorm
(backbone-only assignment; see DESIGN.md §4)."""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866, encoder_layers=32,
    n_audio_frames=1500, mlp_kind="gelu", norm_type="rmsnorm",
    rope_theta=1e4, param_dtype="float32", act_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="whisper-large-v3-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256, encoder_layers=2, n_audio_frames=8,
    act_dtype="float32")
