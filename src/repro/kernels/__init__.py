"""Pallas TPU kernels for the compute hot-spots, each with a pure-jnp
oracle in ref.py; validated with interpret=True on CPU.

The edge-latency hot path is V-blocked for compiled execution and routed
through :mod:`repro.kernels.dispatch` (XLA einsum vs Pallas, interpret vs
compiled, autotuned block shapes) — see kernels/README.md.
"""

from repro.kernels.autotune import (DEFAULT_CONFIG, KernelConfig, ShapeKey,
                                    get_config)
from repro.kernels.dispatch import (KernelPlan, backend_name, edge_latency,
                                    edge_latency_structured, plan_edge_kernel,
                                    resolve_flags)
from repro.kernels.edge_latency import (LANE, SUBLANE, BlockGeometry,
                                        block_geometry)

__all__ = [
    "LANE", "SUBLANE", "BlockGeometry", "block_geometry",
    "KernelConfig", "ShapeKey", "DEFAULT_CONFIG", "get_config",
    "KernelPlan", "backend_name", "resolve_flags", "plan_edge_kernel",
    "edge_latency", "edge_latency_structured",
]
