"""Pallas TPU kernels for the compute hot-spots, each with a pure-jnp
oracle in ref.py; validated with interpret=True on CPU."""
