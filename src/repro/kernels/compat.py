"""Version compatibility for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` around
0.5; this container runs 0.4.37.  All kernels route through
:func:`tpu_compiler_params` so they lower on either spelling.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["tpu_compiler_params"]

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    return _PARAMS_CLS(**kwargs)
