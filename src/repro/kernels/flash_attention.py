"""Pallas TPU flash attention (causal, GQA-ready — kv pre-repeated to H).

TPU-native tiling: the (batch·head) axis and query blocks are parallel grid
dimensions; key/value blocks are the innermost *arbitrary* (sequential) grid
dimension so the online-softmax state (m, l, acc) lives in VMEM scratch
across kv steps.  Block shapes default to 128×128 — MXU-aligned (multiples
of 128 on both matmul dims) and small enough that q, k, v, acc tiles fit
VMEM: (bq·D + 2·bk·D + bq·bk + bq·D) · 4B ≈ 0.5 MB at D=128.

Causal skipping: kv blocks strictly above the diagonal are skipped entirely
(no compute, no VMEM traffic) — this is where the kernel beats a dense
softmax by 2× on causal shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import tpu_compiler_params

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _body():
        q = q_ref[0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_prev + p.sum(axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    if causal:
        # skip kv blocks entirely above the diagonal
        pl.when(kj * bk <= qi * bq + bq - 1)(_body)
    else:
        _body()

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, causal: bool = True, bq: int = 128,
                           bk: int = 128, interpret: bool = False):
    """q, k, v: (B, S, H, D) with kv repeated to H.  Returns (B, S, H, D)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scale = D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    if Sq % bq or Skv % bk:
        raise ValueError(f"seq lens ({Sq},{Skv}) must divide blocks ({bq},{bk})")
    # fold batch & heads, put seq in the middle: (BH, S, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    grid = (B * H, Sq // bq, Skv // bk)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m (running max)
            pltpu.VMEM((bq,), jnp.float32),       # l (running denom)
            pltpu.VMEM((bq, D), jnp.float32),     # acc
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
