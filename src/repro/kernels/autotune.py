"""VMEM-aware block-shape autotuning for the edge-latency kernels.

Picking ``(block_edges, block_v)`` is a real tradeoff the kernels cannot
resolve locally: larger edge blocks re-stream the com matrix fewer times
(dense HBM traffic carries a ``n_e · V²`` term), larger V blocks re-stream
the endpoint rows fewer times (a ``n_u`` factor on x_j) — but both inflate
the per-step VMEM footprint, and a block pair that spills VMEM doesn't
lower at all.  This module ranks candidate pairs with two analytic models
that price EXACTLY what the kernels run (both sides share
:func:`repro.kernels.edge_latency.block_geometry`):

  * :func:`vmem_bytes` — the per-grid-step VMEM footprint: every streamed
    input tile double-buffered, plus the scratch accumulator and output;
  * :func:`predict_seconds` — a roofline estimate (``repro.perf.roofline``
    peaks): max(compute term, HBM-traffic term) + per-grid-step overhead.
    HBM traffic counts tile *revisits* (the dense kernel re-reads com once
    per edge block and x_j once per u block), which is what makes the
    ranking non-trivial.

Decisions persist in a process-wide table keyed by
``(backend, kind, V, E, R, B-bucket)`` — B buckets to powers of two, the
same rule the serving layer uses, so one warm entry covers the whole
bucket.  ``get_config`` consults the table first; a miss ranks candidates
analytically and (optionally, when the caller supplies a ``timer`` — real
accelerators only; interpret-mode timings rank Python overhead, not
hardware) races the top candidates empirically.  The table round-trips to
JSON via :func:`save_table` / :func:`load_table` (format in
kernels/README.md).

The table is consulted at TRACE time by the dispatch layer: a decision
returns a config, and the (already-jitted, static-block-arg) kernel
wrapper is reused — autotuning never constructs a ``pallas_call`` per
iteration, so the no-silent-retrace discipline holds (lint-enforced).
Decisions and chosen block shapes are exported through ``repro.obs``.
"""

from __future__ import annotations

import dataclasses
import json
import threading

from repro import obs
from repro.kernels.edge_latency import block_geometry
from repro.perf.roofline import HBM_BW, PEAK_FLOPS

__all__ = ["KernelConfig", "ShapeKey", "DEFAULT_CONFIG", "VMEM_BUDGET_BYTES",
           "candidate_configs", "vmem_bytes", "predict_seconds", "rank",
           "get_config", "table_rows", "save_table", "load_table",
           "clear_table"]

BYTES_F32 = 4
VMEM_BYTES_TOTAL = 16 * 2 ** 20   # ~16 MiB of VMEM per TPU core
VMEM_FRACTION = 0.75              # headroom for compiler temporaries
VMEM_BUDGET_BYTES = int(VMEM_BYTES_TOTAL * VMEM_FRACTION)

# per-grid-step dispatch overhead in the analytic model: compiled TPU grids
# cost ~a microsecond of sequencing per step; interpret mode (CPU) runs the
# kernel body in Python, where per-step overhead dominates everything —
# which is exactly why the model must price it, or it would happily pick
# tiny blocks on the backend the container actually runs
STEP_OVERHEAD_S = {"cpu": 100e-6}
STEP_OVERHEAD_DEFAULT_S = 1.5e-6

BLOCK_EDGES_CANDIDATES = (32, 64, 128, 256, 512)
BLOCK_V_CANDIDATES = (128, 256, 512, 1024, 2048)
EMPIRICAL_TOP_K = 3


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One block-shape decision for the edge-latency kernels."""

    block_edges: int = 128
    block_v: int = 512


DEFAULT_CONFIG = KernelConfig()


@dataclasses.dataclass(frozen=True)
class ShapeKey:
    """Decision-table key: everything the choice may depend on.  B buckets
    to the next power of two (one entry per serving-layer shape bucket)."""

    backend: str
    kind: str          # "dense" | "structured"
    V: int
    E: int
    R: int | None
    b_bucket: int

    @classmethod
    def of(cls, backend: str, kind: str, B: int, E: int, V: int,
           R: int | None) -> "ShapeKey":
        return cls(backend=backend, kind=kind, V=int(V), E=int(E),
                   R=None if R is None else int(R),
                   b_bucket=1 << max(int(B) - 1, 0).bit_length())


_lock = threading.Lock()
_table: dict[ShapeKey, tuple[KernelConfig, str]] = {}


def vmem_bytes(kind: str, E: int, V: int, R: int | None,
               config: KernelConfig) -> int:
    """Per-grid-step VMEM footprint of the blocked kernel under ``config``:
    streamed input tiles double-buffered (the compiler overlaps the next
    tile's DMA with compute), scratch and output single-buffered."""
    g = block_geometry(kind, E, V, R, config.block_edges, config.block_v)
    if kind == "dense":
        inputs = g.be * g.bv + g.be * g.bv + g.bv * g.bv  # xi, xj, com
        scratch = g.be * g.bv                             # t accumulator
    else:
        # xi, xj, mass, a, corr
        inputs = 2 * g.be * g.bv + g.be * g.r_pad + g.r_pad * g.bv + g.bv
        scratch = 0
    return BYTES_F32 * (2 * inputs + scratch + 2 * g.be)


def predict_seconds(kind: str, B: int, E: int, V: int, R: int | None,
                    config: KernelConfig, com_batch: int = 1,
                    backend: str = "tpu") -> float:
    """Analytic time estimate for one kernel launch: roofline terms over
    the PADDED shape (so over-padding from a too-coarse block is priced),
    with HBM traffic counting every tile revisit the index maps imply."""
    g = block_geometry(kind, E, V, R, config.block_edges, config.block_v)
    if kind == "dense":
        steps = B * g.n_e * g.n_u * g.n_v
        flops = 2.0 * B * g.e_pad * g.v_pad * g.v_pad \
            + 3.0 * B * g.e_pad * g.v_pad
        traffic = (B * g.e_pad * g.v_pad            # xi: once per (e, u)
                   + B * g.e_pad * g.v_pad * g.n_u  # xj: re-read per u block
                   + com_batch * g.n_e * g.v_pad * g.v_pad  # com: per e blk
                   + B * g.e_pad)                   # output
    else:
        steps = B * g.n_e * g.n_u
        flops = 2.0 * B * g.e_pad * g.r_pad * g.v_pad \
            + 4.0 * B * g.e_pad * g.v_pad
        traffic = (2 * B * g.e_pad * g.v_pad        # xi, xj: once per (e, u)
                   + B * g.e_pad * g.r_pad * g.n_u  # mass: re-read per u blk
                   + com_batch * g.r_pad * g.v_pad * g.n_e  # a: per e block
                   + com_batch * g.v_pad * g.n_e    # corr: per e block
                   + B * g.e_pad)
    overhead = STEP_OVERHEAD_S.get(backend, STEP_OVERHEAD_DEFAULT_S)
    return max(flops / PEAK_FLOPS, BYTES_F32 * traffic / HBM_BW) \
        + steps * overhead


def candidate_configs(kind: str, E: int, V: int,
                      R: int | None) -> list[KernelConfig]:
    """VMEM-feasible (block_edges, block_v) pairs, deduplicated by the
    geometry they actually clamp to (a 512-wide block over V = 300 is the
    same kernel as a 384-wide one).  Never empty: the smallest candidate
    tile fits the budget at any R ≤ a few thousand."""
    out, seen = [], set()
    for be in BLOCK_EDGES_CANDIDATES:
        for bv in BLOCK_V_CANDIDATES:
            cfg = KernelConfig(block_edges=be, block_v=bv)
            g = block_geometry(kind, E, V, R, be, bv)
            if (g.be, g.bv) in seen:
                continue
            if vmem_bytes(kind, E, V, R, cfg) > VMEM_BUDGET_BYTES:
                continue
            seen.add((g.be, g.bv))
            out.append(cfg)
    if not out:  # huge R can exhaust the budget; fall back to minimum tiles
        out.append(KernelConfig(block_edges=BLOCK_EDGES_CANDIDATES[0],
                                block_v=BLOCK_V_CANDIDATES[0]))
    return out


def rank(kind: str, B: int, E: int, V: int, R: int | None = None,
         com_batch: int = 1, backend: str = "tpu") -> list[KernelConfig]:
    """Feasible candidates, best predicted first (deterministic: ties break
    toward the larger blocks, which also minimize grid-sequencing steps)."""
    cands = candidate_configs(kind, E, V, R)
    return sorted(
        cands,
        key=lambda c: (predict_seconds(kind, B, E, V, R, c,
                                       com_batch=com_batch, backend=backend),
                       -c.block_v, -c.block_edges))


def get_config(kind: str, B: int, E: int, V: int, R: int | None = None,
               com_batch: int = 1, backend: str | None = None,
               timer=None) -> KernelConfig:
    """The block config for one shape: decision-table hit, else analytic
    ranking (plus an empirical race over the top candidates when ``timer``
    — a ``callable(KernelConfig) -> seconds`` — is supplied), stored.

    Safe to call at trace time: pure host work, deterministic per key."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    key = ShapeKey.of(backend, kind, B, E, V, R)
    with _lock:
        hit = _table.get(key)
    reg = obs.registry()
    if hit is not None:
        if reg.enabled:
            reg.counter("kernels.autotune.decisions", kind=kind,
                        source="table", backend=backend).add(1)
        return hit[0]
    ranked = rank(kind, key.b_bucket, E, V, R, com_batch=com_batch,
                  backend=backend)
    best, source = ranked[0], "analytic"
    if timer is not None:
        timed = [(timer(c), c) for c in ranked[:EMPIRICAL_TOP_K]]
        best, source = min(timed, key=lambda t: t[0])[1], "empirical"
    with _lock:
        _table[key] = (best, source)
    if reg.enabled:
        reg.counter("kernels.autotune.decisions", kind=kind, source=source,
                    backend=backend).add(1)
        reg.gauge("kernels.autotune.block_edges", kind=kind,
                  V=str(V)).set(best.block_edges)
        reg.gauge("kernels.autotune.block_v", kind=kind,
                  V=str(V)).set(best.block_v)
    return best


# -- decision-table persistence ----------------------------------------------

def table_rows() -> list[dict]:
    """The decision table as JSON-ready rows (format: kernels/README.md)."""
    with _lock:
        items = sorted(_table.items(),
                       key=lambda kv: (kv[0].backend, kv[0].kind, kv[0].V,
                                       kv[0].E, kv[0].b_bucket))
    return [{"backend": k.backend, "kind": k.kind, "V": k.V, "E": k.E,
             "R": k.R, "b_bucket": k.b_bucket,
             "block_edges": cfg.block_edges, "block_v": cfg.block_v,
             "source": source}
            for k, (cfg, source) in items]


def save_table(path) -> None:
    rows = table_rows()
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": rows}, f, indent=2)


def load_table(path) -> int:
    """Merge a saved decision table into the process table (existing
    entries win — a live decision is never clobbered by a stale file).
    Returns the number of entries loaded."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") != 1:
        raise ValueError(f"unknown autotune table version "
                         f"{doc.get('version')!r}")
    loaded = 0
    with _lock:
        for row in doc["entries"]:
            key = ShapeKey(backend=row["backend"], kind=row["kind"],
                           V=int(row["V"]), E=int(row["E"]),
                           R=None if row["R"] is None else int(row["R"]),
                           b_bucket=int(row["b_bucket"]))
            if key in _table:
                continue
            _table[key] = (KernelConfig(block_edges=int(row["block_edges"]),
                                        block_v=int(row["block_v"])),
                           row.get("source", "table"))
            loaded += 1
    return loaded


def clear_table() -> None:
    with _lock:
        _table.clear()
