"""Backend dispatch for the edge-latency hot path: one place that decides
XLA-einsum vs Pallas, interpret vs compiled, and which block shapes.

Before this module, ``use_pallas``/``interpret`` flags were scattered across
``sim/batched.py``, ``serve/service.py``, ``search/``, and the kernel
wrappers — with DIVERGENT defaults (the serving layer defaulted
``interpret=True`` while the kernels defaulted ``interpret=False``), so a
caller could silently run interpreted kernels on an accelerator or try to
compile Pallas on CPU.  Every edge-latency consumer now routes through:

  * :func:`resolve_flags` — turns ``None`` (= "auto") flags into concrete
    booleans for the active backend: CPU → XLA einsum + interpret=True;
    accelerators → Pallas + compiled.  An EXPLICIT ``interpret=False`` on
    CPU is coerced back to True (compiled Pallas cannot lower there) and
    counted in ``repro.obs`` rather than left to crash at trace time.
  * :func:`edge_latency` / :func:`edge_latency_structured` — functional
    entry points that resolve flags, fetch a block config from
    :mod:`repro.kernels.autotune` (unless the caller pins one), and call
    either the XLA reference einsum or the blocked Pallas kernel.  The
    Pallas wrappers are module-level jits with static block args, so a
    table-stable config means zero warm recompiles.

``plan_edge_kernel`` exposes the decision itself (impl, interpret, config)
for callers that want to introspect or log it; plans are exported as
``kernels.dispatch.plans`` counter samples.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import autotune
from repro.kernels.edge_latency import (edge_latency_pallas,
                                        edge_latency_structured_pallas)

__all__ = ["backend_name", "resolve_flags", "KernelPlan", "plan_edge_kernel",
           "edge_latency", "edge_latency_structured"]


def backend_name() -> str:
    """The active JAX backend ("cpu", "tpu", "gpu"); the dispatch policy
    keys off this, never off caller-supplied booleans alone."""
    return jax.default_backend()


def resolve_flags(use_pallas: bool | None = None,
                  interpret: bool | None = None,
                  backend: str | None = None) -> tuple[bool, bool]:
    """(use_pallas, interpret) with ``None`` meaning "auto for the backend".

    Policy: on CPU the fast path is the XLA einsum (interpreted Pallas is a
    correctness tool, not a fast path) and compiled Pallas cannot lower, so
    auto resolves to (False, True) and an explicit ``interpret=False`` is
    coerced to True.  On accelerators auto resolves to (True, False); an
    explicit ``interpret=True`` is honored (debugging) but counted."""
    if backend is None:
        backend = backend_name()
    on_cpu = backend == "cpu"
    if use_pallas is None:
        use_pallas = not on_cpu
    if interpret is None:
        interpret = on_cpu
    reg = obs.registry()
    if on_cpu and not interpret:
        if reg.enabled:
            reg.counter("kernels.dispatch.coerced", flag="interpret",
                        backend=backend).add(1)
        interpret = True
    elif not on_cpu and interpret and use_pallas:
        if reg.enabled:
            reg.counter("kernels.dispatch.interpret_on_accelerator",
                        backend=backend).add(1)
    return bool(use_pallas), bool(interpret)


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """One resolved dispatch decision for an edge-latency shape."""

    impl: str                             # "pallas" | "xla"
    interpret: bool
    config: autotune.KernelConfig | None  # None for the XLA route


def plan_edge_kernel(kind: str, B: int, E: int, V: int, R: int | None = None,
                     *, use_pallas: bool | None = None,
                     interpret: bool | None = None,
                     backend: str | None = None, com_batch: int = 1,
                     block_edges: int | None = None,
                     block_v: int | None = None) -> KernelPlan:
    """Resolve flags and block shapes for one shape.  Caller-pinned blocks
    bypass the autotuner; otherwise the decision table supplies them."""
    if backend is None:
        backend = backend_name()
    use_pallas_r, interpret_r = resolve_flags(use_pallas, interpret, backend)
    if not use_pallas_r:
        plan = KernelPlan(impl="xla", interpret=interpret_r, config=None)
    elif block_edges is not None or block_v is not None:
        dflt = autotune.DEFAULT_CONFIG
        cfg = autotune.KernelConfig(
            block_edges=block_edges if block_edges is not None
            else dflt.block_edges,
            block_v=block_v if block_v is not None else dflt.block_v)
        plan = KernelPlan(impl="pallas", interpret=interpret_r, config=cfg)
    else:
        cfg = autotune.get_config(kind, B, E, V, R, com_batch=com_batch,
                                  backend=backend)
        plan = KernelPlan(impl="pallas", interpret=interpret_r, config=cfg)
    reg = obs.registry()
    if reg.enabled:
        reg.counter("kernels.dispatch.plans", kind=kind, impl=plan.impl,
                    interpret=str(plan.interpret)).add(1)
    return plan


def _edge_latency_xla(x_i, x_j, com):
    # com may be (1, V, V) shared across the B placement rows — einsum
    # broadcasting handles both batch layouts without materializing copies
    t = jnp.einsum("buv,bev->beu", com.astype(jnp.float32),
                   x_j.astype(jnp.float32))
    return jnp.max(x_i.astype(jnp.float32) * t, axis=-1)


def _edge_latency_structured_xla(x_i, x_j, mass, a, corr):
    t = jnp.einsum("ber,bru->beu", mass.astype(jnp.float32),
                   a.astype(jnp.float32))
    t = t + corr.astype(jnp.float32) * x_j.astype(jnp.float32)
    return jnp.max(x_i.astype(jnp.float32) * t, axis=-1)


def edge_latency(x_i, x_j, com, *, use_pallas: bool | None = None,
                 interpret: bool | None = None, backend: str | None = None,
                 block_edges: int | None = None, block_v: int | None = None):
    """Dense edge-latency max through the dispatch policy: (B, E, V) rows ×
    (B|1, V, V) com → (B, E).  Auto flags pick the backend-appropriate
    route; block shapes come from the autotune table unless pinned."""
    B, E, V = x_i.shape
    if E == 0:
        return jnp.zeros((B, 0), jnp.float32)
    plan = plan_edge_kernel("dense", B, E, V, use_pallas=use_pallas,
                            interpret=interpret, backend=backend,
                            com_batch=com.shape[0], block_edges=block_edges,
                            block_v=block_v)
    if plan.impl == "xla":
        return _edge_latency_xla(x_i, x_j, com)
    return edge_latency_pallas(x_i, x_j, com,
                               block_edges=plan.config.block_edges,
                               block_v=plan.config.block_v,
                               interpret=plan.interpret)


def edge_latency_structured(x_i, x_j, mass, a, corr, *,
                            use_pallas: bool | None = None,
                            interpret: bool | None = None,
                            backend: str | None = None,
                            block_edges: int | None = None,
                            block_v: int | None = None):
    """Structured (RegionFleet) edge-latency max through the dispatch
    policy: t = mass @ a + corr·x_j with R ≪ V (see kernels/edge_latency)."""
    B, E, V = x_i.shape
    if E == 0:
        return jnp.zeros((B, 0), jnp.float32)
    plan = plan_edge_kernel("structured", B, E, V, mass.shape[-1],
                            use_pallas=use_pallas, interpret=interpret,
                            backend=backend, com_batch=a.shape[0],
                            block_edges=block_edges, block_v=block_v)
    if plan.impl == "xla":
        return _edge_latency_structured_xla(x_i, x_j, mass, a, corr)
    return edge_latency_structured_pallas(
        x_i, x_j, mass, a, corr, block_edges=plan.config.block_edges,
        block_v=plan.config.block_v, interpret=plan.interpret)
