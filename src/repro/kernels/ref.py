"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately naive (full materialization / sequential scans) —
clarity over speed.  tests/test_kernels.py sweeps shapes & dtypes asserting
kernel(interpret=True) ≈ oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "ssd_ref", "rmsnorm_ref",
           "edge_latency_ref"]


def flash_attention_ref(q, k, v, causal: bool = True):
    """q,k,v: (B, S, H, D) (kv already repeated to H).  Full softmax."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), dtype=bool), k=Skv - Sq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def ssd_ref(x, B, C, dt, A, D):
    """Sequential (per-token) SSD recurrence — the definitional oracle.

    x: (b, L, H, P); B, C: (b, L, N); dt: (b, L, H); A, D: (H,).
    h_t = exp(A·dt_t)·h_{t-1} + dt_t·B_t⊗x_t ;  y_t = C_t·h_t + D·x_t.
    Returns (y (b,L,H,P), final_state (b,H,N,P)).
    """
    b, L, H, Pd = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(S, t):
        decay = jnp.exp(dtf[:, t] * A[None, :])  # (b,H)
        S = decay[..., None, None] * S + jnp.einsum(
            "bN,bh,bhp->bhNp", Bf[:, t], dtf[:, t], xf[:, t])
        y = jnp.einsum("bN,bhNp->bhp", Cf[:, t], S) \
            + D[None, :, None] * xf[:, t]
        return S, y

    S0 = jnp.zeros((b, H, N, Pd), jnp.float32)
    S, ys = jax.lax.scan(step, S0, jnp.arange(L))
    return ys.swapaxes(0, 1).astype(x.dtype), S


def rmsnorm_ref(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def edge_latency_ref(x_i, x_j, com):
    """x_i, x_j: (B, E, V) (selectivity folded into x_i); com: (B, V, V).

    out[b, e] = max_u x_i[b,e,u] · Σ_v com[b,u,v] · x_j[b,e,v] — the paper's
    per-edge bilinear-max, fully materialized."""
    t = jnp.einsum("buv,bev->beu", com.astype(jnp.float32),
                   x_j.astype(jnp.float32))
    return jnp.max(x_i.astype(jnp.float32) * t, axis=-1)
