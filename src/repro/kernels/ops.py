"""jit'd dispatch wrappers for the Pallas kernels.

``interpret=True`` executes the kernel body in Python on CPU (correctness
validation in this container); ``interpret=False`` lowers for real TPUs.
The model layer passes ``attention_impl``/``ssm_impl`` through to here.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

__all__ = ["flash_attention", "ssd_scan", "rmsnorm", "edge_latency_max",
           "edge_latency_structured_max"]


def flash_attention(q, k, v, causal: bool = True, interpret: bool = False,
                    bq: int = 128, bk: int = 128):
    """(B, S, H, D) attention; kv repeated to H (GQA handled by caller)."""
    Sq = q.shape[1]
    bq = _largest_divisor_block(Sq, bq)
    bk = _largest_divisor_block(k.shape[1], bk)
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=interpret)


def ssd_scan(x, B, C, dt, A, D, chunk: int = 128, head_block: int = 8,
             interpret: bool = False):
    chunk = _largest_divisor_block(x.shape[1], chunk)
    head_block = _largest_divisor_block(x.shape[2], head_block)
    return ssd_scan_pallas(x, B, C, dt, A, D, chunk=chunk,
                           head_block=head_block, interpret=interpret)


def rmsnorm(x, w, eps: float = 1e-6, interpret: bool = False):
    return rmsnorm_pallas(x, w, eps=eps, interpret=interpret)


def edge_latency_max(x_i, x_j, com, interpret: bool | None = None,
                     block_edges: int | None = None,
                     block_v: int | None = None):
    """(B, E) fused ``max_u x_i·(com @ x_j)`` on the Pallas route — see
    kernels/edge_latency.py.  ``interpret=None`` resolves per backend via
    :mod:`repro.kernels.dispatch`; block shapes come from the autotune
    table unless pinned.  No divisor shrinking: the kernel pads E up to the
    block size, so a prime E still runs full tiles."""
    return dispatch.edge_latency(x_i, x_j, com, use_pallas=True,
                                 interpret=interpret,
                                 block_edges=block_edges, block_v=block_v)


def edge_latency_structured_max(x_i, x_j, mass, a, corr,
                                interpret: bool | None = None,
                                block_edges: int | None = None,
                                block_v: int | None = None):
    """(B, E) structured edge-latency max over precomputed region masses —
    the RegionFleetFamily hot path (kernels/edge_latency.py), dispatched
    like :func:`edge_latency_max`."""
    return dispatch.edge_latency_structured(
        x_i, x_j, mass, a, corr, use_pallas=True, interpret=interpret,
        block_edges=block_edges, block_v=block_v)


def _largest_divisor_block(n: int, target: int) -> int:
    b = min(target, n)
    while n % b:
        b -= 1
    return max(b, 1)
