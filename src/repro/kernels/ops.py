"""jit'd dispatch wrappers for the Pallas kernels.

``interpret=True`` executes the kernel body in Python on CPU (correctness
validation in this container); ``interpret=False`` lowers for real TPUs.
The model layer passes ``attention_impl``/``ssm_impl`` through to here.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.edge_latency import (edge_latency_pallas,
                                        edge_latency_structured_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

__all__ = ["flash_attention", "ssd_scan", "rmsnorm", "edge_latency_max",
           "edge_latency_structured_max"]


def flash_attention(q, k, v, causal: bool = True, interpret: bool = False,
                    bq: int = 128, bk: int = 128):
    """(B, S, H, D) attention; kv repeated to H (GQA handled by caller)."""
    Sq = q.shape[1]
    bq = _largest_divisor_block(Sq, bq)
    bk = _largest_divisor_block(k.shape[1], bk)
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=interpret)


def ssd_scan(x, B, C, dt, A, D, chunk: int = 128, head_block: int = 8,
             interpret: bool = False):
    chunk = _largest_divisor_block(x.shape[1], chunk)
    head_block = _largest_divisor_block(x.shape[2], head_block)
    return ssd_scan_pallas(x, B, C, dt, A, D, chunk=chunk,
                           head_block=head_block, interpret=interpret)


def rmsnorm(x, w, eps: float = 1e-6, interpret: bool = False):
    return rmsnorm_pallas(x, w, eps=eps, interpret=interpret)


def edge_latency_max(x_i, x_j, com, interpret: bool = False,
                     block_edges: int = 128):
    """(B, E) fused ``max_u x_i·(com @ x_j)`` — see kernels/edge_latency.py.

    No divisor shrinking here: the kernel pads E up to the block size, so a
    prime E still runs one full tile instead of E degenerate ones."""
    return edge_latency_pallas(x_i, x_j, com, block_edges=block_edges,
                               interpret=interpret)


def edge_latency_structured_max(x_i, x_j, mass, a, corr,
                                interpret: bool = False,
                                block_edges: int = 128):
    """(B, E) structured edge-latency max over precomputed region masses —
    the RegionFleetFamily hot path (see kernels/edge_latency.py)."""
    return edge_latency_structured_pallas(x_i, x_j, mass, a, corr,
                                          block_edges=block_edges,
                                          interpret=interpret)


def _largest_divisor_block(n: int, target: int) -> int:
    b = min(target, n)
    while n % b:
        b -= 1
    return max(b, 1)
