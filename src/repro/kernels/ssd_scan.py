"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch, head_block, n_chunks) — batch and head blocks are parallel;
the chunk axis is the innermost *arbitrary* (sequential) dimension so the
inter-chunk SSM state (Hb, N, P) persists in VMEM scratch between steps,
exactly the TPU analogue of the paper's chunked state-passing algorithm
(DESIGN.md: HBM→VMEM streaming replaces the GPU SRAM tiling of the official
Triton kernel).

Per chunk the quadratic intra-chunk form runs on the MXU:
  CB (Q×Q) ← C·Bᵀ; masked/decayed; Y ← M·X  — all f32 accumulation.
VMEM per step ≈ (3·Q·N + Q·Hb·(2P+2) + Q² + Hb·N·P)·4B; at Q=128, N=128,
Hb=8, P=64 that is ≈ 0.9 MB — comfortably inside the ~16 MB v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import tpu_compiler_params

__all__ = ["ssd_scan_pallas"]


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, d_ref, y_ref, state_ref,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)    # (Q, Hb, P)
    B = b_ref[0, 0].astype(jnp.float32)    # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)    # (Q, N)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q, Hb)
    A = a_ref[0].astype(jnp.float32)       # (Hb,)
    D = d_ref[0].astype(jnp.float32)       # (Hb,)
    Q = x.shape[0]

    dtA = dt * A[None, :]                        # (Q, Hb)
    cum = jnp.cumsum(dtA, axis=0)                # (Q, Hb)
    total = cum[-1, :]                           # (Hb,)
    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    decay = jnp.exp(cum[:, None, :] - cum[None, :, :])  # (i, j, Hb)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    mask = (jj <= ii)[:, :, None]
    M = CB[:, :, None] * jnp.where(mask, decay, 0.0) * dt[None, :, :]  # (i,j,Hb)
    y = jnp.einsum("ijh,jhp->ihp", M, x)         # intra-chunk
    # inter-chunk: contribution of carried state
    S = state_ref[...]                            # (Hb, N, P)
    y += jnp.einsum("iN,hNp->ihp", C, S) * jnp.exp(cum)[..., None]
    y += D[None, :, None] * x
    # state update
    w = jnp.exp(total[None, :] - cum) * dt        # (Q, Hb)
    state_ref[...] = jnp.exp(total)[:, None, None] * S + jnp.einsum(
        "jN,jh,jhp->hNp", B, w, x)
    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "head_block", "interpret"))
def ssd_scan_pallas(x, B, C, dt, A, D, chunk: int = 128,
                    head_block: int = 8, interpret: bool = False):
    """x: (b, L, H, P); B, C: (b, L, N); dt: (b, L, H); A, D: (H,).

    Returns y (b, L, H, P).  L must divide by ``chunk``, H by ``head_block``.
    """
    b, L, H, Pd = x.shape
    N = B.shape[-1]
    chunk = min(chunk, L)
    head_block = min(head_block, H)
    if L % chunk or H % head_block:
        raise ValueError(f"L={L} % chunk={chunk} or H={H} % hb={head_block}")
    n = L // chunk
    nh = H // head_block
    # (b, n, Q, …) chunked layouts
    xc = x.reshape(b, n, chunk, H, Pd)
    Bc = B.reshape(b, n, chunk, N)
    Cc = C.reshape(b, n, chunk, N)
    dtc = dt.reshape(b, n, chunk, H)
    Ab = jnp.broadcast_to(A[None], (1, H))
    Db = jnp.broadcast_to(D[None], (1, H))
    grid = (b, nh, n)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, head_block, Pd),
                         lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, head_block),
                         lambda bi, hi, ci: (bi, ci, 0, hi)),
            pl.BlockSpec((1, head_block), lambda bi, hi, ci: (0, hi)),
            pl.BlockSpec((1, head_block), lambda bi, hi, ci: (0, hi)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, head_block, Pd),
                               lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, chunk, H, Pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((head_block, N, Pd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xc, Bc, Cc, dtc, Ab, Db)
    return out.reshape(b, L, H, Pd)
