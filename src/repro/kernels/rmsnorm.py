"""Pallas TPU fused RMSNorm: one HBM read, one write per row block.

Grid over row blocks; each step loads a (rows_block, D) tile into VMEM,
reduces in f32 and writes the normalized+scaled tile.  D is kept whole in
the block (lane-dim aligned; all model widths here are multiples of 128
except none — the kernel pads rows only)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import tpu_compiler_params

__all__ = ["rmsnorm_pallas"]


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * inv * w[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x, w, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = False):
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
