"""Pallas TPU kernel for the cost model's hot edge-latency reduction.

The paper's edge latency (§3) is, per edge ``i→j`` with placement rows
``x_i``/``x_j`` and communication matrix ``com``:

    edgeLat = max_u  x_{i,u} · s_i · Σ_v com_{u,v} · x_{j,v}

The batched what-if evaluator (repro.sim.batched) scores (scenario ×
placement) grids, so the reduction runs over a (B, E, V) tensor of gathered
edge endpoint rows against a (B, V, V) tensor of per-scenario com matrices —
a fused matvec + row-max that dominates evaluation time once B·E·V² grows.

One grid step handles one (scenario, edge-block) tile: the com matrix stays
resident in VMEM across the edge blocks of a scenario while ``x`` tiles
stream through — one HBM read per operand, one write per (B, E) tile.
Selectivity is folded into ``x_i`` by the caller, keeping the kernel a pure
bilinear-max.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import tpu_compiler_params

__all__ = ["edge_latency_pallas", "edge_latency_structured_pallas"]


def _edge_latency_kernel(xi_ref, xj_ref, com_ref, o_ref):
    xi = xi_ref[0].astype(jnp.float32)    # (be, V) — pre-scaled by s_i
    xj = xj_ref[0].astype(jnp.float32)    # (be, V)
    com = com_ref[0].astype(jnp.float32)  # (V, V)
    # t[e, u] = Σ_v com[u, v] · xj[e, v]
    t = jax.lax.dot_general(xj, com, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = jnp.max(xi * t, axis=1)


@functools.partial(jax.jit, static_argnames=("block_edges", "interpret"))
def edge_latency_pallas(x_i, x_j, com, block_edges: int = 128,
                        interpret: bool = False):
    """x_i, x_j: (B, E, V) with selectivity folded into x_i; com: (B, V, V)
    or (1, V, V) → (B, E) latencies ``max_u x_i[b,e,u]·(com[b] @ x_j[b,e])_u``.

    A singleton com batch dim is shared across B via the index map (no
    replication in HBM) — the score-grid path scores every placement of one
    scenario against a single resident com matrix."""
    B, E, V = x_i.shape
    if E == 0:
        return jnp.zeros((B, 0), jnp.float32)
    if com.shape[0] not in (1, B):
        raise ValueError(f"com batch dim {com.shape[0]} must be 1 or {B}")
    shared_com = com.shape[0] == 1
    be = min(block_edges, E)
    pad = (-E) % be
    if pad:
        zeros = jnp.zeros((B, pad, V), x_i.dtype)
        x_i = jnp.concatenate([x_i, zeros], axis=1)
        x_j = jnp.concatenate([x_j, zeros.astype(x_j.dtype)], axis=1)
    n_blocks = x_i.shape[1] // be
    com_index = (lambda b, e: (0, 0, 0)) if shared_com \
        else (lambda b, e: (b, 0, 0))
    out = pl.pallas_call(
        _edge_latency_kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, be, V), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, be, V), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, V, V), com_index),
        ],
        out_specs=pl.BlockSpec((1, be), lambda b, e: (b, e)),
        out_shape=jax.ShapeDtypeStruct((B, x_i.shape[1]), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x_i, x_j, com)
    return out[:, :E]


# -- structured (RegionFleet) variant -----------------------------------------
#
# At 10⁵ devices the (V, V) com matrix no longer exists; the structured path
# factors the per-edge matvec through region space:
#
#   t[e, u] = Σ_r A[r, u] · mass[e, r]  +  corr[u] · x_j[e, u]
#   A[r, u] = degrade_u · inter[region_u, r]          (R, V), per scenario
#   mass[e, r] = Σ_{v ∈ region r} degrade_v · x_j[e, v]   (E, R), XLA scatter
#
# so the kernel's inner product is (be, R) @ (R, V) — R ≪ V — and the only
# V-sized operands are the same (E, V) endpoint rows the dense kernel already
# streams.  The caller precomputes ``mass``/``A``/``corr`` (cheap XLA
# gathers/scatters, no V² anywhere) and the kernel fuses the small matmul,
# the diagonal correction, and the row-max in one VMEM-resident pass.


def _edge_latency_structured_kernel(xi_ref, xj_ref, mass_ref, a_ref, corr_ref,
                                    o_ref):
    xi = xi_ref[0].astype(jnp.float32)      # (be, V) — pre-scaled by s_i
    xj = xj_ref[0].astype(jnp.float32)      # (be, V)
    mass = mass_ref[0].astype(jnp.float32)  # (be, R)
    a = a_ref[0].astype(jnp.float32)        # (R, V)
    corr = corr_ref[0].astype(jnp.float32)  # (1, V)
    t = jax.lax.dot_general(mass, a, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = jnp.max(xi * (t + corr * xj), axis=1)


@functools.partial(jax.jit, static_argnames=("block_edges", "interpret"))
def edge_latency_structured_pallas(x_i, x_j, mass, a, corr,
                                   block_edges: int = 128,
                                   interpret: bool = False):
    """x_i, x_j: (B, E, V); mass: (B, E, R); a: (Bc, R, V); corr: (Bc, 1, V)
    with Bc ∈ {1, B} → (B, E) latencies ``max_u x_i·(mass @ a + corr·x_j)``.

    A singleton scenario batch (Bc == 1) is shared across all B placement
    rows via the index map, mirroring the dense kernel's shared-com path."""
    B, E, V = x_i.shape
    R = mass.shape[-1]
    if E == 0:
        return jnp.zeros((B, 0), jnp.float32)
    if a.shape[0] not in (1, B) or corr.shape[0] != a.shape[0]:
        raise ValueError(
            f"scenario batch dims {a.shape[0]}/{corr.shape[0]} must match "
            f"and be 1 or {B}")
    shared = a.shape[0] == 1
    be = min(block_edges, E)
    pad = (-E) % be
    if pad:
        zeros = jnp.zeros((B, pad, V), x_i.dtype)
        x_i = jnp.concatenate([x_i, zeros], axis=1)
        x_j = jnp.concatenate([x_j, zeros.astype(x_j.dtype)], axis=1)
        mass = jnp.concatenate(
            [mass, jnp.zeros((B, pad, R), mass.dtype)], axis=1)
    n_blocks = x_i.shape[1] // be
    scen_index = (lambda b, e: (0, 0, 0)) if shared \
        else (lambda b, e: (b, 0, 0))
    out = pl.pallas_call(
        _edge_latency_structured_kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, be, V), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, be, V), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, be, R), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, R, V), scen_index),
            pl.BlockSpec((1, 1, V), scen_index),
        ],
        out_specs=pl.BlockSpec((1, be), lambda b, e: (b, e)),
        out_shape=jax.ShapeDtypeStruct((B, x_i.shape[1]), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x_i, x_j, mass, a, corr)
    return out[:, :E]
