"""Pallas TPU kernels for the cost model's hot edge-latency reduction.

The paper's edge latency (§3) is, per edge ``i→j`` with placement rows
``x_i``/``x_j`` and communication matrix ``com``:

    edgeLat = max_u  x_{i,u} · s_i · Σ_v com_{u,v} · x_{j,v}

The batched what-if evaluator (repro.sim.batched) scores (scenario ×
placement) grids, so the reduction runs over a (B, E, V) tensor of gathered
edge endpoint rows against a (B, V, V) tensor of per-scenario com matrices —
a fused matvec + row-max that dominates evaluation time once B·E·V² grows.
Selectivity is folded into ``x_i`` by the caller, keeping the kernels pure
bilinear-maxes.

Compiled-ready blocking scheme (see kernels/README.md for the full story):

  * every V-sized axis is padded to the f32 lane width (128) inside the
    wrapper, and E to the sublane width (8), so arbitrary fleet sizes lower
    cleanly — padded u-columns are masked to -inf before the row max,
    padded v-columns contribute exact zeros to the contraction;
  * the DENSE kernel runs a (B, E/be, V/bv, V/bv) grid: the innermost v
    axis accumulates the ``com @ x_j`` matvec into a VMEM scratch tile, the
    u axis folds per-block row maxima into the output with a running max —
    so the (E, V) endpoint rows and the (V, V) com matrix stream through
    VMEM in (be, bv) / (bv, bv) tiles instead of requiring residency;
  * the STRUCTURED kernel (RegionFleetFamily: ``t = mass @ A + corr·x_j``
    with R ≪ V) runs a (B, E/be, V/bv) grid, V-blocking its (be, R)@(R, bv)
    product and diagonal correction with the same running max over u-tiles.

Block shapes come from :mod:`repro.kernels.autotune` via the dispatch layer
(:mod:`repro.kernels.dispatch`); the single-tile kernels the blocked ones
replaced are kept as ``*_single_tile`` parity references — at small V the
blocked kernels reproduce them bitwise (gated in tests/test_kernel_blocking).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

__all__ = ["LANE", "SUBLANE", "BlockGeometry", "block_geometry",
           "edge_latency_pallas", "edge_latency_structured_pallas",
           "edge_latency_pallas_single_tile",
           "edge_latency_structured_pallas_single_tile"]

LANE = 128     # f32 minor-dim tile width on TPU
SUBLANE = 8    # f32 second-minor tile width


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class BlockGeometry:
    """Concrete padded dims + clamped block shapes for one problem shape.

    This is THE single source of truth for how a (E, V[, R]) shape lowers:
    the kernel wrappers pad/grid exactly by it and the autotune VMEM/time
    models price exactly it, so the model can never drift from the kernel.
    """

    be: int           # edge-block rows (≤ padded E, multiple of SUBLANE)
    bv: int           # V-block width (≤ padded V, multiple of LANE)
    e_pad: int        # E padded to a multiple of be
    v_pad: int        # V padded to a multiple of bv
    r_pad: int | None  # R padded to a multiple of LANE (structured only)
    n_e: int          # edge-block grid steps
    n_u: int          # u-axis (row-max) grid steps
    n_v: int          # v-axis (contraction) grid steps; 1 for structured


def block_geometry(kind: str, E: int, V: int, R: int | None,
                   block_edges: int, block_v: int) -> BlockGeometry:
    """Clamp a requested (block_edges, block_v) to a legal geometry for the
    shape: blocks are rounded to hardware tile multiples, then the axes pad
    up to block multiples (never the other way round — a requested block
    larger than the padded axis shrinks to it)."""
    if kind not in ("dense", "structured"):
        raise ValueError(f"kind must be dense|structured, got {kind!r}")
    if E < 1 or V < 1:
        raise ValueError(f"need E >= 1 and V >= 1, got E={E}, V={V}")
    bv = _round_up(max(1, block_v), LANE)
    bv = min(bv, _round_up(V, LANE))
    v_pad = _round_up(V, bv)
    be = _round_up(max(1, block_edges), SUBLANE)
    be = min(be, _round_up(E, SUBLANE))
    e_pad = _round_up(E, be)
    n_v = v_pad // bv if kind == "dense" else 1
    r_pad = None
    if kind == "structured":
        if R is None or R < 1:
            raise ValueError(f"structured geometry needs R >= 1, got {R}")
        r_pad = _round_up(R, LANE)
    return BlockGeometry(be=be, bv=bv, e_pad=e_pad, v_pad=v_pad,
                         r_pad=r_pad, n_e=e_pad // be, n_u=v_pad // bv,
                         n_v=n_v)


def _pad_axis(x: jnp.ndarray, axis: int, target: int) -> jnp.ndarray:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# -- dense V-blocked kernel ---------------------------------------------------
#
# grid = (B, n_e, n_u, n_v); iteration is row-major, so for one (b, e, u)
# the v axis runs innermost: the scratch tile accumulates the partial
# matvec t[e, u_blk] += com[u_blk, v_blk] @ x_j[e, v_blk] across v-tiles,
# and on the last v-tile the block's row max folds into the output under a
# running max across u-tiles.  Padded u-columns are masked to -inf so the
# max over real columns is exact for operands of any sign.


def _edge_latency_blocked_kernel(n_v: int, v_real: int, xi_ref, xj_ref,
                                 com_ref, o_ref, t_acc):
    u = pl.program_id(2)
    v = pl.program_id(3)

    @pl.when(v == 0)
    def _zero():
        t_acc[...] = jnp.zeros_like(t_acc)

    xj = xj_ref[0].astype(jnp.float32)    # (be, bv) — v-tile of x_j
    com = com_ref[0].astype(jnp.float32)  # (bu=bv, bv) — (u, v) com tile
    # t_acc[e, u'] += Σ_{v'} com[u', v'] · xj[e, v']
    t_acc[...] += jax.lax.dot_general(xj, com, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)

    @pl.when(v == n_v - 1)
    def _fold_max():
        xi = xi_ref[0].astype(jnp.float32)  # (be, bu) — pre-scaled by s_i
        u_ix = u * xi.shape[1] + jax.lax.broadcasted_iota(
            jnp.int32, xi.shape, 1)
        part = jnp.max(jnp.where(u_ix < v_real, xi * t_acc[...], -jnp.inf),
                       axis=1)

        @pl.when(u == 0)
        def _init():
            o_ref[0] = part

        @pl.when(u > 0)
        def _running():
            o_ref[0] = jnp.maximum(o_ref[0], part)


@functools.partial(jax.jit,
                   static_argnames=("block_edges", "block_v", "interpret"))
def edge_latency_pallas(x_i, x_j, com, block_edges: int = 128,
                        block_v: int = 512, interpret: bool = False):
    """x_i, x_j: (B, E, V) with selectivity folded into x_i; com: (B, V, V)
    or (1, V, V) → (B, E) latencies ``max_u x_i[b,e,u]·(com[b] @ x_j[b,e])_u``.

    V-blocked: (E, V) tiles and (bv, bv) com tiles stream through VMEM (see
    module docstring), so V needs neither lane alignment nor VMEM residency.
    A singleton com batch dim is shared across B via the index map (no
    replication in HBM) — the score-grid path scores every placement of one
    scenario against a single resident com matrix."""
    B, E, V = x_i.shape
    if E == 0:
        return jnp.zeros((B, 0), jnp.float32)
    if com.shape[0] not in (1, B):
        raise ValueError(f"com batch dim {com.shape[0]} must be 1 or {B}")
    shared_com = com.shape[0] == 1
    g = block_geometry("dense", E, V, None, block_edges, block_v)
    x_i = _pad_axis(_pad_axis(x_i, 2, g.v_pad), 1, g.e_pad)
    x_j = _pad_axis(_pad_axis(x_j, 2, g.v_pad), 1, g.e_pad)
    com = _pad_axis(_pad_axis(com, 2, g.v_pad), 1, g.v_pad)
    com_ix = (lambda b, e, u, v: (0, u, v)) if shared_com \
        else (lambda b, e, u, v: (b, u, v))
    out = pl.pallas_call(
        functools.partial(_edge_latency_blocked_kernel, g.n_v, V),
        grid=(B, g.n_e, g.n_u, g.n_v),
        in_specs=[
            pl.BlockSpec((1, g.be, g.bv), lambda b, e, u, v: (b, e, u)),
            pl.BlockSpec((1, g.be, g.bv), lambda b, e, u, v: (b, e, v)),
            pl.BlockSpec((1, g.bv, g.bv), com_ix),
        ],
        out_specs=pl.BlockSpec((1, g.be), lambda b, e, u, v: (b, e)),
        out_shape=jax.ShapeDtypeStruct((B, g.e_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((g.be, g.bv), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(x_i, x_j, com)
    return out[:, :E]


# -- structured (RegionFleet) V-blocked kernel --------------------------------
#
# At 10⁵ devices the (V, V) com matrix no longer exists; the structured path
# factors the per-edge matvec through region space:
#
#   t[e, u] = Σ_r A[r, u] · mass[e, r]  +  corr[u] · x_j[e, u]
#   A[r, u] = degrade_u · inter[region_u, r]          (R, V), per scenario
#   mass[e, r] = Σ_{v ∈ region r} degrade_v · x_j[e, v]   (E, R), XLA scatter
#
# so the kernel's inner product is (be, R) @ (R, bv) — R ≪ V — and the only
# V-sized operands are the same (E, V) endpoint rows the dense kernel already
# streams.  The caller precomputes ``mass``/``A``/``corr`` (cheap XLA
# gathers/scatters, no V² anywhere); here the u axis is V-blocked with the
# same running max as the dense kernel, so A/corr/x tiles stream through
# VMEM in (R, bv)/(1, bv)/(be, bv) slices and V = 131 072 fleets never need
# a V-resident row.  R pads to the lane width (zero rows of mass/A add
# exact zeros to the product).


def _edge_latency_structured_blocked_kernel(v_real: int, xi_ref, xj_ref,
                                            mass_ref, a_ref, corr_ref,
                                            o_ref):
    u = pl.program_id(2)
    xi = xi_ref[0].astype(jnp.float32)      # (be, bv) — pre-scaled by s_i
    xj = xj_ref[0].astype(jnp.float32)      # (be, bv)
    mass = mass_ref[0].astype(jnp.float32)  # (be, Rp)
    a = a_ref[0].astype(jnp.float32)        # (Rp, bv)
    corr = corr_ref[0].astype(jnp.float32)  # (1, bv)
    t = jax.lax.dot_general(mass, a, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u_ix = u * xi.shape[1] + jax.lax.broadcasted_iota(jnp.int32, xi.shape, 1)
    part = jnp.max(jnp.where(u_ix < v_real, xi * (t + corr * xj), -jnp.inf),
                   axis=1)

    @pl.when(u == 0)
    def _init():
        o_ref[0] = part

    @pl.when(u > 0)
    def _running():
        o_ref[0] = jnp.maximum(o_ref[0], part)


@functools.partial(jax.jit,
                   static_argnames=("block_edges", "block_v", "interpret"))
def edge_latency_structured_pallas(x_i, x_j, mass, a, corr,
                                   block_edges: int = 128,
                                   block_v: int = 512,
                                   interpret: bool = False):
    """x_i, x_j: (B, E, V); mass: (B, E, R); a: (Bc, R, V); corr: (Bc, 1, V)
    with Bc ∈ {1, B} → (B, E) latencies ``max_u x_i·(mass @ a + corr·x_j)``.

    V-blocked over the u axis with a running max (module docstring); R pads
    to the lane width with exact-zero rows.  A singleton scenario batch
    (Bc == 1) is shared across all B placement rows via the index map,
    mirroring the dense kernel's shared-com path."""
    B, E, V = x_i.shape
    R = mass.shape[-1]
    if E == 0:
        return jnp.zeros((B, 0), jnp.float32)
    if a.shape[0] not in (1, B) or corr.shape[0] != a.shape[0]:
        raise ValueError(
            f"scenario batch dims {a.shape[0]}/{corr.shape[0]} must match "
            f"and be 1 or {B}")
    shared = a.shape[0] == 1
    g = block_geometry("structured", E, V, R, block_edges, block_v)
    x_i = _pad_axis(_pad_axis(x_i, 2, g.v_pad), 1, g.e_pad)
    x_j = _pad_axis(_pad_axis(x_j, 2, g.v_pad), 1, g.e_pad)
    mass = _pad_axis(_pad_axis(mass, 2, g.r_pad), 1, g.e_pad)
    a = _pad_axis(_pad_axis(a, 2, g.v_pad), 1, g.r_pad)
    corr = _pad_axis(corr, 2, g.v_pad)
    scen_ix = (lambda b, e, u: (0, 0, u)) if shared \
        else (lambda b, e, u: (b, 0, u))
    out = pl.pallas_call(
        functools.partial(_edge_latency_structured_blocked_kernel, V),
        grid=(B, g.n_e, g.n_u),
        in_specs=[
            pl.BlockSpec((1, g.be, g.bv), lambda b, e, u: (b, e, u)),
            pl.BlockSpec((1, g.be, g.bv), lambda b, e, u: (b, e, u)),
            pl.BlockSpec((1, g.be, g.r_pad), lambda b, e, u: (b, e, 0)),
            pl.BlockSpec((1, g.r_pad, g.bv), scen_ix),
            pl.BlockSpec((1, 1, g.bv), scen_ix),
        ],
        out_specs=pl.BlockSpec((1, g.be), lambda b, e, u: (b, e)),
        out_shape=jax.ShapeDtypeStruct((B, g.e_pad), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(x_i, x_j, mass, a, corr)
    return out[:, :E]


# -- single-tile parity references --------------------------------------------
#
# The pre-blocking kernels: whole-V tiles resident in VMEM, no lane padding.
# Kept verbatim as the exact-parity targets the blocked kernels are gated
# against at small V (tests/test_kernel_blocking.py) — at one (u, v) tile
# the blocked kernels reduce to precisely this computation.


def _edge_latency_single_tile_kernel(xi_ref, xj_ref, com_ref, o_ref):
    xi = xi_ref[0].astype(jnp.float32)    # (be, V) — pre-scaled by s_i
    xj = xj_ref[0].astype(jnp.float32)    # (be, V)
    com = com_ref[0].astype(jnp.float32)  # (V, V)
    t = jax.lax.dot_general(xj, com, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = jnp.max(xi * t, axis=1)


@functools.partial(jax.jit, static_argnames=("block_edges", "interpret"))
def edge_latency_pallas_single_tile(x_i, x_j, com, block_edges: int = 128,
                                    interpret: bool = False):
    """The original whole-V dense kernel (parity reference; assumes the
    (V, V) com tile fits VMEM — do not use for large V)."""
    B, E, V = x_i.shape
    if E == 0:
        return jnp.zeros((B, 0), jnp.float32)
    if com.shape[0] not in (1, B):
        raise ValueError(f"com batch dim {com.shape[0]} must be 1 or {B}")
    shared_com = com.shape[0] == 1
    be = min(block_edges, E)
    x_i = _pad_axis(x_i, 1, _round_up(E, be))
    x_j = _pad_axis(x_j, 1, _round_up(E, be))
    n_blocks = x_i.shape[1] // be
    com_index = (lambda b, e: (0, 0, 0)) if shared_com \
        else (lambda b, e: (b, 0, 0))
    out = pl.pallas_call(
        _edge_latency_single_tile_kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, be, V), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, be, V), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, V, V), com_index),
        ],
        out_specs=pl.BlockSpec((1, be), lambda b, e: (b, e)),
        out_shape=jax.ShapeDtypeStruct((B, x_i.shape[1]), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x_i, x_j, com)
    return out[:, :E]


def _edge_latency_structured_single_tile_kernel(xi_ref, xj_ref, mass_ref,
                                                a_ref, corr_ref, o_ref):
    xi = xi_ref[0].astype(jnp.float32)      # (be, V) — pre-scaled by s_i
    xj = xj_ref[0].astype(jnp.float32)      # (be, V)
    mass = mass_ref[0].astype(jnp.float32)  # (be, R)
    a = a_ref[0].astype(jnp.float32)        # (R, V)
    corr = corr_ref[0].astype(jnp.float32)  # (1, V)
    t = jax.lax.dot_general(mass, a, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0] = jnp.max(xi * (t + corr * xj), axis=1)


@functools.partial(jax.jit, static_argnames=("block_edges", "interpret"))
def edge_latency_structured_pallas_single_tile(x_i, x_j, mass, a, corr,
                                               block_edges: int = 128,
                                               interpret: bool = False):
    """The original whole-V structured kernel (parity reference; (R, V) and
    (be, V) tiles resident — do not use for large V)."""
    B, E, V = x_i.shape
    R = mass.shape[-1]
    if E == 0:
        return jnp.zeros((B, 0), jnp.float32)
    if a.shape[0] not in (1, B) or corr.shape[0] != a.shape[0]:
        raise ValueError(
            f"scenario batch dims {a.shape[0]}/{corr.shape[0]} must match "
            f"and be 1 or {B}")
    shared = a.shape[0] == 1
    be = min(block_edges, E)
    e_pad = _round_up(E, be)
    x_i = _pad_axis(x_i, 1, e_pad)
    x_j = _pad_axis(x_j, 1, e_pad)
    mass = _pad_axis(mass, 1, e_pad)
    n_blocks = x_i.shape[1] // be
    scen_index = (lambda b, e: (0, 0, 0)) if shared \
        else (lambda b, e: (b, 0, 0))
    out = pl.pallas_call(
        _edge_latency_structured_single_tile_kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, be, V), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, be, V), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, be, R), lambda b, e: (b, e, 0)),
            pl.BlockSpec((1, R, V), scen_index),
            pl.BlockSpec((1, 1, V), scen_index),
        ],
        out_specs=pl.BlockSpec((1, be), lambda b, e: (b, e)),
        out_shape=jax.ShapeDtypeStruct((B, x_i.shape[1]), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x_i, x_j, mass, a, corr)
    return out[:, :E]
