"""Streaming execution engine: runs a StreamGraph over a device fleet
according to a fractional Placement (paper §3 made executable).

Each batch flows source→sinks; every operator's rows are split across its
devices by ``x_{i,u}``, processed per-device (with per-device speed
modifiers so heterogeneity/stragglers are *felt*, not just modeled), and
re-partitioned along each edge.  The engine reports BOTH:

  * modeled latency — the paper's cost model on the current fleet state,
  * observed per-device busy time — fed back into the straggler monitor,
    which degrades the fleet and re-optimizes placement (runtime loop).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.costmodel import CostConfig, edge_latencies, latency
from repro.core.devices import ExplicitFleet, RegionFleet
from repro.core.optimizers import PlacementProblem, greedy_transfer
from repro.streaming.operators import StreamGraph

__all__ = ["StreamingEngine", "BatchReport"]


@dataclasses.dataclass
class BatchReport:
    modeled_latency: float
    edge_latencies: np.ndarray
    device_busy: np.ndarray  # observed seconds per device
    rows_in: int
    rows_out: dict
    wall_s: float


class StreamingEngine:
    def __init__(self, graph: StreamGraph, fleet, placement: np.ndarray,
                 alpha: float = 0.0, device_speed: np.ndarray | None = None):
        self.graph = graph
        self.fleet = fleet
        self.x = np.asarray(placement, dtype=np.float64)
        self.cfg = CostConfig(alpha=alpha)
        n = fleet.n_devices
        self.device_speed = (np.ones(n) if device_speed is None
                             else np.asarray(device_speed, float))
        self.observed_busy = np.zeros(n)

    # ------------------------------------------------------------ running --
    def _split_rows(self, rows: np.ndarray, fractions: np.ndarray):
        """Deterministic proportional row split across devices."""
        n = len(rows)
        counts = np.floor(fractions * n).astype(int)
        rem = n - counts.sum()
        if rem > 0:
            order = np.argsort(-(fractions * n - counts))
            counts[order[:rem]] += 1
        out, start = {}, 0
        for u, c in enumerate(counts):
            if c > 0:
                out[u] = rows[start:start + c]
                start += c
        return out

    def run_batch(self, batch: np.ndarray) -> BatchReport:
        t0 = time.perf_counter()
        g = self.graph
        busy = np.zeros(self.fleet.n_devices)
        outputs: dict[int, np.ndarray] = {}
        rows_out: dict[str, int] = {}
        for i in g.meta.topo_order:
            op = g.ops[i]
            if not g.meta.predecessors(i):
                rows = batch
            else:
                parts = [outputs[p] for p in g.meta.predecessors(i)]
                rows = np.concatenate(parts, axis=0) if len(parts) > 1 \
                    else parts[0]
            shards = self._split_rows(rows, self.x[i])
            processed = []
            for u, shard in shards.items():
                t1 = time.perf_counter()
                processed.append(op.fn(shard))
                dt = (time.perf_counter() - t1) / self.device_speed[u]
                busy[u] += dt
            out = (np.concatenate(processed, axis=0) if processed
                   else rows[:0])
            outputs[i] = out
            if not g.meta.successors(i):
                rows_out[op.name] = len(out)
        self.observed_busy = 0.8 * self.observed_busy + 0.2 * busy
        elat = edge_latencies(g.meta, self.fleet, self.x, self.cfg)
        lat = latency(g.meta, self.fleet, self.x, self.cfg)
        return BatchReport(lat, elat, busy, len(batch), rows_out,
                           time.perf_counter() - t0)

    # ------------------------------------------------------- trace hooks --
    def apply_event(self, kind: str, device: int, factor: float = 1.0,
                    beta: float = 0.0):
        """Uniform entry point for replayed trace events (repro.sim.replay):
        ``degrade`` → degrade_and_replace, ``remove`` → remove_device.
        ``device`` indexes the CURRENT fleet."""
        if kind == "degrade":
            return self.degrade_and_replace(device, factor, beta=beta)
        if kind == "remove":
            return self.remove_device(device, beta=beta)
        raise ValueError(f"unknown event kind {kind!r}")

    # ------------------------------------------------- straggler handling --
    def degrade_and_replace(self, device: int, factor: float,
                            beta: float = 0.0):
        """Straggler mitigation: fold the observed slowdown into the fleet,
        re-run the placement optimizer, adopt the new x (the paper's
        heterogeneity terms used as live state)."""
        if isinstance(self.fleet, RegionFleet):
            self.fleet = ExplicitFleet(com_cost=self.fleet.com_matrix(),
                                       speed=self.fleet.effective_speed(),
                                       available=self.fleet.available)
        self.fleet = self.fleet.degrade_device(device, factor)
        prob = PlacementProblem(self.graph.meta, self.fleet,
                                CostConfig(alpha=self.cfg.alpha,
                                           include_compute=True), beta=beta)
        res = greedy_transfer(prob, x0=self.x)
        self.x = res.x
        self.device_speed[device] /= factor
        return res

    def remove_device(self, device: int, beta: float = 0.0):
        """Elastic down-scale after a device loss: rebuild the fleet without
        it, re-optimize, remap fractions (column deleted, rows renormalized
        as a warm start)."""
        if isinstance(self.fleet, RegionFleet):
            self.fleet = ExplicitFleet(com_cost=self.fleet.com_matrix(),
                                       speed=self.fleet.effective_speed(),
                                       available=self.fleet.available)
        fleet2, keep = self.fleet.without_devices([device])
        x0 = self.x[:, keep]
        x0 = x0 / np.maximum(x0.sum(axis=1, keepdims=True), 1e-9)
        prob = PlacementProblem(self.graph.meta, fleet2,
                                CostConfig(alpha=self.cfg.alpha,
                                           include_compute=True), beta=beta)
        res = greedy_transfer(prob, x0=x0)
        self.fleet = fleet2
        self.x = res.x
        self.device_speed = self.device_speed[keep]
        self.observed_busy = self.observed_busy[keep]
        return res
