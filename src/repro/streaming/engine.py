"""Streaming execution engine: runs a StreamGraph over a device fleet
according to a fractional Placement (paper §3 made executable).

Each batch flows source→sinks; every operator's rows are split across its
devices by ``x_{i,u}``, processed per-device (with per-device speed
modifiers so heterogeneity/stragglers are *felt*, not just modeled), and
re-partitioned along each edge.  The engine reports BOTH:

  * modeled latency — the paper's cost model on the current fleet state,
  * observed per-device busy time — fed back into the straggler monitor,
    which degrades the fleet and re-optimizes placement (runtime loop).

The engine is also the WORLD of the closed adaptive loop
(:mod:`repro.adapt`): trace events mutate its true fleet state
(``degrade`` / ``remove`` / region-level ``outage`` / ``recover``) and its
true operator behavior (``drift`` — runtime selectivity drift the cost
model does NOT see), while an external controller watches only the
observations and decides when to recalibrate and re-place.  For that loop
the event hooks accept ``reoptimize=False`` (the controller, not the
engine, owns placement) and ``observed="work"`` makes busy accounting
deterministic (work-model seconds instead of wall time), so controller
decisions are reproducible under a fixed seed.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import obs
from repro.core.costmodel import CostConfig, edge_latencies, latency
from repro.core.devices import ExplicitFleet, RegionFleet
from repro.core.graph import OpGraph
from repro.core.optimizers import PlacementProblem, greedy_transfer
from repro.streaming.operators import StreamGraph

__all__ = ["StreamingEngine", "BatchReport"]

# seconds of simulated busy time per (work unit × row) at unit speed when
# observed="work" — an arbitrary physical unit the calibration loop re-fits
# from observation anyway (repro.core.calibration.refit_from_replay)
WORK_SECONDS_PER_ROW = 1e-6


@dataclasses.dataclass
class BatchReport:
    modeled_latency: float
    edge_latencies: np.ndarray
    device_busy: np.ndarray  # observed seconds per device
    rows_in: int
    rows_out: dict
    wall_s: float
    # the WORLD's end-to-end latency: the cost model on the current fleet
    # with the DRIFTED selectivities (true_graph).  Equal to modeled_latency
    # until a "drift" event lands; this is the signal an external observer
    # would measure, and what the adaptive controller watches — the stale
    # modeled_latency above is what the engine's own nominal model believes
    true_latency: float = 0.0
    # per-operator row counters — observables any real runtime has, and the
    # closed loop's calibration inputs: inputs drive the busy/occupancy
    # refit exactly (no nominal-selectivity bias), outputs/inputs IS the
    # operator's true selectivity this tick (drift included)
    op_rows_in: np.ndarray | None = None   # (n_ops,)
    op_rows_out: np.ndarray | None = None  # (n_ops,)


class StreamingEngine:
    def __init__(self, graph: StreamGraph, fleet, placement: np.ndarray,
                 alpha: float = 0.0, device_speed: np.ndarray | None = None,
                 observed: str = "wall"):
        self.graph = graph
        self.fleet = fleet
        self.x = np.asarray(placement, dtype=np.float64)
        self.cfg = CostConfig(alpha=alpha)
        n = fleet.n_devices
        # default to the fleet's own effective speeds: the simulated compute
        # behavior then matches the fleet description the cost model prices
        # (a heterogeneous fleet whose devices all ran at speed 1 would make
        # every observation contradict the model from tick 0)
        self.device_speed = (
            np.asarray(fleet.effective_speed(), dtype=np.float64).copy()
            if device_speed is None
            else np.asarray(device_speed, float))
        self.observed_busy = np.zeros(n)
        if observed not in ("wall", "work"):
            raise ValueError(f"observed must be 'wall' or 'work', "
                             f"got {observed!r}")
        self.observed = observed
        # runtime selectivity multipliers: the TRUE per-op behavior drifts
        # away from the cost-model metadata (sel_scale ≠ 1 ⇒ the model is
        # stale until someone recalibrates) — see apply_event("drift")
        self.sel_scale = np.ones(graph.meta.n_ops)

    # ------------------------------------------------------------ running --
    def _split_rows(self, rows: np.ndarray, fractions: np.ndarray):
        """Deterministic proportional row split across devices."""
        n = len(rows)
        counts = np.floor(fractions * n).astype(int)
        rem = n - counts.sum()
        if rem > 0:
            order = np.argsort(-(fractions * n - counts))
            counts[order[:rem]] += 1
        out, start = {}, 0
        for u, c in enumerate(counts):
            if c > 0:
                out[u] = rows[start:start + c]
                start += c
        return out

    def _apply_sel_scale(self, out: np.ndarray, i: int) -> np.ndarray:
        """Resample operator i's output rows to its drifted TRUE selectivity
        (sel_scale·s_i): truncate when drifted down, repeat rows when drifted
        up.  sel_scale == 1 is exactly a no-op."""
        scale = self.sel_scale[i]
        if scale == 1.0 or len(out) == 0:
            return out
        target = max(int(round(len(out) * scale)), 0)
        if target <= len(out):
            return out[:target]
        reps = -(-target // len(out))  # ceil
        return np.concatenate([out] * reps, axis=0)[:target]

    def run_batch(self, batch: np.ndarray) -> BatchReport:
        with obs.span("engine.run_batch", rows=len(batch)):
            report = self._run_batch(batch)
        reg = obs.registry()
        if reg.enabled:
            reg.counter("engine.batches").add(1)
            reg.counter("engine.rows_in").add(report.rows_in)
            # the WORLD's end-to-end latency signal, as a Perfetto counter
            # timeline — what an adaptive controller watches
            obs.counter_sample("engine.true_latency", report.true_latency)
        return report

    def _run_batch(self, batch: np.ndarray) -> BatchReport:
        t0 = time.perf_counter()
        g = self.graph
        busy = np.zeros(self.fleet.n_devices)
        outputs: dict[int, np.ndarray] = {}
        rows_out: dict[str, int] = {}
        op_in = np.zeros(g.meta.n_ops)
        op_out = np.zeros(g.meta.n_ops)
        for i in g.meta.topo_order:
            op = g.ops[i]
            if not g.meta.predecessors(i):
                rows = batch
            else:
                parts = [outputs[p] for p in g.meta.predecessors(i)]
                rows = np.concatenate(parts, axis=0) if len(parts) > 1 \
                    else parts[0]
            shards = self._split_rows(rows, self.x[i])
            processed = []
            for u, shard in shards.items():
                t1 = time.perf_counter()
                processed.append(op.fn(shard))
                if self.observed == "work":
                    # deterministic observation: work-model seconds (the
                    # simulated world's ground truth, reproducible across
                    # runs — wall time of tiny numpy calls is not)
                    dt = op.work * len(shard) * WORK_SECONDS_PER_ROW \
                        / self.device_speed[u]
                else:
                    dt = (time.perf_counter() - t1) / self.device_speed[u]
                busy[u] += dt
            out = (np.concatenate(processed, axis=0) if processed
                   else rows[:0])
            out = self._apply_sel_scale(out, i)
            outputs[i] = out
            op_in[i] = len(rows)
            op_out[i] = len(out)
            if not g.meta.successors(i):
                rows_out[op.name] = len(out)
        self.observed_busy = 0.8 * self.observed_busy + 0.2 * busy
        elat = edge_latencies(g.meta, self.fleet, self.x, self.cfg)
        lat = latency(g.meta, self.fleet, self.x, self.cfg)
        tlat = lat if np.all(self.sel_scale == 1.0) else \
            latency(self.true_graph(), self.fleet, self.x, self.cfg)
        return BatchReport(lat, elat, busy, len(batch), rows_out,
                           time.perf_counter() - t0, true_latency=tlat,
                           op_rows_in=op_in, op_rows_out=op_out)

    # ------------------------------------------------------- trace hooks --
    def apply_event(self, kind: str, device: int, factor: float = 1.0,
                    beta: float = 0.0, reoptimize: bool = True):
        """Uniform entry point for replayed trace events (repro.sim.replay):

          * ``degrade``  → degrade_and_replace (``device`` indexes the
            CURRENT fleet),
          * ``remove``   → remove_device,
          * ``outage``   → every current device of REGION ``device`` is
            degraded by ``factor`` (time-correlated whole-region failure;
            paired with a later ``recover``),
          * ``recover``  → the region's devices degraded by ``1/factor``
            (the outage lifts),
          * ``drift``    → operator ``device``'s TRUE selectivity is scaled
            by ``factor`` (the cost-model metadata is left stale — this is
            the drift an adaptive controller exists to chase).

        ``reoptimize=False`` applies the fleet mutation without re-running
        the placement optimizer (placement is remapped mechanically on
        removals) — the mode :mod:`repro.adapt` uses, since the controller
        owns the re-optimization decision.
        """
        if kind == "degrade":
            return self.degrade_and_replace(device, factor, beta=beta,
                                            reoptimize=reoptimize)
        if kind == "remove":
            return self.remove_device(device, beta=beta,
                                      reoptimize=reoptimize)
        if kind in ("outage", "recover"):
            f = factor if kind == "outage" else 1.0 / factor
            region = np.asarray(self.fleet.region)
            hit = [int(u) for u in np.flatnonzero(region == device)]
            res = None
            for u in hit:
                # one optimizer pass at most (after ALL links moved), never
                # one per device — regions can be wide
                res = self.degrade_and_replace(
                    u, f, beta=beta,
                    reoptimize=reoptimize and u == hit[-1])
            return res
        if kind == "drift":
            self.sel_scale[device] *= factor
            return None
        raise ValueError(f"unknown event kind {kind!r}")

    def true_graph(self) -> OpGraph:
        """The WORLD's operator graph: cost-model metadata with the drifted
        runtime selectivities folded in (``s_i·sel_scale_i``).  This is what
        an omniscient oracle prices; the engine's own ``modeled_latency``
        keeps using the stale nominal graph, exactly like the controller's
        belief does."""
        meta = self.graph.meta
        if np.all(self.sel_scale == 1.0):
            return meta
        ops = [dataclasses.replace(
            op, selectivity=float(op.selectivity * self.sel_scale[i]))
            for i, op in enumerate(meta.operators)]
        return OpGraph(ops, list(meta.edges))

    # ------------------------------------------------- straggler handling --
    def degrade_and_replace(self, device: int, factor: float,
                            beta: float = 0.0, reoptimize: bool = True):
        """Straggler mitigation: fold the observed slowdown into the fleet,
        re-run the placement optimizer, adopt the new x (the paper's
        heterogeneity terms used as live state).  ``reoptimize=False`` only
        mutates the fleet/speed state."""
        if isinstance(self.fleet, RegionFleet):
            self.fleet = ExplicitFleet(com_cost=self.fleet.com_matrix(),
                                       speed=self.fleet.effective_speed(),
                                       available=self.fleet.available,
                                       region=self.fleet.region)
        self.fleet = self.fleet.degrade_device(device, factor)
        self.device_speed[device] /= factor
        if not reoptimize:
            return None
        prob = PlacementProblem(self.graph.meta, self.fleet,
                                CostConfig(alpha=self.cfg.alpha,
                                           include_compute=True), beta=beta)
        res = greedy_transfer(prob, x0=self.x)
        self.x = res.x
        return res

    def remove_device(self, device: int, beta: float = 0.0,
                      reoptimize: bool = True):
        """Elastic down-scale after a device loss: rebuild the fleet without
        it, re-optimize, remap fractions (column deleted, rows renormalized
        as a warm start).  ``reoptimize=False`` keeps the renormalized
        warm-start placement as-is."""
        if isinstance(self.fleet, RegionFleet):
            self.fleet = ExplicitFleet(com_cost=self.fleet.com_matrix(),
                                       speed=self.fleet.effective_speed(),
                                       available=self.fleet.available,
                                       region=self.fleet.region)
        fleet2, keep = self.fleet.without_devices([device])
        x0 = self.x[:, keep]
        x0 = x0 / np.maximum(x0.sum(axis=1, keepdims=True), 1e-9)
        self.fleet = fleet2
        self.device_speed = self.device_speed[keep]
        self.observed_busy = self.observed_busy[keep]
        if not reoptimize:
            self.x = x0
            return None
        prob = PlacementProblem(self.graph.meta, fleet2,
                                CostConfig(alpha=self.cfg.alpha,
                                           include_compute=True), beta=beta)
        res = greedy_transfer(prob, x0=x0)
        self.x = res.x
        return res
