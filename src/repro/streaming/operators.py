"""Streaming operators: the executable counterpart of the paper's ``V_op``.

A :class:`StreamOperator` couples the cost-model metadata (selectivity,
work, DQ eligibility) with an actual batch function, so the same DAG object
is both *optimized* (repro.core) and *executed* (repro.streaming.engine).
Model inference is just another operator — an LM decode step wrapped with
its batch semantics — which is how the paper's "massively parallel complex
streaming analytics" meets the model zoo.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.graph import Operator, OpGraph

__all__ = ["StreamOperator", "StreamGraph", "source", "map_op", "filter_op",
           "window_agg", "quality_op", "model_op"]


@dataclasses.dataclass
class StreamOperator:
    name: str
    fn: Callable[[np.ndarray], np.ndarray]  # rows → rows
    selectivity: float = 1.0
    out_bytes: float = 8.0
    work: float = 1.0
    dq_eligible: bool = False

    def to_meta(self) -> Operator:
        return Operator(self.name, self.selectivity, self.out_bytes,
                        self.work, self.dq_eligible)


class StreamGraph:
    """Executable operator DAG + its cost-model shadow."""

    def __init__(self, operators: list[StreamOperator],
                 edges: list[tuple[int, int]]):
        self.ops = operators
        self.meta = OpGraph([o.to_meta() for o in operators], edges)

    @property
    def edges(self):
        return self.meta.edges


# -------------------------------------------------------- constructors -----

def source(name: str = "source") -> StreamOperator:
    return StreamOperator(name, fn=lambda x: x, selectivity=1.0, work=0.0)


def map_op(name: str, fn, out_bytes: float = 8.0,
           work: float = 1.0) -> StreamOperator:
    return StreamOperator(name, fn=fn, selectivity=1.0, out_bytes=out_bytes,
                          work=work)


def filter_op(name: str, predicate, selectivity: float,
              work: float = 0.5) -> StreamOperator:
    def fn(rows):
        keep = predicate(rows)
        return rows[keep]

    return StreamOperator(name, fn=fn, selectivity=selectivity, work=work)


def window_agg(name: str, window: int, agg=np.mean,
               work: float = 1.0) -> StreamOperator:
    def fn(rows):
        n = (len(rows) // window) * window
        if n == 0:
            return rows[:0]
        return agg(rows[:n].reshape(-1, window, *rows.shape[1:]), axis=1)

    return StreamOperator(name, fn=fn, selectivity=1.0 / window, work=work)


def quality_op(name: str = "dq_check", threshold: float = 0.5,
               work: float = 2.0) -> StreamOperator:
    """The paper's data-quality operator: scores rows, drops low quality."""
    from repro.streaming.quality import quality_scores

    def fn(rows):
        r = rows if rows.ndim == 2 else rows[:, None]
        scores = quality_scores(r.astype(np.int64), missing_sentinel=-1)
        return rows[scores >= threshold]

    return StreamOperator(name, fn=fn, selectivity=0.95, work=work,
                          dq_eligible=True)


def model_op(name: str, model, params, cfg, work: float = 50.0,
             out_bytes: float = 4.0) -> StreamOperator:
    """LM scoring as a streaming operator: rows are (S,) token windows;
    output is one perplexity score per row."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def score(tokens):
        logits, _ = model.forward(params, {"tokens": tokens})
        from repro.models.layers import cross_entropy_loss
        lp = jax.vmap(lambda lg, lb: cross_entropy_loss(lg[None, :-1],
                                                        lb[None, 1:]))(
            logits, tokens)
        return lp

    def fn(rows):
        toks = jnp.asarray(np.clip(rows.astype(np.int32), 0, cfg.vocab - 1))
        return np.asarray(score(toks))[:, None]

    return StreamOperator(name, fn=fn, selectivity=1.0, work=work,
                          out_bytes=out_bytes)
