"""Data-quality scoring (paper §3.1): completeness, validity, timeliness.

``quality_scores`` rates rows in [0,1]; the paper's ``DQ_fraction`` decides
how many rows get scored (scoring costs compute/latency — eq. 8 prices that
trade-off), and β decides how much quality is worth.
"""

from __future__ import annotations

import numpy as np

__all__ = ["quality_scores", "quality_scores_jnp", "dq_latency_model"]


def quality_scores(tokens: np.ndarray, missing_sentinel: int = -1,
                   weights=(0.5, 0.3, 0.2)) -> np.ndarray:
    """(B, S) int tokens → (B,) quality in [0,1].

    completeness: share of non-missing entries;
    validity: share of entries inside an expected z-score band;
    repetition: 1 − longest-run share (stuck-sensor detector).
    """
    B, S = tokens.shape
    missing = tokens == missing_sentinel
    completeness = 1.0 - missing.mean(axis=1)

    valid = tokens.astype(np.float64)
    valid[missing] = np.nan
    mu = np.nanmean(valid, axis=1, keepdims=True)
    sd = np.nanstd(valid, axis=1, keepdims=True) + 1e-9
    z = np.abs((valid - mu) / sd)
    validity = np.nan_to_num((z < 4.0), nan=0.0).mean(axis=1)

    same = tokens[:, 1:] == tokens[:, :-1]
    run = np.zeros(B)
    cur = np.zeros(B)
    for t in range(same.shape[1]):  # S is small for quality windows
        cur = np.where(same[:, t], cur + 1, 0)
        run = np.maximum(run, cur)
    repetition = 1.0 - run / max(S - 1, 1)

    w = np.asarray(weights)
    return (w[0] * completeness + w[1] * validity + w[2] * repetition) / w.sum()


def quality_scores_jnp(tokens, missing_sentinel: int = -1,
                       weights=(0.5, 0.3, 0.2)):
    """jnp variant used inside jitted streaming operators.

    Mirrors :func:`quality_scores` term for term (completeness, validity,
    repetition, same weights) so the two stay interchangeable the way
    costmodel/jaxmodel are — asserted by a property test.
    """
    import jax
    import jax.numpy as jnp

    B, S = tokens.shape
    missing = tokens == missing_sentinel
    completeness = 1.0 - missing.mean(axis=1)

    valid = jnp.where(missing, jnp.nan, tokens.astype(jnp.float32))
    mu = jnp.nanmean(valid, axis=1, keepdims=True)
    sd = jnp.nanstd(valid, axis=1, keepdims=True) + 1e-9
    z = jnp.abs((valid - mu) / sd)
    validity = jnp.nan_to_num((z < 4.0).astype(jnp.float32)).mean(axis=1)

    same = tokens[:, 1:] == tokens[:, :-1]

    def step(carry, col):
        run, cur = carry
        cur = jnp.where(col, cur + 1.0, 0.0)
        return (jnp.maximum(run, cur), cur), None

    (run, _), _ = jax.lax.scan(step, (jnp.zeros(B), jnp.zeros(B)), same.T)
    repetition = 1.0 - run / max(S - 1, 1)

    w = jnp.asarray(weights)
    return (w[0] * completeness + w[1] * validity + w[2] * repetition) / w.sum()


def dq_latency_model(base_latency: float, dq_fraction: float,
                     beta: float) -> float:
    """Paper eq. (8) as used by the serving layer."""
    return base_latency / (1.0 + beta * dq_fraction)
