"""Train / serve step builders (the jit roots the launcher lowers).

``make_train_step``: CE loss (+ MoE aux) → grads → AdamW update, with
optional microbatch gradient accumulation (a ``lax.scan`` over microbatches
with a single deferred gradient reduction — the "one psum per step"
distributed-optimization trick).

``make_prefill_step`` / ``make_decode_step``: the serving roots; decode
donates the KV cache.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.api import ModelConfig, build_model
from repro.models.layers import cross_entropy_loss
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

__all__ = ["make_loss_fn", "make_train_step", "make_prefill_step",
           "make_decode_step", "MOE_AUX_COEF"]

MOE_AUX_COEF = 0.01


def make_loss_fn(model, cfg: ModelConfig):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        loss = cross_entropy_loss(logits, batch["labels"],
                                  batch.get("loss_mask"))
        return loss + MOE_AUX_COEF * aux, (loss, aux)

    return loss_fn


def make_train_step(model, cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    """(params, opt_state, batch) → (params, opt_state, metrics).

    Gradient accumulation dtype: f32, except giant bf16-param (8-bit-Adam)
    configs accumulate in bf16 — at 477B params the f32 accumulator alone is
    7.3 GB/chip; pre-scaling each microbatch by 1/n keeps bf16 accumulation
    well-conditioned."""
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    acc_dtype = jnp.bfloat16 if opt_cfg.bits8 else jnp.float32

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            (_, (loss, aux)), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            inv = 1.0 / microbatches

            def acc_body(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                (_, (l, a)), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda acc, gi: acc + (gi * inv).astype(acc.dtype),
                    g_acc, g)
                return (g_acc, loss_acc + l, aux_acc + a), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (zeros, jnp.float32(0.0), jnp.float32(0.0)), micro)
            loss = loss / microbatches
            aux = aux / microbatches
        params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                opt_cfg)
        metrics = {"loss": loss, "aux": aux, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model, cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        return logits, cache

    return prefill_step


def make_decode_step(model, cfg: ModelConfig):
    def decode_step(params, cache, pos, tokens):
        logits, cache = model.decode_step(params, cache, pos, tokens)
        # greedy next token over the TRUE vocab (tables are padded to 256)
        valid = logits[:, -1, :cfg.vocab]
        next_tok = jnp.argmax(valid, axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, cache

    return decode_step
