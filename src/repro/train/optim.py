"""Optimizers: AdamW with f32 or 8-bit block-quantized moments.

8-bit states (bitsandbytes-style linear block quantization, block=128 along
the trailing axis) are what make the 480B-parameter MoE cells fit 256×16 GB
v5e: params bf16 (2B) + m,v int8 (2B) + f32 block scales (~0.06B) ≈ 4.1B per
parameter instead of 16B.  Quantization error is re-absorbed every step by
re-quantizing the *updated* moment (no drift accumulation across steps
beyond one step's rounding).

Everything is a pure pytree transform — no optax dependency — so opt state
shards with the same PartitionSpecs as the parameters (ZeRO via GSPMD).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs",
           "quantize_blockwise", "dequantize_blockwise"]

QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    bits8: bool = False  # 8-bit block-quantized m/v


# ------------------------------------------------------ 8-bit quantization -
# Shape-preserving row-wise quantization: q is int8 in the PARAM's shape and
# scale is one f32 per trailing row.  Keeping the parameter's dimensionality
# means the moments shard with the parameter's own PartitionSpec and the
# dequant→update→requant chain stays elementwise per shard — no flattening
# reshape for GSPMD to trip over (a flat-block layout replicated a 1.9 TB
# moment tensor on every device; see EXPERIMENTS.md §Dry-run notes).

def quantize_blockwise(x: jnp.ndarray) -> dict:
    if x.ndim == 0:
        x = x[None]
        scale = jnp.maximum(jnp.abs(x) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return {"q": q[0], "scale": scale.astype(jnp.float32)[0]}
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def dequantize_blockwise(qd: dict, shape) -> jnp.ndarray:
    q, scale = qd["q"], qd["scale"]
    if q.ndim == 0:
        return (q.astype(jnp.float32) * scale).reshape(shape)
    return (q.astype(jnp.float32) * scale).reshape(shape)


# ----------------------------------------------------------------- AdamW ---

def _moment_init(p: jnp.ndarray, bits8: bool):
    if bits8:
        return quantize_blockwise(jnp.zeros_like(p, dtype=jnp.float32))
    return jnp.zeros_like(p, dtype=jnp.float32)


def adamw_init(params, cfg: AdamWConfig):
    return {
        "m": jax.tree.map(lambda p: _moment_init(p, cfg.bits8), params),
        "v": jax.tree.map(lambda p: _moment_init(p, cfg.bits8), params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    def leaf_sq(x):
        if x.size == 0:
            return jnp.float32(0.0)
        if x.size >= BIG_LEAF_ELEMS and x.ndim >= 3 and x.shape[0] <= 512:
            # slice-wise over the stacked-layer axis: avoids materializing a
            # full-stack f32 convert of a multi-GB bf16 gradient
            return jnp.sum(jax.lax.map(
                lambda s: jnp.sum(jnp.square(s.astype(jnp.float32))), x))
        return jnp.sum(jnp.square(x.astype(jnp.float32)))

    return jnp.sqrt(sum(leaf_sq(x) for x in jax.tree.leaves(tree)))


BIG_LEAF_ELEMS = 1 << 26  # scan the update over the stacked-layer axis


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    count = opt_state["count"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def _update(p, g, m, v, decay: bool):
        g = g.astype(jnp.float32) * clip
        if cfg.bits8:
            m_f = dequantize_blockwise(m, p.shape)
            v_f = dequantize_blockwise(v, p.shape)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mhat = m_f / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v_f / (1 - cfg.b2 ** count.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if decay:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - cfg.lr * upd).astype(p.dtype)
        if cfg.bits8:
            return new_p, quantize_blockwise(m_f), quantize_blockwise(v_f)
        return new_p, m_f, v_f

    def leaf(p, g, m, v):
        if p.size == 0:  # placeholder leaves (non-parametric norms)
            return p, m, v
        decay = p.ndim >= 2
        if p.size >= BIG_LEAF_ELEMS and p.ndim >= 3 and p.shape[0] <= 512:
            # giant STACKED leaf (leading dim = n_layers, e.g. 35×128×7168×
            # 4864 MoE experts): scan the elementwise update over the layer
            # axis so f32 moment transients are bounded by one layer's
            # slice.  2-D tables (embed/head) must NOT take this path — a
            # map over the vocab axis is 152k sequential steps (§Perf it. 2).
            return jax.lax.map(
                lambda args: _update(*args, decay=decay), (p, g, m, v))
        return _update(p, g, m, v, decay)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_q = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}
    flat_m = jax.tree.leaves(opt_state["m"], is_leaf=is_q) if cfg.bits8 \
        else jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"], is_leaf=is_q) if cfg.bits8 \
        else jax.tree.leaves(opt_state["v"])
    outs = [leaf(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm


def opt_state_specs(param_specs, cfg: AdamWConfig):
    """Opt-state PartitionSpecs mirroring the parameter specs.

    8-bit: q keeps the parameter's own spec; the per-row scale drops the
    last (reduced) dimension's entry."""
    from jax.sharding import PartitionSpec as P

    def leaf(spec):
        if not isinstance(spec, P):
            spec = P()
        if cfg.bits8:
            entries = tuple(spec)
            return {"q": P(*entries),
                    "scale": P(*(entries[:-1] + (None,))) if entries else P()}
        return spec

    moments = jax.tree.map(leaf, param_specs,
                           is_leaf=lambda s: isinstance(s, P))
    return {"m": moments, "v": moments, "count": P()}
