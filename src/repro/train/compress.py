"""Gradient compression for the slow (inter-pod / DCI) tier.

int8 block quantization with error feedback: each step transmits
quantize(g + e) and keeps e ← (g + e) − dequant(quantize(g + e)) locally.
Error feedback makes the scheme unbiased over time — tests assert a toy
optimization converges to the uncompressed trajectory's loss.

Two entry points:
  * ``compress_decompress`` — the pure function (what goes on the wire);
  * ``compressed_psum`` — shard_map collective: quantize → all_gather int8
    over the named axis → dequantize → sum.  4× less DCI traffic than a
    bf16 all-reduce at equal participant count (2× vs f32 reduce-scatter+AG
    pipelines), which directly shrinks the cost model's pod-axis term.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.train.optim import dequantize_blockwise, quantize_blockwise

__all__ = ["compress_decompress", "compressed_psum", "ErrorFeedbackState",
           "ef_compress_step"]


def compress_decompress(g: jnp.ndarray) -> jnp.ndarray:
    """What the receiver reconstructs from one compressed gradient."""
    return dequantize_blockwise(quantize_blockwise(g), g.shape)


def ef_compress_step(g: jnp.ndarray, err: jnp.ndarray):
    """Error-feedback compression: returns (wire_payload_dequantized,
    new_err).  The caller averages payloads across workers."""
    corrected = g + err
    sent = compress_decompress(corrected)
    return sent, corrected - sent


class ErrorFeedbackState:
    """Per-leaf error accumulators (a pytree mirroring the grads)."""

    @staticmethod
    def init(grads):
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    @staticmethod
    def step(grads, err_state):
        outs = jax.tree.map(
            lambda g, e: ef_compress_step(g.astype(jnp.float32), e),
            grads, err_state)
        sent = jax.tree.map(lambda o: o[0], outs,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda o: o[1], outs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return sent, new_err


def compressed_psum(g: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-on-the-wire mean over ``axis_name`` (use inside shard_map).

    Quantizes locally, all-gathers the int8 payload + scales, dequantizes
    and averages — the wire carries ~1/4 the bytes of f32."""
    qd = quantize_blockwise(g)
    qs = jax.lax.all_gather(qd["q"], axis_name)  # (W, blocks, 128) int8
    ss = jax.lax.all_gather(qd["scale"], axis_name)
    n = qs.shape[0]
    total = jnp.zeros(g.shape, jnp.float32)
    for w in range(n):  # unrolled: W is small (pods)
        total = total + dequantize_blockwise({"q": qs[w], "scale": ss[w]},
                                             g.shape)
    return total / n
