"""Scenario generators: parameterized random families of geo-fleets, DAG
topologies, and streaming workload traces.

COSTREAM-style cost models earn their keep when evaluated over large
families of *unseen* operator/hardware combinations, not one hand-built
instance.  This module is the family factory:

  * fleets  — region counts, heterogeneous device speeds, and com-cost
    distributions drawn from lognormals (WAN links are heavy-tailed);
  * graphs  — chains, diamonds, fan-in/fan-out, layered random DAGs
    (the paper's Table 2 topologies, randomized);
  * traces  — diurnal rate curves with burst injections plus timed device
    degradations/losses, replayable through the StreamingEngine
    (repro.sim.replay).

``scenario_batch`` fixes one job graph and device count so the resulting
(placement × fleet) tensors stack — the contract the batched evaluator
(repro.sim.batched) scores in one dispatch.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.devices import ExplicitFleet, RegionFleet, RegionFleetFamily
from repro.core.graph import Operator, OpGraph, random_dag

__all__ = [
    "MIN_ALIVE_DEVICES",
    "ScenarioConfig",
    "TraceEvent",
    "Scenario",
    "random_fleet",
    "perturbed_fleet",
    "region_fleet_family",
    "random_graph",
    "diurnal_rate",
    "random_trace",
    "random_scenario",
    "scenario_batch",
    "region_scenario_batch",
]

GRAPH_FAMILIES = ("chain", "diamond", "fan_out", "fan_in", "layered")

# The device-removal floor shared by trace GENERATION (random_trace) and
# trace REPLAY (repro.sim.replay.replay_trace): a removal is only allowed
# while more than this many devices are alive, so the fleet never drops
# below MIN_ALIVE_DEVICES — the engine always has somewhere to re-place
# AND a second device to move load to.
MIN_ALIVE_DEVICES = 2


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Knobs of the random scenario family (all distributions, no fixtures).

    Fleet: ``n_regions`` regions of ``devices_per_region`` devices; link
    costs are lognormal(``com_logmean``, ``com_logstd``) between regions and
    ``intra_discount``× that within one; device speeds are lognormal around
    1.  Trace: ``trace_len`` ticks of a diurnal curve with amplitude
    ``diurnal_amplitude`` around ``base_rate`` rows/tick, plus bursts
    (``burst_prob`` per tick, ×``burst_factor``) and fleet events
    (``degrade_prob``/``loss_prob`` per tick).
    """

    n_regions: tuple[int, int] = (2, 5)
    devices_per_region: tuple[int, int] = (2, 6)
    com_logmean: float = 0.0
    com_logstd: float = 0.6
    intra_discount: float = 0.1
    speed_logstd: float = 0.3
    graph_families: tuple[str, ...] = GRAPH_FAMILIES
    n_ops: tuple[int, int] = (4, 10)
    max_selectivity: float = 2.0
    # per-operator payloads so the §3.1 objectives are non-degenerate on
    # generated graphs: out_bytes drives network movement, op_work drives
    # device occupancy (zero work ⇒ occupancy identically zero)
    out_bytes: tuple[float, float] = (0.25, 4.0)
    op_work: tuple[float, float] = (0.05, 0.5)
    trace_len: int = 48
    base_rate: float = 256.0
    diurnal_amplitude: float = 0.6
    diurnal_period: int = 24
    burst_prob: float = 0.08
    burst_factor: float = 4.0
    degrade_prob: float = 0.04
    degrade_factor: tuple[float, float] = (2.0, 8.0)
    loss_prob: float = 0.02
    # Markov time-correlated whole-region outages WITHIN one trace: a healthy
    # region enters outage with prob outage_on_prob per tick and stays out
    # for a geometric duration (leaves with prob outage_off_prob per tick) —
    # correlated failures over time, not independent per-tick coin flips.
    # 0.0 (default) disables them AND leaves the rng stream of pre-existing
    # traces untouched (seed-for-seed backward compatible).
    outage_on_prob: float = 0.0
    outage_off_prob: float = 0.25
    trace_outage_factor: float = 32.0
    # selectivity drift: each tick one random operator's TRUE selectivity
    # takes a lognormal(0, selectivity_drift_std) random-walk step (clamped
    # so the cumulative scale stays within selectivity_drift_bounds); the
    # cost-model metadata goes stale until a controller recalibrates.
    # 0.0 (default) disables it, preserving the pre-existing rng stream.
    selectivity_drift_std: float = 0.0
    selectivity_drift_bounds: tuple[float, float] = (0.25, 4.0)
    explicit_fleet: bool = True  # materialize ExplicitFleet (else RegionFleet)
    # structured (RegionFleetFamily) what-if knobs: per-scenario region-level
    # link jitter, independent device stragglers, and whole-region outages
    region_jitter: float = 0.3
    straggler_prob: float = 0.05
    outage_prob: float = 0.04
    outage_factor: float = 1e4


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One tick of a workload trace.

    kind: "rate" (plain tick), "burst" (rate spike), "degrade" (device's
    links/compute get ``factor``× slower), "remove" (device loss),
    "outage" / "recover" (whole-REGION failure entering/lifting — ``device``
    holds the region id and ``factor`` the degrade multiplier), "drift"
    (operator ``device``'s TRUE selectivity scales by ``factor``; the cost
    model's metadata is left stale).
    """

    t: int
    kind: str
    rate: float
    device: int = -1  # device id; region id for outage/recover; op for drift
    factor: float = 1.0


@dataclasses.dataclass
class Scenario:
    """One generated what-if world: a job graph on a fleet under a trace."""

    name: str
    graph: OpGraph
    fleet: ExplicitFleet | RegionFleet
    trace: list[TraceEvent]
    beta: float = 0.0
    dq_fraction: float = 0.0

    @property
    def n_devices(self) -> int:
        return self.fleet.n_devices


# -- fleets -------------------------------------------------------------------

def random_fleet(rng: np.random.Generator, cfg: ScenarioConfig = ScenarioConfig(),
                 n_devices: int | None = None):
    """Random geo-fleet.  ``n_devices`` pins the device count (so fleets of
    one scenario batch stack); regions then get a random partition of it."""
    n_regions = int(rng.integers(cfg.n_regions[0], cfg.n_regions[1] + 1))
    if n_devices is None:
        per = rng.integers(cfg.devices_per_region[0],
                           cfg.devices_per_region[1] + 1, n_regions)
    else:
        n_regions = min(n_regions, n_devices)
        per = np.ones(n_regions, dtype=np.int64)
        extra = rng.multinomial(n_devices - n_regions,
                                np.ones(n_regions) / n_regions)
        per = per + extra
    region = np.repeat(np.arange(n_regions), per)
    inter = rng.lognormal(cfg.com_logmean, cfg.com_logstd,
                          (n_regions, n_regions))
    inter = (inter + inter.T) / 2.0
    np.fill_diagonal(inter, np.diag(inter) * cfg.intra_discount)
    speed = rng.lognormal(0.0, cfg.speed_logstd, region.size)
    rf = RegionFleet(region=region, inter=inter, self_cost=0.0, speed=speed)
    if not cfg.explicit_fleet:
        return rf
    return ExplicitFleet(com_cost=rf.com_matrix(), speed=speed, region=region)


def perturbed_fleet(fleet, rng: np.random.Generator, jitter: float = 0.3):
    """A nearby what-if fleet: every link cost multiplied by an independent
    lognormal(1, jitter) factor (symmetric).  Used to turn one measured
    fleet into a robustness family."""
    com = np.asarray(fleet.com_matrix(), dtype=np.float64)
    noise = rng.lognormal(0.0, jitter, com.shape)
    noise = (noise + noise.T) / 2.0
    com2 = com * noise
    np.fill_diagonal(com2, np.diag(com))
    # effective speed: the com matrix above carries any degrade multipliers,
    # so the materialized fleet must carry the matching compute slowdown too
    return ExplicitFleet(com_cost=com2, speed=fleet.effective_speed().copy(),
                         region=getattr(fleet, "region", None))


def region_fleet_family(rng: np.random.Generator, n_scenarios: int,
                        cfg: ScenarioConfig = ScenarioConfig(),
                        n_devices: int | None = None,
                        base: RegionFleet | None = None) -> RegionFleetFamily:
    """A structured what-if family around one base RegionFleet.

    Each scenario perturbs *region-level* state only, so the family packs as
    a :class:`RegionFleetFamily` — O(S·(R² + V)) memory, never an (S, V, V)
    tensor, which is what lets ``score_grid`` reach 10⁵-device fleets:

      * link jitter — every inter-region cost multiplied by a symmetric
        lognormal(1, ``region_jitter``) factor (WAN weather);
      * stragglers — each device independently degraded with probability
        ``straggler_prob`` by a ``degrade_factor``-range multiplier;
      * whole-region outages — with probability ``outage_prob`` per region,
        every link touching that region's devices gets ``outage_factor``×
        slower (a soft outage: the optimizer routes around it).  At least
        one region is always kept healthy.
    """
    if base is None:
        base = random_fleet(rng, dataclasses.replace(cfg, explicit_fleet=False),
                            n_devices=n_devices)
    if not isinstance(base, RegionFleet):
        raise ValueError("region_fleet_family needs a RegionFleet base")
    v, r = base.n_devices, base.n_regions
    base_d = base.degrade_or_ones()
    inters = np.empty((n_scenarios, r, r))
    degrades = np.ones((n_scenarios, v))
    for s in range(n_scenarios):
        noise = rng.lognormal(0.0, cfg.region_jitter, (r, r))
        inters[s] = base.inter * (noise + noise.T) / 2.0
        d = base_d.copy()
        straggler = rng.random(v) < cfg.straggler_prob
        d[straggler] *= rng.uniform(*cfg.degrade_factor, int(straggler.sum()))
        outage = rng.random(r) < cfg.outage_prob
        if outage.all():
            outage[int(rng.integers(r))] = False
        d[outage[base.region]] *= cfg.outage_factor
        degrades[s] = d
    return RegionFleetFamily(
        region=base.region.copy(), inter=inters, degrade=degrades,
        self_cost=base.self_cost,
        speed=None if base.speed is None else base.speed.copy())


# -- graphs -------------------------------------------------------------------

def _sel(rng: np.random.Generator, cfg: ScenarioConfig) -> float:
    return float(rng.uniform(0.1, cfg.max_selectivity))


def _with_payload(g: OpGraph, rng: np.random.Generator,
                  cfg: ScenarioConfig) -> OpGraph:
    """Draw per-operator out_bytes / work so every §3.1 objective has
    something to price on a generated graph (uniform over the configured
    ranges; applied to all topology families alike)."""
    ops = [dataclasses.replace(
        op,
        out_bytes=float(rng.uniform(*cfg.out_bytes)),
        work=float(rng.uniform(*cfg.op_work)))
        for op in g.operators]
    return OpGraph(ops, list(g.edges))


def random_graph(rng: np.random.Generator,
                 cfg: ScenarioConfig = ScenarioConfig(),
                 family: str | None = None) -> OpGraph:
    """One topology drawn from the configured families, with per-operator
    out_bytes/work payloads (network movement and occupancy objectives are
    non-degenerate on every generated graph)."""
    family = family or cfg.graph_families[
        int(rng.integers(len(cfg.graph_families)))]
    n = int(rng.integers(cfg.n_ops[0], cfg.n_ops[1] + 1))
    if family == "chain":
        ops = [Operator(f"op{i}", _sel(rng, cfg)) for i in range(n)]
        g = OpGraph(ops, [(i, i + 1) for i in range(n - 1)])
    elif family == "diamond":
        width = max(n - 2, 2)
        ops = ([Operator("src", 1.0)]
               + [Operator(f"mid{k}", _sel(rng, cfg)) for k in range(width)]
               + [Operator("sink", 1.0)])
        edges = [(0, 1 + k) for k in range(width)] \
            + [(1 + k, 1 + width) for k in range(width)]
        g = OpGraph(ops, edges)
    elif family == "fan_out":
        ops = [Operator("src", 1.0)] \
            + [Operator(f"leaf{k}", _sel(rng, cfg)) for k in range(n - 1)]
        g = OpGraph(ops, [(0, k) for k in range(1, n)])
    elif family == "fan_in":
        ops = [Operator(f"feed{k}", _sel(rng, cfg)) for k in range(n - 1)] \
            + [Operator("agg", 1.0)]
        g = OpGraph(ops, [(k, n - 1) for k in range(n - 1)])
    elif family == "layered":
        g = random_dag(n, edge_prob=0.45, rng=rng,
                       max_selectivity=cfg.max_selectivity)
    else:
        raise ValueError(f"unknown graph family {family!r}; "
                         f"choose from {GRAPH_FAMILIES}")
    return _with_payload(g, rng, cfg)


# -- traces -------------------------------------------------------------------

def diurnal_rate(t: int, cfg: ScenarioConfig = ScenarioConfig(),
                 phase: float = 0.0) -> float:
    """Rows per tick on the daily sine: base·(1 + A·sin(2πt/period + φ))."""
    return cfg.base_rate * (
        1.0 + cfg.diurnal_amplitude
        * math.sin(2.0 * math.pi * t / cfg.diurnal_period + phase))


def random_trace(rng: np.random.Generator, n_devices: int,
                 cfg: ScenarioConfig = ScenarioConfig(),
                 n_regions: int | None = None,
                 n_ops: int | None = None) -> list[TraceEvent]:
    """A timed event sequence; at most one classic fleet event per tick.

    Removal floor: a ``remove`` is only emitted while MORE than
    :data:`MIN_ALIVE_DEVICES` devices are alive, so the fleet never drops
    below ``MIN_ALIVE_DEVICES`` (= 2) — the same invariant
    :func:`repro.sim.replay.replay_trace` enforces at replay time (a
    regression test pins the 3-device boundary).

    Two correlated-over-time realism layers, both off by default (their
    config knobs are 0.0, and disabled layers draw NOTHING from the rng, so
    pre-existing seeds reproduce byte-identical traces):

      * Markov whole-region outages (``cfg.outage_on_prob`` > 0, needs
        ``n_regions``): each healthy region enters outage with
        ``outage_on_prob`` per tick, emits ``outage`` (region id in
        ``device``, ``trace_outage_factor`` in ``factor``), and leaves with
        ``outage_off_prob`` per tick via a matching ``recover`` — geometric
        outage durations, i.e. failures correlated over TIME.  At least one
        region always stays healthy, and every open outage is closed by a
        final recover so the trace ends on a healthy fleet.
      * selectivity drift (``cfg.selectivity_drift_std`` > 0, needs
        ``n_ops``): each tick one random operator takes a lognormal
        random-walk step, clamped so the cumulative drift stays within
        ``cfg.selectivity_drift_bounds``.
    """
    phase = float(rng.uniform(0.0, 2.0 * math.pi))
    alive = list(range(n_devices))
    events: list[TraceEvent] = []
    out_regions: set[int] = set()
    sel_cum = None if n_ops is None else np.ones(n_ops)
    markov = cfg.outage_on_prob > 0.0 and n_regions is not None \
        and n_regions > 1
    drifting = cfg.selectivity_drift_std > 0.0 and n_ops
    for t in range(cfg.trace_len):
        rate = diurnal_rate(t, cfg, phase)
        kind = "rate"
        if rng.random() < cfg.burst_prob:
            kind, rate = "burst", rate * cfg.burst_factor
        events.append(TraceEvent(t=t, kind=kind, rate=rate))
        roll = rng.random()
        if roll < cfg.loss_prob and len(alive) > MIN_ALIVE_DEVICES:
            dead = alive.pop(int(rng.integers(len(alive))))
            events.append(TraceEvent(t=t, kind="remove", rate=0.0,
                                     device=dead))
        elif roll < cfg.loss_prob + cfg.degrade_prob and alive:
            events.append(TraceEvent(
                t=t, kind="degrade", rate=0.0,
                device=alive[int(rng.integers(len(alive)))],
                factor=float(rng.uniform(*cfg.degrade_factor))))
        if markov:
            for r in sorted(out_regions):
                if rng.random() < cfg.outage_off_prob:
                    out_regions.discard(r)
                    events.append(TraceEvent(
                        t=t, kind="recover", rate=0.0, device=r,
                        factor=cfg.trace_outage_factor))
            for r in range(n_regions):
                if r in out_regions:
                    continue
                # keep ≥1 healthy region so the optimizer has a refuge
                if len(out_regions) >= n_regions - 1:
                    break
                if rng.random() < cfg.outage_on_prob:
                    out_regions.add(r)
                    events.append(TraceEvent(
                        t=t, kind="outage", rate=0.0, device=r,
                        factor=cfg.trace_outage_factor))
        if drifting:
            op = int(rng.integers(n_ops))
            step = float(rng.lognormal(0.0, cfg.selectivity_drift_std))
            lo, hi = cfg.selectivity_drift_bounds
            clipped = float(np.clip(sel_cum[op] * step, lo, hi))
            step = clipped / sel_cum[op]
            sel_cum[op] = clipped
            if step != 1.0:
                events.append(TraceEvent(t=t, kind="drift", rate=0.0,
                                         device=op, factor=step))
    # close any outage still open, so replaying the whole trace returns the
    # fleet to (degrade-)health and back-to-back traces compose
    for r in sorted(out_regions):
        events.append(TraceEvent(t=cfg.trace_len, kind="recover", rate=0.0,
                                 device=r, factor=cfg.trace_outage_factor))
    return events


# -- whole scenarios ----------------------------------------------------------

def random_scenario(rng: np.random.Generator,
                    cfg: ScenarioConfig = ScenarioConfig(),
                    graph: OpGraph | None = None,
                    n_devices: int | None = None,
                    name: str = "scenario") -> Scenario:
    g = graph if graph is not None else random_graph(rng, cfg)
    fleet = random_fleet(rng, cfg, n_devices=n_devices)
    trace = random_trace(rng, fleet.n_devices, cfg,
                         n_regions=int(np.asarray(fleet.region).max()) + 1,
                         n_ops=g.n_ops)
    return Scenario(name=name, graph=g, fleet=fleet, trace=trace)


def scenario_batch(rng: np.random.Generator, n_scenarios: int,
                   cfg: ScenarioConfig = ScenarioConfig(),
                   graph: OpGraph | None = None,
                   n_devices: int | None = None) -> list[Scenario]:
    """N what-if worlds sharing ONE graph and device count — the stackable
    family the batched evaluator scores as a (scenario × placement) grid."""
    g = graph if graph is not None else random_graph(rng, cfg)
    if n_devices is None:
        lo, hi = cfg.n_regions, cfg.devices_per_region
        n_devices = int(rng.integers(lo[0], lo[1] + 1)) \
            * int(rng.integers(hi[0], hi[1] + 1))
    return [
        random_scenario(rng, cfg, graph=g, n_devices=n_devices,
                        name=f"scenario{k}")
        for k in range(n_scenarios)
    ]


def region_scenario_batch(rng: np.random.Generator, n_scenarios: int,
                          cfg: ScenarioConfig = ScenarioConfig(),
                          graph: OpGraph | None = None,
                          n_devices: int | None = None) -> list[Scenario]:
    """N what-if worlds whose fleets are members of ONE RegionFleetFamily
    (shared graph, region layout, device count, and traces per scenario).

    Because every fleet shares the region assignment, ``robust_placement``
    re-packs the batch structurally (pack_region_fleets) and the score grid
    runs the segment-sum path — no (S, V, V) com stack even at 10⁵ devices.
    """
    g = graph if graph is not None else random_graph(rng, cfg)
    fam = region_fleet_family(rng, n_scenarios, cfg, n_devices=n_devices)
    return [
        Scenario(name=f"region_scenario{k}", graph=g, fleet=fam.fleet(k),
                 trace=random_trace(rng, fam.n_devices, cfg,
                                    n_regions=fam.n_regions, n_ops=g.n_ops))
        for k in range(n_scenarios)
    ]
