"""Harvesting learned-prior training tuples from replay traces.

Replay traces already generate (placement, fleet, observed-cost) tuples for
free: every :class:`repro.core.calibration.ReplayWindow` pins down which
devices carried busy signal, how slow each one actually ran, and what each
operator's true selectivity was.  :func:`training_tuples` pairs those
refit estimates with the identity-free featurization of
:mod:`repro.belief.features`, producing the supervised rows
:func:`repro.belief.prior.fit_prior` trains on — so a prior fit on fleets
the simulator has generated prices devices of a fleet it has never seen.

Rows are evidence-weighted with the same work-mass weights the belief
posterior uses: a device estimate backed by a window of real load teaches
the prior more than a sliver-of-mass one.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.belief.features import device_features, op_features
from repro.core.calibration import ReplayWindow, refit_from_replay
from repro.core.costmodel import CostConfig

__all__ = ["TrainingTuples", "training_tuples", "merge_tuples"]


@dataclasses.dataclass
class TrainingTuples:
    """Supervised rows for :func:`repro.belief.prior.fit_prior` — the
    keyword layout matches its signature, so fitting is
    ``fit_prior(**dataclasses.asdict(tuples))`` modulo names."""

    device_features: np.ndarray     # (N_d, F_d)
    device_log_degrade: np.ndarray  # (N_d,)
    device_weights: np.ndarray      # (N_d,) work-mass evidence weights
    op_features: np.ndarray         # (N_o, F_o)
    op_log_sel_scale: np.ndarray    # (N_o,)
    op_weights: np.ndarray          # (N_o,) input-row evidence weights

    @property
    def n_device_rows(self) -> int:
        return self.device_log_degrade.size

    @property
    def n_op_rows(self) -> int:
        return self.op_log_sel_scale.size


def training_tuples(graph, fleet, window: ReplayWindow,
                    cfg: CostConfig = CostConfig(),
                    work_unit: float | None = None) -> TrainingTuples:
    """One replay window → supervised rows.

    ``fleet`` must be the belief the window was replayed against (typically
    the BASE fleet for harvested traces) — the refit's degrades are relative
    to it, so the targets are log-slowdowns vs that baseline.  Only devices
    with busy signal and operators with observed input rows contribute rows;
    a window can legitimately yield zero of either.
    """
    refit = refit_from_replay(graph, fleet, window, cfg=cfg,
                              work_unit=work_unit)
    d_feats = device_features(fleet)
    sig = np.asarray(refit.signal, dtype=bool)
    d_rows = d_feats[sig]
    d_y = np.log(np.maximum(refit.degrade[sig], 1e-12))
    d_w = np.asarray(refit.obs_weight, dtype=np.float64)[sig]
    if refit.op_obs_weight is not None:
        o_feats = op_features(graph)
        pos = np.asarray(refit.op_obs_weight, dtype=np.float64) > 0.0
        o_rows = o_feats[pos]
        o_y = np.log(np.maximum(refit.sel_scale[pos], 1e-12))
        o_w = np.asarray(refit.op_obs_weight, dtype=np.float64)[pos]
    else:
        n_f = op_features(graph).shape[1]
        o_rows = np.zeros((0, n_f))
        o_y = np.zeros(0)
        o_w = np.zeros(0)
    return TrainingTuples(device_features=d_rows, device_log_degrade=d_y,
                          device_weights=d_w, op_features=o_rows,
                          op_log_sel_scale=o_y, op_weights=o_w)


def merge_tuples(parts: list[TrainingTuples]) -> TrainingTuples:
    """Concatenate harvested rows across windows / traces / fleets — the
    corpus a transferable prior is fit on."""
    if not parts:
        raise ValueError("merge_tuples needs at least one part")
    return TrainingTuples(
        device_features=np.concatenate([p.device_features for p in parts]),
        device_log_degrade=np.concatenate(
            [p.device_log_degrade for p in parts]),
        device_weights=np.concatenate([p.device_weights for p in parts]),
        op_features=np.concatenate([p.op_features for p in parts]),
        op_log_sel_scale=np.concatenate([p.op_log_sel_scale for p in parts]),
        op_weights=np.concatenate([p.op_weights for p in parts]),
    )
