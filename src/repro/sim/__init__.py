"""Scenario simulation: generated what-if families, batched evaluation
(dense or structured RegionFleetFamily), trace replay (see sim/README.md for
the generators → batched eval → replay pipeline)."""

from repro.sim.batched import (BatchedEvaluator, pack_fleets, pack_placements,
                               pack_region_fleets, pack_speeds)
from repro.sim.execache import (ExecutableCache, executable_cache,
                                fresh_cache, graph_key, set_executable_cache)
from repro.sim.replay import (ReplayReport, ReplayStep, apply_fleet_event,
                              replay_trace, robust_placement,
                              scenario_robust_search)
from repro.sim.scenarios import (MIN_ALIVE_DEVICES, Scenario, ScenarioConfig,
                                 TraceEvent, diurnal_rate, perturbed_fleet,
                                 random_fleet, random_graph, random_scenario,
                                 random_trace, region_fleet_family,
                                 region_scenario_batch, scenario_batch)
from repro.sim.training import TrainingTuples, merge_tuples, training_tuples

__all__ = [
    "BatchedEvaluator", "pack_fleets", "pack_placements", "pack_region_fleets",
    "pack_speeds",
    "ExecutableCache", "executable_cache", "fresh_cache", "graph_key",
    "set_executable_cache",
    "ReplayReport", "ReplayStep", "apply_fleet_event", "replay_trace",
    "robust_placement", "scenario_robust_search",
    "MIN_ALIVE_DEVICES", "Scenario", "ScenarioConfig", "TraceEvent",
    "diurnal_rate", "perturbed_fleet", "random_fleet", "random_graph",
    "random_scenario", "random_trace", "region_fleet_family",
    "region_scenario_batch", "scenario_batch",
    "TrainingTuples", "merge_tuples", "training_tuples",
]
