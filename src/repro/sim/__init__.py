"""Scenario simulation: generated what-if families, batched evaluation,
trace replay (see sim/README.md for the generators → batched eval → replay
pipeline)."""

from repro.sim.batched import BatchedEvaluator, pack_fleets, pack_placements
from repro.sim.replay import (ReplayReport, ReplayStep, replay_trace,
                              robust_placement, scenario_robust_search)
from repro.sim.scenarios import (Scenario, ScenarioConfig, TraceEvent,
                                 diurnal_rate, perturbed_fleet, random_fleet,
                                 random_graph, random_scenario, random_trace,
                                 scenario_batch)

__all__ = [
    "BatchedEvaluator", "pack_fleets", "pack_placements",
    "ReplayReport", "ReplayStep", "replay_trace", "robust_placement",
    "scenario_robust_search",
    "Scenario", "ScenarioConfig", "TraceEvent", "diurnal_rate",
    "perturbed_fleet", "random_fleet", "random_graph", "random_scenario",
    "random_trace", "scenario_batch",
]
