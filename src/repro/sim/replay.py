"""Trace replay and robustness: run generated scenarios through the real
StreamingEngine and pick placements that survive the whole family.

Two instruments:

  * :func:`replay_trace` — drive a StreamingEngine through a generated
    event trace (diurnal/burst ticks, ``degrade``/``remove`` fleet events
    mapped onto the engine's straggler/elasticity hooks) and report the
    modeled-vs-observed latency drift per scenario.  Drift is the evidence
    the paper's model tracks reality as conditions shift.
  * :func:`robust_placement` / :func:`scenario_robust_search` — min–max
    placement selection over a scenario batch.  The implementations moved
    to :mod:`repro.search.robust` (the batched search subsystem's decision
    layer, which adds per-scenario DQ co-optimization); these names are
    signature-preserving delegators, imported function-locally so the sim
    package never imports the search layer at import time.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costmodel import CostConfig
from repro.core.graph import OpGraph
from repro.core.objectives import ObjectiveSet
from repro.sim.scenarios import MIN_ALIVE_DEVICES, Scenario, TraceEvent

__all__ = ["ReplayStep", "ReplayReport", "apply_fleet_event", "replay_trace",
           "robust_placement", "scenario_robust_search"]


@dataclasses.dataclass
class ReplayStep:
    t: int
    kind: str
    rate: float
    rows_in: int
    modeled_latency: float
    observed_busy: float  # max per-device busy seconds this tick
    n_devices: int
    # full per-device busy vector this tick (V,) — what refit_from_replay
    # fits effective speeds from; observed_busy above keeps the max for
    # backward compatibility
    device_busy: np.ndarray | None = None


@dataclasses.dataclass
class ReplayReport:
    scenario: str
    steps: list[ReplayStep]
    n_degrades: int
    n_removes: int
    n_outages: int = 0
    n_drifts: int = 0

    @property
    def modeled(self) -> np.ndarray:
        return np.array([s.modeled_latency for s in self.steps])

    @property
    def observed(self) -> np.ndarray:
        return np.array([s.observed_busy for s in self.steps])

    @property
    def rates(self) -> np.ndarray:
        return np.array([s.rate for s in self.steps])

    def busy_series(self) -> np.ndarray:
        """(T, V) per-device busy matrix over the trailing run of ticks with
        a constant device count (device losses change V mid-trace, so only
        the suffix after the last removal stacks).  Empty (0, 0) when no
        step recorded a device_busy vector."""
        steps = [s for s in self.steps if s.device_busy is not None]
        if not steps:
            return np.zeros((0, 0))
        v = steps[-1].n_devices
        tail = []
        for s in reversed(steps):
            if s.n_devices != v:
                break
            tail.append(s.device_busy)
        return np.stack(tail[::-1])

    def drift(self) -> dict:
        """Modeled-vs-observed latency drift over the trace.

        The engine's observed busy time and the model's latency live in
        different units, so drift is measured on *normalized* series: the
        std of the per-tick ratio around its mean (0 ⇒ the model tracks
        observation perfectly up to a constant factor)."""
        m, o = self.modeled, self.observed
        keep = (m > 0) & (o > 0)
        if keep.sum() < 2:
            return {"ratio_mean": float("nan"), "ratio_rel_std": float("nan"),
                    "n_ticks": int(keep.sum())}
        r = o[keep] / m[keep]
        return {"ratio_mean": float(r.mean()),
                "ratio_rel_std": float(r.std() / (r.mean() + 1e-12)),
                "n_ticks": int(keep.sum())}


def apply_fleet_event(engine, ev: TraceEvent, alive: list[int],
                      beta: float = 0.0,
                      reoptimize: bool = True) -> str | None:
    """Apply one non-tick trace event to the engine, remapping the event's
    original-fleet device id through the ``alive`` list (mutated on
    removals).  Returns the event kind when it was applied, None when it was
    dropped (dead device, or a removal blocked by the
    :data:`repro.sim.scenarios.MIN_ALIVE_DEVICES` floor).

    Shared by :func:`replay_trace` (engine self-heals: ``reoptimize=True``)
    and the closed-loop controller (:mod:`repro.adapt` passes
    ``reoptimize=False`` — the controller owns re-placement)."""
    if ev.kind == "degrade":
        if ev.device not in alive:
            return None
        engine.apply_event("degrade", alive.index(ev.device),
                           factor=ev.factor, beta=beta,
                           reoptimize=reoptimize)
        return ev.kind
    if ev.kind == "remove":
        if ev.device not in alive or len(alive) <= MIN_ALIVE_DEVICES:
            return None
        engine.apply_event("remove", alive.index(ev.device), beta=beta,
                           reoptimize=reoptimize)
        alive.remove(ev.device)
        return ev.kind
    if ev.kind in ("outage", "recover", "drift"):
        # region ids (outage/recover) and operator ids (drift) survive
        # removals unchanged — no remapping needed
        engine.apply_event(ev.kind, ev.device, factor=ev.factor, beta=beta,
                           reoptimize=reoptimize)
        return ev.kind
    raise ValueError(f"unknown trace event kind {ev.kind!r}")


def replay_trace(engine, trace: list[TraceEvent], rng: np.random.Generator,
                 row_width: int = 4, beta: float = 0.0,
                 name: str = "scenario") -> ReplayReport:
    """Drive ``engine`` (repro.streaming.engine.StreamingEngine) through the
    trace.  Device ids in fleet events index the *original* fleet; removals
    shift the survivors, so ids are remapped through the engine's live
    device count (events on already-dead devices are dropped).

    Removal floor: removals are skipped once only
    :data:`repro.sim.scenarios.MIN_ALIVE_DEVICES` (= 2) devices remain —
    the same invariant ``random_trace`` enforces at generation time, so
    hand-built traces (or traces replayed against a smaller fleet) can
    never strand the engine below 2 devices either.

    Beyond the classic per-device events, traces may carry the
    time-correlated realism events ``outage`` / ``recover`` (whole-region
    failures; counted in ``n_outages``) and ``drift`` (runtime selectivity
    drift; counted in ``n_drifts``) — see
    :func:`repro.sim.scenarios.random_trace`."""
    steps: list[ReplayStep] = []
    counts = {"degrade": 0, "remove": 0, "outage": 0, "drift": 0}
    alive = list(range(engine.fleet.n_devices))
    for ev in trace:
        if ev.kind in ("rate", "burst"):
            rows = max(int(ev.rate), 1)
            batch = rng.normal(size=(rows, row_width))
            rep = engine.run_batch(batch)
            steps.append(ReplayStep(
                t=ev.t, kind=ev.kind, rate=ev.rate, rows_in=rep.rows_in,
                modeled_latency=rep.modeled_latency,
                observed_busy=float(rep.device_busy.max(initial=0.0)),
                n_devices=engine.fleet.n_devices,
                device_busy=rep.device_busy.copy()))
        else:
            applied = apply_fleet_event(engine, ev, alive, beta=beta)
            if applied in ("degrade", "remove", "outage", "drift"):
                counts[applied] += 1
    return ReplayReport(scenario=name, steps=steps,
                        n_degrades=counts["degrade"],
                        n_removes=counts["remove"],
                        n_outages=counts["outage"],
                        n_drifts=counts["drift"])


def robust_placement(graph: OpGraph, scenarios: list[Scenario],
                     rng: np.random.Generator, n_candidates: int = 256,
                     cfg: CostConfig = CostConfig(), beta: float = 0.0,
                     dq: float | np.ndarray = 0.0, sparsity: float = 0.5,
                     extra_candidates: list[np.ndarray] | None = None,
                     use_pallas: bool | None = None,
                     objectives: ObjectiveSet | None = None):
    """Min–max what-if selection over a scenario batch — a
    signature-preserving delegator to
    :func:`repro.search.robust.robust_placement` (the search subsystem's
    decision layer), returning ``(x_best, worst_score, grid)`` exactly as
    before."""
    from repro.search.robust import robust_placement as impl

    return impl(graph, scenarios, rng, n_candidates=n_candidates, cfg=cfg,
                beta=beta, dq=dq, sparsity=sparsity,
                extra_candidates=extra_candidates, use_pallas=use_pallas,
                objectives=objectives)


def scenario_robust_search(graph: OpGraph, scenarios: list[Scenario],
                           rng: np.random.Generator, n_candidates: int = 512,
                           cost_cfg: CostConfig = CostConfig(),
                           beta: float = 0.0,
                           dq: float | np.ndarray = 0.0,
                           sparsity: float = 0.5, warm_start: bool = True,
                           objectives: ObjectiveSet | None = None,
                           **kwargs):
    """Optimizer-grade min–max robust search — a signature-preserving
    delegator to :func:`repro.search.robust.scenario_robust_search`, which
    also accepts the search layer's joint-DQ extensions
    (``co_optimize_dq=True, dq_steps=..., dq_coupling=...``) through
    ``**kwargs``."""
    from repro.search.robust import scenario_robust_search as impl

    return impl(graph, scenarios, rng, n_candidates=n_candidates,
                cost_cfg=cost_cfg, beta=beta, dq=dq, sparsity=sparsity,
                warm_start=warm_start, objectives=objectives, **kwargs)
