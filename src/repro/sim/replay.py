"""Trace replay and robustness: run generated scenarios through the real
StreamingEngine and pick placements that survive the whole family.

Two instruments:

  * :func:`replay_trace` — drive a StreamingEngine through a generated
    event trace (diurnal/burst ticks, ``degrade``/``remove`` fleet events
    mapped onto the engine's straggler/elasticity hooks) and report the
    modeled-vs-observed latency drift per scenario.  Drift is the evidence
    the paper's model tracks reality as conditions shift.
  * :func:`robust_placement` — min–max placement selection over a scenario
    batch: among P candidates, take the one minimizing worst-case F across
    S fleets, scored by the batched evaluator in one dispatch.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.costmodel import CostConfig, latency, objective_F
from repro.core.devices import RegionFleet, RegionFleetFamily
from repro.core.graph import OpGraph
from repro.core.objectives import ObjectiveSet, as_objective_set
from repro.core.placement import random_placement, uniform_placement
from repro.sim.batched import (BatchedEvaluator, pack_fleets,
                               pack_placements, pack_region_fleets,
                               pack_speeds)
from repro.sim.scenarios import MIN_ALIVE_DEVICES, Scenario, TraceEvent

__all__ = ["ReplayStep", "ReplayReport", "replay_trace", "robust_placement",
           "scenario_robust_search"]


@dataclasses.dataclass
class ReplayStep:
    t: int
    kind: str
    rate: float
    rows_in: int
    modeled_latency: float
    observed_busy: float  # max per-device busy seconds this tick
    n_devices: int


@dataclasses.dataclass
class ReplayReport:
    scenario: str
    steps: list[ReplayStep]
    n_degrades: int
    n_removes: int

    @property
    def modeled(self) -> np.ndarray:
        return np.array([s.modeled_latency for s in self.steps])

    @property
    def observed(self) -> np.ndarray:
        return np.array([s.observed_busy for s in self.steps])

    def drift(self) -> dict:
        """Modeled-vs-observed latency drift over the trace.

        The engine's observed busy time and the model's latency live in
        different units, so drift is measured on *normalized* series: the
        std of the per-tick ratio around its mean (0 ⇒ the model tracks
        observation perfectly up to a constant factor)."""
        m, o = self.modeled, self.observed
        keep = (m > 0) & (o > 0)
        if keep.sum() < 2:
            return {"ratio_mean": float("nan"), "ratio_rel_std": float("nan"),
                    "n_ticks": int(keep.sum())}
        r = o[keep] / m[keep]
        return {"ratio_mean": float(r.mean()),
                "ratio_rel_std": float(r.std() / (r.mean() + 1e-12)),
                "n_ticks": int(keep.sum())}


def replay_trace(engine, trace: list[TraceEvent], rng: np.random.Generator,
                 row_width: int = 4, beta: float = 0.0,
                 name: str = "scenario") -> ReplayReport:
    """Drive ``engine`` (repro.streaming.engine.StreamingEngine) through the
    trace.  Device ids in fleet events index the *original* fleet; removals
    shift the survivors, so ids are remapped through the engine's live
    device count (events on already-dead devices are dropped).

    Removal floor: removals are skipped once only
    :data:`repro.sim.scenarios.MIN_ALIVE_DEVICES` (= 2) devices remain —
    the same invariant ``random_trace`` enforces at generation time, so
    hand-built traces (or traces replayed against a smaller fleet) can
    never strand the engine below 2 devices either."""
    steps: list[ReplayStep] = []
    n_deg = n_rem = 0
    alive = list(range(engine.fleet.n_devices))
    for ev in trace:
        if ev.kind in ("rate", "burst"):
            rows = max(int(ev.rate), 1)
            batch = rng.normal(size=(rows, row_width))
            rep = engine.run_batch(batch)
            steps.append(ReplayStep(
                t=ev.t, kind=ev.kind, rate=ev.rate, rows_in=rep.rows_in,
                modeled_latency=rep.modeled_latency,
                observed_busy=float(rep.device_busy.max(initial=0.0)),
                n_devices=engine.fleet.n_devices))
        elif ev.kind == "degrade":
            if ev.device in alive:
                engine.apply_event("degrade", alive.index(ev.device),
                                   factor=ev.factor, beta=beta)
                n_deg += 1
        elif ev.kind == "remove":
            if ev.device in alive and len(alive) > MIN_ALIVE_DEVICES:
                engine.apply_event("remove", alive.index(ev.device),
                                   beta=beta)
                alive.remove(ev.device)
                n_rem += 1
        else:
            raise ValueError(f"unknown trace event kind {ev.kind!r}")
    return ReplayReport(scenario=name, steps=steps, n_degrades=n_deg,
                        n_removes=n_rem)


# above this many bytes of stacked float64 com matrices the dense fallback
# would OOM long before producing a useful error — refuse it instead
_DENSE_FALLBACK_MAX_BYTES = 2 ** 31


def _pack_scenario_fleets(scenarios: list[Scenario]):
    """Structured pack (RegionFleetFamily) when every fleet shares one
    region layout, dense (S, V, V) stack otherwise — the evaluator
    dispatches on the result's type."""
    fleets = [s.fleet for s in scenarios]
    if all(isinstance(f, RegionFleet) for f in fleets):
        try:
            return pack_region_fleets(fleets)
        except ValueError as e:
            # heterogeneous layouts — dense is the only stack left; at the
            # fleet sizes the structured path exists for, say so instead of
            # dying in an (S, V, V) allocation
            v = fleets[0].n_devices
            dense_bytes = len(fleets) * v * v * 8
            if dense_bytes > _DENSE_FALLBACK_MAX_BYTES:
                raise ValueError(
                    f"scenario fleets do not stack structurally ({e}); the "
                    f"dense fallback would materialize ~{dense_bytes / 1e9:.1f}"
                    f" GB of (S, V, V) com matrices — align the region "
                    f"layouts (e.g. region_scenario_batch) to stay on the "
                    f"structured path") from e
            warnings.warn(
                f"scenario fleets do not stack structurally ({e}); "
                f"falling back to the dense (S, V, V) path", RuntimeWarning,
                stacklevel=3)
    return pack_fleets(fleets)


def robust_placement(graph: OpGraph, scenarios: list[Scenario],
                     rng: np.random.Generator, n_candidates: int = 256,
                     cfg: CostConfig = CostConfig(), beta: float = 0.0,
                     dq: float | np.ndarray = 0.0, sparsity: float = 0.5,
                     extra_candidates: list[np.ndarray] | None = None,
                     use_pallas: bool = False,
                     objectives: ObjectiveSet | None = None):
    """Min–max what-if selection: the placement minimizing the worst-case
    score over the scenario batch.

    Scenario batches of RegionFleets sharing one region layout (e.g.
    ``region_scenario_batch``) are scored on the structured segment-sum path
    — no (S, V, V) com stack, so the family can hold 10⁵-device fleets.
    ``dq`` may be a scalar or per-scenario ``(S,)`` (scenario s's quality
    knob divides its row of the grid).

    ``objectives=None`` scores F alone (paper eq. 8); an ObjectiveSet makes
    the score the weighted §3.1 scalarization — every objective's grid and
    the weighted sum still come from ONE dispatch, so the min–max can trade
    worst-case F against WAN bytes moved or occupancy skew.  On the dense
    fallback the fleets' effective speeds are packed alongside the com stack
    so the occupancy objectives see stragglers.

    Returns ``(x_best, worst_score, grid)`` where grid is the full (S, P)
    score matrix (the weighted scalarization when multi-objective; useful
    for regret analysis: column min vs row min)."""
    if not scenarios:
        raise ValueError("need at least one scenario")
    n_dev = scenarios[0].n_devices
    avail = np.ones((graph.n_ops, n_dev), dtype=bool)
    candidates = [uniform_placement(graph.n_ops, avail)]
    candidates += [random_placement(graph.n_ops, avail, rng, sparsity)
                   for _ in range(max(n_candidates - 1, 0))]
    if extra_candidates:
        candidates += [np.asarray(x) for x in extra_candidates]
    ev = BatchedEvaluator(graph, cfg, use_pallas=use_pallas)
    pack = _pack_scenario_fleets(scenarios)
    speed = None
    if objectives is not None and not isinstance(pack, RegionFleetFamily):
        speed = pack_speeds([s.fleet for s in scenarios])
    res = ev.score_grid(pack_placements(candidates), pack,
                        dq=dq, beta=beta, objectives=objectives, speed=speed)
    grid = np.asarray(res if objectives is None else res.scalarized)  # (S, P)
    worst = grid.max(axis=0)                   # (P,) worst case per candidate
    k = int(worst.argmin())
    return candidates[k], float(worst[k]), grid


def scenario_robust_search(graph: OpGraph, scenarios: list[Scenario],
                           rng: np.random.Generator, n_candidates: int = 512,
                           cost_cfg: CostConfig = CostConfig(),
                           beta: float = 0.0,
                           dq: float | np.ndarray = 0.0,
                           sparsity: float = 0.5, warm_start: bool = True,
                           objectives: ObjectiveSet | None = None):
    """Optimizer-grade wrapper around :func:`robust_placement`.

    Random candidates are scored against every scenario fleet in one
    batched dispatch (structured when the fleets share a region layout);
    ``warm_start`` additionally seeds per-scenario greedy optima (each
    scenario's best placement competes for the min–max crown — cheap and
    often the winner when one fleet dominates the worst case).

    ``dq`` may be a scalar or a per-scenario ``(S,)`` array (scenario s runs
    its own quality knob).  The returned OptResult's F/latency/dq_fraction
    are for the worst-case scenario of the winning placement, recomputed
    with the exact oracle — and the worst case is the scenario maximizing
    the score (**F**, not latency: with per-scenario dq the (1 + β·dq_s)
    denominators differ, so the largest latency need not be the binding
    scenario).

    With an ``objectives`` ObjectiveSet the whole loop goes multi-objective:
    warm-start greedy seeds descend the weighted scalarization, the grid is
    the scalarized (S, P) matrix, and the reported F is the worst-case
    scenario's scalarized score (latency stays that scenario's raw
    critical-path latency).

    Also reachable as ``repro.core.scenario_robust_search`` (a delegator —
    the implementation lives here so the dependency arrow stays sim → core).
    """
    from repro.core.optimizers import (OptResult, PlacementProblem,
                                       greedy_transfer)

    obj_set = None if objectives is None else as_objective_set(objectives)
    dq_s = np.broadcast_to(np.asarray(dq, dtype=np.float64),
                           (len(scenarios),))
    extra = []
    if warm_start:
        for s in scenarios[: min(len(scenarios), 4)]:
            prob = PlacementProblem(graph, s.fleet, cost_cfg, beta=beta,
                                    objectives=obj_set)
            extra.append(greedy_transfer(prob, max_rounds=10).x)
    x, worst_F, grid = robust_placement(
        graph, scenarios, rng, n_candidates=n_candidates, cfg=cost_cfg,
        beta=beta, dq=dq_s, sparsity=sparsity, extra_candidates=extra,
        objectives=obj_set)
    # worst-case scenario of the winner via the exact oracle (independent of
    # the grid's candidate ordering), picked by the scenario score so
    # per-scenario dq denominators participate in the max
    lats = [latency(graph, s.fleet, x, cost_cfg) for s in scenarios]
    if obj_set is None:
        fs = [objective_F(lat, float(d), beta) for lat, d in zip(lats, dq_s)]
    else:
        fs = [obj_set.scalar_total(graph, s.fleet, x, float(d), beta,
                                   cost_cfg)
              for s, d in zip(scenarios, dq_s)]
    k = int(np.argmax(fs))
    return OptResult(x=x, dq_fraction=float(dq_s[k]), F=fs[k],
                     latency=lats[k], history=[worst_F],
                     evals=int(np.asarray(grid).size))
