"""Process-wide executable cache: ONE compiled callable per (evaluator
family, layout, objective set), shared across every consumer.

Before this module, each :class:`repro.sim.batched.BatchedEvaluator`
instance owned its jitted entry points and its ``_structured_cache`` /
``_multi_cache`` dicts — so two evaluators built over identically-packed
fleets compiled the SAME program twice (jax's compilation cache keys on
function identity, and per-instance closures are distinct functions).  The
what-if serving layer (:mod:`repro.serve`) makes that cost structural: many
tenants, one process, one set of hot shapes.

The fix is an LRU of *callables* keyed by semantic identity:

  * the evaluator family — :func:`graph_key` (operator tuple + edge list,
    so separately-constructed but identical graphs collide on purpose),
    the frozen :class:`~repro.core.costmodel.CostConfig`, and the
    ``use_pallas`` / ``interpret`` flags;
  * the entry point kind (dense grid, structured layout, multi-objective
    set, ...) plus whatever static state it closes over (region layout
    bytes, the hashable ``ObjectiveSet``).

Because the cached value is the jitted *function object*, jax's own
executable cache then does the per-shape-bucket work: the first dispatch of
an unseen (bucket, scenario-count) shape compiles, every later dispatch —
from ANY evaluator instance with an equal key — hits.  Eviction is safe:
a rebuilt callable just recompiles on first use (counted as an eviction
plus a miss).

Hit/miss/evict counters publish into ``repro.obs`` (label ``kind=`` the
key's leading tag) when the registry is enabled; :meth:`ExecutableCache.
stats` reports them unconditionally for the serving layer's per-bucket
accounting.  :func:`fresh_cache` scopes an isolated cache — tests and the
``bench_serve`` dedicated-evaluator baseline use it to measure exactly the
per-consumer recompilation this module deletes.
"""

from __future__ import annotations

import collections
import contextlib
import threading

from repro import obs

__all__ = ["ExecutableCache", "executable_cache", "set_executable_cache",
           "fresh_cache", "graph_key"]


def graph_key(graph) -> tuple:
    """Content identity of an :class:`~repro.core.graph.OpGraph`: the
    operator tuple (frozen dataclasses) plus the edge list.  Two graphs
    built independently from the same spec hash equal — that equality is
    what lets separate consumers share one compiled evaluator."""
    return (tuple(graph.operators), tuple(graph.edges))


class ExecutableCache:
    """Thread-safe LRU of built callables.

    ``get_or_build(key, builder)`` returns the cached callable for ``key``
    or invokes ``builder()`` (cheap — jit *wrapping*, not compilation) and
    caches it.  Keys are arbitrary hashable tuples whose first element
    names the entry-point kind (used as the obs label).
    """

    def __init__(self, capacity: int = 512, name: str = "executables"):
        if capacity < 1:
            raise ValueError(f"need capacity >= 1, got {capacity}")
        self.capacity = capacity
        self.name = name
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _count(self, event: str, kind: str) -> None:
        reg = obs.registry()
        if reg.enabled:
            reg.counter(f"cache.{self.name}.{event}", kind=kind).add(1)

    def get_or_build(self, key: tuple, builder):
        kind = str(key[0]) if isinstance(key, tuple) and key else "?"
        with self._lock:
            fn = self._entries.get(key)
            if fn is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                self._count("hits", kind)
                return fn
            self.misses += 1
            self._count("misses", kind)
            fn = builder()
            self._entries[key] = fn
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
                self._count("evictions", kind)
            return fn

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def stats(self) -> dict:
        """JSON-able counters (always collected, registry or not)."""
        with self._lock:
            lookups = self.hits + self.misses
            return {"name": self.name, "size": len(self._entries),
                    "capacity": self.capacity, "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "hit_rate": self.hits / lookups if lookups else None}


_cache = ExecutableCache()


def executable_cache() -> ExecutableCache:
    """The process-wide default cache every evaluator builds through."""
    return _cache


def set_executable_cache(cache: ExecutableCache) -> ExecutableCache:
    """Swap the process-wide cache (returns the previous one)."""
    global _cache
    prev, _cache = _cache, cache
    return prev


@contextlib.contextmanager
def fresh_cache(capacity: int = 512, name: str = "executables"):
    """Scope an isolated ExecutableCache as the process default — restores
    the previous cache on exit.  Used by tests (isolation) and by the
    ``bench_serve`` dedicated-evaluator baseline, which must NOT benefit
    from sharing to measure the cost of per-consumer compilation."""
    prev = set_executable_cache(ExecutableCache(capacity, name))
    try:
        yield executable_cache()
    finally:
        set_executable_cache(prev)
