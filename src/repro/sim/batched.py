"""Batched what-if evaluation: score (scenario × placement) grids in one
device dispatch.

The scalar path (repro.core.costmodel) walks edges in Python — fine for one
placement on one fleet, hopeless for scoring thousands of candidates over a
scenario family.  This module is the vectorized twin, with TWO scenario
representations behind one API:

  * **dense** — the communication matrix is an *argument* (one (V, V) per
    scenario), so a single jitted function evaluates every
    (fleet, placement) pair of a grid; on the hot path the bilinear-max runs
    in the Pallas kernel ``repro.kernels.edge_latency``.  Memory is
    O(S·V²) — fine to a few thousand devices.
  * **structured** — a :class:`repro.core.devices.RegionFleetFamily`
    (shared region layout, (S, R, R) inter matrices, (S, V) degrade
    multipliers) is scored via the segment-sum formulation
    (``make_edge_latencies_region_fn``): O(S·(R² + V)) scenario state and
    O(P·E·V) working set, never an (S, V, V) tensor — what-if grids reach
    the 10⁵-device fleets the scalar ``make_latency_fn`` already prices.

``BatchedEvaluator`` dispatches on the type of the ``com`` argument:
a stacked array (from :func:`pack_fleets`) takes the dense path, a
``RegionFleetFamily`` (from :func:`pack_region_fleets`) the structured one —
same ``edge_latencies`` / ``latency`` / ``objective`` / ``score_grid``
surface either way.  The critical-path DP is shared: it unrolls over the
static topo order with (B,) vector states, so it vectorizes over the whole
batch for free.

The float64 numpy oracle stays the correctness reference: property tests
assert agreement to ≤1e-5 relative on random graphs/fleets/placements,
including RegionFleet(Family) and ``alpha > 0`` enabledLinks cases.

This module is the scoring backend of the search subsystem: the batched
searchers (``repro.search``) chunk their candidate batches through
``score_grid`` — single-problem searches pack the fleet as a singleton
scenario — and the decision layer consumes the per-objective grids for
Pareto extraction and normalization (see ``src/repro/search/README.md``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis import sanitize
from repro.core.costmodel import CostConfig
from repro.sim.execache import ExecutableCache, executable_cache, graph_key
from repro.core.devices import ExplicitFleet, RegionFleet, RegionFleetFamily
from repro.core.graph import OpGraph
from repro.core.jaxmodel import (SmoothConfig, _edge_arrays, _region_factors,
                                 critical_path_dp,
                                 make_edge_latencies_com_fn,
                                 make_edge_latencies_region_fn)
from repro.core.objectives import (ObjectiveGrids, ObjectiveSet,
                                   as_objective_set)

__all__ = ["BatchedEvaluator", "pack_fleets", "pack_placements",
           "pack_region_fleets", "pack_speeds"]

# instance memo behind BatchedEvaluator.shared(): one evaluator per
# (graph content, cfg, pallas flags), so independent consumers (search
# engines, the serving layer, examples) converge on the same instance —
# and therefore the same compiled executables — instead of warming their
# own.  The compiled state itself lives in repro.sim.execache either way;
# this only spares re-deriving the static edge arrays.
_shared_evaluators = ExecutableCache(capacity=64, name="evaluators")

Fleet = ExplicitFleet | RegionFleet


def pack_fleets(fleets: list[Fleet], dtype=jnp.float32) -> jnp.ndarray:
    """(S, V, V) stacked com matrices — the DENSE scenario pack.

    Any fleet (RegionFleets included) is materialized, so this caps out at a
    few thousand devices; families of RegionFleets sharing a region layout
    should use :func:`pack_region_fleets` instead, which keeps the O(R² + V)
    structure all the way through ``score_grid``.
    """
    mats = [np.asarray(f.com_matrix(), dtype=np.float64) for f in fleets]
    shapes = {m.shape for m in mats}
    if len(shapes) != 1:
        raise ValueError(f"fleets disagree on device count: {sorted(shapes)}")
    return jnp.asarray(np.stack(mats), dtype=dtype)


def pack_region_fleets(fleets: list[RegionFleet]) -> RegionFleetFamily:
    """Pack RegionFleets sharing one region layout into the STRUCTURED
    scenario representation (no (S, V, V) materialization anywhere).

    Raises ValueError when the fleets don't stack structurally — fall back
    to :func:`pack_fleets` for heterogeneous-layout families.
    """
    if not all(isinstance(f, RegionFleet) for f in fleets):
        raise ValueError("pack_region_fleets needs RegionFleets; "
                         "use pack_fleets for mixed/dense fleets")
    return RegionFleetFamily.from_fleets(fleets)


def pack_placements(xs: list[np.ndarray], dtype=jnp.float32) -> jnp.ndarray:
    """(P, n_ops, V) stacked candidate placements."""
    return jnp.asarray(np.stack([np.asarray(x) for x in xs]), dtype=dtype)


def pack_speeds(fleets: list[Fleet], dtype=jnp.float32) -> jnp.ndarray:
    """(S, V) stacked *effective* device speeds — the dense-path companion
    of :func:`pack_fleets` for the occupancy objectives (the com stack
    carries link state only; compute speed rides separately).  Structured
    families don't need this: a RegionFleetFamily carries its own speeds."""
    sp = [np.asarray(f.effective_speed(), dtype=np.float64) for f in fleets]
    shapes = {s.shape for s in sp}
    if len(shapes) != 1:
        raise ValueError(f"fleets disagree on device count: {sorted(shapes)}")
    return jnp.asarray(np.stack(sp), dtype=dtype)


@dataclasses.dataclass
class _StructuredFns:
    """Jitted structured-path entry points for one family layout (lat_raw
    is the unjitted latency fn the multi-objective grid composes into its
    own jitted dispatch)."""

    elat: callable
    lat: callable
    obj: callable
    grid: callable
    lat_raw: callable


@dataclasses.dataclass
class BatchedEvaluator:
    """vmap/jit twin of edge_latencies / latency / objective_F for one graph.

    Batch conventions (x and the scenario batch must share the SAME leading
    batch size B, or the scenario batch is a singleton shared across B;
    score_grid forms the cross product itself).  ``com`` is either a dense
    (B, V, V) stack (pack_fleets) or a RegionFleetFamily (pack_region_fleets):

      edge_latencies(x (B,n,V), com)      -> (B, E)
      latency(x, com)                     -> (B,)
      objective(x, com, dq, beta)         -> (B,)
      score_grid(x (P,n,V), com [S scen]) -> (S, P)   — ONE dispatch

    ``use_pallas`` routes the inner reduction through the Pallas kernels
    (dense bilinear-max or structured region-mass matmul).  Both flags
    default to ``None`` = "auto for the backend" and resolve ONCE through
    :func:`repro.kernels.dispatch.resolve_flags` (CPU: jnp path +
    interpret; accelerators: Pallas + compiled), so no caller silently
    runs interpreted kernels on an accelerator or compiled mode on CPU.
    After construction both attributes are concrete booleans.
    """

    graph: OpGraph
    cfg: CostConfig = CostConfig()
    use_pallas: bool | None = None
    interpret: bool | None = None

    def __post_init__(self):
        from repro.kernels.dispatch import resolve_flags
        self.use_pallas, self.interpret = resolve_flags(self.use_pallas,
                                                        self.interpret)
        src, dst, sel = _edge_arrays(self.graph)
        self._src = jnp.asarray(src)
        self._dst = jnp.asarray(dst)
        self._sel = jnp.asarray(sel, dtype=jnp.float32)
        if self.cfg.include_compute:
            raise NotImplementedError(
                "batched evaluator covers the paper-faithful model "
                "(communication dominates); compute extension is scalar-only")
        # single source of truth for the jnp edge math: vmap the com-traced
        # twin from core.jaxmodel (hard max; same alpha/nz_eps semantics)
        self._elat_single = make_edge_latencies_com_fn(
            self.graph, SmoothConfig(alpha=self.cfg.alpha),
            nz_eps=self.cfg.nz_eps)
        # every jitted entry point resolves through the PROCESS-WIDE
        # executable cache (repro.sim.execache), keyed by the evaluator's
        # semantic identity: two evaluators built over identical graphs and
        # configs share ONE jitted function object, so jax's compilation
        # cache hits instead of recompiling per instance.  The builder
        # closures bind this instance, which is safe exactly because the
        # key pins everything they read (graph content, cfg, pallas flags).
        ek = self._eval_key = (graph_key(self.graph), self.cfg,
                              self.use_pallas, self.interpret)
        cache = executable_cache()
        self._jit_elat = cache.get_or_build(
            ("dense_elat", ek), lambda: jax.jit(self._elat_batched))
        self._jit_lat = cache.get_or_build(
            ("dense_lat", ek), lambda: jax.jit(self._lat_batched))
        self._jit_obj = cache.get_or_build(
            ("dense_obj", ek), lambda: jax.jit(self._obj_batched))
        self._jit_grid = cache.get_or_build(
            ("dense_grid", ek), lambda: jax.jit(self._grid))

    @classmethod
    def shared(cls, graph: OpGraph, cfg: CostConfig = CostConfig(),
               use_pallas: bool | None = None,
               interpret: bool | None = None) -> "BatchedEvaluator":
        """The process-shared evaluator for this (graph, cfg, flags) —
        equal-content graphs map to the SAME instance, so every consumer
        (search engines, :mod:`repro.serve`, scripts) reuses one set of
        compiled executables instead of warming its own.  Flags resolve
        through the dispatch policy BEFORE the memo key, so ``None`` and
        its concrete resolution map to the same instance."""
        from repro.kernels.dispatch import resolve_flags
        use_pallas, interpret = resolve_flags(use_pallas, interpret)
        key = ("evaluator", graph_key(graph), cfg, use_pallas, interpret)
        return _shared_evaluators.get_or_build(
            key, lambda: cls(graph, cfg, use_pallas=use_pallas,
                             interpret=interpret))

    # -- dense batched math (all shapes carry a leading B) -------------------
    def _elat_batched(self, x: jnp.ndarray, com: jnp.ndarray) -> jnp.ndarray:
        """x (B, n, V) against com (B, V, V), or (1, V, V) = one shared
        scenario (the Pallas index map / vmap in_axes share it without
        replicating it in memory)."""
        if not self.use_pallas:
            if com.shape[0] == 1 and x.shape[0] != 1:
                return jax.vmap(self._elat_single, in_axes=(0, None))(
                    x, com[0])                             # (B, E)
            return jax.vmap(self._elat_single)(x, com)     # (B, E)
        x_i = x[:, self._src] * self._sel[None, :, None]   # (B, E, V)
        x_j = x[:, self._dst]                              # (B, E, V)
        from repro.kernels.dispatch import edge_latency
        out = edge_latency(x_i, x_j, com, use_pallas=True,
                           interpret=self.interpret)
        return out + self._links_term(x, out.dtype)

    def _links_term(self, x: jnp.ndarray, dtype) -> jnp.ndarray:
        """α·enabledLinks per edge, (B, E) — zero when alpha is off."""
        if not self.cfg.alpha:
            return jnp.zeros((), dtype)
        nz = (x > self.cfg.nz_eps).astype(dtype)
        counts = nz.sum(axis=-1)                           # (B, n_ops)
        both = (nz[:, self._src] * nz[:, self._dst]).sum(axis=-1)
        links = counts[:, self._src] * counts[:, self._dst] - both
        return self.cfg.alpha * links

    def _lat_batched(self, x: jnp.ndarray, com: jnp.ndarray) -> jnp.ndarray:
        return critical_path_dp(self.graph, self._elat_batched(x, com))

    def _obj_batched(self, x, com, dq, beta):
        return self._lat_batched(x, com) / (1.0 + beta * dq)

    def _grid(self, placements: jnp.ndarray, coms: jnp.ndarray,
              dq, beta) -> jnp.ndarray:
        # cross product WITHOUT materializing S·P operand copies: map over
        # scenarios, each scoring all P placements against one shared com
        # (at the ROADMAP's V=4096 targets a replicated com tensor would be
        # tens of GB).  lax.map keeps one trace; P stays the wide batch dim.
        lat = jax.lax.map(
            lambda com: self._lat_batched(placements, com[None]), coms)
        return self._finish_grid(lat, coms.shape[0], dq, beta)

    @staticmethod
    def _finish_grid(lat: jnp.ndarray, S: int, dq, beta) -> jnp.ndarray:
        """(S, P) latencies → objectives; dq scalar or per-scenario (S,)."""
        dq = jnp.broadcast_to(jnp.asarray(dq, lat.dtype), (S,))
        return lat / (1.0 + beta * dq[:, None])

    # -- structured batched math (RegionFleetFamily scenarios) ---------------
    @staticmethod
    def _layout_key(fam: RegionFleetFamily) -> tuple:
        return (fam.region.tobytes(), fam.n_regions, float(fam.self_cost))

    def _structured(self, fam: RegionFleetFamily) -> _StructuredFns:
        # structured fns are built lazily per family layout (the region
        # assignment is static structure, like the graph) and cached
        # process-wide: same layout + same evaluator identity ⇒ same
        # compiled executables, whichever instance asked first
        key = ("structured", self._eval_key, self._layout_key(fam))
        return executable_cache().get_or_build(
            key, lambda: self._build_structured(fam.region, fam.n_regions,
                                                fam.self_cost))

    def _build_structured(self, region: np.ndarray, n_regions: int,
                          self_cost: float) -> _StructuredFns:
        elat_single = make_edge_latencies_region_fn(
            self.graph, region, n_regions, self_cost,
            SmoothConfig(alpha=self.cfg.alpha), nz_eps=self.cfg.nz_eps)
        region_ix = jnp.asarray(np.asarray(region, dtype=np.int64))

        def elat_b(x, inter, degrade):
            """x (B, n, V); inter (Sb, R, R), degrade (Sb, V), Sb ∈ {1, B}."""
            if not self.use_pallas:
                if inter.shape[0] == 1 and x.shape[0] != 1:
                    return jax.vmap(elat_single, in_axes=(0, None, None))(
                        x, inter[0], degrade[0])           # (B, E)
                return jax.vmap(elat_single)(x, inter, degrade)
            # Pallas route: precompute the region-space factors (XLA
            # gathers/scatters, all O(V·R) or smaller), fuse the rest;
            # the pricing rule itself lives in jaxmodel._region_factors,
            # shared with the vmap route's elat twin
            x_i = x[:, self._src] * self._sel[None, :, None]   # (B, E, V)
            x_j = x[:, self._dst]                              # (B, E, V)
            dj = degrade[:, None, :] * x_j                     # (B, E, V)
            B, E, V = x_i.shape
            mass = jnp.zeros((B, E, n_regions), x.dtype)
            mass = mass.at[:, :, region_ix].add(dj)            # (B, E, R)
            a, corr = jax.vmap(
                lambda i, d: _region_factors(i, d, region_ix, self_cost)
            )(inter, degrade)                        # (Sb, R, V), (Sb, V)
            from repro.kernels.dispatch import edge_latency_structured
            out = edge_latency_structured(
                x_i.astype(jnp.float32), x_j.astype(jnp.float32),
                mass.astype(jnp.float32), a.astype(jnp.float32),
                corr[:, None, :].astype(jnp.float32),
                use_pallas=True, interpret=self.interpret)
            return out + self._links_term(x, out.dtype)

        def lat_b(x, inter, degrade):
            return critical_path_dp(self.graph, elat_b(x, inter, degrade))

        def obj_b(x, inter, degrade, dq, beta):
            return lat_b(x, inter, degrade) / (1.0 + beta * dq)

        def grid(placements, inters, degrades, dq, beta):
            # same no-replication cross product as the dense path: scenarios
            # stream through lax.map carrying only (R, R) + (V,) state each
            lat = jax.lax.map(
                lambda sc: lat_b(placements, sc[0][None], sc[1][None]),
                (inters, degrades))
            return self._finish_grid(lat, inters.shape[0], dq, beta)

        return _StructuredFns(elat=jax.jit(elat_b), lat=jax.jit(lat_b),
                              obj=jax.jit(obj_b), grid=jax.jit(grid),
                              lat_raw=lat_b)

    @staticmethod
    def _family_args(fam: RegionFleetFamily) -> tuple[jnp.ndarray, jnp.ndarray]:
        return (jnp.asarray(fam.inter, jnp.float32),
                jnp.asarray(fam.degrade, jnp.float32))

    # -- multi-objective grids (ObjectiveSet, §3.1) --------------------------
    #
    # One jitted dispatch returns EVERY objective's (S, P) grid plus the
    # weighted scalarization, on both scenario representations.  The
    # scenario lax.map carries a pytree of per-objective (P,) rows, so the
    # no-replication cross product is unchanged; dq/beta normalization
    # (spec.finish — only latency-F uses it) and the weighted sum happen
    # after the map, where per-scenario dq broadcasts over the grid.
    #
    # latency_f is carved out by name: it rides the evaluator's own edge
    # machinery (which honors use_pallas and is already built per graph)
    # instead of the spec's reference builders — a test pins the two routes
    # to the same oracle so they can't drift.

    def _finish_multi(self, obj_set: ObjectiveSet, raw: dict, S: int,
                      dq, beta, weights):
        dq_col = jnp.broadcast_to(jnp.asarray(dq, jnp.float32), (S,))[:, None]
        grids = {s.name: s.finish(raw[s.name], dq_col, beta)
                 for s in obj_set.specs}
        stacked = jnp.stack([grids[n] for n in obj_set.names])  # (K, S, P)
        return grids, jnp.einsum("k,ksp->sp", weights, stacked)

    def _multi_dense(self, obj_set: ObjectiveSet):
        # multi-objective grid fns cache per (evaluator identity,
        # ObjectiveSet) — ObjectiveSet is hashable for exactly this
        def build():
            builders = {s.name: s.build_dense(self.graph, self.cfg)
                        for s in obj_set.specs if s.name != "latency_f"}
            has_lat = "latency_f" in obj_set.names

            def grid(placements, coms, speeds, dq, beta, weights):
                def per_scenario(op):
                    com, speed = op
                    outs = {}
                    if has_lat:
                        # the evaluator's own edge machinery (Pallas-aware)
                        outs["latency_f"] = self._lat_batched(
                            placements, com[None])
                    for name, f in builders.items():
                        outs[name] = jax.vmap(
                            lambda x: f(x, com, speed))(placements)
                    return outs                       # dict of (P,)
                raw = jax.lax.map(per_scenario, (coms, speeds))
                return self._finish_multi(obj_set, raw, coms.shape[0],
                                          dq, beta, weights)

            return jax.jit(grid)

        return executable_cache().get_or_build(
            ("multi_dense", self._eval_key, obj_set), build)

    def _multi_structured(self, fam: RegionFleetFamily,
                          obj_set: ObjectiveSet):
        def build():
            sf = self._structured(fam)
            builders = {s.name: s.build_structured(
                            self.graph, fam.region, fam.n_regions,
                            fam.self_cost, self.cfg)
                        for s in obj_set.specs if s.name != "latency_f"}
            has_lat = "latency_f" in obj_set.names

            def grid(placements, inters, degrades, speeds, dq, beta,
                     weights):
                def per_scenario(sc):
                    inter, degrade, speed = sc
                    outs = {}
                    if has_lat:
                        outs["latency_f"] = sf.lat_raw(
                            placements, inter[None], degrade[None])
                    for name, f in builders.items():
                        outs[name] = jax.vmap(
                            lambda x: f(x, inter, degrade, speed))(placements)
                    return outs
                raw = jax.lax.map(per_scenario, (inters, degrades, speeds))
                return self._finish_multi(obj_set, raw, inters.shape[0],
                                          dq, beta, weights)

            return jax.jit(grid)

        key = ("multi_structured", self._eval_key, self._layout_key(fam),
               obj_set)
        return executable_cache().get_or_build(key, build)

    @staticmethod
    def _validate_dq(dq, S: int) -> jnp.ndarray:
        """dq must be a scalar or EXACTLY (S,) — a wrong-length vector that
        happens to broadcast (e.g. (1,) against S scenarios, or a (P,)
        slipped in as dq) would silently mis-scale the grid."""
        arr = np.asarray(dq, dtype=np.float64)
        if arr.ndim != 0 and arr.shape != (S,):
            raise ValueError(
                f"dq must be a scalar or shape ({S},) — one entry per "
                f"scenario; got shape {arr.shape} for S={S}")
        return jnp.asarray(arr, jnp.float32)

    def _dense_speeds(self, coms: jnp.ndarray, speed) -> jnp.ndarray:
        """Normalize the dense path's optional speed operand to (S, V):
        None ⇒ unit speeds (the paper-faithful 'communication dominates'
        default), (V,) shared, or (S, V) per-scenario (pack_speeds)."""
        S, V = coms.shape[0], coms.shape[1]
        if speed is None:
            return jnp.ones((S, V), jnp.float32)
        arr = np.asarray(speed, dtype=np.float64)
        if arr.shape == (V,):
            arr = np.broadcast_to(arr, (S, V))
        elif arr.shape != (S, V):
            raise ValueError(f"speed must be (V,) or (S, V) = ({S}, {V}); "
                             f"got shape {arr.shape}")
        return jnp.asarray(arr, jnp.float32)

    # -- public API ----------------------------------------------------------
    def edge_latencies(self, x, com) -> jnp.ndarray:
        """(B, E) edge latencies — batched edge_latencies()."""
        if isinstance(com, RegionFleetFamily):
            return self._structured(com).elat(jnp.asarray(x),
                                              *self._family_args(com))
        return self._jit_elat(jnp.asarray(x), jnp.asarray(com))

    def latency(self, x, com) -> jnp.ndarray:
        """(B,) critical-path latencies — batched latency()."""
        if isinstance(com, RegionFleetFamily):
            return self._structured(com).lat(jnp.asarray(x),
                                             *self._family_args(com))
        return self._jit_lat(jnp.asarray(x), jnp.asarray(com))

    def objective(self, x, com, dq=0.0, beta: float = 0.0) -> jnp.ndarray:
        """(B,) paper eq. (8) objectives — batched objective_F()."""
        if isinstance(com, RegionFleetFamily):
            return self._structured(com).obj(
                jnp.asarray(x), *self._family_args(com),
                jnp.asarray(dq, jnp.float32), float(beta))
        return self._jit_obj(jnp.asarray(x), jnp.asarray(com),
                             jnp.asarray(dq, jnp.float32), float(beta))

    def score_grid(self, placements, coms, dq=0.0, beta: float = 0.0,
                   objectives: ObjectiveSet | None = None, speed=None,
                   guard_output: bool = True):
        """Score every (scenario, placement) pair in one jitted dispatch.

        ``coms`` is a dense (S, V, V) stack or a RegionFleetFamily; ``dq``
        must be a scalar or exactly per-scenario (S,).

        ``objectives=None`` (default) returns the (S, P) latency-F grid —
        the single-objective fast path.  With an :class:`ObjectiveSet` (or
        anything ``as_objective_set`` accepts) the SAME dispatch computes
        every objective's (S, P) grid plus the weighted scalarization,
        returned as an :class:`ObjectiveGrids`; the structured path still
        never materializes an (S, V, V) array.  ``speed`` feeds the
        occupancy objectives on the dense path ((V,) or (S, V), see
        :func:`pack_speeds`; default unit speeds); structured families
        carry their own speeds, so ``speed`` must stay None there.
        """
        placements = jnp.asarray(placements)
        structured = isinstance(coms, RegionFleetFamily)
        if not structured:
            coms = jnp.asarray(coms)
        S = coms.n_scenarios if structured else coms.shape[0]
        dq_arr = self._validate_dq(dq, S)
        san = sanitize.state()
        if san.enabled and san.domain_check:
            sanitize.check_dq(dq)  # host-side operand: no device round-trip
        path = "structured" if structured else "dense"
        multi = objectives is not None
        reg = obs.registry()
        if reg.enabled:
            reg.counter("eval.score_grid.dispatches", path=path).add(1)
            reg.histogram("eval.score_grid.cells", lo=1.0).observe(
                S * int(placements.shape[0]))
        with obs.span("score_grid", S=S, P=int(placements.shape[0]),
                      path=path, multi=multi) as sp:
            out = self._dispatch_grid(placements, coms, dq_arr, beta,
                                      objectives, speed, structured)
            sp.sync(out.scalarized if isinstance(out, ObjectiveGrids)
                    else out)
        if guard_output and san.enabled and san.nan_check:
            # jax.Array caches its host copy, so downstream np conversions
            # don't pay this device→host transfer twice.  Callers that run
            # their own output guard on the host copy they already make
            # (BatchedProblem) pass guard_output=False — one guard per
            # value, at the layer that owns the transfer
            sanitize.check_finite(
                "score_grid",
                out.scalarized if isinstance(out, ObjectiveGrids) else out)
        return out

    def _dispatch_grid(self, placements, coms, dq_arr, beta, objectives,
                       speed, structured: bool):
        if objectives is None:
            if speed is not None:
                raise ValueError("speed only feeds the occupancy objectives "
                                 "— pass objectives= to use it")
            if structured:
                return self._structured(coms).grid(
                    placements, *self._family_args(coms), dq_arr,
                    float(beta))
            return self._jit_grid(placements, coms, dq_arr, float(beta))
        obj_set = as_objective_set(objectives)
        weights = jnp.asarray(obj_set.weights, jnp.float32)
        if structured:
            if speed is not None:
                raise ValueError("structured families carry their own "
                                 "speeds; leave speed=None")
            # nominal speeds: the structured occupancy twin applies the
            # traced degrade itself (effective = speed / degrade)
            speeds = jnp.asarray(coms.speed_or_ones(), jnp.float32)
            grids, scal = self._multi_structured(coms, obj_set)(
                placements, *self._family_args(coms), speeds, dq_arr,
                float(beta), weights)
        else:
            grids, scal = self._multi_dense(obj_set)(
                placements, coms, self._dense_speeds(coms, speed), dq_arr,
                float(beta), weights)
        # jit returns dict pytrees in sorted-key order; present the grids
        # in the set's declared objective order
        return ObjectiveGrids(names=obj_set.names,
                              grids={n: grids[n] for n in obj_set.names},
                              scalarized=scal, weights=obj_set.weights)
