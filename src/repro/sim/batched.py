"""Batched what-if evaluation: score (scenario × placement) grids in one
device dispatch.

The scalar path (repro.core.costmodel) walks edges in Python — fine for one
placement on one fleet, hopeless for scoring thousands of candidates over a
scenario family.  This module is the vectorized twin:

  * the communication matrix is an *argument* (one per scenario), so a
    single jitted function evaluates every (fleet, placement) pair of a
    grid — no retracing, no Python per edge;
  * edge latencies are computed for all edges at once (gather endpoint
    rows → one batched matvec → row-max); on the hot path that reduction
    runs in the Pallas kernel ``repro.kernels.edge_latency``;
  * the critical-path DP is unrolled over the static topo order with (B,)
    vector states, so it vectorizes over the whole batch for free.

The float64 numpy oracle stays the correctness reference: property tests
assert agreement to ≤1e-5 relative on random graphs/fleets/placements,
including RegionFleet and ``alpha > 0`` enabledLinks cases.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostConfig
from repro.core.devices import ExplicitFleet, RegionFleet
from repro.core.graph import OpGraph
from repro.core.jaxmodel import (SmoothConfig, _edge_arrays, critical_path_dp,
                                 make_edge_latencies_com_fn)

__all__ = ["BatchedEvaluator", "pack_fleets", "pack_placements"]

Fleet = ExplicitFleet | RegionFleet


def pack_fleets(fleets: list[Fleet], dtype=jnp.float32) -> jnp.ndarray:
    """(S, V, V) stacked com matrices (RegionFleets are materialized —
    scenario batches hold modest V; the structured 10⁵-device path stays on
    make_latency_fn)."""
    mats = [np.asarray(f.com_matrix(), dtype=np.float64) for f in fleets]
    shapes = {m.shape for m in mats}
    if len(shapes) != 1:
        raise ValueError(f"fleets disagree on device count: {sorted(shapes)}")
    return jnp.asarray(np.stack(mats), dtype=dtype)


def pack_placements(xs: list[np.ndarray], dtype=jnp.float32) -> jnp.ndarray:
    """(P, n_ops, V) stacked candidate placements."""
    return jnp.asarray(np.stack([np.asarray(x) for x in xs]), dtype=dtype)


@dataclasses.dataclass
class BatchedEvaluator:
    """vmap/jit twin of edge_latencies / latency / objective_F for one graph.

    Batch conventions (x and com must share the SAME leading batch size B;
    score_grid forms the cross product itself):
      edge_latencies(x (B,n,V), com (B,V,V)) -> (B, E)
      latency(x, com)                        -> (B,)
      objective(x, com, dq, beta)            -> (B,)
      score_grid(x (P,n,V), com (S,V,V))     -> (S, P)   — ONE dispatch

    ``use_pallas`` routes the inner bilinear-max through the Pallas kernel
    (``interpret=True`` executes it on CPU; flip off on real TPUs).
    """

    graph: OpGraph
    cfg: CostConfig = CostConfig()
    use_pallas: bool = False
    interpret: bool = True

    def __post_init__(self):
        src, dst, sel = _edge_arrays(self.graph)
        self._src = jnp.asarray(src)
        self._dst = jnp.asarray(dst)
        self._sel = jnp.asarray(sel, dtype=jnp.float32)
        if self.cfg.include_compute:
            raise NotImplementedError(
                "batched evaluator covers the paper-faithful model "
                "(communication dominates); compute extension is scalar-only")
        # single source of truth for the jnp edge math: vmap the com-traced
        # twin from core.jaxmodel (hard max; same alpha/nz_eps semantics)
        self._elat_single = make_edge_latencies_com_fn(
            self.graph, SmoothConfig(alpha=self.cfg.alpha),
            nz_eps=self.cfg.nz_eps)
        self._jit_elat = jax.jit(self._elat_batched)
        self._jit_lat = jax.jit(self._lat_batched)
        self._jit_obj = jax.jit(self._obj_batched)
        self._jit_grid = jax.jit(self._grid)

    # -- core batched math (all shapes carry a leading B) --------------------
    def _elat_batched(self, x: jnp.ndarray, com: jnp.ndarray) -> jnp.ndarray:
        """x (B, n, V) against com (B, V, V), or (1, V, V) = one shared
        scenario (the Pallas index map / vmap in_axes share it without
        replicating it in memory)."""
        if not self.use_pallas:
            if com.shape[0] == 1 and x.shape[0] != 1:
                return jax.vmap(self._elat_single, in_axes=(0, None))(
                    x, com[0])                             # (B, E)
            return jax.vmap(self._elat_single)(x, com)     # (B, E)
        x_i = x[:, self._src] * self._sel[None, :, None]   # (B, E, V)
        x_j = x[:, self._dst]                              # (B, E, V)
        from repro.kernels.ops import edge_latency_max
        out = edge_latency_max(x_i, x_j, com, interpret=self.interpret)
        if self.cfg.alpha:
            nz = (x > self.cfg.nz_eps).astype(out.dtype)
            counts = nz.sum(axis=-1)                       # (B, n_ops)
            both = (nz[:, self._src] * nz[:, self._dst]).sum(axis=-1)
            links = counts[:, self._src] * counts[:, self._dst] - both
            out = out + self.cfg.alpha * links
        return out

    def _lat_batched(self, x: jnp.ndarray, com: jnp.ndarray) -> jnp.ndarray:
        return critical_path_dp(self.graph, self._elat_batched(x, com))

    def _obj_batched(self, x, com, dq, beta):
        return self._lat_batched(x, com) / (1.0 + beta * dq)

    def _grid(self, placements: jnp.ndarray, coms: jnp.ndarray,
              dq, beta) -> jnp.ndarray:
        # cross product WITHOUT materializing S·P operand copies: map over
        # scenarios, each scoring all P placements against one shared com
        # (at the ROADMAP's V=4096 targets a replicated com tensor would be
        # tens of GB).  lax.map keeps one trace; P stays the wide batch dim.
        S = coms.shape[0]
        lat = jax.lax.map(
            lambda com: self._lat_batched(placements, com[None]), coms)
        dq = jnp.broadcast_to(jnp.asarray(dq, lat.dtype), (S,))
        return lat / (1.0 + beta * dq[:, None])

    # -- public API ----------------------------------------------------------
    def edge_latencies(self, x, com) -> jnp.ndarray:
        """(B, E) edge latencies — batched edge_latencies()."""
        return self._jit_elat(jnp.asarray(x), jnp.asarray(com))

    def latency(self, x, com) -> jnp.ndarray:
        """(B,) critical-path latencies — batched latency()."""
        return self._jit_lat(jnp.asarray(x), jnp.asarray(com))

    def objective(self, x, com, dq=0.0, beta: float = 0.0) -> jnp.ndarray:
        """(B,) paper eq. (8) objectives — batched objective_F()."""
        return self._jit_obj(jnp.asarray(x), jnp.asarray(com),
                             jnp.asarray(dq, jnp.float32), float(beta))

    def score_grid(self, placements, coms, dq=0.0,
                   beta: float = 0.0) -> jnp.ndarray:
        """(S, P) objective grid — every (scenario, placement) pair in one
        jitted dispatch.  ``dq`` may be scalar or per-scenario (S,)."""
        return self._jit_grid(jnp.asarray(placements), jnp.asarray(coms),
                              jnp.asarray(dq, jnp.float32), float(beta))
