"""Batched what-if evaluation: score (scenario × placement) grids in one
device dispatch.

The scalar path (repro.core.costmodel) walks edges in Python — fine for one
placement on one fleet, hopeless for scoring thousands of candidates over a
scenario family.  This module is the vectorized twin, with TWO scenario
representations behind one API:

  * **dense** — the communication matrix is an *argument* (one (V, V) per
    scenario), so a single jitted function evaluates every
    (fleet, placement) pair of a grid; on the hot path the bilinear-max runs
    in the Pallas kernel ``repro.kernels.edge_latency``.  Memory is
    O(S·V²) — fine to a few thousand devices.
  * **structured** — a :class:`repro.core.devices.RegionFleetFamily`
    (shared region layout, (S, R, R) inter matrices, (S, V) degrade
    multipliers) is scored via the segment-sum formulation
    (``make_edge_latencies_region_fn``): O(S·(R² + V)) scenario state and
    O(P·E·V) working set, never an (S, V, V) tensor — what-if grids reach
    the 10⁵-device fleets the scalar ``make_latency_fn`` already prices.

``BatchedEvaluator`` dispatches on the type of the ``com`` argument:
a stacked array (from :func:`pack_fleets`) takes the dense path, a
``RegionFleetFamily`` (from :func:`pack_region_fleets`) the structured one —
same ``edge_latencies`` / ``latency`` / ``objective`` / ``score_grid``
surface either way.  The critical-path DP is shared: it unrolls over the
static topo order with (B,) vector states, so it vectorizes over the whole
batch for free.

The float64 numpy oracle stays the correctness reference: property tests
assert agreement to ≤1e-5 relative on random graphs/fleets/placements,
including RegionFleet(Family) and ``alpha > 0`` enabledLinks cases.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostConfig
from repro.core.devices import ExplicitFleet, RegionFleet, RegionFleetFamily
from repro.core.graph import OpGraph
from repro.core.jaxmodel import (SmoothConfig, _edge_arrays, _region_factors,
                                 critical_path_dp,
                                 make_edge_latencies_com_fn,
                                 make_edge_latencies_region_fn)

__all__ = ["BatchedEvaluator", "pack_fleets", "pack_placements",
           "pack_region_fleets"]

Fleet = ExplicitFleet | RegionFleet


def pack_fleets(fleets: list[Fleet], dtype=jnp.float32) -> jnp.ndarray:
    """(S, V, V) stacked com matrices — the DENSE scenario pack.

    Any fleet (RegionFleets included) is materialized, so this caps out at a
    few thousand devices; families of RegionFleets sharing a region layout
    should use :func:`pack_region_fleets` instead, which keeps the O(R² + V)
    structure all the way through ``score_grid``.
    """
    mats = [np.asarray(f.com_matrix(), dtype=np.float64) for f in fleets]
    shapes = {m.shape for m in mats}
    if len(shapes) != 1:
        raise ValueError(f"fleets disagree on device count: {sorted(shapes)}")
    return jnp.asarray(np.stack(mats), dtype=dtype)


def pack_region_fleets(fleets: list[RegionFleet]) -> RegionFleetFamily:
    """Pack RegionFleets sharing one region layout into the STRUCTURED
    scenario representation (no (S, V, V) materialization anywhere).

    Raises ValueError when the fleets don't stack structurally — fall back
    to :func:`pack_fleets` for heterogeneous-layout families.
    """
    if not all(isinstance(f, RegionFleet) for f in fleets):
        raise ValueError("pack_region_fleets needs RegionFleets; "
                         "use pack_fleets for mixed/dense fleets")
    return RegionFleetFamily.from_fleets(fleets)


def pack_placements(xs: list[np.ndarray], dtype=jnp.float32) -> jnp.ndarray:
    """(P, n_ops, V) stacked candidate placements."""
    return jnp.asarray(np.stack([np.asarray(x) for x in xs]), dtype=dtype)


@dataclasses.dataclass
class _StructuredFns:
    """Jitted structured-path entry points for one family layout."""

    elat: callable
    lat: callable
    obj: callable
    grid: callable


@dataclasses.dataclass
class BatchedEvaluator:
    """vmap/jit twin of edge_latencies / latency / objective_F for one graph.

    Batch conventions (x and the scenario batch must share the SAME leading
    batch size B, or the scenario batch is a singleton shared across B;
    score_grid forms the cross product itself).  ``com`` is either a dense
    (B, V, V) stack (pack_fleets) or a RegionFleetFamily (pack_region_fleets):

      edge_latencies(x (B,n,V), com)      -> (B, E)
      latency(x, com)                     -> (B,)
      objective(x, com, dq, beta)         -> (B,)
      score_grid(x (P,n,V), com [S scen]) -> (S, P)   — ONE dispatch

    ``use_pallas`` routes the inner reduction through the Pallas kernels
    (dense bilinear-max or structured region-mass matmul;
    ``interpret=True`` executes them on CPU, flip off on real TPUs).
    """

    graph: OpGraph
    cfg: CostConfig = CostConfig()
    use_pallas: bool = False
    interpret: bool = True

    def __post_init__(self):
        src, dst, sel = _edge_arrays(self.graph)
        self._src = jnp.asarray(src)
        self._dst = jnp.asarray(dst)
        self._sel = jnp.asarray(sel, dtype=jnp.float32)
        if self.cfg.include_compute:
            raise NotImplementedError(
                "batched evaluator covers the paper-faithful model "
                "(communication dominates); compute extension is scalar-only")
        # single source of truth for the jnp edge math: vmap the com-traced
        # twin from core.jaxmodel (hard max; same alpha/nz_eps semantics)
        self._elat_single = make_edge_latencies_com_fn(
            self.graph, SmoothConfig(alpha=self.cfg.alpha),
            nz_eps=self.cfg.nz_eps)
        self._jit_elat = jax.jit(self._elat_batched)
        self._jit_lat = jax.jit(self._lat_batched)
        self._jit_obj = jax.jit(self._obj_batched)
        self._jit_grid = jax.jit(self._grid)
        # structured fns are built lazily per family layout (the region
        # assignment is static structure, like the graph)
        self._structured_cache: dict = {}

    # -- dense batched math (all shapes carry a leading B) -------------------
    def _elat_batched(self, x: jnp.ndarray, com: jnp.ndarray) -> jnp.ndarray:
        """x (B, n, V) against com (B, V, V), or (1, V, V) = one shared
        scenario (the Pallas index map / vmap in_axes share it without
        replicating it in memory)."""
        if not self.use_pallas:
            if com.shape[0] == 1 and x.shape[0] != 1:
                return jax.vmap(self._elat_single, in_axes=(0, None))(
                    x, com[0])                             # (B, E)
            return jax.vmap(self._elat_single)(x, com)     # (B, E)
        x_i = x[:, self._src] * self._sel[None, :, None]   # (B, E, V)
        x_j = x[:, self._dst]                              # (B, E, V)
        from repro.kernels.ops import edge_latency_max
        out = edge_latency_max(x_i, x_j, com, interpret=self.interpret)
        return out + self._links_term(x, out.dtype)

    def _links_term(self, x: jnp.ndarray, dtype) -> jnp.ndarray:
        """α·enabledLinks per edge, (B, E) — zero when alpha is off."""
        if not self.cfg.alpha:
            return jnp.zeros((), dtype)
        nz = (x > self.cfg.nz_eps).astype(dtype)
        counts = nz.sum(axis=-1)                           # (B, n_ops)
        both = (nz[:, self._src] * nz[:, self._dst]).sum(axis=-1)
        links = counts[:, self._src] * counts[:, self._dst] - both
        return self.cfg.alpha * links

    def _lat_batched(self, x: jnp.ndarray, com: jnp.ndarray) -> jnp.ndarray:
        return critical_path_dp(self.graph, self._elat_batched(x, com))

    def _obj_batched(self, x, com, dq, beta):
        return self._lat_batched(x, com) / (1.0 + beta * dq)

    def _grid(self, placements: jnp.ndarray, coms: jnp.ndarray,
              dq, beta) -> jnp.ndarray:
        # cross product WITHOUT materializing S·P operand copies: map over
        # scenarios, each scoring all P placements against one shared com
        # (at the ROADMAP's V=4096 targets a replicated com tensor would be
        # tens of GB).  lax.map keeps one trace; P stays the wide batch dim.
        lat = jax.lax.map(
            lambda com: self._lat_batched(placements, com[None]), coms)
        return self._finish_grid(lat, coms.shape[0], dq, beta)

    @staticmethod
    def _finish_grid(lat: jnp.ndarray, S: int, dq, beta) -> jnp.ndarray:
        """(S, P) latencies → objectives; dq scalar or per-scenario (S,)."""
        dq = jnp.broadcast_to(jnp.asarray(dq, lat.dtype), (S,))
        return lat / (1.0 + beta * dq[:, None])

    # -- structured batched math (RegionFleetFamily scenarios) ---------------
    def _structured(self, fam: RegionFleetFamily) -> _StructuredFns:
        key = (fam.region.tobytes(), fam.n_regions, float(fam.self_cost))
        fns = self._structured_cache.get(key)
        if fns is None:
            fns = self._build_structured(fam.region, fam.n_regions,
                                         fam.self_cost)
            self._structured_cache[key] = fns
        return fns

    def _build_structured(self, region: np.ndarray, n_regions: int,
                          self_cost: float) -> _StructuredFns:
        elat_single = make_edge_latencies_region_fn(
            self.graph, region, n_regions, self_cost,
            SmoothConfig(alpha=self.cfg.alpha), nz_eps=self.cfg.nz_eps)
        region_ix = jnp.asarray(np.asarray(region, dtype=np.int64))

        def elat_b(x, inter, degrade):
            """x (B, n, V); inter (Sb, R, R), degrade (Sb, V), Sb ∈ {1, B}."""
            if not self.use_pallas:
                if inter.shape[0] == 1 and x.shape[0] != 1:
                    return jax.vmap(elat_single, in_axes=(0, None, None))(
                        x, inter[0], degrade[0])           # (B, E)
                return jax.vmap(elat_single)(x, inter, degrade)
            # Pallas route: precompute the region-space factors (XLA
            # gathers/scatters, all O(V·R) or smaller), fuse the rest;
            # the pricing rule itself lives in jaxmodel._region_factors,
            # shared with the vmap route's elat twin
            x_i = x[:, self._src] * self._sel[None, :, None]   # (B, E, V)
            x_j = x[:, self._dst]                              # (B, E, V)
            dj = degrade[:, None, :] * x_j                     # (B, E, V)
            B, E, V = x_i.shape
            mass = jnp.zeros((B, E, n_regions), x.dtype)
            mass = mass.at[:, :, region_ix].add(dj)            # (B, E, R)
            a, corr = jax.vmap(
                lambda i, d: _region_factors(i, d, region_ix, self_cost)
            )(inter, degrade)                        # (Sb, R, V), (Sb, V)
            from repro.kernels.ops import edge_latency_structured_max
            out = edge_latency_structured_max(
                x_i.astype(jnp.float32), x_j.astype(jnp.float32),
                mass.astype(jnp.float32), a.astype(jnp.float32),
                corr[:, None, :].astype(jnp.float32),
                interpret=self.interpret)
            return out + self._links_term(x, out.dtype)

        def lat_b(x, inter, degrade):
            return critical_path_dp(self.graph, elat_b(x, inter, degrade))

        def obj_b(x, inter, degrade, dq, beta):
            return lat_b(x, inter, degrade) / (1.0 + beta * dq)

        def grid(placements, inters, degrades, dq, beta):
            # same no-replication cross product as the dense path: scenarios
            # stream through lax.map carrying only (R, R) + (V,) state each
            lat = jax.lax.map(
                lambda sc: lat_b(placements, sc[0][None], sc[1][None]),
                (inters, degrades))
            return self._finish_grid(lat, inters.shape[0], dq, beta)

        return _StructuredFns(elat=jax.jit(elat_b), lat=jax.jit(lat_b),
                              obj=jax.jit(obj_b), grid=jax.jit(grid))

    @staticmethod
    def _family_args(fam: RegionFleetFamily) -> tuple[jnp.ndarray, jnp.ndarray]:
        return (jnp.asarray(fam.inter, jnp.float32),
                jnp.asarray(fam.degrade, jnp.float32))

    # -- public API ----------------------------------------------------------
    def edge_latencies(self, x, com) -> jnp.ndarray:
        """(B, E) edge latencies — batched edge_latencies()."""
        if isinstance(com, RegionFleetFamily):
            return self._structured(com).elat(jnp.asarray(x),
                                              *self._family_args(com))
        return self._jit_elat(jnp.asarray(x), jnp.asarray(com))

    def latency(self, x, com) -> jnp.ndarray:
        """(B,) critical-path latencies — batched latency()."""
        if isinstance(com, RegionFleetFamily):
            return self._structured(com).lat(jnp.asarray(x),
                                             *self._family_args(com))
        return self._jit_lat(jnp.asarray(x), jnp.asarray(com))

    def objective(self, x, com, dq=0.0, beta: float = 0.0) -> jnp.ndarray:
        """(B,) paper eq. (8) objectives — batched objective_F()."""
        if isinstance(com, RegionFleetFamily):
            return self._structured(com).obj(
                jnp.asarray(x), *self._family_args(com),
                jnp.asarray(dq, jnp.float32), float(beta))
        return self._jit_obj(jnp.asarray(x), jnp.asarray(com),
                             jnp.asarray(dq, jnp.float32), float(beta))

    def score_grid(self, placements, coms, dq=0.0,
                   beta: float = 0.0) -> jnp.ndarray:
        """(S, P) objective grid — every (scenario, placement) pair in one
        jitted dispatch.  ``coms`` is a dense (S, V, V) stack or a
        RegionFleetFamily; ``dq`` may be scalar or per-scenario (S,)."""
        if isinstance(coms, RegionFleetFamily):
            return self._structured(coms).grid(
                jnp.asarray(placements), *self._family_args(coms),
                jnp.asarray(dq, jnp.float32), float(beta))
        return self._jit_grid(jnp.asarray(placements), jnp.asarray(coms),
                              jnp.asarray(dq, jnp.float32), float(beta))
