"""Sharding plans: parameter FSDP transform + input specs per (arch × shape).

``fsdp_specs`` implements ZeRO-3-via-GSPMD: every large parameter gets its
largest still-replicated dimension sharded over the intra-pod ``data`` axis
on top of its tensor-parallel spec.  XLA then all-gathers weights on use and
reduce-scatters gradients — 16× less parameter/optimizer memory per chip,
which is what lets 33B-f32 and 480B-bf16 cells fit 16 GB v5e chips.
The `pod` axis is deliberately NOT used for FSDP: parameter all-gathers
would ride the slow DCI tier every step (the geo cost model prices exactly
this; see DESIGN.md §5).

``input_specs`` produces the ShapeDtypeStruct stand-ins for every model
input of a cell — weak-type-correct, shardable, no device allocation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.registry import Shape
from repro.models.api import ModelConfig

__all__ = ["fsdp_specs", "input_specs", "batch_specs", "cache_len"]

FSDP_MIN_SIZE = 1 << 20  # leaves smaller than 1M elements stay as-is


def fsdp_specs(spec_tree, shape_tree, mesh, axis: str = "data"):
    """Add `axis` to the largest divisible replicated dim of big leaves
    (shared leaf rule: repro.models.sharding.fsdp_leaf_spec — the in-body
    constraint must pin the SAME spec)."""
    from repro.models.sharding import fsdp_leaf_spec

    def leaf(spec, sds):
        if not isinstance(spec, P):
            spec = P()
        return fsdp_leaf_spec(spec, sds.shape, mesh, axis)

    return jax.tree.map(leaf, spec_tree, shape_tree,
                        is_leaf=lambda s: isinstance(s, P))


def choose_batch_axes(global_batch: int, mesh) -> tuple[str, ...]:
    """Largest ("pod","data") prefix whose product divides the batch —
    long_500k has batch 1, which simply can't data-shard (its parallelism
    is the model axis; noted as a seq-parallel hillclimb lever)."""
    sizes = dict(mesh.shape)
    for axes in (("pod", "data"), ("data",), ("pod",), ()):
        if all(a in mesh.axis_names for a in axes):
            ways = 1
            for a in axes:
                ways *= sizes[a]
            if ways and global_batch % ways == 0:
                return axes
    return ()


def batch_specs(mesh, global_batch: int | None = None) -> P:
    if global_batch is None:
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    else:
        axes = choose_batch_axes(global_batch, mesh)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def cache_len(shape: Shape) -> int:
    """KV/cache capacity for a cell: prefill writes seq_len; decode holds a
    cache of seq_len and appends one token (capacity +1, rounded to 128)."""
    if shape.kind == "decode":
        return shape.seq_len + 128
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: Shape, mesh):
    """dict of ShapeDtypeStruct for the cell's step function inputs
    (the batch part only — params/opt/cache SDS come from eval_shape)."""
    B = shape.global_batch
    bspec = batch_specs(mesh, B)

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=jax.sharding.NamedSharding(mesh, spec))

    out = {}
    if shape.kind == "train":
        out["tokens"] = sds((B, shape.seq_len), jnp.int32, bspec)
        out["labels"] = sds((B, shape.seq_len), jnp.int32, bspec)
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, shape.seq_len), jnp.int32, bspec)
    else:  # decode
        out["tokens"] = sds((B, 1), jnp.int32, bspec)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model),
                                  jnp.float32, P(*bspec, None, None))
    if cfg.family == "audio" and shape.kind != "decode":
        out["audio_frames"] = sds((B, cfg.n_audio_frames, cfg.d_model),
                                  jnp.float32, P(*bspec, None, None))
    return out
