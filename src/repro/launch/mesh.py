"""Production meshes.

Single pod: (data=16, model=16) — 256 v5e chips.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the `pod` axis is the
geo-distribution axis (DCI links), priced accordingly by the cost model
(repro.core.devices.fleet_from_tpu_mesh).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any device init).
"""

from __future__ import annotations

import jax

__all__ = ["make_mesh", "use_mesh", "named_shardings", "make_production_mesh",
           "mesh_chips", "data_axes", "MODEL_AXIS"]

MODEL_AXIS = "model"


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    jax supports them (``axis_types`` and ``AxisType`` only exist ≥ 0.5)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def use_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Tried in the order the API evolved so the installed mesh is always the
    one ``repro.models.sharding._active_mesh`` reads back: ``jax.set_mesh``
    (≥ 0.6), ``jax.sharding.use_mesh`` (0.5.x, feeds get_abstract_mesh),
    else the Mesh object itself (≤ 0.4, thread-resources env).
    """
    setter = getattr(jax, "set_mesh", None) \
        or getattr(jax.sharding, "use_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def named_shardings(mesh: jax.sharding.Mesh, spec_tree):
    """PartitionSpec tree → NamedSharding tree.

    jax < 0.5 rejects bare PartitionSpecs in jit in/out_shardings; wrapping
    in NamedSharding works on every version.
    """
    P = jax.sharding.PartitionSpec
    return jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                        spec_tree, is_leaf=lambda x: isinstance(x, P))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_chips(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for _, s in mesh.shape.items():
        n *= s
    return n


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
