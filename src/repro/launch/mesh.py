"""Production meshes.

Single pod: (data=16, model=16) — 256 v5e chips.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the `pod` axis is the
geo-distribution axis (DCI links), priced accordingly by the cost model
(repro.core.devices.fleet_from_tpu_mesh).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any device init).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_chips", "data_axes", "MODEL_AXIS"]

MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_chips(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for _, s in mesh.shape.items():
        n *= s
    return n


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
