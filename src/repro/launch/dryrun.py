import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with ZERO device allocation (ShapeDtypeStruct
inputs only):

  * ``compiled.memory_analysis()``  — proves the cell fits 16 GB v5e chips,
  * ``compiled.cost_analysis()``    — per-device HLO FLOPs / bytes,
  * collective wire bytes parsed from the post-SPMD HLO text,
  * the three roofline terms (repro.perf.roofline),

written as JSON to ``experiments/dryrun/<arch>__<shape>__<mesh>[__variant].json``.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --sweep --mesh both          # all cells
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k \
      --variant remat=dots,microbatches=4                     # perf iteration

Variants (the §Perf hillclimb levers): remat=full|dots|none,
microbatches=N, no_vocab_dp (embed/head FSDP off), attn_chunk=N,
moe_group=N, seq_shard (sequence-parallel activations), param_dtype=...
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path


def _build_cell(arch: str, shape_name: str, multi_pod: bool, variant: str):
    """Lower+compile one cell; returns the record dict."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import SHAPES, get_config, shape_skip_reason
    from repro.launch.mesh import (make_production_mesh, mesh_chips,
                                  named_shardings, use_mesh)
    from repro.launch.shardings import (batch_specs, cache_len, fsdp_specs,
                                        input_specs)
    from repro.models.api import analytic_flops, build_model, count_params
    from repro.perf.hlo import analyze_module
    from repro.perf.roofline import compute_terms
    from repro.train.optim import AdamWConfig, adamw_init
    from repro.train.steps import (make_decode_step, make_prefill_step,
                                   make_train_step)

    from repro.models import sharding as _shmod
    _shmod.set_axis_rules(_shmod.DEFAULT_RULES)  # fresh rules per cell
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = shape_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "skipped": skip}

    # ---- defaults that make the baseline FIT (recorded in the JSON), then
    # ---- variant overrides (the hillclimb levers) ----
    from repro.models.api import count_params
    total_params, _ = count_params(cfg)
    if shape.kind == "train":
        # Megatron-style sequence parallelism + size-scaled microbatching —
        # without these, >8B f32 cells exceed 16 GB v5e (see EXPERIMENTS.md)
        seq_shard = True
        microbatches = 2 if total_params < 10e9 else (
            4 if total_params < 100e9 else 8)
    else:
        seq_shard = False
        microbatches = 1
    fsdp_embed = True
    overrides = {}
    for item in filter(None, variant.split(",")):
        if "=" in item:
            k, v = item.split("=", 1)
        else:
            k, v = item, "1"
        if k == "microbatches":
            microbatches = int(v)
        elif k == "remat":
            overrides["remat"] = v
        elif k == "attn_chunk":
            overrides["attn_chunk"] = int(v)
        elif k == "moe_group":
            overrides["moe_group_size"] = int(v)
        elif k == "param_dtype":
            overrides["param_dtype"] = v
        elif k == "no_vocab_dp":
            fsdp_embed = False
        elif k == "no_fsdp":
            fsdp_embed = "none"  # serve: TP-only weights (no ZeRO gather)
        elif k == "seq_shard":
            seq_shard = True
        elif k == "unroll":
            overrides["scan_layers"] = False
        elif k == "moe_ep":
            from repro.models import sharding as shmod2
            r2 = dict(shmod2.axis_rules().rules)
            r2["experts"] = v  # e.g. "data": expert-parallel over data axis
            shmod2.set_axis_rules(shmod2.AxisRules(r2))
        elif k == "scan":
            overrides["scan_layers"] = True
        elif k == "no_seq_shard":
            seq_shard = False
        else:
            raise ValueError(f"unknown variant item {item!r}")
    if shape.kind != "train":
        overrides.setdefault("remat", "none")
    if overrides:
        cfg = cfg.replace(**overrides)
    if seq_shard:
        from repro.models import sharding as shmod
        rules = dict(shmod.axis_rules().rules)  # keep variant rule edits
        rules["seq"] = "model"
        shmod.set_axis_rules(shmod.AxisRules(rules))

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    # batch axes must divide the global batch (long_500k: batch 1 → the
    # "batch" logical axis replicates; model axis is the parallelism)
    from repro.launch.shardings import choose_batch_axes
    from repro.models import sharding as shmod
    baxes = choose_batch_axes(shape.global_batch, mesh)
    rules = dict(shmod.axis_rules().rules)
    rules["batch"] = baxes if baxes else None
    shmod.set_axis_rules(shmod.AxisRules(rules))
    model = build_model(cfg)
    t0 = time.time()
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": variant or "baseline",
        "chips": chips, "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "effective": {"seq_shard": seq_shard, "microbatches": microbatches,
                      "remat": cfg.remat, "param_dtype": cfg.param_dtype},
    }

    with use_mesh(mesh):
        params_sds = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        pspecs = model.param_specs()
        if fsdp_embed != "none":
            pspecs = fsdp_specs(pspecs, params_sds, mesh)
        if fsdp_embed is False:
            pspecs["embed"] = model.param_specs()["embed"]
            pspecs["head"] = model.param_specs()["head"]
        batch_sds = input_specs(cfg, shape, mesh)

        def with_spec(sds_tree, spec_tree):
            return jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(
                    s.shape, s.dtype,
                    sharding=jax.sharding.NamedSharding(mesh, sp)),
                sds_tree, spec_tree,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

        if shape.kind == "train":
            opt_cfg = AdamWConfig(bits8=(cfg.param_dtype == "bfloat16"))
            from repro.train.optim import opt_state_specs
            opt_sds = jax.eval_shape(
                lambda p: adamw_init(p, opt_cfg), params_sds)
            ospecs = opt_state_specs(pspecs, opt_cfg)
            if opt_cfg.bits8:
                # shard the big int8 moment blocks over data as well
                ospecs = fsdp_specs(ospecs, opt_sds, mesh)
            step = make_train_step(model, cfg, opt_cfg,
                                   microbatches=microbatches)
            jitted = jax.jit(
                step,
                in_shardings=named_shardings(mesh, (pspecs, ospecs,
                    jax.tree.map(lambda s: s.sharding.spec, batch_sds))),
                out_shardings=named_shardings(mesh, (pspecs, ospecs, None)),
                donate_argnums=(0, 1))
            lowered = jitted.lower(with_spec(params_sds, pspecs),
                                   with_spec(opt_sds, ospecs), batch_sds)
        else:
            cl = cache_len(shape)
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, cl))
            cspecs = model.cache_specs()
            cache_sds = with_spec(cache_sds, cspecs)
            if shape.kind == "prefill":
                step = make_prefill_step(model, cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=named_shardings(mesh, (pspecs,
                        jax.tree.map(lambda s: s.sharding.spec, batch_sds),
                        cspecs)),
                    donate_argnums=(2,))
                lowered = jitted.lower(with_spec(params_sds, pspecs),
                                       batch_sds, cache_sds)
            else:
                step = make_decode_step(model, cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=named_shardings(mesh, (pspecs, cspecs, P(),
                        batch_specs(mesh, shape.global_batch))),
                    donate_argnums=(1,))
                pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = jitted.lower(with_spec(params_sds, pspecs),
                                       cache_sds, pos_sds,
                                       batch_sds["tokens"])
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_bytes": int(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes
                              + mem.output_size_in_bytes
                              - mem.alias_size_in_bytes),
            "fits_16GB": bool(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes
                              + mem.output_size_in_bytes
                              - mem.alias_size_in_bytes < 16 * 2**30),
        }
        ca = compiled.cost_analysis() or {}
        rec["xla_cost_analysis"] = {  # raw (known to count loop bodies once)
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        # trip-count-aware module analysis (repro.perf.hlo); buffers whose
        # trailing dim == kv length are attention score/probability rows
        kv_len = cache_len(shape) if shape.kind == "decode" else shape.seq_len
        stats = analyze_module(compiled.as_text(), flag_trailing_dim=kv_len)
        coll = stats.collectives
        rec["collectives"] = coll.summary()
        mflops = analytic_flops(cfg, shape.seq_len, shape.global_batch,
                                shape.kind)
        terms = compute_terms(stats.flops, stats.hbm_bytes,
                              coll.total_wire_bytes, chips, mflops,
                              per_device=True)
        rec["hlo_flops_per_device"] = stats.flops
        rec["hlo_bytes_per_device"] = stats.hbm_bytes
        # Pallas-kernel-adjusted memory term: on TPU the flash kernel keeps
        # score rows in VMEM.  adjusted = measured - score-row traffic +
        # analytic kernel q/k/v/o HBM traffic (conservative: projections'
        # own writes are still counted in `measured`).
        from repro.models.api import _n_attn_applications
        from repro.perf.roofline import HBM_BW
        model_ways = dict(mesh.shape).get("model", 1)
        h_loc = max(cfg.n_heads / model_ways, 1.0)
        k_loc = max(cfg.n_kv_heads / model_ways, 1.0)
        data_ways = max(chips / model_ways, 1)
        if shape.kind == "decode":
            q_tokens = shape.global_batch / data_ways
            kv_tokens = q_tokens * kv_len
            passes = 1.0
        else:
            q_tokens = shape.global_batch * shape.seq_len / data_ways
            kv_tokens = q_tokens
            passes = 3.0 if (shape.kind == "train" and cfg.remat != "none") \
                else (2.0 if shape.kind == "train" else 1.0)
        act = 2.0
        flash_ideal = passes * _n_attn_applications(cfg) * (
            2.0 * q_tokens * h_loc * cfg.hd * act
            + 2.0 * kv_tokens * k_loc * cfg.hd * act)
        adj_bytes = max(stats.hbm_bytes - stats.flagged_bytes, 0.0) \
            + flash_ideal
        from repro.perf.roofline import ICI_BW
        rec["kernel_adjusted"] = {
            "score_row_bytes": stats.flagged_bytes,
            "flash_ideal_bytes": flash_ideal,
            "memory_s": adj_bytes / HBM_BW,
            "collective_s": coll.tpu_wire_bytes / ICI_BW,
            "step_time_s": max(terms.compute_s, adj_bytes / HBM_BW,
                               coll.tpu_wire_bytes / ICI_BW),
            "note": "TPU adjustments: flash kernel keeps score rows in "
                    "VMEM (kernel validated in tests/test_kernels.py); "
                    "partial-sum collectives ride at bf16 (CPU XLA upcasts "
                    "bf16 dots to f32)",
        }
        ka = rec["kernel_adjusted"]
        rec["mfu_bound_tpu_adjusted"] = (
            mflops / (chips * 197e12 * ka["step_time_s"])
            if ka["step_time_s"] > 0 else 0.0)
        rec["roofline"] = terms.row()
        total, active = count_params(cfg)
        rec["params_total"] = total
        rec["params_active"] = active
    return rec


def run_cell(arch, shape, mesh_name, variant, out_dir: Path):
    rec = _build_cell(arch, shape, mesh_name == "multi", variant)
    tag = f"{arch}__{shape}__{mesh_name}"
    if variant:
        tag += "__" + variant.replace(",", "+").replace("=", "-")
    out = out_dir / f"{tag}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    if "skipped" in rec:
        print(f"SKIP {tag}: {rec['skipped']}")
    else:
        r = rec["roofline"]
        print(f"OK   {tag}: compile={rec['compile_s']}s "
              f"peak={rec['memory']['peak_bytes']/1e9:.2f}GB "
              f"fits={rec['memory']['fits_16GB']} "
              f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s dom={r['dominant']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--sweep", action="store_true",
                    help="subprocess-per-cell sweep (robust to OOM/crash)")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, SHAPES
    from repro.configs.registry import canonical_arch
    archs = ARCH_IDS if args.arch == "all" else [canonical_arch(args.arch)]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out_dir = Path(args.out)

    if args.sweep:
        failures = []
        for arch in archs:
            for shape in shapes:
                for mesh_name in meshes:
                    tag = f"{arch}__{shape}__{mesh_name}"
                    if args.variant:
                        tag += "__" + args.variant.replace(",", "+").replace("=", "-")
                    if (out_dir / f"{tag}.json").exists():
                        print(f"HAVE {tag}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", mesh_name, "--out", str(out_dir)]
                    if args.variant:
                        cmd += ["--variant", args.variant]
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    sys.stdout.write(r.stdout)
                    if r.returncode != 0:
                        failures.append(tag)
                        (out_dir / f"{tag}.FAILED.log").write_text(
                            r.stdout + "\n" + r.stderr)
                        print(f"FAIL {tag} (log written)")
        print(f"sweep done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                try:
                    run_cell(arch, shape, mesh_name, args.variant, out_dir)
                except Exception:
                    traceback.print_exc()
                    sys.exit(1)


if __name__ == "__main__":
    main()
