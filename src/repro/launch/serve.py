"""Batched serving loop: continuous-batching-lite request server.

Requests (token prompts) arrive in waves; the server packs a wave into a
fixed-shape batch, runs prefill once, then decode steps with a donated KV
cache until every request hits its token budget or EOS.  Per-request
latency, the paper's DQ-aware objective (eq. 8 — quality scoring of the
generated stream costs latency, β prices it), and throughput are reported.

Example (CPU, reduced olmo):
  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
      --requests 16 --batch 8 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.api import build_model
from repro.train.steps import make_decode_step, make_prefill_step

__all__ = ["ServeStats", "serve_wave", "main"]


class ServeStats:
    def __init__(self):
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.tokens_out = 0
        self.requests = 0

    def summary(self) -> dict:
        dec_tok_s = self.tokens_out / self.decode_s if self.decode_s else 0.0
        return {
            "requests": self.requests,
            "tokens_out": self.tokens_out,
            "prefill_s": round(self.prefill_s, 4),
            "decode_s": round(self.decode_s, 4),
            "decode_tok_per_s": round(dec_tok_s, 1),
        }


def serve_wave(model, cfg, params, prompts: np.ndarray, gen_tokens: int,
               extras: dict | None = None, stats: ServeStats | None = None):
    """prompts: (B, S) int32 → generated (B, gen_tokens) int32."""
    stats = stats or ServeStats()
    B, S = prompts.shape
    prefill = jax.jit(make_prefill_step(model, cfg))
    decode = jax.jit(make_decode_step(model, cfg), donate_argnums=(1,))
    cache = model.init_cache(B, S + gen_tokens)
    batch = {"tokens": jnp.asarray(prompts)}
    if extras:
        batch.update(extras)
    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(prefill(params, batch, cache))
    stats.prefill_s += time.perf_counter() - t0
    tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1).astype(jnp.int32)[:, None]
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(gen_tokens - 1):
        tok, _, cache = decode(params, cache, jnp.int32(S + i), tok)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    stats.decode_s += time.perf_counter() - t0
    stats.tokens_out += B * gen_tokens
    stats.requests += B
    return np.concatenate(out, axis=1), stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--beta", type=float, default=1.0)
    ap.add_argument("--dq-fraction", type=float, default=0.5)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.n_image_tokens,
                                    cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        extras["audio_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_audio_frames,
                                    cfg.d_model), jnp.float32)
    stats = ServeStats()
    done = 0
    while done < args.requests:
        b = min(args.batch, args.requests - done)
        prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                               dtype=np.int32)  # fixed shape; pad last wave
        out, stats = serve_wave(model, cfg, params, prompts, args.gen,
                                extras, stats)
        done += b
    s = stats.summary()
    # paper eq. (8): quality-adjusted objective for the serving deployment
    from repro.streaming.quality import dq_latency_model
    lat = s["decode_s"] / max(s["tokens_out"], 1)
    s["latency_per_token_s"] = round(lat, 6)
    s["F_quality_adjusted"] = round(
        dq_latency_model(lat, args.dq_fraction, args.beta), 6)
    print(s)


if __name__ == "__main__":
    main()
