"""End-to-end trainer driver: data pipeline → jit'd train step →
checkpoints → fault tolerance.

Runs on whatever devices exist (1 CPU here, a pod in production): the same
code path the dry-run lowers.  Supports --resume (picks up the latest
checkpoint + pipeline cursor) and --die-at-step (fault injection for the
kill/restart test).

Example (CPU, ~20M params):
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 20
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import PipelineConfig, Prefetcher, TokenStream
from repro.runtime.checkpoint import (latest_step, restore_checkpoint,
                                      save_checkpoint)
from repro.models.api import build_model
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.steps import make_train_step

__all__ = ["run_training", "main"]


def run_training(cfg, *, steps: int, global_batch: int, seq_len: int,
                 ckpt_dir=None, ckpt_every: int = 50, resume: bool = False,
                 die_at_step: int | None = None, lr: float = 3e-4,
                 dq_fraction: float = 0.0, log_every: int = 10,
                 seed: int = 0, keep: int = 3) -> dict:
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=lr, bits8=(cfg.param_dtype == "bfloat16"))
    params = model.init_params(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params, opt_cfg)
    pipe_cfg = PipelineConfig(vocab=cfg.vocab, seq_len=seq_len,
                              global_batch=global_batch, seed=seed,
                              dq_fraction=dq_fraction)
    stream = TokenStream(pipe_cfg)
    start_step = 0

    if resume and ckpt_dir is not None:
        last = latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), extra = restore_checkpoint(
                ckpt_dir, last, (params, opt_state))
            stream = TokenStream.from_state(pipe_cfg, extra["pipeline"])
            start_step = extra["step"]
            print(f"[train] resumed from step {start_step} "
                  f"(cursor={stream.cursor})")

    # modality-frontend stubs (per assignment): fixed synthetic embeddings
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (global_batch, cfg.n_image_tokens,
                                    cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        extras["audio_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (global_batch, cfg.n_audio_frames,
                                    cfg.d_model), jnp.float32)

    step_fn = jax.jit(make_train_step(model, cfg, opt_cfg), donate_argnums=(0, 1))
    prefetch = Prefetcher(stream)
    losses = []
    t0 = time.time()
    try:
        consumed_cursor = stream.cursor
        for step in range(start_step, steps):
            batch_np = prefetch.next()
            consumed_cursor = int(batch_np.pop("_cursor"))
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            batch.update(extras)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (step + 1) % log_every == 0 or step + 1 == steps:
                loss = float(metrics["loss"])
                losses.append((step + 1, loss))
                dt = time.time() - t0
                tok_s = (step + 1 - start_step) * global_batch * seq_len / dt
                print(f"[train] step {step+1}/{steps} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"tok/s={tok_s:,.0f}")
            if ckpt_dir is not None and (step + 1) % ckpt_every == 0:
                save_checkpoint(ckpt_dir, step + 1, (params, opt_state),
                                extra={"step": step + 1,
                                       "pipeline": {"cursor": consumed_cursor,
                                                    "seed": stream.cfg.seed}},
                                keep=keep)
            if die_at_step is not None and step + 1 == die_at_step:
                raise SystemExit(13)  # simulated node failure
    finally:
        prefetch.close()
    return {"losses": losses, "params": params, "final_step": steps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--die-at-step", type=int, default=None)
    ap.add_argument("--dq-fraction", type=float, default=0.0)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run_training(cfg, steps=args.steps, global_batch=args.batch,
                 seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                 ckpt_every=args.ckpt_every, resume=args.resume,
                 die_at_step=args.die_at_step, lr=args.lr,
                 dq_fraction=args.dq_fraction)


if __name__ == "__main__":
    main()
