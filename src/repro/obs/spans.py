"""Structured spans with wall/compile/execute split and Chrome-trace export.

``span("score_grid", S=4, P=1024)`` is a context manager recording one
timed region into the process-local trace buffer.  Each span carries:

  * ``wall_s``    — perf_counter wall time of the region;
  * ``compile_s`` — jax compile time attributed by the
    :mod:`repro.obs.jaxhooks` listener to the innermost active span (a
    cache hit attributes nothing, so steady-state spans read compile 0);
  * ``n_compiles`` — backend compilations inside the span (recompiles,
    once past warmup);
  * ``execute_s`` — ``wall_s − compile_s``: everything that is not
    compilation.  Call ``sp.sync(value)`` (``jax.block_until_ready``)
    before leaving the span so asynchronously dispatched device work is
    *inside* the wall measurement — otherwise a dispatch-and-return would
    read as ~0 execute.

Spans nest (``parent`` links reconstruct the tree) and export as
Chrome-trace events — one JSON object per line (JSONL), each a complete
``"ph": "X"`` duration event, plus ``"ph": "C"`` counter samples for the
timelines (:func:`counter_sample`) — so a whole adaptive run opens in
``ui.perfetto.dev`` or ``chrome://tracing``.  :func:`load_trace` /
:func:`validate_events` are the schema the export is tested against.

Everything here is registry-gated: with the default registry disabled,
``span(...)`` returns a shared no-op span and the buffer never grows.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time

from repro.obs.registry import registry

__all__ = ["Span", "span", "current_span", "counter_sample", "trace_events",
           "clear_trace", "export_trace", "load_trace", "validate_events",
           "TRACE_EVENT_KEYS"]

# required keys of one exported Chrome-trace event line
TRACE_EVENT_KEYS = {"name", "ph", "ts", "pid", "tid"}

_local = threading.local()
_buffer_lock = threading.Lock()
_events: list[dict] = []
# one perf_counter origin per process so ts is comparable across threads
_T0 = time.perf_counter()


def _now_us() -> float:
    return (time.perf_counter() - _T0) * 1e6


def _stack() -> list:
    st = getattr(_local, "stack", None)
    if st is None:
        st = _local.stack = []
    return st


class Span:
    """One live timed region; becomes a ``"ph": "X"`` trace event on exit."""

    __slots__ = ("name", "args", "t0_us", "wall_s", "compile_s",
                 "n_compiles", "_synced")

    def __init__(self, name: str, args: dict):
        self.name = name
        self.args = args
        self.t0_us = 0.0
        self.wall_s = 0.0
        self.compile_s = 0.0
        self.n_compiles = 0
        self._synced = False

    @property
    def execute_s(self) -> float:
        return max(self.wall_s - self.compile_s, 0.0)

    def sync(self, value):
        """``jax.block_until_ready`` on ``value`` (any pytree) so device
        work lands inside this span's wall time; returns ``value``."""
        import jax

        jax.block_until_ready(value)
        self._synced = True
        return value

    def __enter__(self):
        self.t0_us = _now_us()
        _stack().append(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = _now_us()
        self.wall_s = (t1 - self.t0_us) / 1e6
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        args = dict(self.args)
        args["compile_s"] = self.compile_s
        args["execute_s"] = self.execute_s
        args["n_compiles"] = self.n_compiles
        args["synced"] = self._synced
        ev = {"name": self.name, "ph": "X", "ts": self.t0_us,
              "dur": t1 - self.t0_us, "pid": os.getpid(),
              "tid": threading.get_ident(), "args": args}
        with _buffer_lock:
            _events.append(ev)
        return False


class _NullSpan:
    """Shared disabled-path span: every operation is a no-op."""

    __slots__ = ()
    name = ""
    args: dict = {}
    wall_s = compile_s = execute_s = 0.0
    n_compiles = 0

    def sync(self, value):
        return value

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL = _NullSpan()


def span(name: str, **args):
    """Open a span when telemetry is enabled; a shared no-op otherwise.
    ``args`` must be JSON-able (they land in the trace event's ``args``)."""
    if not registry().enabled:
        return _NULL
    return Span(name, args)


def current_span():
    """The innermost active span of this thread (None outside any span, or
    when telemetry is disabled)."""
    st = getattr(_local, "stack", None)
    return st[-1] if st else None


def _attribute_compile(duration: float, is_backend: bool) -> None:
    """jaxhooks → innermost active span (no-op outside spans)."""
    st = getattr(_local, "stack", None)
    if st:
        sp = st[-1]
        sp.compile_s += duration
        if is_backend:
            sp.n_compiles += 1


def counter_sample(name: str, value: float, **more) -> None:
    """Append one counter sample (Perfetto renders a counter track per
    name) — the drift/regret timelines of the adaptive loop.  No-op when
    telemetry is disabled."""
    if not registry().enabled:
        return
    series = {name: float(value)}
    for k, v in more.items():
        series[k] = float(v)
    ev = {"name": name, "ph": "C", "ts": _now_us(), "pid": os.getpid(),
          "tid": threading.get_ident(), "args": series}
    with _buffer_lock:
        _events.append(ev)


def trace_events() -> list[dict]:
    """Snapshot of the buffered trace events (copies the list, not the
    events)."""
    with _buffer_lock:
        return list(_events)


def clear_trace() -> None:
    with _buffer_lock:
        _events.clear()


def export_trace(path) -> int:
    """Write the buffer as Chrome-trace JSONL: one complete event object
    per line.  Perfetto and ``chrome://tracing`` both ingest the JSON
    array form; :func:`load_trace` turns the JSONL back into that form.
    Returns the number of events written."""
    events = trace_events()
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev, sort_keys=True) + "\n")
    return len(events)


def load_trace(path) -> list[dict]:
    """Load + schema-validate an exported JSONL trace (the bench_obs /
    tier-1 gate that the export stays viewer-loadable)."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    validate_events(events)
    return events


def validate_events(events: list[dict]) -> None:
    """Raise ValueError unless every event is a well-formed Chrome-trace
    event: required keys, numeric ts (µs), ``X`` events carry a numeric
    ``dur`` and a dict ``args``, ``C`` events a numeric series dict."""
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object: {ev!r}")
        missing = TRACE_EVENT_KEYS - ev.keys()
        if missing:
            raise ValueError(f"event {i} missing keys {sorted(missing)}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {i} ts is not numeric")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i} ('X') needs numeric dur >= 0")
            if not isinstance(ev.get("args", {}), dict):
                raise ValueError(f"event {i} args is not an object")
        elif ev["ph"] == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                raise ValueError(f"event {i} ('C') needs a numeric series")
        else:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")


@contextlib.contextmanager
def _fresh_trace():
    """Test helper: run with an empty buffer, restore afterwards."""
    global _events
    with _buffer_lock:
        saved, _events = _events, []
    try:
        yield
    finally:
        with _buffer_lock:
            _events = saved
