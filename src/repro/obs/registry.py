"""Process-local metrics registry: counters, gauges, histograms.

Zero-dependency, opt-in-cheap telemetry.  The registry the instrumented
subsystems publish into (``BatchedEvaluator``, ``BatchedProblem``,
``AdaptiveController``, ``StreamingEngine``) is DISABLED by default: every
instrumentation site guards on ``registry().enabled`` (one attribute read),
so an un-enabled process pays nothing measurable on the hot loops —
``benchmarks/bench_obs.py`` gates the disabled overhead at <5% of the
bench_search hot loop.  Enabling never changes numerics: instrumentation
only *reads* values the computation already produced (no rng draws, no
extra dispatches) — also gated in bench_obs.

Metric identity is ``(name, sorted labels)``; metrics are created lazily on
first use and cached, so call sites just say
``reg.counter("search.dispatches").add(1)``.

Histograms use exponential buckets (``lo · growth^i``): the observed
quantities span decades (µs dispatches to multi-second refits, 1-candidate
neighborhoods to 4096-candidate chunks), where linear buckets would waste
resolution.
"""

from __future__ import annotations

import dataclasses
import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "registry", "set_registry", "enable", "disable", "enabled"]


def _key(name: str, labels: dict) -> tuple:
    return (name,) + tuple(sorted(labels.items()))


@dataclasses.dataclass
class Counter:
    """Monotone float accumulator (counts AND seconds-style totals)."""

    name: str
    labels: dict
    value: float = 0.0

    def add(self, n: float = 1.0) -> None:
        self.value += n

    def row(self) -> dict:
        return {"type": "counter", "name": self.name, "labels": self.labels,
                "value": float(self.value)}


@dataclasses.dataclass
class Gauge:
    """Last-write-wins sample (drift level, belief com scale, ...)."""

    name: str
    labels: dict
    value: float = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def row(self) -> dict:
        return {"type": "gauge", "name": self.name, "labels": self.labels,
                "value": float(self.value)}


class Histogram:
    """Exponential-bucket histogram: bucket i holds observations in
    ``(lo·growth^(i-1), lo·growth^i]``; underflows land in bucket 0,
    overflows in the last bucket.  Tracks sum/count/min/max exactly."""

    def __init__(self, name: str, labels: dict, lo: float = 1e-6,
                 growth: float = 2.0, n_buckets: int = 48):
        if lo <= 0 or growth <= 1 or n_buckets < 2:
            raise ValueError("need lo > 0, growth > 1, n_buckets >= 2")
        self.name = name
        self.labels = labels
        self.lo = float(lo)
        self.growth = float(growth)
        self.buckets = [0] * n_buckets
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._log_g = math.log(growth)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= self.lo:
            i = 0
        else:
            i = min(int(math.log(v / self.lo) / self._log_g) + 1,
                    len(self.buckets) - 1)
        self.buckets[i] += 1

    def bucket_upper_bounds(self) -> list[float]:
        return [self.lo * self.growth ** i for i in range(len(self.buckets))]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def quantile(self, q: float) -> float:
        """Estimated q-quantile from the exponential buckets.

        The crossing bucket is interpolated *geometrically* (the natural
        interpolation on a log-spaced grid: linear interpolation there
        over-weights the bucket's top end by up to the growth factor), and
        the estimate is clamped to the exactly-tracked [min, max] — so
        small-count histograms degrade to honest answers instead of
        bucket-edge artifacts, and q=0 / q=1 return min / max exactly.
        The worst-case estimation error within a bucket is a factor of
        ``growth`` (2× at the default), which is the resolution admission
        control needs: budgets are set in decades, not percent."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"need 0 <= q <= 1, got {q}")
        if self.count == 0:
            return float("nan")
        target = q * self.count
        cum = 0.0
        for i, c in enumerate(self.buckets):
            cum += c
            if cum >= target and c > 0:
                frac = (target - (cum - c)) / c          # position in bucket
                if i == 0:
                    est = self.lo * frac                  # (0, lo] linearly
                else:
                    # (lo·g^(i-1), lo·g^i] — geometric interpolation
                    est = self.lo * self.growth ** (i - 1 + frac)
                return float(min(max(est, self.min), self.max))
        return float(self.max)

    def quantiles(self, qs=(0.5, 0.95, 0.99)) -> dict[str, float]:
        """{"p50": ..., "p95": ..., "p99": ...} — the export admission
        control and the benchmark suites consume."""
        return {f"p{round(q * 100)}": self.quantile(q) for q in qs}

    def row(self) -> dict:
        return {"type": "histogram", "name": self.name, "labels": self.labels,
                "count": self.count, "sum": self.sum,
                "min": None if self.count == 0 else self.min,
                "max": None if self.count == 0 else self.max,
                "lo": self.lo, "growth": self.growth,
                "buckets": list(self.buckets),
                **({k: v for k, v in self.quantiles().items()}
                   if self.count else {"p50": None, "p95": None,
                                       "p99": None})}


class MetricsRegistry:
    """One process-local bag of metrics.  ``enabled`` is the single opt-in
    switch every instrumentation site checks before touching a metric."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._metrics: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: dict, **kwargs):
        k = _key(name, labels)
        m = self._metrics.get(k)
        if m is None:
            with self._lock:
                m = self._metrics.get(k)
                if m is None:
                    m = cls(name, labels, **kwargs)
                    self._metrics[k] = m
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r}{labels} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, lo: float = 1e-6, growth: float = 2.0,
                  n_buckets: int = 48, **labels) -> Histogram:
        return self._get(Histogram, name, labels, lo=lo, growth=growth,
                         n_buckets=n_buckets)

    def get(self, name: str, **labels):
        """Metric lookup without creation (None when absent)."""
        return self._metrics.get(_key(name, labels))

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        m = self._metrics.get(_key(name, labels))
        return default if m is None else float(m.value)

    def snapshot(self) -> list[dict]:
        """JSON-able rows of every metric, sorted by (name, labels)."""
        return [self._metrics[k].row() for k in sorted(self._metrics)]

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


_registry = MetricsRegistry(enabled=False)


def registry() -> MetricsRegistry:
    """The process-local default registry (disabled until ``enable()``)."""
    return _registry


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the default registry (tests / multi-tenant isolation); returns
    the previous one."""
    global _registry
    prev, _registry = _registry, reg
    return prev


def enable() -> None:
    """Turn telemetry on: metrics record, spans buffer, and the jax
    compile hooks install (recompile accounting needs the listener)."""
    from repro.obs import jaxhooks

    _registry.enabled = True
    jaxhooks.install()


def disable() -> None:
    _registry.enabled = False


def enabled() -> bool:
    return _registry.enabled
