"""repro.obs — unified telemetry: spans, metrics, dispatch/recompile
accounting across sim → search → adapt.

Zero-dependency and opt-in-cheap: the default registry is DISABLED until
:func:`enable` — every instrumentation site guards on one attribute read,
and enabling never changes numerics (gated in ``benchmarks/bench_obs.py``).

    from repro import obs

    obs.enable()
    with obs.span("score_grid", S=4, P=1024) as sp:
        sp.sync(ev.score_grid(placements, coms))
    obs.export_trace("run.trace.jsonl")      # open in ui.perfetto.dev
    obs.registry().snapshot()                # metrics rows

See ``src/repro/obs/README.md`` for the telemetry flow diagram.
"""

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                disable, enable, enabled, registry,
                                set_registry)
from repro.obs.spans import (Span, clear_trace, counter_sample, current_span,
                             export_trace, load_trace, span, trace_events,
                             validate_events)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "registry", "set_registry", "enable", "disable", "enabled",
    "Span", "span", "current_span", "counter_sample",
    "trace_events", "clear_trace", "export_trace", "load_trace",
    "validate_events",
]
