"""Shared benchmark timing harness (the eight hand-rolled
``time.perf_counter`` helpers that used to live in ``benchmarks/bench_*.py``
— warmup conventions, ``block_until_ready`` and median-of-n now happen in
ONE place, consistently).

  * :func:`measure`   — warmup calls, then n timed calls; every call is
    flushed with ``jax.block_until_ready`` so async dispatch can't leak
    device work past the clock.  Returns a :class:`Timing` with
    median/mean/min/max seconds plus the compile accounting of the TIMED
    region (``n_recompiles`` > 0 after warmup = a shape bucket missed).
  * :func:`time_once` — one timed call returning ``(seconds, result)`` —
    the one-shot form the searcher races use (warm the callable first
    when steady-state cost is the claim under test).

Benchmark rows embed ``Timing.row()`` (seconds = median) so every
``BENCH_*.json`` reports recompile counts for free.
"""

from __future__ import annotations

import dataclasses
import statistics
import time

from repro.obs import jaxhooks

__all__ = ["Timing", "measure", "time_once"]


@dataclasses.dataclass
class Timing:
    """Timed-region summary; ``seconds`` (the headline number) is the
    median — robust to one-off scheduler noise, unlike mean or min."""

    times: list[float]
    n_recompiles: int
    compile_s: float
    # the LAST timed call's return value — benchmarks feed it to oracle
    # spot-checks without paying an extra dispatch
    result: object = None

    @property
    def seconds(self) -> float:
        return statistics.median(self.times)

    @property
    def mean_s(self) -> float:
        return statistics.fmean(self.times)

    @property
    def min_s(self) -> float:
        return min(self.times)

    @property
    def max_s(self) -> float:
        return max(self.times)

    def row(self) -> dict:
        return {"seconds": self.seconds, "mean_s": self.mean_s,
                "min_s": self.min_s, "max_s": self.max_s,
                "n_timed": len(self.times),
                "n_recompiles": self.n_recompiles,
                "compile_s": self.compile_s}


def _call_blocked(f, block: bool):
    out = f()
    if block:
        import jax

        jax.block_until_ready(out)
    return out


def measure(f, n: int = 5, warmup: int = 1, block: bool = True) -> Timing:
    """``warmup`` un-timed calls (jit compiles land here), then ``n`` timed
    calls flushed via ``block_until_ready`` (``block=False`` for pure-host
    callables whose results aren't jax arrays).

    Compile accounting covers the TIMED region only: ``n_recompiles`` > 0
    means the supposedly-warm loop still compiled — e.g. a chunked
    ``score_batch`` crossing into an unseen shape bucket."""
    if n < 1:
        raise ValueError(f"need n >= 1 timed calls, got {n}")
    for _ in range(warmup):
        _call_blocked(f, block)
    snap = jaxhooks.snapshot()
    times = []
    out = None
    for _ in range(n):
        t0 = time.perf_counter()
        out = _call_blocked(f, block)
        times.append(time.perf_counter() - t0)
    n_rec, comp_s = snap.delta()
    return Timing(times=times, n_recompiles=n_rec, compile_s=comp_s,
                  result=out)


def time_once(f, block: bool = True):
    """One timed call → ``(seconds, result)``, flushed like
    :func:`measure`.  No warmup: callers racing cold-vs-warm decide
    themselves what to warm."""
    t0 = time.perf_counter()
    out = _call_blocked(f, block)
    return time.perf_counter() - t0, out
