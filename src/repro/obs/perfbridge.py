"""The ``repro.perf`` bridge: attach HLO FLOPs/bytes/collective stats and
roofline fractions to any jitted callable.

``repro.perf.hlo`` (trip-count-aware HLO analysis) and
``repro.perf.roofline`` (TPU-v5e roofline terms) existed but were
disconnected from the sim/search stack (ROADMAP item 1).  This module is
the wire: :func:`hlo_record` lowers a jitted callable at given operands,
parses the compiled module, and returns the JSON-able record every
``BENCH_*.json`` row embeds —

    {"hlo_flops": ..., "hlo_bytes": ..., "wire_bytes": ...,
     "collective_counts": {...}, "roofline": {...},
     "roofline_fraction": ..., "n_recompiles": ...}

``roofline_fraction`` is roofline-bound time over measured time: the
fraction of the hardware roofline the measured dispatch achieves (1.0 =
running exactly at the max(compute, memory, collective) bound; CPU runs
score low against the TPU-v5e constants — the point is tracking the ratio
per shape over time, not absolute truth).

``n_recompiles`` rides along from :mod:`repro.obs.jaxhooks` when the
caller hands a :class:`~repro.obs.jaxhooks.CompileSnapshot` taken before
the measured region — the convention :func:`repro.obs.bench.measure`
implements.
"""

from __future__ import annotations

from repro.obs import jaxhooks

__all__ = ["hlo_record", "attach_to_span", "compiled_text"]


def compiled_text(jitted_fn, *args, **kwargs) -> str:
    """Compiled (post-optimization) HLO text of a jitted callable at these
    abstract operands (arrays or jax.ShapeDtypeStruct)."""
    return jitted_fn.lower(*args, **kwargs).compile().as_text()


def hlo_record(jitted_fn, args: tuple = (), kwargs: dict | None = None,
               measured_s: float | None = None,
               model_flops: float | None = None, chips: int = 1,
               compile_snapshot: jaxhooks.CompileSnapshot | None = None,
               hlo_text: str | None = None) -> dict:
    """Build the benchmark-record HLO/roofline block for one jitted
    callable (pass ``hlo_text`` to skip the lower+compile when the caller
    already has the module text).

    ``measured_s`` (seconds per call of the same operands) turns the
    roofline bound into ``roofline_fraction``; ``model_flops`` defaults to
    the HLO count (useful_fraction 1.0) when the caller has no analytic
    model.  ``compile_snapshot`` — taken BEFORE the measured region —
    contributes ``n_recompiles`` / ``compile_s`` for that region; without
    one they report the lower+compile this call itself performed.
    """
    from repro.perf.hlo import analyze_module
    from repro.perf.roofline import compute_terms

    own = jaxhooks.snapshot()
    if hlo_text is None:
        hlo_text = compiled_text(jitted_fn, *args, **(kwargs or {}))
    stats = analyze_module(hlo_text)
    wire = stats.collectives.total_wire_bytes
    terms = compute_terms(
        hlo_flops=stats.flops, hlo_bytes=stats.hbm_bytes, wire_bytes=wire,
        chips=chips,
        model_flops=stats.flops if model_flops is None else model_flops,
        per_device=True)
    snap = compile_snapshot if compile_snapshot is not None else own
    n_recompiles, compile_s = snap.delta()
    record = {
        "hlo_flops": float(stats.flops),
        "hlo_bytes": float(stats.hbm_bytes),
        "wire_bytes": float(wire),
        "collective_counts": {k: int(v)
                              for k, v in stats.collectives.counts.items()},
        "roofline": terms.row(),
        "roofline_fraction": (
            None if not measured_s or measured_s <= 0
            else terms.step_time_s / measured_s),
        "measured_s": measured_s,
        "n_recompiles": int(n_recompiles),
        "compile_s": float(compile_s),
    }
    return record


def attach_to_span(sp, jitted_fn, args: tuple = (),
                   kwargs: dict | None = None, **rec_kwargs) -> dict:
    """Compute :func:`hlo_record` and fold it into a live span's args (the
    trace event then carries the FLOPs/roofline block).  Works on the
    disabled-path null span too (record still returned, nothing stored)."""
    from repro.obs.spans import Span

    rec = hlo_record(jitted_fn, args, kwargs, **rec_kwargs)
    if isinstance(sp, Span):
        sp.args["hlo"] = rec
    return rec
