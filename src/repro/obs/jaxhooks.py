"""Recompile and compile-time accounting via ``jax.monitoring``.

jax 0.4.x fires a ``/jax/core/compile/backend_compile_duration`` event for
every XLA backend compilation — including the silent retraces a
shape-bucket miss triggers in chunked ``score_batch`` — and nothing at all
for compilation-cache hits.  One module-level listener (installed lazily,
at most once; ``jax.monitoring`` has no unregister, so the listener itself
stays registered and checks an armed flag) turns those events into:

  * module-level totals (``compile_count`` / ``compile_seconds``), always
    updated while armed — the bench harness snapshots them around timed
    regions to report ``n_recompiles`` per benchmark record;
  * the default registry's ``jax.compiles`` counter and
    ``jax.compile_seconds`` total (when the registry is enabled);
  * compile-time attribution on the innermost active span
    (:mod:`repro.obs.spans`), which is how a span splits its wall time
    into compile vs execute.

``compile_count`` counts *backend compilations*: the first compilation of a
callable and every subsequent recompile look identical to XLA, so
"recompiles" in steady-state accounting means snapshotting after warmup
(what :func:`repro.obs.bench.measure` does).
"""

from __future__ import annotations

import threading

__all__ = ["install", "installed", "snapshot", "CompileSnapshot",
           "compile_count", "compile_seconds"]

# total-duration events of the three compile phases; backend_compile is the
# one that fires exactly once per XLA compilation, so it carries the count
_BACKEND_COMPILE = "/jax/core/compile/backend_compile_duration"
_COMPILE_PHASES = (
    "/jax/core/compile/jaxpr_trace_duration",
    "/jax/core/compile/jaxpr_to_mlir_module_duration",
    _BACKEND_COMPILE,
)

_lock = threading.Lock()
_installed = False
_armed = False

compile_count = 0
compile_seconds = 0.0


def _on_duration(event: str, duration: float, **kwargs) -> None:
    global compile_count, compile_seconds
    if not _armed or event not in _COMPILE_PHASES:
        return
    compile_seconds += duration
    is_backend = event == _BACKEND_COMPILE
    if is_backend:
        compile_count += 1
    from repro.obs import spans
    from repro.obs.registry import registry

    spans._attribute_compile(duration, is_backend)
    reg = registry()
    if reg.enabled:
        reg.counter("jax.compile_seconds").add(duration)
        if is_backend:
            reg.counter("jax.compiles").add(1)


def install() -> None:
    """Arm compile accounting (idempotent).  Registered once per process;
    never unregistered — disarming via the flag keeps repeat
    enable/disable cycles from stacking listeners."""
    global _installed, _armed
    with _lock:
        if not _installed:
            from jax import monitoring

            monitoring.register_event_duration_secs_listener(_on_duration)
            _installed = True
        _armed = True


def installed() -> bool:
    return _installed and _armed


class CompileSnapshot:
    """Point-in-time compile totals; subtract two to get a window."""

    def __init__(self):
        self.count = compile_count
        self.seconds = compile_seconds

    def delta(self) -> tuple[int, float]:
        """(compilations, compile seconds) since this snapshot."""
        return (compile_count - self.count, compile_seconds - self.seconds)


def snapshot() -> CompileSnapshot:
    """Arm the hooks and snapshot the totals (see CompileSnapshot)."""
    install()
    return CompileSnapshot()
