"""Learned cost priors with per-parameter uncertainty.

The package closes ROADMAP open item 2: instead of assuming the cost
model's per-device / per-operator parameters are known (the paper's
setting) or learnable only for pairs the current placement happens to
touch (PR 5's refit), it

  * featurizes devices and operators (:mod:`repro.belief.features`) so a
    ridge prior (:mod:`repro.belief.prior`) fit on replay-harvested tuples
    transfers to never-observed pairs, and
  * tracks an explicit posterior (:mod:`repro.belief.state`) whose
    variance contracts with observation mass and re-inflates under age
    decay — feeding robust search posterior samples instead of fixed
    jitter, and telling the probing candidates which devices are worth
    paying to observe.
"""

from repro.belief.features import (DEVICE_FEATURES, OP_FEATURES,
                                   device_features, op_features,
                                   speed_percentile)
from repro.belief.prior import LearnedPrior, fit_prior, ridge_loss
from repro.belief.state import BeliefState, apply_degrade

__all__ = [
    "DEVICE_FEATURES",
    "OP_FEATURES",
    "device_features",
    "op_features",
    "speed_percentile",
    "LearnedPrior",
    "fit_prior",
    "ridge_loss",
    "BeliefState",
    "apply_degrade",
]
