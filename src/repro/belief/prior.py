"""The learned cost prior: a small JAX-native ridge model over the
(operator, device) featurization.

Two independent heads, both linear in the features of
:mod:`repro.belief.features`:

  * **device head** — predicts per-device log-slowdown (``log degrade``,
    0 = healthy) from device features;
  * **op head** — predicts per-operator log selectivity scale (0 = the
    nominal metadata is right) from op features.

Training minimizes ONE jitted weighted ridge loss per head
(:func:`ridge_loss`); :func:`_ridge_solve` evaluates its exact minimizer
(normal equations) in the same jitted float32 program, so fitting is a
single dispatch per head — no Python-side optimization loop, no retraces
across refits (shapes are padded per call site by the caller's data, and
the solve is jitted once at module import).

The fit is *observation-count weighted*: a (device, window) tuple whose
estimate rests on 10⁴ work·rows of busy evidence moves the prior more than
a sliver-of-mass tuple — the same weights the belief posterior uses
(:class:`repro.belief.state.BeliefState`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LearnedPrior", "fit_prior", "ridge_loss"]


def _design(x: jnp.ndarray) -> jnp.ndarray:
    """[1 | features] design matrix (bias absorbed as the first column)."""
    ones = jnp.ones((x.shape[0], 1), dtype=jnp.float32)
    return jnp.concatenate([ones, x], axis=1)


def _ridge_loss(w: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray,
                sw: jnp.ndarray, lam: jnp.ndarray) -> jnp.ndarray:
    """Weighted ridge loss ``Σ_n sw_n (X_n·w − y_n)² + λ‖w₁:‖²`` (the bias
    is not penalized).  THE training objective — `_ridge_solve` returns its
    exact minimizer."""
    resid = _design(x) @ w - y
    penalty = lam * jnp.sum(w[1:] ** 2)
    return jnp.sum(sw * resid ** 2) + penalty


def _ridge_solve(x: jnp.ndarray, y: jnp.ndarray, sw: jnp.ndarray,
                 lam: jnp.ndarray) -> jnp.ndarray:
    """Exact minimizer of :func:`_ridge_loss` via the weighted normal
    equations (float32; the λ ridge keeps the system well-posed even with
    collinear one-hot tiers)."""
    d = _design(x)
    g = (d * sw[:, None]).T @ d
    reg = jnp.eye(d.shape[1], dtype=jnp.float32) * lam
    reg = reg.at[0, 0].set(0.0)
    rhs = (d * sw[:, None]).T @ y
    return jnp.linalg.solve(g + reg, rhs)


_ridge_solve_jit = jax.jit(_ridge_solve)
_ridge_loss_jit = jax.jit(_ridge_loss)


def ridge_loss(w: np.ndarray, feats: np.ndarray, targets: np.ndarray,
               weights: np.ndarray, ridge: float) -> float:
    """Host-facing view of the jitted training loss (diagnostics/tests)."""
    return float(_ridge_loss_jit(
        jnp.asarray(w, dtype=jnp.float32),
        jnp.asarray(feats, dtype=jnp.float32),
        jnp.asarray(targets, dtype=jnp.float32),
        jnp.asarray(weights, dtype=jnp.float32),
        jnp.asarray(ridge, dtype=jnp.float32)))


def _fit_head(feats: np.ndarray, targets: np.ndarray, weights: np.ndarray,
              ridge: float) -> np.ndarray:
    x = jnp.asarray(feats, dtype=jnp.float32)
    y = jnp.asarray(targets, dtype=jnp.float32)
    sw = jnp.asarray(weights, dtype=jnp.float32)
    # scale-free weights: only relative evidence matters, and normalizing
    # keeps the float32 normal equations away from overflow for huge
    # work-mass units
    sw = sw / jnp.maximum(jnp.mean(sw), jnp.float32(1e-30))
    w = _ridge_solve_jit(x, y, sw, jnp.asarray(ridge, dtype=jnp.float32))
    return np.asarray(w, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class LearnedPrior:
    """Fitted prior weights (host-side float64 copies of the float32 fit).

    ``predict_*`` are pure numpy — prediction sits on the controller's
    decision path, where a jit dispatch per tick would violate the
    O(adaptations) dispatch budget."""

    w_device: np.ndarray | None      # (F_d + 1,) → log degrade
    w_op: np.ndarray | None          # (F_o + 1,) → log selectivity scale
    ridge: float
    n_device_samples: int
    n_op_samples: int
    # spread of the training residuals — the belief's prior variance
    device_residual_var: float = 0.25
    op_residual_var: float = 0.25

    def predict_log_degrade(self, feats: np.ndarray) -> np.ndarray:
        feats = np.asarray(feats, dtype=np.float64)
        if self.w_device is None:
            return np.zeros(feats.shape[0])
        pred = self.w_device[0] + feats @ self.w_device[1:]
        return np.clip(pred, np.log(1e-2), np.log(1e6))

    def predict_degrade(self, feats: np.ndarray) -> np.ndarray:
        """(V,) predicted slowdown multipliers (1 = healthy)."""
        return np.exp(self.predict_log_degrade(feats))

    def predict_log_sel_scale(self, feats: np.ndarray) -> np.ndarray:
        feats = np.asarray(feats, dtype=np.float64)
        if self.w_op is None:
            return np.zeros(feats.shape[0])
        pred = self.w_op[0] + feats @ self.w_op[1:]
        return np.clip(pred, np.log(1e-3), np.log(1e3))

    def predict_sel_scale(self, feats: np.ndarray) -> np.ndarray:
        """(n_ops,) predicted selectivity drift scales (1 = none)."""
        return np.exp(self.predict_log_sel_scale(feats))


def fit_prior(device_features: np.ndarray | None = None,
              device_log_degrade: np.ndarray | None = None,
              device_weights: np.ndarray | None = None,
              op_features: np.ndarray | None = None,
              op_log_sel_scale: np.ndarray | None = None,
              op_weights: np.ndarray | None = None,
              ridge: float = 1e-2) -> LearnedPrior:
    """Fit the two ridge heads from harvested training tuples
    (:func:`repro.sim.training.training_tuples` produces them from replay
    windows).  Either head may be absent (None / empty arrays) — the prior
    then predicts the healthy default for that head."""
    w_d, var_d, n_d = None, 0.25, 0
    if device_features is not None and np.size(device_log_degrade):
        feats = np.asarray(device_features, dtype=np.float64)
        y = np.asarray(device_log_degrade, dtype=np.float64)
        sw = np.ones(y.size) if device_weights is None \
            else np.asarray(device_weights, dtype=np.float64)
        w_d = _fit_head(feats, y, sw, ridge)
        resid = (w_d[0] + feats @ w_d[1:]) - y
        tot = sw.sum()
        var_d = float((sw * resid ** 2).sum() / tot) if tot > 0 else 0.25
        n_d = int(y.size)
    w_o, var_o, n_o = None, 0.25, 0
    if op_features is not None and np.size(op_log_sel_scale):
        feats = np.asarray(op_features, dtype=np.float64)
        y = np.asarray(op_log_sel_scale, dtype=np.float64)
        sw = np.ones(y.size) if op_weights is None \
            else np.asarray(op_weights, dtype=np.float64)
        w_o = _fit_head(feats, y, sw, ridge)
        resid = (w_o[0] + feats @ w_o[1:]) - y
        tot = sw.sum()
        var_o = float((sw * resid ** 2).sum() / tot) if tot > 0 else 0.25
        n_o = int(y.size)
    return LearnedPrior(w_device=w_d, w_op=w_o, ridge=float(ridge),
                        n_device_samples=n_d, n_op_samples=n_o,
                        device_residual_var=max(var_d, 1e-4),
                        op_residual_var=max(var_o, 1e-4))
