"""Featurization of devices and operators for the learned cost prior.

COSTREAM / Zero-Shot Cost Models (PAPERS.md) transfer learned cost models
to unseen configurations by featurizing operators and hardware instead of
keying on identities.  The same idea here: a device is described by its
speed tier and its region's link-cost profile, an operator by its
selectivity / payload / work and its position in the DAG — NEVER by its
index — so a prior fit on one generated fleet prices devices of a fleet it
has never seen.

Invariance contract (property-tested in ``tests/test_belief.py``): the
feature vector follows the device, not the index — reindexing devices
within a region permutes the feature rows by exactly the same permutation.
Every feature is therefore a function of device *values* (speed, region
aggregates), not of device ids.
"""

from __future__ import annotations

import numpy as np

__all__ = ["DEVICE_FEATURES", "OP_FEATURES", "device_features", "op_features",
           "speed_percentile"]

#: Column names of :func:`device_features` (order is the contract).
DEVICE_FEATURES = (
    "log_speed",          # log effective speed (1.0 = nominal)
    "speed_percentile",   # rank of the device's speed within the fleet [0, 1]
    "tier_slow",          # bottom-third speed tier (cheap hardware class)
    "tier_mid",
    "tier_fast",
    "log_out_com",        # log mean com cost of the device's outgoing links
    "log_intra_com",      # log mean com cost within the device's region
    "region_frac",        # fraction of the fleet in the device's region
)

#: Column names of :func:`op_features`.
OP_FEATURES = (
    "log_selectivity",
    "log_out_bytes",
    "log1p_work",
    "log_cum_rate",       # rows reaching the op per source row (dataflow depth)
    "in_degree",
    "out_degree",
    "is_source",
    "is_sink",
    "dq_eligible",
)


def speed_percentile(speed: np.ndarray) -> np.ndarray:
    """Mid-rank percentile of each device's speed within the fleet — a pure
    function of the speed *multiset*, so it is invariant under any device
    permutation (ties share one value instead of splitting by index)."""
    s = np.asarray(speed, dtype=np.float64)
    below = (s[None, :] < s[:, None]).mean(axis=1)
    equal = (s[None, :] == s[:, None]).mean(axis=1)
    return below + 0.5 * equal


def device_features(fleet) -> np.ndarray:
    """(V, len(DEVICE_FEATURES)) feature matrix for a fleet (ExplicitFleet
    or RegionFleet — anything with ``effective_speed``/``com_matrix``/
    ``region``)."""
    speed = np.asarray(fleet.effective_speed(), dtype=np.float64)
    com = np.asarray(fleet.com_matrix(), dtype=np.float64)
    region = np.asarray(getattr(fleet, "region", None)
                        if getattr(fleet, "region", None) is not None
                        else np.zeros(speed.size, dtype=np.int64))
    v = speed.size
    pct = speed_percentile(speed)
    tier_slow = (pct < 1.0 / 3.0).astype(np.float64)
    tier_fast = (pct >= 2.0 / 3.0).astype(np.float64)
    tier_mid = 1.0 - tier_slow - tier_fast
    off = com.copy()
    np.fill_diagonal(off, 0.0)
    out_com = off.sum(axis=1) / max(v - 1, 1)
    intra_com = np.zeros(v)
    region_frac = np.zeros(v)
    for r in np.unique(region):
        mask = region == r
        n_r = int(mask.sum())
        region_frac[mask] = n_r / v
        if n_r > 1:
            block = off[np.ix_(mask, mask)]
            intra_com[mask] = block.sum() / (n_r * (n_r - 1))
        else:
            intra_com[mask] = 0.0
    feats = np.stack([
        np.log(np.maximum(speed, 1e-12)),
        pct,
        tier_slow,
        tier_mid,
        tier_fast,
        np.log1p(out_com),
        np.log1p(intra_com),
        region_frac,
    ], axis=1)
    return feats


def op_features(graph) -> np.ndarray:
    """(n_ops, len(OP_FEATURES)) feature matrix for an OpGraph."""
    n = graph.n_ops
    in_deg = np.zeros(n)
    out_deg = np.zeros(n)
    for a, b in graph.edges:
        out_deg[a] += 1.0
        in_deg[b] += 1.0
    cum = np.asarray(graph.cumulative_rates(), dtype=np.float64)
    feats = np.stack([
        np.array([np.log(max(op.selectivity, 1e-12))
                  for op in graph.operators]),
        np.array([np.log(max(op.out_bytes, 1e-12))
                  for op in graph.operators]),
        np.array([np.log1p(max(op.work, 0.0)) for op in graph.operators]),
        np.log(np.maximum(cum, 1e-12)),
        in_deg,
        out_deg,
        (in_deg == 0).astype(np.float64),
        (out_deg == 0).astype(np.float64),
        np.array([float(getattr(op, "dq_eligible", False))
                  for op in graph.operators]),
    ], axis=1)
    return feats
