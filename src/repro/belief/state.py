"""The belief state: per-parameter posterior over the cost model's unknowns.

``refit_from_replay`` produces *point estimates* of per-device slowdown and
per-operator selectivity, and PR 5's controller hedged against their error
with ad-hoc fixed-σ lognormal jitter — every device equally uncertain
forever.  :class:`BeliefState` replaces that with an explicit posterior in
log space:

  * **mean** — an observation-count-weighted blend of the running refit
    estimate and the learned prior (:class:`repro.belief.prior.
    LearnedPrior`): ``(n·est + κ·prior) / (n + κ)``.  A device with ZERO
    observations returns *exactly* the prior mean (property-tested).
  * **variance** — ``prior_var · κ / (κ + n)``: monotone non-increasing in
    the observation count ``n``, so well-measured devices stop being
    jittered while never-observed ones keep their full prior spread.
  * **age decay** — :meth:`decay` shrinks the observation counts, which
    simultaneously RAISES the variance and relaxes the mean back toward the
    prior: stale evidence loses its grip exactly as fast for the mean as
    for the spread.

Observations arrive through :meth:`update_from_refit` (the calibration
layer calls it via ``refit_from_replay(..., belief=...)``), weighted by the
predicted work mass behind each per-device estimate — a stray sliver of
placement mass buys almost no posterior contraction.  :meth:`sample_fleets`
turns the posterior into robust-search scenario fleets: per-device
lognormal draws with the posterior σ, replacing the fixed-jitter
``perturbed_fleet`` copies.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.belief.features import device_features, op_features
from repro.belief.prior import LearnedPrior
from repro.core.devices import ExplicitFleet

__all__ = ["BeliefState", "apply_degrade"]


def apply_degrade(fleet, degrade: np.ndarray) -> ExplicitFleet:
    """Materialize per-device slowdowns into an ExplicitFleet: links scale
    by ``d_u·d_v`` off-diagonal (the self-cost diagonal is kept) and speeds
    drop by ``d`` — the same structure ``refit_from_replay`` builds."""
    d = np.asarray(degrade, dtype=np.float64)
    com = np.asarray(fleet.com_matrix(), dtype=np.float64)
    com2 = com * np.outer(d, d)
    np.fill_diagonal(com2, np.diag(com))
    speed = np.asarray(fleet.effective_speed(), dtype=np.float64) / d
    return ExplicitFleet(com_cost=com2, speed=speed,
                         available=getattr(fleet, "available", None),
                         region=getattr(fleet, "region", None))


@dataclasses.dataclass
class BeliefState:
    """Posterior belief over per-device log-slowdown (and, optionally,
    per-op log selectivity scale), all relative to the BASE fleet the
    controller was handed.

    ``prior_strength`` is κ — how many (weight-normalized) observations the
    prior is worth.  ``cum_log`` tracks the slowdown the believed fleet
    currently carries (refits compose multiplicatively; the controller
    calls :meth:`commit` when it adopts one), so observations arriving as
    *relative* refit degrades can be anchored absolutely."""

    prior_mean_log: np.ndarray      # (V,) prior log-degrade
    prior_var: np.ndarray           # (V,) prior variance of log-degrade
    est_log: np.ndarray             # (V,) running observed log-degrade
    obs_count: np.ndarray           # (V,) effective observation counts
    cum_log: np.ndarray             # (V,) believed-fleet cumulative log-degrade
    prior_strength: float = 4.0
    # optional per-op selectivity-scale head (same machinery, log space)
    op_prior_mean_log: np.ndarray | None = None
    op_prior_var: np.ndarray | None = None
    op_est_log: np.ndarray | None = None
    op_obs_count: np.ndarray | None = None

    # -- construction ---------------------------------------------------------
    @classmethod
    def from_fleet(cls, fleet, graph=None, prior: LearnedPrior | None = None,
                   prior_strength: float = 4.0,
                   default_var: float = 0.25) -> "BeliefState":
        """Belief over ``fleet``'s devices.  With a :class:`LearnedPrior`
        the prior mean is its featurized prediction (a never-observed
        device gets a calibrated estimate instead of "healthy"); without
        one the prior is the base fleet itself (log-degrade 0)."""
        v = fleet.n_devices
        if prior is not None:
            feats = device_features(fleet)
            mean = prior.predict_log_degrade(feats)
            var = np.full(v, max(prior.device_residual_var, 1e-4))
        else:
            mean = np.zeros(v)
            var = np.full(v, default_var)
        op_mean = op_var = op_est = op_cnt = None
        if graph is not None:
            n_ops = graph.n_ops
            if prior is not None and prior.w_op is not None:
                op_mean = prior.predict_log_sel_scale(op_features(graph))
                op_var = np.full(n_ops, max(prior.op_residual_var, 1e-4))
            else:
                op_mean = np.zeros(n_ops)
                op_var = np.full(n_ops, default_var)
            op_est = op_mean.copy()
            op_cnt = np.zeros(n_ops)
        return cls(prior_mean_log=mean, prior_var=var, est_log=mean.copy(),
                   obs_count=np.zeros(v), cum_log=np.zeros(v),
                   prior_strength=float(prior_strength),
                   op_prior_mean_log=op_mean, op_prior_var=op_var,
                   op_est_log=op_est, op_obs_count=op_cnt)

    @property
    def n_devices(self) -> int:
        return self.prior_mean_log.size

    # -- posterior ------------------------------------------------------------
    def posterior_mean_log(self) -> np.ndarray:
        """(V,) posterior mean log-degrade: the count-weighted blend.  At
        ``obs_count == 0`` this is EXACTLY ``prior_mean_log`` (guarded with
        a ``where``, not arithmetic that merely converges to it)."""
        k = self.prior_strength
        blend = (self.obs_count * self.est_log
                 + k * self.prior_mean_log) / (self.obs_count + k)
        return np.where(self.obs_count > 0.0, blend, self.prior_mean_log)

    def posterior_mean_degrade(self) -> np.ndarray:
        return np.exp(self.posterior_mean_log())

    def posterior_var(self) -> np.ndarray:
        """(V,) posterior variance of log-degrade:
        ``prior_var · κ / (κ + obs_count)`` — non-increasing in the count,
        exactly ``prior_var`` at zero observations."""
        k = self.prior_strength
        return self.prior_var * (k / (k + self.obs_count))

    def op_posterior_mean_log(self) -> np.ndarray | None:
        if self.op_est_log is None:
            return None
        k = self.prior_strength
        blend = (self.op_obs_count * self.op_est_log
                 + k * self.op_prior_mean_log) / (self.op_obs_count + k)
        return np.where(self.op_obs_count > 0.0, blend,
                        self.op_prior_mean_log)

    # -- updates --------------------------------------------------------------
    def observe(self, log_degrade: np.ndarray, weight: np.ndarray) -> None:
        """Count-weighted running update of the device estimates: entries
        with ``weight == 0`` are untouched."""
        w = np.asarray(weight, dtype=np.float64)
        est = np.asarray(log_degrade, dtype=np.float64)
        tot = self.obs_count + w
        upd = np.where(w > 0.0,
                       (self.obs_count * self.est_log + w * est)
                       / np.maximum(tot, 1e-30),
                       self.est_log)
        self.est_log = upd
        self.obs_count = tot

    def update_from_refit(self, refit) -> None:
        """Ingest one :class:`repro.core.calibration.ReplayRefit`: the
        refit's per-device degrades (relative to the CURRENT believed
        fleet) become absolute observations via ``cum_log``, weighted by
        the predicted work mass behind each estimate (normalized so a
        typical well-observed device contributes ~1 count per window)."""
        if refit.obs_weight is None or refit.signal is None:
            return
        w = np.asarray(refit.obs_weight, dtype=np.float64).copy()
        sig = np.asarray(refit.signal, dtype=bool)
        w[~sig] = 0.0
        if sig.any():
            scale = float(np.median(w[sig]))
            if scale > 0.0:
                w = np.minimum(w / scale, 4.0)
        obs_log = self.cum_log + np.log(np.maximum(refit.degrade, 1e-12))
        self.observe(obs_log, w)
        if self.op_est_log is not None and refit.op_obs_weight is not None \
                and refit.sel_scale.size == self.op_est_log.size:
            ow = np.asarray(refit.op_obs_weight, dtype=np.float64).copy()
            pos = ow > 0.0
            if pos.any():
                s = float(np.median(ow[pos]))
                if s > 0.0:
                    ow = np.minimum(ow / s, 4.0)
            est = np.log(np.maximum(refit.sel_scale, 1e-12))
            tot = self.op_obs_count + ow
            self.op_est_log = np.where(
                ow > 0.0,
                (self.op_obs_count * self.op_est_log + ow * est)
                / np.maximum(tot, 1e-30),
                self.op_est_log)
            self.op_obs_count = tot

    def commit(self, degrade: np.ndarray) -> None:
        """Record that the believed fleet adopted a refit: future relative
        observations compose on top of this cumulative slowdown."""
        self.cum_log = self.cum_log \
            + np.log(np.maximum(np.asarray(degrade, dtype=np.float64),
                                1e-12))

    def decay(self, factor: float) -> None:
        """Age decay: one adaptation epoch passes, evidence fades.  Counts
        shrink by ``factor`` (< 1), so the posterior variance rises and the
        posterior mean relaxes toward the prior."""
        f = float(np.clip(factor, 0.0, 1.0))
        self.obs_count = self.obs_count * f
        if self.op_obs_count is not None:
            self.op_obs_count = self.op_obs_count * f

    def without_devices(self, keep: np.ndarray) -> "BeliefState":
        """Shrink the belief with the fleet on device removal."""
        keep = np.asarray(keep)
        return dataclasses.replace(
            self,
            prior_mean_log=self.prior_mean_log[keep],
            prior_var=self.prior_var[keep],
            est_log=self.est_log[keep],
            obs_count=self.obs_count[keep],
            cum_log=self.cum_log[keep])

    # -- consumers ------------------------------------------------------------
    def sample_degrade_rel(self, rng: np.random.Generator,
                           n: int) -> np.ndarray:
        """(n, V) multiplicative slowdown factors RELATIVE to the believed
        fleet: lognormal draws centered on the posterior mean's offset from
        the committed belief, spread by the posterior σ.  A well-observed
        device barely moves; a never-observed one swings with its full
        prior spread."""
        std = np.sqrt(self.posterior_var())
        center = self.posterior_mean_log() - self.cum_log
        noise = rng.standard_normal((n, self.n_devices))
        return np.exp(center[None, :] + std[None, :] * noise)

    def sample_fleets(self, base_fleet, rng: np.random.Generator,
                      n: int) -> list[ExplicitFleet]:
        """``n`` posterior-sampled what-if fleets around ``base_fleet`` —
        the drop-in replacement for fixed-jitter ``perturbed_fleet`` copies
        in min–max robust re-optimization."""
        rel = self.sample_degrade_rel(rng, n)
        return [apply_degrade(base_fleet, rel[k]) for k in range(n)]
