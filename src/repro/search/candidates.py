"""Layer 1 — candidate generation: composable proposal sources that emit
*batches* of (placement, dq) candidates.

The seed optimizers interleaved proposal generation with one-at-a-time
scoring; here every source produces whole (B, n_ops, V) arrays (plus the DQ
grid as an independent axis) so Layer 2 (:mod:`repro.search.engine`) can
score each batch in a single jitted dispatch.  Sources:

  * :func:`grid_placements`        — the exhaustive composition grid
    (``x_{i,·} ∈ {k/granularity}``), streamed lazily so the state count can
    exceed memory as long as it is chunked;
  * :func:`random_placements`      — Dirichlet random restarts;
  * :func:`transfer_neighborhood`  — the greedy δ-mass transfer moves of one
    operator, in the seed's deterministic (u-major, v-minor) order so a
    first-occurrence ``argmin`` over the batch reproduces the scalar loop's
    tie-breaking exactly;
  * :func:`anneal_path`            — a cumulative random-walk block of
    simulated-annealing moves (mass transfers and, when β > 0, DQ jumps)
    for one incumbent, Metropolis-walked after a single dispatch;
  * :func:`probe_candidates`       — deterministic probing variants of an
    incumbent that keep ε placement mass on high-uncertainty devices
    (belief-posterior std from :mod:`repro.belief`), so the controller can
    *buy* observations of devices its placement would otherwise never
    touch;
  * :func:`dq_grid`                — the DQ candidate grid, which ALWAYS
    contains the incumbent ``dq_fraction`` (``include=``): the seed grid
    could regress the DQ term simply because the incumbent value was not a
    multiple of 1/steps.

The joint (placement × dq) cross product is deliberately *not* materialized
here: DQ only enters the objective through the analytic ``/(1 + β·dq)``
factor and the DQCoupling feasibility caps, so Layer 2 expands it after the
dispatch at O(P·D) numpy cost (see ``BatchedProblem.score_batch``).
"""

from __future__ import annotations

import itertools
import math
from typing import Iterator, Sequence

import numpy as np

__all__ = [
    "dq_grid",
    "grid_placements",
    "count_grid_states",
    "incumbent_candidates",
    "probe_candidates",
    "random_placements",
    "transfer_neighborhood",
    "anneal_path",
    "chunked",
]


def dq_grid(beta: float, steps: int = 5,
            include: Sequence[float] = ()) -> np.ndarray:
    """DQ_fraction candidates: {k/steps} when β > 0, else {0}, PLUS every
    ``include`` value (clipped to [0, 1]).

    ``include`` carries the search's incumbent dq so a re-optimization
    starting from a previous result can never lose its dq term to grid
    quantization — the values are deduplicated and sorted, so downstream
    first-occurrence argmins stay deterministic."""
    vals = {0.0} if beta == 0.0 else \
        {float(v) for v in np.linspace(0.0, 1.0, steps + 1)}
    vals.update(float(np.clip(v, 0.0, 1.0)) for v in include)
    return np.array(sorted(vals), dtype=np.float64)


def _per_op_rows(avail: np.ndarray, granularity: int) -> list[list[np.ndarray]]:
    """For each operator, every grid row x_{i,·} ∈ {k/granularity} on its
    available devices (the seed's ``_compositions`` enumeration order)."""
    n_ops, n_dev = avail.shape
    out: list[list[np.ndarray]] = []
    for i in range(n_ops):
        idx = np.flatnonzero(avail[i])
        rows = []
        for comp in _compositions(granularity, idx.size):
            row = np.zeros(n_dev)
            row[idx] = np.asarray(comp) / granularity
            rows.append(row)
        out.append(rows)
    return out


def _compositions(total: int, parts: int):
    """All ways to write ``total`` as an ordered sum of ``parts`` ≥0 ints."""
    if parts == 1:
        yield (total,)
        return
    for head in range(total + 1):
        for tail in _compositions(total - head, parts - 1):
            yield (head,) + tail


def count_grid_states(avail: np.ndarray, granularity: int) -> int:
    """Size of the composition grid — the exhaustive searcher's budget check
    (computed without enumerating: C(granularity + k − 1, k − 1) per op)."""
    n = 1
    for i in range(avail.shape[0]):
        k = int(np.flatnonzero(avail[i]).size)
        n *= math.comb(granularity + k - 1, k - 1)
    return n


def grid_placements(avail: np.ndarray,
                    granularity: int) -> Iterator[np.ndarray]:
    """Stream every composition-grid placement in the seed's enumeration
    order (itertools.product over per-op rows).  O(1) memory per state —
    chunk with :func:`chunked` for batched scoring."""
    for rows in itertools.product(*_per_op_rows(avail, granularity)):
        yield np.stack(rows)


def random_placements(avail: np.ndarray, rng: np.random.Generator, n: int,
                      sparsity: float = 0.0) -> np.ndarray:
    """(n, n_ops, V) Dirichlet-random placements (repro.core.placement's
    ``random_placement`` semantics, batched; consumes the rng stream in the
    same per-candidate order as the seed's scalar loop)."""
    from repro.core.placement import random_placement

    n_ops = avail.shape[0]
    return np.stack([random_placement(n_ops, avail, rng, sparsity)
                     for _ in range(n)])


def incumbent_candidates(x: np.ndarray, avail: np.ndarray,
                         rng: np.random.Generator, n: int,
                         jitter: float = 0.25,
                         sparsity: float = 0.5) -> np.ndarray:
    """(n, n_ops, V) warm-start batch around an incumbent placement: the
    incumbent itself FIRST (a re-optimization can therefore never regress —
    first-occurrence argmin keeps it on ties), then ~half jittered copies
    (simplex-renormalized mixtures of the incumbent with Dirichlet noise —
    local moves for drift-chasing re-placement), then Dirichlet random
    restarts (global escapes).  The shape of choice for closed-loop
    re-optimization (:mod:`repro.adapt`), where the previous placement is
    usually nearly right and the search budget is one dispatch."""
    x = np.asarray(x, dtype=np.float64)
    if n < 1:
        raise ValueError(f"need n ≥ 1 candidates, got {n}")
    out = [x]
    n_local = (n - 1 + 1) // 2
    for _ in range(n_local):
        noise = random_placements(avail, rng, 1, 0.0)[0]
        cand = (1.0 - jitter) * x + jitter * noise
        mass = cand.sum(axis=1, keepdims=True)
        out.append(np.divide(cand, mass, out=np.zeros_like(cand),
                             where=mass > 0.0))
    if len(out) < n:
        out.extend(random_placements(avail, rng, n - len(out), sparsity))
    return np.stack(out[:n])


def probe_candidates(x: np.ndarray, avail: np.ndarray,
                     uncertainty: np.ndarray, epsilon: float,
                     top_k: int = 2) -> np.ndarray:
    """(top_k, n_ops, V) probing variants of the incumbent: variant k moves
    ε of every operator's mass onto the k most-uncertain devices (mass
    split ∝ posterior std among them, masked per-op by availability).

    Deterministic — no rng — so probing perturbs neither the controller's
    candidate stream nor reproducibility, and it costs ZERO extra
    dispatches: the variants ride in the same ``score_grid`` batch as the
    incumbent candidates.  A probe is only adopted when the robust
    objective (plus the exploration bonus the controller applies) says the
    information is worth its price.  With ``epsilon <= 0``, no uncertainty
    signal, or nothing available, the batch is empty."""
    x = np.asarray(x, dtype=np.float64)
    std = np.asarray(uncertainty, dtype=np.float64)
    if epsilon <= 0.0 or top_k < 1 or not np.any(std > 0.0):
        return np.empty((0,) + x.shape)
    eps = float(np.clip(epsilon, 0.0, 1.0))
    # most-uncertain devices first; stable sort keeps ties index-ordered
    order = np.argsort(-std, kind="stable")
    out = []
    for k in range(1, top_k + 1):
        chosen = order[:k]
        weights = np.zeros(std.size)
        weights[chosen] = std[chosen]
        # per-op availability mask + renormalization: an op that can run on
        # none of the probe targets keeps its incumbent row
        target = np.asarray(avail, dtype=np.float64) * weights[None, :]
        mass = target.sum(axis=1, keepdims=True)
        target = np.divide(target, mass, out=np.zeros_like(target),
                           where=mass > 0.0)
        movable = mass[:, 0] > 0.0
        cand = x.copy()
        cand[movable] = (1.0 - eps) * x[movable] + eps * target[movable]
        out.append(cand)
    return np.stack(out)


def transfer_neighborhood(x: np.ndarray, avail: np.ndarray, op: int,
                          delta: float) -> np.ndarray:
    """(M, n_ops, V) — every δ-mass transfer of operator ``op`` between its
    available device pairs (u → v, u ≠ v, x[op, u] ≥ δ).

    Emission order is u-major / v-minor, matching the seed greedy's nested
    loop, so ``argmin`` over the scored batch (first occurrence on ties)
    selects the same move the scalar loop would."""
    idx = np.flatnonzero(avail[op])
    moves = [(u, v) for u in idx if x[op, u] >= delta - 1e-12
             for v in idx if v != u]
    if not moves:
        return np.empty((0,) + x.shape)
    out = np.repeat(x[None, :, :], len(moves), axis=0)
    for m, (u, v) in enumerate(moves):
        out[m, op, u] -= delta
        out[m, op, v] += delta
    return out


def anneal_path(x: np.ndarray, dq: float, avail: np.ndarray,
                rng: np.random.Generator, k: int, beta: float,
                dq_move_prob: float = 0.15
                ) -> tuple[np.ndarray, np.ndarray]:
    """A CUMULATIVE random-walk path of ``k`` simulated-annealing moves from
    the incumbent ``(x, dq)``: point m applies one seed-SA move (a random
    mass transfer, or a DQ jump with probability ``dq_move_prob`` when
    β > 0) on top of point m − 1.

    The searcher scores the whole path in one dispatch and Metropolis-walks
    it point by point: relative to the currently-accepted state, every path
    point is a symmetric random-walk composite (the moves were drawn
    independently of the accept/reject decisions), so up to ``k`` moves can
    be accepted per dispatch — the chain length is bounded by ``steps``,
    not by the dispatch count.  Returns
    ``(placements (k, n_ops, V), dqs (k,))``."""
    n_ops = x.shape[0]
    cands = np.empty((k,) + x.shape, dtype=np.float64)
    dqs = np.empty(k, dtype=np.float64)
    cur, cur_dq = x.copy(), float(dq)
    for m in range(k):
        if beta > 0.0 and rng.random() < dq_move_prob:
            cur_dq = float(np.clip(
                cur_dq + rng.choice([-0.2, -0.1, 0.1, 0.2]), 0.0, 1.0))
        else:
            i = rng.integers(n_ops)
            idx = np.flatnonzero(avail[i])
            if idx.size >= 2:
                u, v = rng.choice(idx, size=2, replace=False)
                amt = rng.uniform(0.0, cur[i, u])
                cur[i, u] -= amt
                cur[i, v] += amt
        cands[m] = cur
        dqs[m] = cur_dq
    return cands, dqs


def chunked(it: Iterator[np.ndarray], size: int) -> Iterator[np.ndarray]:
    """Stack a placement stream into (≤size, n_ops, V) batches."""
    block: list[np.ndarray] = []
    for x in it:
        block.append(x)
        if len(block) == size:
            yield np.stack(block)
            block = []
    if block:
        yield np.stack(block)
