"""Batched searchers: the seed's scalar-loop optimizers rebuilt on the
three-layer search stack (candidates → batched scoring → decision).

Signatures and semantics match ``repro.core.optimizers`` — the old entry
points re-export these — but every candidate batch is scored through
``BatchedProblem.score_batch`` (one jitted dispatch per chunk) instead of
one ``prob.score`` call per candidate:

  * :func:`exhaustive_search`   — streams the composition grid in chunks;
    same enumeration order and tie-breaking as the seed loop, O(states /
    chunk) dispatches.
  * :func:`greedy_transfer`     — the seed's per-operator move scan, but
    each operator's whole (u → v) transfer neighborhood is one dispatch;
    the selected move is confirmed against the float64 oracle before it is
    applied, so float32 batch noise can't walk the descent.  The DQ grid is
    co-scanned each round and ALWAYS contains the incumbent dq (``dq0``).
  * :func:`simulated_annealing` — block SA: each dispatch scores a
    cumulative random-walk path of proposals from the incumbent, then
    Metropolis-walks it (up to ``block`` accepted moves per dispatch).
    Same move kernel, O(steps / block) dispatches.
  * :func:`random_search`       — random restarts × the full DQ grid in
    chunked dispatches; joint (placement × dq) selection is analytic.

All searchers co-optimize ``dq_fraction`` jointly with the placement
(DQCoupling-aware: infeasible (candidate, dq) pairs score +inf), honor
``prob.objectives`` for multi-objective scalarized search — including
:func:`random_search`, which the seed scored by latency-F only — and
re-score the winner through the exact float64 oracle (``OptResult.of``).
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.optimizers import OptResult, PlacementProblem, _dq_grid
from repro.core.placement import (project_with_caps, random_placement,
                                  uniform_placement)
from repro.search.candidates import (anneal_path, chunked,
                                     count_grid_states, grid_placements,
                                     random_placements, transfer_neighborhood)
from repro.search.engine import BatchedProblem

__all__ = [
    "exhaustive_search",
    "greedy_transfer",
    "simulated_annealing",
    "random_search",
]


def _engine(prob: PlacementProblem,
            engine: BatchedProblem | None) -> tuple[BatchedProblem, int, int]:
    """Reuse a caller-provided engine (its jitted dispatch functions stay
    warm across repeated searches on one problem) or build a fresh one;
    returns (engine, evals snapshot, dispatches snapshot) so the OptResult
    reports THIS search's counts even on a shared engine."""
    if engine is None:
        engine = BatchedProblem(prob)
    elif engine.prob is not prob:
        raise ValueError("engine was built for a different PlacementProblem")
    return engine, engine.evals, engine.dispatches


def _start(prob: PlacementProblem, avail: np.ndarray, x0: np.ndarray | None,
           dq: float, rng: np.random.Generator | None = None) -> np.ndarray:
    x = (random_placement(avail.shape[0], avail, rng) if rng is not None
         else uniform_placement(avail.shape[0], avail)) \
        if x0 is None else np.asarray(x0, dtype=np.float64).copy()
    if prob.dq is not None:
        x = project_with_caps(x, prob.dq.caps(dq), avail)
    return x


# -- exhaustive oracle --------------------------------------------------------

def exhaustive_search(prob: PlacementProblem, granularity: int = 4,
                      max_states: int = 2_000_000, chunk: int = 4096,
                      engine: BatchedProblem | None = None) -> OptResult:
    """Enumerate placements on the grid x_{i,·} ∈ {k/granularity} — the
    discrete oracle the heuristics are tested against.  Exponential state
    count, but O(states / chunk) dispatches."""
    avail = prob.availability()
    n_states = count_grid_states(avail, granularity)
    if n_states > max_states:
        raise ValueError(f"search space {n_states} exceeds "
                         f"max_states={max_states}")
    eng, e0, d0 = _engine(prob, engine)
    dqs = _dq_grid(prob)
    best_F, best_x, best_dq = math.inf, None, 0.0
    for xs in chunked(grid_placements(avail, granularity), min(chunk, eng.chunk)):
        scores = eng.score_batch(xs, dqs)
        k = int(np.argmin(scores))
        i, d = divmod(k, scores.shape[1])
        if scores[i, d] < best_F:
            best_F, best_x, best_dq = float(scores[i, d]), xs[i], dqs[d]
    return OptResult.of(prob, best_x, best_dq, [best_F], eng.evals - e0,
                        dispatches=eng.dispatches - d0)


# -- greedy local descent -----------------------------------------------------

def greedy_transfer(prob: PlacementProblem, x0: np.ndarray | None = None,
                    deltas: tuple[float, ...] = (0.4, 0.2, 0.1, 0.05),
                    max_rounds: int = 60, dq0: float = 0.0,
                    engine: BatchedProblem | None = None) -> OptResult:
    """Move δ mass between device pairs while it improves exact F.

    Deterministic bottleneck chasing, one dispatch per (operator, round):
    operator i's whole transfer neighborhood is scored as a batch, the
    first-occurrence argmin reproduces the scalar loop's (u, v) scan order,
    and the winning move is re-checked with the float64 oracle before being
    applied.  DQ is co-optimized on a grid (including the incumbent
    ``dq0``) at each round."""
    avail = prob.availability()
    n_ops, _ = avail.shape
    dq = float(dq0)
    x = _start(prob, avail, x0, dq)
    eng, e0, d0 = _engine(prob, engine)
    best = prob.score(x, dq)
    history, scalar_evals = [best], 1
    for delta in deltas:
        for _ in range(max_rounds):
            improved = False
            for dq_cand in _dq_grid(prob, include=(dq,)):
                f = prob.score(x, dq_cand)
                scalar_evals += 1
                if f < best - 1e-12:
                    best, dq, improved = f, dq_cand, True
            for i in range(n_ops):
                cands = transfer_neighborhood(x, avail, i, delta)
                if not cands.shape[0]:
                    continue
                scores = eng.score_batch(cands, (dq,))[:, 0]
                k = int(np.argmin(scores))
                if scores[k] < best - 1e-12:
                    f = prob.score(cands[k], dq)
                    scalar_evals += 1
                    if f < best - 1e-12:
                        x, best, improved = cands[k], f, True
            history.append(best)
            if not improved:
                break
    return OptResult.of(prob, x, dq, history,
                        eng.evals - e0 + scalar_evals,
                        dispatches=eng.dispatches - d0)


# -- simulated annealing ------------------------------------------------------

def simulated_annealing(prob: PlacementProblem, rng: np.random.Generator,
                        steps: int = 4000, t0: float = 0.5, t1: float = 1e-3,
                        x0: np.ndarray | None = None, block: int = 64,
                        dq0: float = 0.0,
                        engine: BatchedProblem | None = None) -> OptResult:
    """Block simulated annealing: per dispatch, score a cumulative
    :func:`anneal_path` of ``block`` moves (the seed's move kernel: random
    mass transfers, DQ jumps when β > 0), then Metropolis-WALK the path —
    relative to the current state every path point is a symmetric
    random-walk composite, so up to ``block`` moves are accepted per
    dispatch and the chain length stays bounded by ``steps`` (not the
    dispatch count).  ``steps`` still counts proposals, so the temperature
    schedule is unchanged; dispatches collapse to ⌈steps / block⌉."""
    avail = prob.availability()
    dq = float(dq0)
    x = _start(prob, avail, x0, dq, rng=rng)
    eng, e0, d0 = _engine(prob, engine)
    cur = prob.score(x, dq)
    best, best_x, best_dq = cur, x.copy(), dq
    history, consumed = [cur], 0
    while consumed < steps:
        k = min(block, steps - consumed)
        cands, dqs_c = anneal_path(x, dq, avail, rng, k, prob.beta)
        scores = eng.score_pairs(cands, dqs_c)
        accepted_m = -1
        for m in range(k):
            t = t0 * (t1 / t0) ** ((consumed + m) / max(steps - 1, 1))
            f = float(scores[m])
            if math.isfinite(f) and (
                    f < cur
                    or rng.random() < math.exp(-(f - cur) / max(t, 1e-9))):
                x, dq, cur, accepted_m = cands[m], float(dqs_c[m]), f, m
                if cur < best:
                    best, best_x, best_dq = cur, x.copy(), dq
        # end-of-block downhill jump: the walk may have passed the block's
        # best point and then accepted an uphill composite — moving to the
        # argmin is a pure descent step (Metropolis accepts it with
        # probability 1), and it restores the seed's hill-climbing power
        # that pre-generated paths otherwise lose at low temperatures
        j = int(np.argmin(scores))
        if j != accepted_m and math.isfinite(scores[j]) and scores[j] < cur:
            x, dq, cur = cands[j], float(dqs_c[j]), float(scores[j])
            if cur < best:
                best, best_x, best_dq = cur, x.copy(), dq
        consumed += k
        history.append(best)
    return OptResult.of(prob, best_x, best_dq, history,
                        eng.evals - e0 + 1, dispatches=eng.dispatches - d0)


# -- vectorized random search -------------------------------------------------

def random_search(prob: PlacementProblem, rng: np.random.Generator,
                  n_candidates: int = 2048, sparsity: float = 0.5,
                  batch: int = 256,
                  engine: BatchedProblem | None = None) -> OptResult:
    """Score random placements × the full DQ grid in chunked dispatches.

    Candidate generation consumes the rng stream in the seed's order; the
    joint (placement × dq) grid is expanded analytically after each
    dispatch, and — unlike the seed loop — a multi-objective problem is
    selected on its weighted scalarization, not latency-F alone."""
    avail = prob.availability()
    n_ops, _ = avail.shape
    eng, e0, d0 = _engine(prob, engine)
    dqs = _dq_grid(prob)
    best_F, best_x, best_dq = math.inf, None, 0.0
    # seed with the uniform placement — never return something worse
    uni = uniform_placement(n_ops, avail)
    scores_u = eng.score_batch(uni[None], dqs)[0]
    d = int(np.argmin(scores_u))
    if scores_u[d] < best_F:
        best_F, best_x, best_dq = float(scores_u[d]), uni, dqs[d]
    history, done = [], 0
    while done < n_candidates:
        b = min(batch, n_candidates - done)
        xs = random_placements(avail, rng, b, sparsity)
        scores = eng.score_batch(xs, dqs)
        k = int(np.argmin(scores))
        i, d = divmod(k, scores.shape[1])
        if scores[i, d] < best_F:
            best_F, best_x, best_dq = float(scores[i, d]), xs[i], dqs[d]
        history.append(best_F)
        done += b
    if best_x is None:  # all infeasible — fall back to uniform
        best_x, best_dq = uni, 0.0
    return OptResult.of(prob, best_x, best_dq, history, eng.evals - e0,
                        dispatches=eng.dispatches - d0)
