"""Min–max robust search over scenario families — the decision layer's
scenario-facing entry points (formerly ``repro.sim.replay``; the old names
re-export these).

:func:`robust_placement` scores P candidates × S scenarios in one
``score_grid`` dispatch (structured RegionFleetFamily packing when the
fleets share a region layout — 10⁵-device families never materialize an
(S, V, V) tensor) and picks the candidate minimizing the worst-case score.

:func:`scenario_robust_search` wraps it with per-scenario greedy warm
starts and exact-oracle re-scoring, and — new in the search layer — can
CO-OPTIMIZE ``dq_fraction`` jointly with the placement
(``co_optimize_dq=True``): the raw latency grid is dispatched once, the
(S, P, D) dq expansion is analytic (:func:`repro.search.decision.
joint_dq_scores`), DQCoupling caps mask infeasible (candidate, dq) pairs,
and every scenario keeps its own best quality knob.
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.core.costmodel import CostConfig, latency, objective_F
from repro.core.devices import RegionFleet, RegionFleetFamily
from repro.core.graph import OpGraph
from repro.core.objectives import ObjectiveSet, as_objective_set
from repro.core.placement import random_placement, uniform_placement
from repro.search.decision import (dq_caps_mask, joint_dq_scores,
                                   robust_select, split_dq_term)
from repro.sim.batched import (BatchedEvaluator, pack_fleets,
                               pack_placements, pack_region_fleets,
                               pack_speeds)

__all__ = ["belief_robust_search", "belief_scenarios", "robust_placement",
           "scenario_robust_search"]


# above this many bytes of stacked float64 com matrices the dense fallback
# would OOM long before producing a useful error — refuse it instead
_DENSE_FALLBACK_MAX_BYTES = 2 ** 31


def _pack_scenario_fleets(scenarios):
    """Structured pack (RegionFleetFamily) when every fleet shares one
    region layout, dense (S, V, V) stack otherwise — the evaluator
    dispatches on the result's type."""
    fleets = [s.fleet for s in scenarios]
    if all(isinstance(f, RegionFleet) for f in fleets):
        try:
            return pack_region_fleets(fleets)
        except ValueError as e:
            # heterogeneous layouts — dense is the only stack left; at the
            # fleet sizes the structured path exists for, say so instead of
            # dying in an (S, V, V) allocation
            v = fleets[0].n_devices
            dense_bytes = len(fleets) * v * v * 8
            if dense_bytes > _DENSE_FALLBACK_MAX_BYTES:
                raise ValueError(
                    f"scenario fleets do not stack structurally ({e}); the "
                    f"dense fallback would materialize ~{dense_bytes / 1e9:.1f}"
                    f" GB of (S, V, V) com matrices — align the region "
                    f"layouts (e.g. region_scenario_batch) to stay on the "
                    f"structured path") from e
            warnings.warn(
                f"scenario fleets do not stack structurally ({e}); "
                f"falling back to the dense (S, V, V) path", RuntimeWarning,
                stacklevel=3)
    return pack_fleets(fleets)


def _candidates(graph: OpGraph, n_dev: int, rng: np.random.Generator,
                n_candidates: int, sparsity: float,
                extra: list[np.ndarray] | None) -> list[np.ndarray]:
    avail = np.ones((graph.n_ops, n_dev), dtype=bool)
    out = [uniform_placement(graph.n_ops, avail)]
    out += [random_placement(graph.n_ops, avail, rng, sparsity)
            for _ in range(max(n_candidates - 1, 0))]
    if extra:
        out += [np.asarray(x) for x in extra]
    return out


def robust_placement(graph: OpGraph, scenarios, rng: np.random.Generator,
                     n_candidates: int = 256,
                     cfg: CostConfig = CostConfig(), beta: float = 0.0,
                     dq: float | np.ndarray = 0.0, sparsity: float = 0.5,
                     extra_candidates: list[np.ndarray] | None = None,
                     use_pallas: bool | None = None,
                     objectives: ObjectiveSet | None = None):
    """Min–max what-if selection: the placement minimizing the worst-case
    score over the scenario batch.

    Scenario batches of RegionFleets sharing one region layout (e.g.
    ``region_scenario_batch``) are scored on the structured segment-sum path
    — no (S, V, V) com stack, so the family can hold 10⁵-device fleets.
    ``dq`` may be a scalar or per-scenario ``(S,)`` (scenario s's quality
    knob divides its row of the grid).

    ``objectives=None`` scores F alone (paper eq. 8); an ObjectiveSet makes
    the score the weighted §3.1 scalarization — every objective's grid and
    the weighted sum still come from ONE dispatch, so the min–max can trade
    worst-case F against WAN bytes moved or occupancy skew.  On the dense
    fallback the fleets' effective speeds are packed alongside the com stack
    so the occupancy objectives see stragglers.

    Returns ``(x_best, worst_score, grid)`` where grid is the full (S, P)
    score matrix (the weighted scalarization when multi-objective; useful
    for regret analysis: column min vs row min)."""
    if not scenarios:
        raise ValueError("need at least one scenario")
    candidates = _candidates(graph, scenarios[0].n_devices, rng,
                             n_candidates, sparsity, extra_candidates)
    ev = BatchedEvaluator(graph, cfg, use_pallas=use_pallas)
    pack = _pack_scenario_fleets(scenarios)
    speed = None
    if objectives is not None and not isinstance(pack, RegionFleetFamily):
        speed = pack_speeds([s.fleet for s in scenarios])
    res = ev.score_grid(pack_placements(candidates), pack,
                        dq=dq, beta=beta, objectives=objectives, speed=speed)
    grid = np.asarray(res if objectives is None else res.scalarized)  # (S, P)
    k, worst = robust_select(grid)
    return candidates[k], float(worst[k]), grid


def _joint_robust_placement(graph: OpGraph, scenarios,
                            candidates: list[np.ndarray],
                            cfg: CostConfig, beta: float,
                            dq_values: np.ndarray, dq_coupling,
                            objectives: ObjectiveSet | None,
                            use_pallas: bool | None = None):
    """Joint (placement × dq) min–max: ONE raw dispatch at dq = 0, then the
    analytic per-scenario dq expansion.  Returns
    ``(x_best, worst, scores (S, P), dq_sel (S,) for the winner)``."""
    ev = BatchedEvaluator(graph, cfg, use_pallas=use_pallas)
    pack = _pack_scenario_fleets(scenarios)
    placements = pack_placements(candidates)
    if objectives is None:
        raw = ev.score_grid(placements, pack, dq=0.0, beta=0.0)
    else:
        speed = None if isinstance(pack, RegionFleetFamily) \
            else pack_speeds([s.fleet for s in scenarios])
        raw = ev.score_grid(placements, pack, dq=0.0, beta=0.0,
                            objectives=objectives, speed=speed)
    lat, rest, w_lat = split_dq_term(raw)
    feasible = dq_caps_mask(np.stack([np.asarray(x) for x in candidates]),
                            dq_values, dq_coupling)
    scores, dq_idx = joint_dq_scores(lat, dq_values, beta, rest=rest,
                                     w_lat=w_lat, feasible=feasible)
    k, worst = robust_select(scores)
    return candidates[k], float(worst[k]), scores, dq_values[dq_idx[:, k]]


def belief_scenarios(belief, base_fleet, rng: np.random.Generator,
                     n_scenarios: int, graph: OpGraph | None = None,
                     beta: float = 0.0) -> list:
    """Scenario batch drawn from a belief posterior
    (:class:`repro.belief.BeliefState`): scenario 0 is the believed fleet
    itself (the posterior mode must stay in the min–max so belief sampling
    can never score WORSE than point-estimate search on the belief's own
    world), scenarios 1..n−1 apply posterior-sampled per-device slowdowns.

    This replaces fixed-jitter ``perturbed_fleet`` copies: a well-observed
    device barely varies across the batch while a never-observed one swings
    with its full prior spread — the min–max hedges exactly where the
    belief is actually uncertain."""
    from repro.sim.scenarios import Scenario

    fleets = [base_fleet]
    if n_scenarios > 1:
        fleets += belief.sample_fleets(base_fleet, rng, n_scenarios - 1)
    g = graph
    return [Scenario(name=f"belief{k}", graph=g, fleet=f, trace=[],
                     beta=beta) for k, f in enumerate(fleets)]


def belief_robust_search(graph: OpGraph, belief, base_fleet,
                         rng: np.random.Generator, n_scenarios: int = 4,
                         **kwargs):
    """:func:`scenario_robust_search` with the scenario family sampled from
    a belief posterior instead of supplied — min–max robust selection whose
    hedging budget follows the posterior variance.  ``kwargs`` pass through
    (n_candidates, beta, objectives, co_optimize_dq, ...)."""
    scenarios = belief_scenarios(belief, base_fleet, rng, n_scenarios,
                                 graph=graph,
                                 beta=float(kwargs.get("beta", 0.0)))
    return scenario_robust_search(graph, scenarios, rng, **kwargs)


def scenario_robust_search(graph: OpGraph, scenarios,
                           rng: np.random.Generator, n_candidates: int = 512,
                           cost_cfg: CostConfig = CostConfig(),
                           beta: float = 0.0,
                           dq: float | np.ndarray = 0.0,
                           sparsity: float = 0.5, warm_start: bool = True,
                           objectives: ObjectiveSet | None = None,
                           co_optimize_dq: bool = False, dq_steps: int = 5,
                           dq_coupling=None):
    """Optimizer-grade wrapper around :func:`robust_placement`.

    Random candidates are scored against every scenario fleet in one
    batched dispatch (structured when the fleets share a region layout);
    ``warm_start`` additionally seeds per-scenario greedy optima (each
    scenario's best placement competes for the min–max crown — cheap and
    often the winner when one fleet dominates the worst case).

    ``dq`` may be a scalar or a per-scenario ``(S,)`` array (scenario s runs
    its own quality knob).  The returned OptResult's F/latency/dq_fraction
    are for the worst-case scenario of the winning placement, recomputed
    with the exact oracle — and the worst case is the scenario maximizing
    the score (**F**, not latency: with per-scenario dq the (1 + β·dq_s)
    denominators differ, so the largest latency need not be the binding
    scenario).

    With an ``objectives`` ObjectiveSet the whole loop goes multi-objective:
    warm-start greedy seeds descend the weighted scalarization, the grid is
    the scalarized (S, P) matrix, and the reported F is the worst-case
    scenario's scalarized score (latency stays that scenario's raw
    critical-path latency).

    ``co_optimize_dq=True`` searches the dq grid (``dq_steps`` intervals,
    always containing the incumbent ``dq`` values) JOINTLY with the
    placement, per scenario: the raw grid is still one dispatch, each
    (scenario, candidate) cell keeps its best feasible quality knob
    (``dq_coupling`` — a :class:`repro.core.optimizers.DQCoupling` — masks
    (candidate, dq) pairs whose caps are violated), and the min–max runs on
    the co-optimized scores.

    Also reachable as ``repro.core.scenario_robust_search`` and
    ``repro.sim.replay.scenario_robust_search`` (delegators — the
    implementation lives in the search layer).
    """
    from repro.core.optimizers import (DQCoupling, OptResult,  # noqa: F401
                                       PlacementProblem, greedy_transfer)
    from repro.search.candidates import dq_grid as make_dq_grid

    obj_set = None if objectives is None else as_objective_set(objectives)
    dq_s = np.broadcast_to(np.asarray(dq, dtype=np.float64),
                           (len(scenarios),))
    extra, n_dispatches = [], 1   # the robust grid itself is ONE dispatch
    if warm_start:
        for s in scenarios[: min(len(scenarios), 4)]:
            prob = PlacementProblem(graph, s.fleet, cost_cfg, beta=beta,
                                    dq=dq_coupling if co_optimize_dq else None,
                                    objectives=obj_set)
            seed = greedy_transfer(prob, max_rounds=10)
            extra.append(seed.x)
            n_dispatches += seed.dispatches
    if co_optimize_dq:
        candidates = _candidates(graph, scenarios[0].n_devices, rng,
                                 n_candidates, sparsity, extra)
        dq_values = make_dq_grid(beta, steps=dq_steps, include=tuple(dq_s))
        x, worst_F, grid, dq_sel = _joint_robust_placement(
            graph, scenarios, candidates, cost_cfg, beta, dq_values,
            dq_coupling, obj_set)
        dq_s = dq_sel
        n_evals = int(grid.size) * dq_values.size
    else:
        x, worst_F, grid = robust_placement(
            graph, scenarios, rng, n_candidates=n_candidates, cfg=cost_cfg,
            beta=beta, dq=dq_s, sparsity=sparsity, extra_candidates=extra,
            objectives=obj_set)
        n_evals = int(np.asarray(grid).size)
    # worst-case scenario of the winner via the exact oracle (independent of
    # the grid's candidate ordering), picked by the scenario score so
    # per-scenario dq denominators participate in the max
    lats = [latency(graph, s.fleet, x, cost_cfg) for s in scenarios]
    if obj_set is None:
        fs = [objective_F(lat, float(d), beta) for lat, d in zip(lats, dq_s)]
    else:
        fs = [obj_set.scalar_total(graph, s.fleet, x, float(d), beta,
                                   cost_cfg)
              for s, d in zip(scenarios, dq_s)]
    k = int(np.argmax(fs))
    return OptResult(x=x, dq_fraction=float(dq_s[k]), F=fs[k],
                     latency=lats[k], history=[worst_F], evals=n_evals,
                     dispatches=n_dispatches)
