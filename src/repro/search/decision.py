"""Layer 3 — decision: turn scored grids into choices.

Inputs are the (S, P) per-objective grids a single
``BatchedEvaluator.score_grid`` dispatch returns (an
:class:`repro.core.objectives.ObjectiveGrids`) or plain (P, K) value
matrices; outputs are selections:

  * :func:`robust_select`        — min–max: worst scenario per candidate,
    argmin over candidates (the decision rule of ``robust_placement``);
  * :func:`joint_dq_scores`      — per-scenario DQ co-optimization: expand
    the dq axis analytically, mask DQCoupling-infeasible (candidate, dq)
    pairs, and return each (scenario, candidate) cell's best-dq score plus
    the chosen dq index;
  * :func:`pareto_front`         — non-dominated extraction over ≥2
    objectives: the weighted sum is one point per weight vector, but the
    per-objective grids already hold the whole front;
  * :func:`epsilon_constraint`   — minimize one objective subject to caps
    (ε) on the others, from the same per-objective grids; ε = ∞ on every
    other objective reduces to the single-objective argmin;
  * :class:`ObjectiveScales`     — automatic objective normalization: fit
    per-objective (offset, scale) from the sampled grid (min/range), so
    scalarization weights become dimensionless trade-off knobs instead of
    raw unit exchange rates.  Min/range is positive-affine-equivariant,
    which makes equal-weight normalized selection invariant under rescaling
    any one objective (property-tested).

Everything here is plain numpy on already-computed grids — no dispatches.
All objectives are minimized.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "ParetoFront",
    "ObjectiveScales",
    "candidate_values",
    "epsilon_constraint",
    "pareto_mask",
    "pareto_front",
    "scalarize",
    "robust_select",
    "split_dq_term",
    "dq_caps_mask",
    "joint_dq_scores",
]


# -- grid → per-candidate objective vectors -----------------------------------

def candidate_values(grids, scenario="worst") -> np.ndarray:
    """(P, K) objective vectors from an :class:`ObjectiveGrids`.

    ``scenario`` picks the row: an int takes that scenario's (P, K) slice;
    ``"worst"`` takes the per-objective max over scenarios — the
    conservative envelope the min–max decision rule already optimizes, so
    fronts extracted from it are robust trade-off menus."""
    cols = []
    for name in grids.names:
        g = np.asarray(grids.grids[name], dtype=np.float64)  # (S, P)
        cols.append(g.max(axis=0) if scenario == "worst"
                    else g[int(scenario)])
    return np.stack(cols, axis=1)


# -- Pareto extraction --------------------------------------------------------

def pareto_mask(values: np.ndarray) -> np.ndarray:
    """(P,) boolean — True where no other point dominates (minimization:
    ``y`` dominates ``x`` iff ``y ≤ x`` everywhere and ``y < x`` somewhere).
    Duplicates of a front point are all kept (they tie, neither dominates).
    O(P²) worst case, but each eliminated point is skipped as a pivot."""
    v = np.asarray(values, dtype=np.float64)
    if v.ndim != 2:
        raise ValueError(f"values must be (P, K), got {v.shape}")
    n = v.shape[0]
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominated = (v >= v[i]).all(axis=1) & (v > v[i]).any(axis=1)
        mask &= ~dominated
    return mask


@dataclasses.dataclass(frozen=True)
class ParetoFront:
    """Non-dominated candidates: ``indices`` into the scored placement
    batch, their ``values`` (M, K), and the objective ``names`` labelling
    the columns.  Rows are sorted by the first objective."""

    indices: np.ndarray
    values: np.ndarray
    names: tuple[str, ...]

    def __len__(self) -> int:
        return int(self.indices.size)

    def __iter__(self):
        for i in range(len(self)):
            yield int(self.indices[i]), self.values[i]


def pareto_front(grids_or_values, scenario="worst",
                 names: tuple[str, ...] | None = None) -> ParetoFront:
    """Extract the non-dominated set from an ObjectiveGrids (one score_grid
    dispatch holds the entire front) or a plain (P, K) value matrix."""
    if hasattr(grids_or_values, "grids"):
        values = candidate_values(grids_or_values, scenario)
        names = tuple(grids_or_values.names)
    else:
        values = np.asarray(grids_or_values, dtype=np.float64)
        names = tuple(names) if names is not None else \
            tuple(f"objective_{k}" for k in range(values.shape[1]))
    idx = np.flatnonzero(pareto_mask(values))
    order = np.argsort(values[idx, 0], kind="stable")
    idx = idx[order]
    return ParetoFront(indices=idx, values=values[idx], names=names)


# -- automatic objective normalization ----------------------------------------

@dataclasses.dataclass(frozen=True)
class ObjectiveScales:
    """Per-objective affine normalization ``(v − offset) / scale`` fit from
    a sampled grid (offset = min, scale = range).

    Because min and range are equivariant under ``v ↦ c·v`` (c > 0), the
    normalized values — and therefore any weighted selection over them —
    are invariant to rescaling an objective's units; weights act as
    dimensionless trade-off knobs on [0, 1]-ish normalized axes."""

    names: tuple[str, ...]
    offset: np.ndarray  # (K,)
    scale: np.ndarray   # (K,) strictly positive

    @classmethod
    def fit(cls, grids_or_values,
            names: tuple[str, ...] | None = None) -> "ObjectiveScales":
        """Fit from an ObjectiveGrids — pooling every (scenario, candidate)
        cell; to fit from one scenario's slice or the worst-case envelope,
        pass ``candidate_values(grids, scenario)`` instead — or from a
        plain (P, K) value matrix.

        Degenerate grids are well-defined, never a zero divide: an
        objective constant over the sample (max == min) gets scale 1 with
        offset = that constant, so every normalized value is exactly 0 and
        the objective contributes nothing to a normalized scalarization
        (which keeps the scale-invariance property).  Non-finite cells
        (±inf from feasibility masks, NaN) are ignored by the fit — and an
        objective with NO finite cell at all normalizes through (offset 0,
        scale 1), passing its ±inf through unchanged.  An empty sample
        (zero rows) raises — there is nothing to fit."""
        if hasattr(grids_or_values, "grids"):
            names = tuple(grids_or_values.names)
            values = np.stack(
                [np.asarray(grids_or_values.grids[n],
                            dtype=np.float64).ravel()
                 for n in names], axis=1)
        else:
            values = np.asarray(grids_or_values, dtype=np.float64)
            if values.ndim != 2:
                raise ValueError(f"values must be 2-D, got {values.shape}")
            names = tuple(names) if names is not None else \
                tuple(f"objective_{k}" for k in range(values.shape[1]))
        if values.shape[0] == 0:
            raise ValueError("cannot fit ObjectiveScales from an empty "
                             "sample (zero rows)")
        # explicit masked min/max — no all-NaN-slice RuntimeWarnings, no
        # nan/0 ranges to divide by later
        finite = np.isfinite(values)
        any_finite = finite.any(axis=0)
        lo = np.where(any_finite,
                      np.min(np.where(finite, values, np.inf), axis=0), 0.0)
        hi = np.where(any_finite,
                      np.max(np.where(finite, values, -np.inf), axis=0), 0.0)
        span = hi - lo
        return cls(names=names, offset=lo,
                   scale=np.where(span > 0.0, span, 1.0))

    def apply(self, values: np.ndarray) -> np.ndarray:
        """Normalize (…, K) objective values (∞ passes through as ∞)."""
        return (np.asarray(values, dtype=np.float64) - self.offset) \
            / self.scale


def scalarize(values: np.ndarray, weights,
              scales: ObjectiveScales | None = None) -> np.ndarray:
    """(P,) weighted sum over (P, K) objective values, optionally on the
    normalized axes (``scales``) so the weights are dimensionless."""
    v = np.asarray(values, dtype=np.float64)
    if scales is not None:
        v = scales.apply(v)
    return v @ np.asarray(weights, dtype=np.float64)


# -- ε-constraint selection ---------------------------------------------------

def epsilon_constraint(grids_or_values, minimize: str | int,
                       caps: dict[str, float] | None = None,
                       scenario="worst",
                       names: tuple[str, ...] | None = None,
                       atol: float = 0.0) -> tuple[int, np.ndarray]:
    """Minimize ONE objective subject to caps (ε) on the others — the
    classic ε-constraint scalarization, next to the weighted sum.

    Where a weighted scalarization asks "what is one unit of WAN traffic
    worth in latency?", the ε-constraint asks the question operators
    actually pose: "minimize latency, but never move more than ε bytes".
    It reuses the per-objective (S, P) grids ONE ``score_grid`` dispatch
    already produced (an :class:`~repro.core.objectives.ObjectiveGrids`,
    or a plain (P, K) value matrix with ``names``) — no extra dispatches,
    same as :func:`pareto_front`.

    ``minimize`` is an objective name (or column index); ``caps`` maps
    other objective names to their ε bounds — objectives absent from
    ``caps`` are unconstrained (ε = ∞), so ``caps=None`` reduces exactly
    to the single-objective argmin over the ``minimize`` column (property
    tested).  ``scenario`` picks the row like :func:`candidate_values`
    ("worst" = the conservative envelope, an int = that scenario).

    Returns ``(index, masked (P,) scores)`` where infeasible candidates
    hold +inf and ``index`` is the first-occurrence argmin.  When NO
    candidate satisfies every cap, every score is +inf and ``index`` is 0
    — callers distinguish "infeasible" via ``np.isinf(scores[index])``
    (the serving layer turns that into a typed response)."""
    if hasattr(grids_or_values, "grids"):
        values = candidate_values(grids_or_values, scenario)
        names = tuple(grids_or_values.names)
    else:
        values = np.asarray(grids_or_values, dtype=np.float64)
        if values.ndim != 2:
            raise ValueError(f"values must be (P, K), got {values.shape}")
        names = tuple(names) if names is not None else \
            tuple(f"objective_{k}" for k in range(values.shape[1]))
    if isinstance(minimize, str):
        if minimize not in names:
            raise ValueError(f"minimize={minimize!r} not among {names}")
        k_min = names.index(minimize)
    else:
        k_min = int(minimize)
        if not 0 <= k_min < len(names):
            raise ValueError(f"minimize index {k_min} out of range "
                             f"for {len(names)} objectives")
    caps = dict(caps or {})
    unknown = set(caps) - set(names)
    if unknown:
        raise ValueError(f"caps name unknown objectives {sorted(unknown)}; "
                         f"choose from {names}")
    if names[k_min] in caps:
        raise ValueError(f"cannot cap the minimized objective "
                         f"{names[k_min]!r} — drop it from caps")
    cap_vec = np.array([caps.get(n, np.inf) for n in names],
                      dtype=np.float64)
    # a cap of +inf is satisfied by any finite value AND by +inf cells
    # (an unconstrained objective can never infeasible-ize a candidate)
    with np.errstate(invalid="ignore"):
        ok = (values <= cap_vec[None, :] + atol) | np.isinf(cap_vec)[None, :]
    feasible = ok.all(axis=1)
    scores = np.where(feasible, values[:, k_min], np.inf)
    return int(np.argmin(scores)), scores


# -- min–max robust selection -------------------------------------------------

def robust_select(grid: np.ndarray) -> tuple[int, np.ndarray]:
    """Min–max over an (S, P) score grid: returns (argmin candidate index,
    (P,) worst-case scores).  First occurrence wins ties."""
    g = np.asarray(grid, dtype=np.float64)
    worst = g.max(axis=0)
    return int(np.argmin(worst)), worst


# -- splitting a raw grid into its dq-dependent and dq-free parts -------------

def split_dq_term(raw_result):
    """Split a RAW ``score_grid`` result (dispatched at dq = 0, β = 0) into
    ``(lat, rest, w_lat)`` with ``score = rest + w_lat·lat/(1 + β·dq)``.

    Only latency-F's ``finish`` depends on dq (paper eq. 8); every other
    §3.1 objective is dq-independent, which is what makes the joint
    (placement × dq) axis analytic.  ``raw_result`` is either the plain
    latency grid (single-objective: rest = 0, w_lat = 1) or an
    :class:`ObjectiveGrids` (its own names/weights locate the latency
    term).  Shapes pass through unchanged ((S, P), (P,), …)."""
    if not hasattr(raw_result, "grids"):
        lat = np.asarray(raw_result, dtype=np.float64)
        return lat, np.zeros_like(lat), 1.0
    scal = np.asarray(raw_result.scalarized, dtype=np.float64)
    w_lat = dict(zip(raw_result.names,
                     raw_result.weights)).get("latency_f", 0.0)
    if "latency_f" in raw_result.names:
        lat = np.asarray(raw_result.grids["latency_f"], dtype=np.float64)
    else:
        lat = np.zeros_like(scal)
    return lat, scal - w_lat * lat, w_lat


def dq_caps_mask(placements, dq_values, coupling,
                 atol: float = 1e-7) -> np.ndarray | None:
    """(P, D) DQCoupling feasibility — the vectorized twin of
    ``PlacementProblem.feasible``: per-device column mass ≤ cap0 − dq·load.
    None coupling ⇒ None (everything feasible)."""
    if coupling is None:
        return None
    col = np.asarray(placements, dtype=np.float64).sum(axis=1)   # (P, V)
    dq_values = np.atleast_1d(np.asarray(dq_values, dtype=np.float64))
    caps = (np.asarray(coupling.cap0, dtype=np.float64)[None, :]
            - dq_values[:, None]
            * np.asarray(coupling.load, dtype=np.float64)[None, :])
    return (col[:, None, :] <= caps[None, :, :] + atol).all(axis=-1)


# -- per-scenario DQ co-optimization ------------------------------------------

def joint_dq_scores(lat: np.ndarray, dq_values: np.ndarray, beta: float,
                    rest: np.ndarray | None = None, w_lat: float = 1.0,
                    feasible: np.ndarray | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Co-optimize ``dq_fraction`` per (scenario, candidate) cell.

    ``lat`` is the raw (S, P) latency grid (ONE dispatch, dq-independent);
    the full (S, P, D) score tensor ``rest + w_lat·lat/(1 + β·dq_d)`` is
    expanded analytically, ``feasible`` ((P, D), DQCoupling caps) masks
    infeasible pairs with +inf, and each cell keeps its best dq.  Returns
    ``(scores (S, P), dq_idx (S, P))`` — feed ``scores`` to
    :func:`robust_select` for min–max with a per-scenario quality knob."""
    lat = np.asarray(lat, dtype=np.float64)
    dq_values = np.asarray(dq_values, dtype=np.float64)
    denom = 1.0 + float(beta) * dq_values                    # (D,)
    cube = w_lat * lat[:, :, None] / denom[None, None, :]    # (S, P, D)
    if rest is not None:
        cube = cube + np.asarray(rest, dtype=np.float64)[:, :, None]
    if feasible is not None:
        cube = np.where(np.asarray(feasible, dtype=bool)[None, :, :],
                        cube, np.inf)
    dq_idx = np.argmin(cube, axis=2)
    return np.take_along_axis(cube, dq_idx[:, :, None], axis=2)[:, :, 0], \
        dq_idx
