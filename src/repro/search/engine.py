"""Layer 2 — batched scoring: every candidate batch goes through
``BatchedEvaluator.score_grid`` in O(dispatches), not O(candidates).

:class:`BatchedProblem` wraps one :class:`repro.core.optimizers.
PlacementProblem` and exposes ``score_batch(placements, dqs) -> (P, D)``
— the exact quantity ``prob.score`` returns, for a whole candidate batch
crossed with a whole DQ grid, from ONE jitted dispatch per chunk:

  * the fleet is packed once — an ExplicitFleet as a (1, V, V) dense com
    stack, a RegionFleet as an S=1 :class:`RegionFleetFamily` so 10⁵-device
    problems never materialize V×V;
  * the evaluator scores the batch at dq = 0 (raw latency / raw objective
    grids); DQ only enters through the analytic ``/(1 + β·dq)`` factor on
    the latency-F term, so the (P, D) joint grid is expanded AFTER the
    dispatch at numpy cost — ``dq_fraction`` becomes a free search
    dimension;
  * DQCoupling feasibility (caps(dq) = cap0 − dq·load ≥ column mass) is a
    vectorized (P, D) mask applied as +inf, mirroring ``prob.score``'s
    infeasible-⇒-inf convention;
  * multi-objective problems split the scalarization into the latency-F
    term (dq-dependent) and the rest (dq-independent), both from the same
    fused ``ObjectiveSet`` dispatch.

Scoring is float32 on the batched path (the evaluator's precision); the
searchers re-score their winners through the float64 oracle before
reporting, so returned objectives match the scalar loop to ≤1e-5 relative.

Problems with ``cfg.include_compute`` fall back to a scalar ``prob.score``
loop — the batched evaluator covers the paper-faithful model only — so
every searcher keeps working on compute-extension problems (e.g. the
StreamingEngine's re-optimization path), just without the batching win.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis import sanitize
from repro.core.devices import ExplicitFleet, RegionFleet, RegionFleetFamily
from repro.core.optimizers import PlacementProblem
from repro.search.decision import dq_caps_mask, split_dq_term
from repro.sim.batched import BatchedEvaluator, pack_placements

__all__ = ["BatchedProblem"]


def _bucket(n: int) -> int:
    """Next power of two — candidate batches are padded up to buckets so
    varying neighborhood sizes don't retrace the jitted grid per shape."""
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass
class BatchedProblem:
    """Batched twin of ``PlacementProblem.score`` for candidate batches.

    ``evals`` counts logical candidate evaluations (what the seed's scalar
    loops counted); ``dispatches`` counts jitted device dispatches — the
    O(candidates) → O(dispatches) collapse the search layer exists for.
    """

    prob: PlacementProblem
    chunk: int = 4096
    use_pallas: bool | None = None
    # an already-built evaluator to reuse (same graph/cfg): callers that
    # re-solve the same problem shape against CHANGING fleets — the
    # closed-loop controller re-optimizing after every recalibration — keep
    # one evaluator so its jitted grid functions compile once, not per
    # reconfiguration (the fleet pack is data, not part of the trace).
    # None ⇒ BatchedEvaluator.shared(): equal-content problems across
    # BatchedProblem instances resolve to ONE evaluator through the
    # process-wide executable cache (repro.sim.execache), so a second
    # engine over an identically-specified problem never recompiles
    evaluator: BatchedEvaluator | None = None

    def __post_init__(self):
        self.evals = 0
        self.dispatches = 0
        # shape buckets this instance has dispatched (telemetry: the first
        # dispatch of an unseen padded size is a compilation-cache miss —
        # a silent retrace unless the evaluator was warmed on that bucket)
        self._seen_buckets: set[int] = set()
        self.scalar_fallback = self.prob.cost_cfg.include_compute
        if self.scalar_fallback:
            return
        self._ev = self.evaluator if self.evaluator is not None else \
            BatchedEvaluator.shared(self.prob.graph, self.prob.cost_cfg,
                                    use_pallas=self.use_pallas)
        fleet = self.prob.fleet
        if isinstance(fleet, RegionFleet):
            self._pack = RegionFleetFamily.from_fleets([fleet])
            self._speed = None  # structured families carry their own speeds
        elif isinstance(fleet, ExplicitFleet):
            self._pack = jnp.asarray(fleet.com_matrix(),
                                     jnp.float32)[None, :, :]
            self._speed = fleet.effective_speed()
        else:
            raise TypeError(f"unsupported fleet type {type(fleet).__name__}")
        obj = self.prob.objectives
        self._w_lat = 1.0
        if obj is not None:
            self._w_lat = dict(zip(obj.names, obj.weights)).get(
                "latency_f", 0.0)

    # -- raw batched values ---------------------------------------------------
    def _raw_chunk(self, xs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """One padded chunk through score_grid at dq = 0: (latency (B,),
        dq-independent scalarization remainder (B,))."""
        b = xs.shape[0]
        bucket = _bucket(b)
        pad = bucket - b
        if pad:
            xs = np.concatenate([xs, np.repeat(xs[:1], pad, axis=0)])
        placements = pack_placements(list(xs))
        obj = self.prob.objectives
        self.dispatches += 1
        first = bucket not in self._seen_buckets
        reg = obs.registry()
        if reg.enabled:
            reg.counter("search.dispatches").add(1)
            reg.counter("search.candidates").add(b)
            reg.histogram("search.candidates_per_dispatch", lo=1.0).observe(b)
            if first:
                # a fresh padded shape: this dispatch retraces/compiles
                # (visible as jax.compiles too, but this names the bucket)
                reg.counter("search.bucket_first_dispatch",
                            bucket=str(bucket)).add(1)
        self._seen_buckets.add(bucket)
        if first and sanitize.state().enabled:
            # same event the telemetry meters — trips the retrace budget
            sanitize.note_first_dispatch(bucket)
        if obj is None:
            raw = self._ev.score_grid(placements, self._pack,
                                      dq=0.0, beta=0.0, guard_output=False)
        else:
            speed = None if self._speed is None or \
                isinstance(self._pack, RegionFleetFamily) else self._speed
            raw = self._ev.score_grid(placements, self._pack, dq=0.0,
                                      beta=0.0, objectives=obj, speed=speed,
                                      guard_output=False)
        lat, rest, _ = split_dq_term(raw)       # (1, B) grids, S == 1
        return lat[0, :b], rest[0, :b]

    def raw_values(self, placements: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """(latency (P,), dq-independent remainder (P,)) over chunked
        dispatches.  ``score = rest + w_lat · lat / (1 + β·dq)``."""
        xs = np.asarray(placements, dtype=np.float64)
        lats, rests = [], []
        for lo in range(0, xs.shape[0], self.chunk):
            lat, rest = self._raw_chunk(xs[lo:lo + self.chunk])
            lats.append(lat)
            rests.append(rest)
        lat_all, rest_all = np.concatenate(lats), np.concatenate(rests)
        san = sanitize.state()
        if san.enabled and san.nan_check:
            # guard AFTER the host transfer concatenate already forces —
            # checking per chunk inside _raw_chunk would sync the device
            # early and forfeit async-dispatch overlap (measurably slower
            # than the check itself)
            self._guard_outputs(lat_all, rest_all)
        return lat_all, rest_all

    def _guard_outputs(self, lat: np.ndarray, rest: np.ndarray) -> None:
        """NaN guard on the assembled raw values; the offending chunk's
        shape bucket is recovered from the first NaN index (error path
        only — the clean path is two ``isnan().any()`` host scans)."""
        for name, arr in (("score_batch.latency", lat),
                          ("score_batch.rest", rest)):
            s = float(arr.sum()) if arr.size else 0.0
            if s == s:          # NaN anywhere poisons the sum
                continue
            if np.isnan(arr).any():
                idx = int(np.isnan(arr).argmax())
                lo = (idx // self.chunk) * self.chunk
                bucket = _bucket(min(arr.shape[0] - lo, self.chunk))
                sanitize.check_finite(name, arr, bucket=bucket)

    # -- feasibility ----------------------------------------------------------
    def feasible_mask(self, placements: np.ndarray,
                      dqs: np.ndarray) -> np.ndarray:
        """(P, D) DQCoupling feasibility — the vectorized twin of
        ``prob.feasible`` (:func:`repro.search.decision.dq_caps_mask`)."""
        mask = dq_caps_mask(placements, dqs, self.prob.dq)
        if mask is None:
            return np.ones((placements.shape[0], dqs.shape[0]), dtype=bool)
        return mask

    # -- the joint (placement × dq) score grid --------------------------------
    def score_batch(self, placements, dqs) -> np.ndarray:
        """(P, D) problem scores (∞ where infeasible) — ``prob.score`` for
        every (candidate, dq) pair of the cross product.

        The candidate batch is validated UP FRONT: a bad dtype or shape
        would otherwise dispatch into a fresh shape bucket and surface as
        an opaque retrace (or an XLA error); instead a typed
        :class:`repro.analysis.AnalysisError` names the offending bucket.
        """
        xs = np.asarray(placements)
        san = sanitize.state()
        # NaN placement mass is caught by the (cheaper) output nan-guard
        # in _raw_chunk when the sanitizer is armed
        sanitize.check_placements(
            xs, self.prob.graph.n_ops, self.prob.fleet.n_devices,
            bucket=_bucket(min(xs.shape[0] if xs.ndim >= 3 else 1,
                               self.chunk)))
        xs = xs.astype(np.float64, copy=False)
        if xs.ndim == 2:
            xs = xs[None]
        dq_arr = np.atleast_1d(np.asarray(dqs, dtype=np.float64))
        if san.enabled and san.domain_check:
            sanitize.check_dq(dq_arr)
        P, D = xs.shape[0], dq_arr.shape[0]
        self.evals += P * D
        if self.scalar_fallback:
            return np.array([[self.prob.score(x, float(d)) for d in dq_arr]
                             for x in xs])
        with obs.span("search.score_batch", P=P, D=D):
            lat, rest = self.raw_values(xs)
        denom = 1.0 + self.prob.beta * dq_arr                      # (D,)
        scores = rest[:, None] + self._w_lat * lat[:, None] / denom[None, :]
        return np.where(self.feasible_mask(xs, dq_arr), scores, np.inf)

    def score_pairs(self, placements, dqs) -> np.ndarray:
        """(P,) problem scores for PAIRED (candidate_i, dq_i) inputs — one
        dq per candidate (e.g. an annealing path whose quality knob moves
        along the walk), so ``evals`` counts P, not a P×D cross product."""
        xs = np.asarray(placements)
        san = sanitize.state()
        sanitize.check_placements(
            xs, self.prob.graph.n_ops, self.prob.fleet.n_devices,
            bucket=_bucket(min(xs.shape[0] if xs.ndim >= 3 else 1,
                               self.chunk)))
        xs = xs.astype(np.float64, copy=False)
        dq_arr = np.broadcast_to(
            np.asarray(dqs, dtype=np.float64), (xs.shape[0],))
        if san.enabled and san.domain_check:
            sanitize.check_dq(dq_arr)
        self.evals += xs.shape[0]
        if self.scalar_fallback:
            return np.array([self.prob.score(x, float(d))
                             for x, d in zip(xs, dq_arr)])
        lat, rest = self.raw_values(xs)
        scores = rest + self._w_lat * lat / (1.0 + self.prob.beta * dq_arr)
        if self.prob.dq is None:
            return scores
        col = xs.sum(axis=1)                                       # (P, V)
        caps = (np.asarray(self.prob.dq.cap0, dtype=np.float64)[None, :]
                - dq_arr[:, None] * np.asarray(self.prob.dq.load,
                                               dtype=np.float64)[None, :])
        feas = (col <= caps + 1e-7).all(axis=-1)                   # (P,)
        return np.where(feas, scores, np.inf)

    def best(self, placements, dqs) -> tuple[int, int, float]:
        """First-occurrence argmin over the (P, D) grid in candidate-major
        order — the seed loops' scan order — as (cand_idx, dq_idx, score)."""
        scores = self.score_batch(placements, dqs)
        k = int(np.argmin(scores))
        i, d = divmod(k, scores.shape[1])
        return i, d, float(scores[i, d])
