"""Unified batched search subsystem: candidates → batched scoring →
decision (see search/README.md for the layer diagram and how the seed's
scalar-loop optimizers map onto it).

Layer 1 (:mod:`repro.search.candidates`) emits *batches* of
(placement, dq) proposals; Layer 2 (:mod:`repro.search.engine`) scores each
batch through ``BatchedEvaluator.score_grid`` in one jitted dispatch per
chunk — O(dispatches) instead of O(candidates) evaluator calls; Layer 3
(:mod:`repro.search.decision`, :mod:`repro.search.robust`) turns grids into
choices: weighted scalarization (optionally on auto-normalized objective
axes), min–max robust selection, Pareto-front extraction, and per-scenario
DQ co-optimization.

The seed entry points (``repro.core.optimizers.{exhaustive_search,
greedy_transfer, simulated_annealing, random_search}``,
``repro.sim.replay.{robust_placement, scenario_robust_search}``) delegate
here and keep their signatures.
"""

from repro.search.candidates import (anneal_path, chunked,
                                     count_grid_states, dq_grid,
                                     grid_placements, incumbent_candidates,
                                     probe_candidates, random_placements,
                                     transfer_neighborhood)
from repro.search.decision import (ObjectiveScales, ParetoFront,
                                   candidate_values, dq_caps_mask,
                                   epsilon_constraint, joint_dq_scores,
                                   pareto_front, pareto_mask, robust_select,
                                   scalarize, split_dq_term)
from repro.search.engine import BatchedProblem
from repro.search.robust import (belief_robust_search, belief_scenarios,
                                 robust_placement, scenario_robust_search)
from repro.search.searchers import (exhaustive_search, greedy_transfer,
                                    random_search, simulated_annealing)

__all__ = [
    # layer 1 — candidates
    "anneal_path", "chunked", "count_grid_states", "dq_grid",
    "grid_placements", "incumbent_candidates", "probe_candidates",
    "random_placements", "transfer_neighborhood",
    # layer 2 — batched scoring
    "BatchedProblem",
    # layer 3 — decision
    "ObjectiveScales", "ParetoFront", "candidate_values", "dq_caps_mask",
    "epsilon_constraint", "joint_dq_scores", "pareto_front", "pareto_mask",
    "robust_select", "scalarize", "split_dq_term",
    "belief_robust_search", "belief_scenarios",
    "robust_placement", "scenario_robust_search",
    # searchers
    "exhaustive_search", "greedy_transfer", "random_search",
    "simulated_annealing",
]
