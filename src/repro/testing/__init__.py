"""Test-support utilities shared by the pytest suites and benchmarks."""
