"""Minimal drop-in for the parts of ``hypothesis`` the test suites use.

This container does not ship hypothesis and nothing may be pip-installed,
so the property-test modules import it defensively:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from repro.testing.propcheck import given, settings, strategies as st

Semantics are a strict subset: every ``@given`` test runs ``max_examples``
deterministic examples (seeded from the test name, so failures reproduce),
with no shrinking and no example database.  When the real hypothesis is
available it is preferred automatically by the import dance above.
"""

from __future__ import annotations

import functools
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A value generator: ``example(rng)`` draws one value."""

    def __init__(self, fn):
        self._fn = fn

    def example(self, rng: np.random.Generator):
        return self._fn(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda rng: items[int(rng.integers(len(items)))])


def _floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(2)))


def _composite(f):
    """``@st.composite`` — f(draw, *args) becomes a strategy factory."""

    @functools.wraps(f)
    def make(*args, **kwargs):
        def gen(rng):
            draw = lambda strat: strat.example(rng)
            return f(draw, *args, **kwargs)

        return _Strategy(gen)

    return make


strategies = types.SimpleNamespace(
    integers=_integers,
    sampled_from=_sampled_from,
    floats=_floats,
    booleans=_booleans,
    composite=_composite,
)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    """Accepts (and mostly ignores) hypothesis settings kwargs."""

    def deco(fn):
        fn._prop_max_examples = max_examples
        return fn

    return deco


def given(*arg_strats, **kw_strats):
    def deco(fn):
        def wrapper():
            # read at CALL time so @settings works both above and below
            # @given (real hypothesis accepts either ordering)
            n = getattr(wrapper, "_prop_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.example(rng) for s in arg_strats]
                drawn_kw = {k: s.example(rng) for k, s in kw_strats.items()}
                fn(*drawn, **drawn_kw)

        # NOT functools.wraps: copying __wrapped__ would make pytest resolve
        # the original argument names as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        if hasattr(fn, "_prop_max_examples"):  # @settings applied below
            wrapper._prop_max_examples = fn._prop_max_examples
        return wrapper

    return deco
