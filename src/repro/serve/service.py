"""`WhatIfService` — the single-host, multi-tenant what-if serving loop.

Query lifecycle (see serve/README.md for the diagram)::

    submit ── normalize ── bucket ── admit ─┬─ Rejected (typed, priced)
                                            └─ queue[CoalesceKey]
    step ──── coalesce queues ── pad to 2^k ── ONE raw dispatch per chunk
                 └── per-query analytic dq/β finish ── stream ResultChunks
                                                        └── final QueryResult

Tenants :meth:`~WhatIfService.register_fleet` scenario packs once (content
digest → equal fleets coalesce across tenants), then
:meth:`~WhatIfService.submit` heterogeneous queries — score a placement
batch, rank candidates (weighted or ε-constraint), extract a Pareto front,
co-optimize placement × dq.  The service normalizes each query to its
:class:`~repro.serve.bucketing.CoalesceKey`, prices it against the p99
budget (:mod:`repro.serve.admission`), and merges admitted rows across
tenants into power-of-two-padded super-batches so the whole mixed stream
runs through a handful of compiled executables — resolved via the
process-wide :mod:`repro.sim.execache`, with recompiles attributed per
dispatch through :func:`repro.obs.jaxhooks.snapshot`.

Every dispatch is RAW (dq = 0, β = 0): the dq-dependent part of the
objective is closed-form (:func:`repro.search.decision.split_dq_term`), so
per-query dq/β — scalars, per-scenario columns, whole dq grids — are
finished on the host afterwards, float32, bitwise equal to a direct
``score_grid`` call for the single-objective path.  Results stream back
per tenant (:meth:`~WhatIfService.poll`) as chunks complete: long queries
yield :class:`ResultChunk` partials before the final :class:`QueryResult`.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro import obs
from repro.core.costmodel import CostConfig
from repro.core.devices import RegionFleetFamily
from repro.core.graph import OpGraph
from repro.core.objectives import ObjectiveGrids, ObjectiveSet, \
    as_objective_set
from repro.obs import jaxhooks
from repro.search.decision import (dq_caps_mask, epsilon_constraint,
                                   joint_dq_scores, pareto_front,
                                   robust_select, split_dq_term)
from repro.serve.admission import (AdmissionConfig, Admitted, Degraded,
                                   DispatchPricer, Rejected, decide)
from repro.serve.bucketing import (CoalesceKey, dq_denominator,
                                   finish_scores, fleet_digest, next_pow2,
                                   pad_rows)
from repro.serve.cache import ServeStats
from repro.sim.batched import BatchedEvaluator

__all__ = ["WhatIfQuery", "QueryTicket", "ResultChunk", "QueryResult",
           "WhatIfService"]

_KINDS = ("score", "rank", "pareto", "joint")


@dataclasses.dataclass(frozen=True)
class WhatIfQuery:
    """One tenant question over a batch of candidate placements.

    ``kind`` picks the post-processing applied to the (scenario, candidate)
    grids the shared dispatch produces — the dispatch itself is identical:

    * ``"score"``  — the finished (S, P) score grid(s), dq/β applied;
    * ``"rank"``   — top-``top_k`` candidates by worst-case score; with
      ``eps_caps`` the ranking is ε-constraint (minimize one objective
      subject to caps on the others) instead of the weighted sum;
    * ``"pareto"`` — the non-dominated front over the key's objectives
      (requires the fleet to be registered with an ObjectiveSet);
    * ``"joint"``  — placement × dq co-optimization over ``dq_values``
      (optionally DQCoupling-masked), min–max selected.

    ``dq`` may be a scalar or per-scenario (S,) column; dq/β never affect
    which super-batch the query coalesces into.
    """

    kind: str
    placements: np.ndarray
    dq: float | np.ndarray = 0.0
    beta: float = 0.0
    # rank
    top_k: int = 1
    minimize: str | None = None
    eps_caps: dict | None = None
    # pareto / rank reduction across scenarios
    scenario: int | str = "worst"
    # joint
    dq_values: np.ndarray | None = None
    coupling: object | None = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        x = np.asarray(self.placements, dtype=np.float32)
        if x.ndim != 3:
            raise ValueError(f"placements must be (P, n_ops, V), "
                             f"got {x.shape}")
        object.__setattr__(self, "placements", x)
        if self.kind == "joint" and self.dq_values is None:
            raise ValueError("joint queries need dq_values")
        if self.eps_caps and self.minimize is None:
            raise ValueError("eps_caps needs minimize=<objective name>")


@dataclasses.dataclass(frozen=True)
class QueryTicket:
    """submit()'s receipt: the query id results will carry, plus the typed
    admission verdict (Admitted or Degraded — Rejected never queues)."""

    query_id: int
    tenant: str
    admission: Admitted | Degraded
    rows: int            # candidate rows actually queued (post-degrade)
    dq_steps: int | None


@dataclasses.dataclass(frozen=True)
class ResultChunk:
    """A streamed partial: finished scores for ``rows`` candidates starting
    at ``offset`` within the (possibly degraded) query batch."""

    query_id: int
    tenant: str
    offset: int
    scores: np.ndarray   # (S, rows) finished scalar scores

    @property
    def rows(self) -> int:
        return int(self.scores.shape[1])


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """The final answer for one query (follows its ResultChunks).

    ``scores`` is always the finished (S, P) scalar grid over the rows the
    query actually dispatched.  Kind-specific extras: ``top``/``worst``/
    ``best`` (rank), ``front`` (pareto), ``best``/``dq_idx`` (joint),
    ``infeasible`` (ε-constraint with no candidate under the caps).
    ``grids`` carries the finished per-objective (S, P) grids when the
    fleet was registered with an ObjectiveSet."""

    query_id: int
    tenant: str
    kind: str
    scores: np.ndarray
    grids: dict | None = None
    degraded: Degraded | None = None
    top: np.ndarray | None = None
    worst: np.ndarray | None = None
    front: object | None = None
    best: int | None = None
    dq_idx: np.ndarray | None = None
    infeasible: bool = False


@dataclasses.dataclass
class _Fleet:
    pack: object                    # (S, V, V) array or RegionFleetFamily
    key: CoalesceKey
    n_scenarios: int
    n_devices: int
    objectives: ObjectiveSet | None
    pricer: DispatchPricer


@dataclasses.dataclass
class _Pending:
    """An admitted query waiting in (or mid-flight through) its key's
    queue, accumulating raw host-side grid columns chunk by chunk."""

    query_id: int
    tenant: str
    query: WhatIfQuery
    placements: np.ndarray          # post-degrade (P, n_ops, V)
    dq_values: np.ndarray | None    # post-degrade
    predicted_s: float
    degraded: Degraded | None
    done_rows: int = 0
    lat_cols: list = dataclasses.field(default_factory=list)
    rest_cols: list = dataclasses.field(default_factory=list)
    w_lat: float = 1.0
    raw_cols: dict = dataclasses.field(default_factory=dict)

    @property
    def rows(self) -> int:
        return int(self.placements.shape[0])


class WhatIfService:
    """Single-host what-if serving for one operator graph.

    One service instance per :class:`~repro.core.graph.OpGraph` /
    :class:`~repro.core.costmodel.CostConfig`; any number of logical
    tenants and registered scenario fleets.  ``max_chunk_rows`` bounds a
    single dispatch (super-batches larger than it stream in chunks, which
    is what makes results *streamable* and keeps the compiled-shape set
    small); admission is configured via :class:`AdmissionConfig`.
    """

    def __init__(self, graph: OpGraph, cfg: CostConfig = CostConfig(),
                 use_pallas: bool | None = None,
                 interpret: bool | None = None,
                 admission: AdmissionConfig = AdmissionConfig(),
                 max_chunk_rows: int = 1024):
        if max_chunk_rows < 1 or max_chunk_rows & (max_chunk_rows - 1):
            raise ValueError(f"max_chunk_rows must be a power of two, "
                             f"got {max_chunk_rows}")
        self.graph = graph
        self.cfg = cfg
        # kernel flags resolve ONCE through the dispatch policy (None =
        # auto for the backend), so the service can never pin interpreted
        # kernels on an accelerator — and the resolved booleans feed both
        # the shared evaluator and every CoalesceKey, keeping the serving
        # layer and sim layer on the same executables
        from repro.kernels.dispatch import resolve_flags
        self.use_pallas, self.interpret = resolve_flags(use_pallas,
                                                        interpret)
        self.admission = admission
        self.max_chunk_rows = max_chunk_rows
        # evaluator resolves through the process-wide executable cache:
        # services, search engines and scripts over equal graphs share one
        self._ev = BatchedEvaluator.shared(graph, cfg,
                                           use_pallas=use_pallas,
                                           interpret=interpret)
        self._fleets: dict[str, _Fleet] = {}
        self._queues: dict[CoalesceKey, list[_Pending]] = {}
        self._mail: dict[str, list] = {}
        self._next_id = 0
        self.stats = ServeStats()

    # -- registration --------------------------------------------------------
    def register_fleet(self, tenant: str, pack,
                       objectives: ObjectiveSet | None = None) -> str:
        """Register a scenario pack (dense (S, V, V) stack or
        RegionFleetFamily) and get back its fleet id — a content digest,
        so two tenants registering equal fleets receive the SAME id and
        their queries coalesce into one dispatch stream.  ``objectives``
        fixes the multi-objective set for queries against this fleet
        (None = single-objective latency-F)."""
        obj_set = as_objective_set(objectives) if objectives is not None \
            else None
        fid = fleet_digest(pack)
        if obj_set is not None:
            fid = f"{fid}:{abs(hash(obj_set)):x}"
        if fid in self._fleets:
            return fid
        if isinstance(pack, RegionFleetFamily):
            S, V = pack.n_scenarios, int(pack.degrade.shape[1])
            R = pack.n_regions
        else:
            pack = np.asarray(pack, dtype=np.float32)
            S, V = int(pack.shape[0]), int(pack.shape[1])
            R = None
        key = CoalesceKey.of(self.graph, self.cfg, self.use_pallas,
                             self.interpret, fid, obj_set)
        self._fleets[fid] = _Fleet(
            pack=pack, key=key, n_scenarios=S, n_devices=V,
            objectives=obj_set,
            pricer=DispatchPricer(len(self.graph.edges), V, R,
                                  cfg=self.admission))
        return fid

    # -- submission (normalize → bucket → admit → queue) ---------------------
    def submit(self, tenant: str, fleet_id: str,
               query: WhatIfQuery) -> QueryTicket | Rejected:
        """Price the query and either queue it (returning a
        :class:`QueryTicket` whose ``admission`` says what, if anything,
        was degraded) or refuse it with a typed :class:`Rejected` —
        nothing is dispatched here; call :meth:`step` / :meth:`drain`."""
        fleet = self._fleets[fleet_id]
        q = query
        if q.kind == "pareto" and fleet.objectives is None:
            raise ValueError("pareto queries need the fleet registered "
                             "with an ObjectiveSet")
        if (q.eps_caps or q.minimize is not None) \
                and fleet.objectives is None:
            raise ValueError("ε-constraint ranking (minimize/eps_caps) "
                             "needs the fleet registered with an "
                             "ObjectiveSet")
        if q.placements.shape[2] != fleet.n_devices:
            raise ValueError(
                f"placements have V={q.placements.shape[2]} devices; "
                f"fleet {fleet_id} has V={fleet.n_devices}")
        dq_steps = None if q.dq_values is None else len(
            np.atleast_1d(q.dq_values))
        rows = q.placements.shape[0]
        verdict = decide(
            fleet.pricer, fleet.n_scenarios, next_pow2(rows),
            backlog_s=self._backlog_s(), cfg=self.admission,
            dq_steps=dq_steps,
            bucket_stats=self.stats.peek_bucket(next_pow2(rows)))
        if isinstance(verdict, Rejected):
            self.stats.rejected += 1
            reg = obs.registry()
            if reg.enabled:
                reg.counter("serve.admission", verdict="rejected").add(1)
            return verdict
        placements, dq_vals, degraded = q.placements, q.dq_values, None
        if isinstance(verdict, Degraded):
            degraded = verdict
            self.stats.degraded += 1
            placements = placements[:verdict.keep_rows]
            if verdict.dq_steps is not None and dq_steps is not None \
                    and verdict.dq_steps < dq_steps:
                grid = np.atleast_1d(
                    np.asarray(q.dq_values, dtype=np.float64))
                pick = np.linspace(0, len(grid) - 1,
                                   verdict.dq_steps).round().astype(int)
                dq_vals = grid[np.unique(pick)]
        else:
            self.stats.admitted += 1
        reg = obs.registry()
        if reg.enabled:
            reg.counter("serve.admission",
                        verdict=("degraded" if degraded else
                                 "admitted")).add(1)
        qid = self._next_id
        self._next_id += 1
        self._queues.setdefault(fleet.key, []).append(_Pending(
            query_id=qid, tenant=tenant, query=q, placements=placements,
            dq_values=dq_vals, predicted_s=verdict.predicted_s,
            degraded=degraded))
        return QueryTicket(query_id=qid, tenant=tenant, admission=verdict,
                           rows=placements.shape[0],
                           dq_steps=None if dq_vals is None
                           else len(np.atleast_1d(dq_vals)))

    def _backlog_s(self) -> float:
        return sum(p.predicted_s for queue in self._queues.values()
                   for p in queue)

    # -- the serving loop (coalesce → pad → dispatch → stream) ---------------
    def step(self) -> int:
        """Serve the oldest non-empty coalesce queue: merge its pending
        queries into one super-batch, dispatch it RAW in ≤max_chunk_rows
        power-of-two chunks, stream each chunk's finished scores to tenant
        mailboxes, finalize completed queries.  Returns the number of
        queries completed (0 = nothing pending)."""
        key = next((k for k, queue in self._queues.items() if queue), None)
        if key is None:
            return 0
        queue = self._queues.pop(key)
        fleet = next(f for f in self._fleets.values() if f.key == key)
        batch = np.concatenate([p.placements for p in queue])
        # (query, slice) spans inside the super-batch, in queue order
        spans, off = [], 0
        for p in queue:
            spans.append((p, off, off + p.rows))
            off += p.rows
        done = 0
        for start in range(0, batch.shape[0], self.max_chunk_rows):
            chunk = batch[start:start + self.max_chunk_rows]
            bucket = next_pow2(chunk.shape[0])
            lat, rest, w_lat, raw = self._dispatch(
                fleet, pad_rows(chunk, bucket), bucket,
                n_rows=chunk.shape[0],
                n_queries=sum(1 for _, a, b in spans
                              if a < start + chunk.shape[0] and b > start))
            end = start + chunk.shape[0]
            for p, a, b in spans:
                lo, hi = max(a, start), min(b, end)
                if lo >= hi:
                    continue
                sl = slice(lo - start, hi - start)
                p.lat_cols.append(lat[:, sl])
                p.rest_cols.append(rest[:, sl])
                p.w_lat = w_lat
                for name, g in raw.items():
                    p.raw_cols.setdefault(name, []).append(g[:, sl])
                if p.query.kind != "joint":
                    fin = finish_scores(p.lat_cols[-1], p.rest_cols[-1],
                                        w_lat, p.query.dq, p.query.beta)
                    self._mail.setdefault(p.tenant, []).append(ResultChunk(
                        query_id=p.query_id, tenant=p.tenant,
                        offset=p.done_rows, scores=fin))
                p.done_rows += hi - lo
                if p.done_rows == p.rows:
                    self._mail.setdefault(p.tenant, []).append(
                        self._finalize(fleet, p))
                    done += 1
        return done

    def drain(self) -> int:
        """step() until every queue is empty; returns queries completed."""
        total = 0
        while True:
            n = self.step()
            if n == 0 and not any(self._queues.values()):
                return total
            total += n

    def poll(self, tenant: str) -> list:
        """Drain the tenant's mailbox: ResultChunk / QueryResult, in
        completion order."""
        return self._mail.pop(tenant, [])

    # -- dispatch + accounting ----------------------------------------------
    def _dispatch(self, fleet: _Fleet, padded: np.ndarray, bucket: int,
                  n_rows: int, n_queries: int):
        """ONE raw score_grid call (dq = 0, β = 0) over the padded chunk;
        returns host-side float32 (lat, rest, w_lat, raw per-objective
        grids) with padding rows already sliced off."""
        snap = jaxhooks.snapshot()
        t0 = time.perf_counter()
        out = self._ev.score_grid(padded, fleet.pack, dq=0.0, beta=0.0,
                                  objectives=fleet.objectives)
        if isinstance(out, ObjectiveGrids):
            # one host transfer for the whole chunk (grids + scalarized)
            host = jax.device_get({"grids": dict(out.grids),
                                   "scal": out.scalarized})
            out = ObjectiveGrids(names=out.names, grids=host["grids"],
                                 scalarized=host["scal"],
                                 weights=out.weights)
            raw = {n: np.asarray(g, dtype=np.float32)[:, :n_rows]
                   for n, g in out.grids.items()}
        else:
            out = jax.device_get(out)
            raw = {}
        seconds = time.perf_counter() - t0
        recompiles, compile_s = snap.delta()
        lat, rest, w_lat = split_dq_term(out)
        lat = np.asarray(lat, dtype=np.float32)[:, :n_rows]
        rest = np.asarray(rest, dtype=np.float32)[:, :n_rows]
        self.stats.bucket(bucket).observe(
            seconds, n_rows=n_rows, n_padded=bucket, n_queries=n_queries,
            n_recompiles=recompiles, compile_s=compile_s)
        # calibrate the pricer on warm execution time only — compile cost
        # is a one-off the executable cache amortizes away, not a per-
        # dispatch price
        fleet.pricer.observe(fleet.n_scenarios, bucket,
                             max(seconds - compile_s, 0.0))
        return lat, rest, w_lat, raw

    # -- per-kind finalization ----------------------------------------------
    def _finalize(self, fleet: _Fleet, p: _Pending) -> QueryResult:
        q = p.query
        lat = np.concatenate(p.lat_cols, axis=1)     # (S, P) float32
        rest = np.concatenate(p.rest_cols, axis=1)
        raw = {n: np.concatenate(cols, axis=1)
               for n, cols in p.raw_cols.items()}
        if q.kind == "joint":
            feas = dq_caps_mask(p.placements, p.dq_values, q.coupling)
            scores, dq_idx = joint_dq_scores(
                lat, np.atleast_1d(p.dq_values), q.beta, rest=rest,
                w_lat=p.w_lat, feasible=feas)
            best, worst = robust_select(scores)
            return QueryResult(
                query_id=p.query_id, tenant=p.tenant, kind=q.kind,
                scores=scores, grids=raw or None, degraded=p.degraded,
                best=best, dq_idx=dq_idx, worst=worst,
                infeasible=bool(np.isinf(worst[best])))
        scores = finish_scores(lat, rest, p.w_lat, q.dq, q.beta)
        # per-objective finished grids: only latency_f carries the dq term
        grids = None
        if raw:
            denom = dq_denominator(q.dq, q.beta, lat.shape[0])
            grids = {n: (g / denom if n == "latency_f" else g)
                     for n, g in raw.items()}
        if q.kind == "score":
            return QueryResult(query_id=p.query_id, tenant=p.tenant,
                               kind=q.kind, scores=scores, grids=grids,
                               degraded=p.degraded)
        if q.kind == "pareto":
            og = ObjectiveGrids(names=fleet.objectives.names, grids=grids,
                                scalarized=scores,
                                weights=fleet.objectives.weights)
            front = pareto_front(og, scenario=q.scenario)
            return QueryResult(query_id=p.query_id, tenant=p.tenant,
                               kind=q.kind, scores=scores, grids=grids,
                               degraded=p.degraded, front=front)
        # rank
        if q.eps_caps or q.minimize is not None:
            og = ObjectiveGrids(names=fleet.objectives.names, grids=grids,
                                scalarized=scores,
                                weights=fleet.objectives.weights)
            best, masked = epsilon_constraint(
                og, q.minimize, q.eps_caps, scenario=q.scenario)
            order = np.argsort(masked, kind="stable")[:q.top_k]
            return QueryResult(
                query_id=p.query_id, tenant=p.tenant, kind=q.kind,
                scores=scores, grids=grids, degraded=p.degraded,
                top=order, worst=masked, best=int(best),
                infeasible=bool(np.isinf(masked[best])))
        best, worst = robust_select(scores)
        order = np.argsort(worst, kind="stable")[:q.top_k]
        return QueryResult(query_id=p.query_id, tenant=p.tenant,
                           kind=q.kind, scores=scores, grids=grids,
                           degraded=p.degraded, top=order, worst=worst,
                           best=int(best))
