"""Query normalization and shape bucketing for the what-if serving layer.

Heterogeneous tenant queries coalesce only when they can share a dispatch.
Two facts make that sharing wide instead of narrow:

  * **dq/β are analytic, not traced.**  Every query is dispatched RAW
    (dq = 0, β = 0, exactly like ``repro.search.engine``): only latency-F
    depends on dq, through the closed-form ``/(1 + β·dq)`` factor, so
    queries with *different* dq values, dq grids, and β coexist in one
    super-batch and get their own finish on the host afterwards
    (:func:`finish_scores`).
  * **rows are independent.**  ``score_grid`` vmaps over the placement
    axis, so concatenating tenants' candidate rows — and padding with
    repeated rows up to a power-of-two bucket — changes nothing about any
    individual row's result (bitwise; gated in ``bench_serve`` and
    ``tests/test_serve.py``).

What remains in the coalescing key is exactly what the compiled executable
and the operands pin: the evaluator family (graph content + CostConfig +
pallas flags), the scenario pack (content digest — two tenants registering
equal fleets coalesce), and the objective set.  The padded row count is
the *shape bucket*: the unit of executable-cache identity, admission
pricing, and per-bucket telemetry.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core.costmodel import CostConfig
from repro.core.devices import RegionFleetFamily
from repro.core.objectives import ObjectiveSet
from repro.sim.execache import graph_key

__all__ = ["CoalesceKey", "dq_denominator", "fleet_digest", "next_pow2",
           "pad_rows", "finish_scores"]


def next_pow2(n: int) -> int:
    """Next power of two ≥ n — the bucketing rule shared with
    ``repro.search.engine``: a handful of padded shapes instead of one
    compiled executable per row count."""
    return 1 << max(int(n) - 1, 0).bit_length()


def fleet_digest(pack) -> str:
    """Content digest of a packed scenario family (dense (S, V, V) stack or
    :class:`RegionFleetFamily`).  Computed ONCE at fleet registration —
    queries then carry the fleet id — so coalescing across tenants keys on
    what the dispatch actually consumes, not on object identity."""
    h = hashlib.sha256()
    if isinstance(pack, RegionFleetFamily):
        h.update(b"structured")
        h.update(np.ascontiguousarray(pack.region).tobytes())
        h.update(np.ascontiguousarray(pack.inter).tobytes())
        h.update(np.ascontiguousarray(pack.degrade).tobytes())
        h.update(np.float64(pack.self_cost).tobytes())
        h.update(np.ascontiguousarray(pack.speed_or_ones()).tobytes())
    else:
        arr = np.asarray(pack, dtype=np.float32)
        if arr.ndim != 3 or arr.shape[1] != arr.shape[2]:
            raise ValueError(f"dense pack must be (S, V, V), "
                             f"got {arr.shape}")
        h.update(b"dense")
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class CoalesceKey:
    """Everything two queries must agree on to share one raw dispatch.

    ``graph`` / ``cfg`` / pallas flags pin the compiled evaluator family,
    ``fleet`` (the registration-time content digest) pins the scenario
    operands, ``objectives`` pins the multi-objective executable (None =
    the single-objective latency grid).  dq/β are deliberately ABSENT —
    they are applied analytically per query after the dispatch."""

    graph: tuple
    cfg: CostConfig
    use_pallas: bool
    interpret: bool
    fleet: str
    objectives: ObjectiveSet | None

    @classmethod
    def of(cls, graph, cfg: CostConfig, use_pallas: bool, interpret: bool,
           fleet_id: str, objectives: ObjectiveSet | None) -> "CoalesceKey":
        return cls(graph=graph_key(graph), cfg=cfg, use_pallas=use_pallas,
                   interpret=interpret, fleet=fleet_id,
                   objectives=objectives)


def pad_rows(xs: np.ndarray, bucket: int) -> np.ndarray:
    """Pad a (P, n_ops, V) super-batch to ``bucket`` rows by repeating the
    last row.  Padding rows are real (valid simplex placements), score
    normally, and are SLICED OFF before any tenant sees results — the
    non-leak property ``tests/test_serve.py`` pins."""
    pad = bucket - xs.shape[0]
    if pad < 0:
        raise ValueError(f"batch of {xs.shape[0]} rows exceeds "
                         f"bucket {bucket}")
    if pad == 0:
        return xs
    return np.concatenate([xs, np.repeat(xs[-1:], pad, axis=0)])


def dq_denominator(dq, beta: float, n_scenarios: int) -> np.ndarray:
    """The (S, 1) float32 column ``1 + β·dq``, computed EXACTLY as the
    compiled dispatch computes it: XLA fuses the multiply-add into an FMA
    (one rounding of the exact β·dq + 1), which numpy's two-rounding
    ``f32(f32(β·dq) + 1)`` misses by 1 ulp on ~⅓ of operands.  Emulated
    here via float64 — the f32×f32 product is exact in double, the +1 sum
    rounds once to f32 — so the host finish divides by the bitwise-same
    denominator the device would."""
    dq_col = np.broadcast_to(
        np.asarray(dq, dtype=np.float32), (n_scenarios,))[:, None]
    return (np.float64(np.float32(beta)) * dq_col.astype(np.float64)
            + 1.0).astype(np.float32)


def finish_scores(lat: np.ndarray, rest: np.ndarray, w_lat: float,
                  dq, beta: float) -> np.ndarray:
    """Apply one query's dq/β finish to its slice of the raw grids:
    ``rest + w_lat · lat / (1 + β·dq)`` with dq a scalar or per-scenario
    (S,) column.

    Arithmetic is float32 in the dispatch's own op order (FMA included,
    see :func:`dq_denominator`) — so a served single-objective score is
    BITWISE what a direct ``score_grid(..., dq=dq, beta=beta)`` computes
    on device (IEEE-754 divide is exactly rounded on both sides; gated in
    ``tests/test_serve.py`` and ``bench_serve``)."""
    lat32 = np.asarray(lat, dtype=np.float32)
    denom = dq_denominator(dq, beta, lat32.shape[0])
    # w_lat = 1 / rest = 0 (the single-objective path) are bitwise no-ops:
    # ×1.0f and +0.0f are exact, so this one expression serves both cases
    return np.asarray(rest, dtype=np.float32) \
        + np.float32(w_lat) * lat32 / denom
