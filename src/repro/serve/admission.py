"""Cost-priced admission control: the cost model prices its own queries.

Benoit et al. (PAPERS.md) frame in-network stream processing as an
admission problem — bound latency by refusing or degrading work the
platform cannot afford.  Here the platform *is* a cost model, so pricing
is self-referential and cheap: every query is priced BEFORE dispatch from

  * an **analytic FLOPs/roofline prior** — the same dominant-term counts
    ``tests/test_perf_hlo.py`` pins against compiled HLO (dense edge
    kernel ``2·B·E·V² + B·E·V``, structured ``2·B·E·R·V + B·E·V``), run
    through :func:`repro.perf.roofline.compute_terms` (the machinery
    behind ``repro.obs.perfbridge``) — available for shape buckets the
    service has never executed, WITHOUT compiling anything;
  * a **calibration factor** — observed/prior ratio (running median of the
    last observations), because the prior is a hardware bound and the host
    is not a TPU-v5e;
  * **observed per-bucket p99** — once a bucket has real dispatch history
    (:class:`repro.serve.cache.BucketStats` histograms), its p99 overrides
    the prior: measured tails beat models.

:func:`decide` compares ``backlog + predicted`` against the p99 budget and
returns a typed verdict: :class:`Admitted`, :class:`Degraded` (candidate
rows subsampled / dq grid coarsened, with the actions spelled out), or
:class:`Rejected` (with the price it refused to pay) — the caller never
has to parse a reason string to learn what happened.
"""

from __future__ import annotations

import dataclasses
import statistics

import numpy as np

from repro.perf.roofline import compute_terms

__all__ = ["AdmissionConfig", "Admitted", "Degraded", "Rejected",
           "DispatchPricer", "decide"]


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission knobs.

    ``p99_budget_s`` bounds the latency a query may add: predicted
    dispatch time plus the backlog already queued ahead of it.  Degrading
    (when allowed) subsamples the candidate batch to the largest row count
    whose price fits, and coarsens joint-query dq grids to
    ``degrade_dq_steps`` values; a query that cannot fit even at
    ``min_rows`` is rejected."""

    p99_budget_s: float = 0.25
    allow_degrade: bool = True
    min_rows: int = 8
    degrade_dq_steps: int = 5
    # prior→observed blend: ratio samples kept for the running median
    calibration_window: int = 32
    initial_calibration: float = 1.0
    # a bucket's own p99 takes over once it has this many observations
    min_bucket_obs: int = 3


@dataclasses.dataclass(frozen=True)
class Admitted:
    predicted_s: float


@dataclasses.dataclass(frozen=True)
class Degraded:
    """Admitted after degradation; ``actions`` names what was traded
    (``"subsample_candidates"``, ``"coarsen_dq_grid"``) and the kept
    shape, so tenants know the answer quality they bought."""

    predicted_s: float
    keep_rows: int
    of_rows: int
    dq_steps: int | None
    actions: tuple[str, ...]
    reason: str


@dataclasses.dataclass(frozen=True)
class Rejected:
    predicted_s: float
    budget_s: float
    backlog_s: float
    reason: str


class DispatchPricer:
    """Seconds-per-dispatch estimator for one evaluator family.

    ``graph_dims`` fixes (E, V[, R]); the per-row flop/byte counts are the
    dominant terms of the edge-latency grid dispatch.  Price =
    ``max(bucket p99, roofline_bound × calibration)`` — the prior keeps
    unseen buckets honest, the observed tail keeps seen buckets honest.
    """

    def __init__(self, n_edges: int, n_devices: int,
                 n_regions: int | None = None,
                 cfg: AdmissionConfig = AdmissionConfig()):
        self.E = int(n_edges)
        self.V = int(n_devices)
        self.R = None if n_regions is None else int(n_regions)
        self.cfg = cfg
        self._ratios: list[float] = []

    # -- the FLOPs/roofline prior --------------------------------------------
    def roofline_bound_s(self, n_scenarios: int, rows: int) -> float:
        """Roofline lower bound for one raw score_grid dispatch of
        ``rows`` placements × ``n_scenarios`` scenarios (perfect overlap,
        TPU-v5e terms — a *bound*, scaled to this host by calibration)."""
        B = n_scenarios * rows
        if self.R is None:
            flops = 2.0 * B * self.E * self.V * self.V + B * self.E * self.V
            # operands re-read per edge: x_i/x_j (B·E·V) + com tiles (E·V²)
            bytes_ = 4.0 * (2.0 * B * self.E * self.V
                            + n_scenarios * self.E * self.V * self.V)
        else:
            flops = 2.0 * B * self.E * self.R * self.V \
                + B * self.E * self.V
            bytes_ = 4.0 * (2.0 * B * self.E * self.V
                            + n_scenarios * self.E * self.R * self.V)
        terms = compute_terms(hlo_flops=flops, hlo_bytes=bytes_,
                              wire_bytes=0.0, chips=1, model_flops=flops)
        return terms.step_time_s

    # -- calibration from observed dispatches --------------------------------
    def observe(self, n_scenarios: int, rows: int, seconds: float) -> None:
        """Fold one measured dispatch into the prior→host calibration
        (running median of observed/bound ratios over a sliding window;
        the median shrugs off one-off compile or scheduler outliers)."""
        bound = self.roofline_bound_s(n_scenarios, rows)
        if bound <= 0.0 or seconds <= 0.0:
            return
        self._ratios.append(seconds / bound)
        if len(self._ratios) > self.cfg.calibration_window:
            del self._ratios[0]

    @property
    def calibration(self) -> float:
        if not self._ratios:
            return self.cfg.initial_calibration
        return statistics.median(self._ratios)

    def price_s(self, n_scenarios: int, rows: int,
                bucket_stats=None) -> float:
        """Predicted seconds for a dispatch of this shape.  A bucket with
        enough real history prices by its own observed p99; otherwise the
        calibrated roofline prior."""
        prior = self.roofline_bound_s(n_scenarios, rows) * self.calibration
        if bucket_stats is not None \
                and bucket_stats.latency.count >= self.cfg.min_bucket_obs:
            return max(float(bucket_stats.p99()), prior * 0.0) or prior
        return prior


def decide(pricer: DispatchPricer, n_scenarios: int, rows: int,
           backlog_s: float, cfg: AdmissionConfig,
           dq_steps: int | None = None,
           bucket_stats=None) -> Admitted | Degraded | Rejected:
    """Price a query and admit / degrade / reject against the p99 budget.

    ``rows`` is the query's candidate count; ``dq_steps`` the length of a
    joint query's dq grid (None for non-joint kinds); ``backlog_s`` the
    predicted seconds of work already queued ahead of it."""
    budget = cfg.p99_budget_s
    predicted = pricer.price_s(n_scenarios, rows, bucket_stats)
    if backlog_s + predicted <= budget:
        return Admitted(predicted_s=predicted)
    if not cfg.allow_degrade:
        return Rejected(
            predicted_s=predicted, budget_s=budget, backlog_s=backlog_s,
            reason=f"predicted {predicted * 1e3:.2f}ms + backlog "
                   f"{backlog_s * 1e3:.2f}ms exceeds p99 budget "
                   f"{budget * 1e3:.2f}ms (degrade disabled)")
    actions: list[str] = []
    headroom = budget - backlog_s
    # the largest candidate PREFIX whose price fits the headroom (prefix,
    # not stride — sources order candidates best-first: incumbent first,
    # neighborhoods in scan order).  Binary search on the price function
    # itself: the roofline bound is affine in rows (a scenario-sized bytes
    # term doesn't scale with them), so inverting it linearly would
    # overshoot.  Degraded sizing prices through the calibrated prior
    # (bucket_stats=None) — shrinking the batch moves it to a different
    # bucket, so the original bucket's p99 no longer applies.
    lo, hi = min(cfg.min_rows, rows), rows
    if headroom <= 0.0 \
            or pricer.price_s(n_scenarios, lo) > headroom:
        return Rejected(
            predicted_s=predicted, budget_s=budget, backlog_s=backlog_s,
            reason=f"predicted {predicted * 1e3:.2f}ms + backlog "
                   f"{backlog_s * 1e3:.2f}ms exceeds p99 budget "
                   f"{budget * 1e3:.2f}ms even degraded to "
                   f"{lo}/{rows} candidates")
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if pricer.price_s(n_scenarios, mid) <= headroom:
            lo = mid
        else:
            hi = mid - 1
    keep = lo
    new_dq = dq_steps
    if dq_steps is not None and dq_steps > cfg.degrade_dq_steps:
        new_dq = cfg.degrade_dq_steps
        actions.append("coarsen_dq_grid")
    if keep < rows:
        actions.append("subsample_candidates")
    degraded_price = pricer.price_s(n_scenarios, keep)
    if not actions:
        # the batch fits on the prior but the bucket's observed p99 says
        # otherwise, and there is nothing left to trade away
        return Rejected(
            predicted_s=predicted, budget_s=budget, backlog_s=backlog_s,
            reason=f"predicted {predicted * 1e3:.2f}ms + backlog "
                   f"{backlog_s * 1e3:.2f}ms exceeds p99 budget "
                   f"{budget * 1e3:.2f}ms with no degrade action left")
    return Degraded(
        predicted_s=degraded_price, keep_rows=keep, of_rows=rows,
        dq_steps=new_dq, actions=tuple(actions),
        reason=f"priced {predicted * 1e3:.2f}ms against "
               f"{max(headroom, 0.0) * 1e3:.2f}ms of budget headroom — "
               f"kept {keep}/{rows} candidates")
