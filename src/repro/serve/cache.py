"""Per-bucket serving statistics over the shared executable cache.

The compiled callables themselves live in :mod:`repro.sim.execache` (the
process-wide LRU the evaluator resolves through — the serve layer adds no
second copy).  What serving adds is *accounting at bucket granularity*:
each (CoalesceKey, padded-row bucket) pair tracks

  * dispatch count, rows scored, queries served, padding waste;
  * a latency :class:`repro.obs.Histogram` (exponential buckets) whose
    p50/p95/p99 feed admission pricing — kept as a LOCAL instance so
    admission control works with the obs registry disabled, and mirrored
    into the registry when it is enabled;
  * recompiles attributed via :class:`repro.obs.jaxhooks.CompileSnapshot`
    deltas around each dispatch — a warm bucket must show zero.

``BucketStats.ok_rate`` / ``snapshot()`` are what ``WhatIfService.stats()``
and ``BENCH_serve.json`` report per bucket.
"""

from __future__ import annotations

import dataclasses

from repro import obs
from repro.sim.execache import executable_cache

__all__ = ["BucketStats", "ServeStats"]

# dispatch latencies span ~100µs (tiny warm buckets) to seconds (cold
# compiles); 1µs × 2^i covers that with ~½-decade resolution
_HIST_LO = 1e-6


@dataclasses.dataclass
class BucketStats:
    """Dispatch accounting for one (coalesce key, padded-rows) bucket."""

    bucket: int                       # padded super-batch rows
    dispatches: int = 0
    queries: int = 0                  # logical queries served via this bucket
    rows: int = 0                     # real (un-padded) candidate rows
    padded_rows: int = 0              # rows incl. padding actually scored
    recompiles: int = 0
    compile_s: float = 0.0
    warm: int = 0                     # dispatches that hit compiled code

    def __post_init__(self):
        self.latency = obs.Histogram("serve.dispatch_s",
                                     {"bucket": str(self.bucket)},
                                     lo=_HIST_LO)
        # compile-free dispatches only: the tail admission budgets bind
        # against (cold compiles are one-offs the executable cache kills)
        self.warm_latency = obs.Histogram("serve.dispatch_warm_s",
                                          {"bucket": str(self.bucket)},
                                          lo=_HIST_LO)

    def observe(self, seconds: float, n_rows: int, n_padded: int,
                n_queries: int, n_recompiles: int, compile_s: float) -> None:
        self.dispatches += 1
        self.queries += n_queries
        self.rows += n_rows
        self.padded_rows += n_padded
        self.recompiles += n_recompiles
        self.compile_s += compile_s
        if n_recompiles == 0:
            self.warm += 1
            self.warm_latency.observe(seconds)
        self.latency.observe(seconds)
        reg = obs.registry()
        if reg.enabled:
            b = str(self.bucket)
            reg.counter("serve.dispatches", bucket=b).add(1)
            reg.counter("serve.rows", bucket=b).add(n_rows)
            reg.counter("serve.recompiles", bucket=b).add(n_recompiles)
            reg.histogram("serve.dispatch_s", lo=_HIST_LO,
                          bucket=b).observe(seconds)

    def p99(self) -> float:
        return self.latency.quantile(0.99)

    def p99_warm(self) -> float:
        """p99 over compile-free dispatches only (NaN until one lands)."""
        return self.warm_latency.quantile(0.99)

    def snapshot(self) -> dict:
        """JSON-able per-bucket row (BENCH_serve / service.stats())."""
        pad = self.padded_rows - self.rows
        return {"bucket": self.bucket, "dispatches": self.dispatches,
                "queries": self.queries, "rows": self.rows,
                "padding_fraction": (pad / self.padded_rows
                                     if self.padded_rows else 0.0),
                "recompiles": self.recompiles, "compile_s": self.compile_s,
                "warm_dispatches": self.warm,
                "p99_warm": (self.p99_warm() if self.warm else None),
                **self.latency.quantiles()}


class ServeStats:
    """All buckets plus the executable cache totals, for one service."""

    def __init__(self):
        self._buckets: dict[int, BucketStats] = {}
        self.admitted = 0
        self.degraded = 0
        self.rejected = 0

    def bucket(self, n: int) -> BucketStats:
        st = self._buckets.get(n)
        if st is None:
            st = self._buckets[n] = BucketStats(bucket=n)
        return st

    def peek_bucket(self, n: int) -> BucketStats | None:
        """The bucket's stats if it has ever dispatched, else None — the
        admission path must not materialize empty buckets."""
        return self._buckets.get(n)

    def buckets(self) -> list[BucketStats]:
        return [self._buckets[k] for k in sorted(self._buckets)]

    def snapshot(self) -> dict:
        """The serving-layer stats block: admission counts, per-bucket
        dispatch/latency/recompile rows, and the process executable-cache
        hit rates every dispatch resolved through."""
        return {
            "admission": {"admitted": self.admitted,
                          "degraded": self.degraded,
                          "rejected": self.rejected},
            "buckets": [b.snapshot() for b in self.buckets()],
            "executable_cache": executable_cache().stats(),
        }
