"""Single-host what-if serving: coalesced, executable-cached, cost-priced
(see serve/README.md for the query lifecycle and design rationale).

:class:`WhatIfService` answers heterogeneous tenant queries — score a
placement batch, rank candidates (weighted or ε-constraint), extract a
Pareto front, co-optimize placement × dq — through shared raw dispatches:
queries normalize to a :class:`CoalesceKey` (evaluator family + fleet
content digest + objective set; dq/β deliberately excluded because they
finish analytically), merge across tenants into power-of-two-padded
super-batches, resolve compiled executables through the process-wide
:mod:`repro.sim.execache`, and stream results back per tenant.  Every
query is priced BEFORE dispatch (FLOPs/roofline prior calibrated by
observed per-bucket latency quantiles) and admitted, degraded, or
rejected with a typed verdict.
"""

from repro.serve.admission import (AdmissionConfig, Admitted, Degraded,
                                   DispatchPricer, Rejected, decide)
from repro.serve.bucketing import (CoalesceKey, finish_scores, fleet_digest,
                                   next_pow2, pad_rows)
from repro.serve.cache import BucketStats, ServeStats
from repro.serve.service import (QueryResult, QueryTicket, ResultChunk,
                                 WhatIfQuery, WhatIfService)

__all__ = [
    # service surface
    "WhatIfService", "WhatIfQuery", "QueryTicket", "ResultChunk",
    "QueryResult",
    # admission
    "AdmissionConfig", "Admitted", "Degraded", "Rejected",
    "DispatchPricer", "decide",
    # bucketing / coalescing
    "CoalesceKey", "fleet_digest", "finish_scores", "next_pow2", "pad_rows",
    # accounting
    "BucketStats", "ServeStats",
]
