"""Post-SPMD HLO module analysis: trip-count-aware FLOPs, HBM-traffic and
collective-traffic extraction.

Why not ``compiled.cost_analysis()``: XLA's entry-point cost analysis counts
a ``while`` body ONCE, but our models scan over layers — a 62-layer model
would be under-counted 62×.  Compiled HLO annotates every while with
``backend_config={"known_trip_count":{"n":…}}``, so we parse the module
text, build the computation call graph, and weight every computation by its
execution count.

Accounting rules (per device — post-SPMD shapes are per-device):
  * FLOPs: dot = 2·|result|·K (K from lhs shape × lhs_contracting_dims);
    reduce/reduce-window = |operand|; everything else ≈ 0.
  * HBM bytes: at fusion boundaries — a fusion reads its operands and writes
    its result; internals live in registers/VMEM.  dynamic-slice counts
    2·|slice|, dynamic-update-slice 2·|update| (not the whole buffer).
  * Collective wire bytes (ring model, per participating device):
      all-reduce 2·B·(n−1)/n, all-gather B_out·(n−1)/n,
      reduce-scatter B_in·(n−1)/n, all-to-all B·(n−1)/n, permute B.
All three are multiplied by the enclosing loops' trip counts.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["CollectiveStats", "ModuleStats", "analyze_module",
           "parse_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([a-z][a-zA-Z0-9\-]*)\(")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[^,()]+))")
_TRIP_RE = re.compile(r'known_trip_count[="\s{:n]+(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _shape_elems(type_str: str) -> int:
    n = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        k = 1
        if dims:
            for d in dims.split(","):
                k *= int(d)
        n += k
    return n


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},")[0].strip("{}")
        return len(first.split(",")) if first else 1
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def _operands(line: str) -> list[str]:
    """%refs inside the first top-level parentheses after the opcode."""
    start = line.find("(", line.find("=") + 1)
    # find opcode-paren: first '(' after the '= TYPE OPCODE' section — use
    # the paren belonging to the opcode matched by _INSTR_RE
    m = _INSTR_RE.match(line)
    if not m:
        return []
    idx = m.end() - 1
    depth = 0
    out = []
    buf = ""
    for ch in line[idx:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                buf += "\0"
                break
        buf += ch
    for ref in re.findall(r"%([\w.\-]+)", buf):
        out.append(ref)
    return out


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class _Comp:
    name: str
    is_entry: bool
    params: dict
    instrs: list
    types: dict  # name -> type_str (params + defs)


def _parse_computations(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr:
            params = {}
            for pname, ptype in _PARAM_RE.findall(hdr.group(3)):
                params[pname] = ptype.strip()
            cur = _Comp(hdr.group(2), bool(hdr.group(1)), params, [],
                        dict(params))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = _Instr(m.group(1), m.group(2), m.group(3), line)
            cur.instrs.append(ins)
            cur.types[ins.name] = ins.type_str
    return comps


def _instr_flops(ins: _Instr, comp: _Comp) -> float:
    if ins.op == "dot":
        result = 1
        for d in _first_shape_dims(ins.type_str):
            result *= d
        ops = _operands(ins.line)
        k = 1
        cd = _CDIMS_RE.search(ins.line)
        if ops and cd is not None:
            lhs_t = comp.types.get(ops[0], "")
            dims = _first_shape_dims(lhs_t)
            for i in (cd.group(1).split(",") if cd.group(1) else []):
                i = int(i)
                if i < len(dims):
                    k *= dims[i]
        return 2.0 * result * k
    if ins.op in ("reduce", "reduce-window"):
        ops = _operands(ins.line)
        if ops:
            return float(_shape_elems(comp.types.get(ops[0], "")))
    if ins.op == "convolution":
        # rough: 2·|result|·(input feature × window) — fall back to 2·|result|
        return 2.0 * _shape_elems(ins.type_str)
    return 0.0


_ZERO_BYTE_OPS = {"get-tuple-element", "tuple", "parameter", "bitcast",
                  "constant", "while", "conditional", "call", "after-all",
                  "partition-id", "replica-id", "iota", "opt-barrier"}


def _instr_bytes(ins: _Instr, comp: _Comp,
                 comps: dict | None = None) -> float:
    if ins.op in _ZERO_BYTE_OPS:
        return 0.0
    ops = _operands(ins.line)
    if ins.op == "dynamic-slice":
        return 2.0 * _shape_bytes(ins.type_str)
    if ins.op == "dynamic-update-slice":
        upd = _shape_bytes(comp.types.get(ops[1], "")) if len(ops) > 1 else 0
        return 2.0 * upd
    total = float(_shape_bytes(ins.type_str))
    for o in ops:
        total += _shape_bytes(comp.types.get(o, ""))
    if ins.op == "fusion" and comps is not None:
        # loop-carried in-place updates: a fusion containing a
        # dynamic-update-slice whose result type matches an operand type is
        # an aliased carry update — charging the full buffer in AND out per
        # loop iteration overstates HBM traffic by buffer/update (e.g. a
        # 62-layer KV-cache stack "touched" whole per layer step).
        m = _CALL_SINGLE_RE.search(ins.line)
        callee = comps.get(m.group(1)) if m else None
        if callee is not None and callee.instrs:
            has_dus = any(i.op == "dynamic-update-slice"
                          for i in callee.instrs)
            result_b = _shape_bytes(ins.type_str)
            aliases_operand = any(
                _shape_bytes(comp.types.get(o, "")) == result_b
                for o in ops)
            if has_dus and aliases_operand:
                total -= 2.0 * result_b
                total = max(total, 0.0)
    return total


def _collective_wire(ins: _Instr, comp: _Comp) -> tuple[str, float, int]:
    op = ins.op
    base = op
    for c in COLLECTIVE_OPS:
        if op == c or op == c + "-start":
            base = c
            break
    else:
        return ("", 0.0, 0)
    if op.endswith("-done"):
        return ("", 0.0, 0)
    b = _shape_bytes(ins.type_str)
    n = _group_size(ins.line)
    ring = (n - 1) / n if n > 1 else 0.0
    if base == "all-reduce":
        wire = 2.0 * b * ring
    elif base == "all-gather":
        wire = b * ring
    elif base == "reduce-scatter":
        wire = b * n * ring
    elif base == "all-to-all":
        wire = b * ring
    else:  # collective-permute
        wire = float(b)
    return (base, wire, b)


_CALL_SINGLE_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CALL_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _exec_counts(comps: dict[str, _Comp]) -> tuple[dict[str, float], set[str]]:
    """Execution count per computation + the set of fusion-called comps."""
    counts: dict[str, float] = defaultdict(float)
    fusion_called: set[str] = set()
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return counts, fusion_called

    import sys
    sys.setrecursionlimit(10000)
    seen_stack: set[str] = set()

    def visit(name: str, mult: float):
        comp = comps.get(name)
        if comp is None or name in seen_stack:
            return
        counts[name] += mult
        seen_stack.add(name)
        for ins in comp.instrs:
            trip = 1.0
            if ins.op == "while":
                m = _TRIP_RE.search(ins.line)
                trip = float(m.group(1)) if m else 1.0
            targets = [t.group(1) for t in _CALL_SINGLE_RE.finditer(ins.line)]
            for br in _CALL_BRANCHES_RE.finditer(ins.line):
                targets += [t.strip().lstrip("%") for t in
                            br.group(1).split(",") if t.strip()]
            for t in targets:
                if ins.op == "fusion":
                    fusion_called.add(t)
                visit(t, mult * (trip if ins.op == "while" else 1.0))
        seen_stack.discard(name)

    visit(entry.name, 1.0)
    return counts, fusion_called


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict
    wire_bytes: dict
    # CPU XLA has no native bf16 dot: it upcasts operands and emits the
    # partial-sum all-reduce at f32 width.  On TPU the same collective rides
    # at bf16.  ``tpu_wire_bytes`` halves the wire bytes of f32 collectives
    # whose producing op is a dot (identified via op_name metadata).
    dot_f32_wire_bytes: float = 0.0

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    @property
    def tpu_wire_bytes(self) -> float:
        return self.total_wire_bytes - 0.5 * self.dot_f32_wire_bytes

    @property
    def total_count(self) -> int:
        return int(sum(self.counts.values()))

    def summary(self) -> dict:
        return {
            "counts": {k: int(v) for k, v in self.counts.items()},
            "result_bytes": {k: float(v) for k, v in self.result_bytes.items()},
            "wire_bytes": {k: float(v) for k, v in self.wire_bytes.items()},
            "total_wire_bytes": self.total_wire_bytes,
            "dot_f32_wire_bytes": float(self.dot_f32_wire_bytes),
            "tpu_wire_bytes": self.tpu_wire_bytes,
        }


@dataclasses.dataclass
class ModuleStats:
    flops: float  # per device, trip-count weighted
    hbm_bytes: float  # per device, fusion-boundary model
    collectives: CollectiveStats
    flagged_bytes: float = 0.0  # bytes of buffers matching flag_trailing_dim

    def summary(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collectives": self.collectives.summary(),
                "flagged_bytes": self.flagged_bytes}


def analyze_module(text: str, flag_trailing_dim: int | None = None,
                   flag_min_rank: int = 3) -> ModuleStats:
    """flag_trailing_dim: additionally accumulate the HBM bytes of buffers
    whose trailing dimension equals this value (rank ≥ flag_min_rank) —
    used to identify attention score/probability rows (trailing dim ==
    kv length), the traffic a fused flash-attention kernel keeps in VMEM."""
    comps = _parse_computations(text)
    counts, fusion_called = _exec_counts(comps)
    flops = 0.0
    hbm = 0.0
    flagged = 0.0
    ccounts: dict[str, float] = defaultdict(float)
    cresult: dict[str, float] = defaultdict(float)
    cwire: dict[str, float] = defaultdict(float)
    dot_f32_wire = 0.0
    for name, comp in comps.items():
        mult = counts.get(name, 0.0)
        if mult <= 0:
            continue
        for ins in comp.instrs:
            flops += mult * _instr_flops(ins, comp)
            if name not in fusion_called:
                b = _instr_bytes(ins, comp, comps)
                hbm += mult * b
                if flag_trailing_dim is not None and b > 0:
                    dims = _first_shape_dims(ins.type_str)
                    if len(dims) >= flag_min_rank and (
                            dims[-1] == flag_trailing_dim
                            or (dims[-2] == flag_trailing_dim
                                and dims[-1] <= 1024)):
                        # score rows (…, kv) or their bwd transposes
                        # (…, kv, chunk); activations keep trailing dims
                        # > 1024 (d_model/d_ff/vocab shards) and stay out
                        flagged += mult * b
                base, wire, cb = _collective_wire(ins, comp)
                if base:
                    ccounts[base] += mult
                    cresult[base] += mult * cb
                    cwire[base] += mult * wire
                    if "dot_general" in ins.line and " f32[" in \
                            " " + ins.type_str:
                        dot_f32_wire += mult * wire
    stats = CollectiveStats(dict(ccounts), dict(cresult), dict(cwire),
                            dot_f32_wire)
    return ModuleStats(flops, hbm, stats, flagged)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Trip-count-aware collective stats (API kept for callers/tests)."""
    return analyze_module(hlo_text).collectives
