"""Roofline terms for compiled dry-run artifacts (TPU v5e targets).

Per (arch × shape × mesh) cell:

  compute_s    = HLO_FLOPs   / (chips × 197e12)         [bf16 MXU peak]
  memory_s     = HLO_bytes   / (chips × 819e9)          [HBM]
  collective_s = wire_bytes  / (chips × 50e9)           [ICI per link]

``cost_analysis()`` on a post-SPMD module reports *per-device* flops/bytes, so
terms divide by 1 device; the helpers below normalize either convention via
``per_device`` — the dry-run stores raw values plus the convention used.

MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) over HLO_FLOPs measures how much
compiled compute is useful (catches remat & redundancy waste).
"""

from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12  # bf16, per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

__all__ = ["RooflineTerms", "compute_terms", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float  # summed over chips
    hlo_bytes_total: float
    wire_bytes_per_chip: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — >1 means XLA counted fewer flops than
        the analytic model (fusions), <1 means remat/redundant compute."""
        if self.hlo_flops_total <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops_total

    @property
    def mfu_bound(self) -> float:
        """Achievable MFU upper bound at this placement: useful flops over
        chips×peak×step_time."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops_total,
            "useful_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
            "step_time_s": self.step_time_s,
            "chips": self.chips,
        }


def compute_terms(
    hlo_flops: float,
    hlo_bytes: float,
    wire_bytes: float,
    chips: int,
    model_flops: float,
    per_device: bool = True,
) -> RooflineTerms:
    """Build roofline terms.

    per_device=True: hlo_flops/hlo_bytes/wire_bytes are per-chip quantities
    (the post-SPMD convention); False: global quantities divided by chips.
    """
    if per_device:
        flops_total = hlo_flops * chips
        bytes_total = hlo_bytes * chips
        wire_per_chip = wire_bytes
    else:
        flops_total = hlo_flops
        bytes_total = hlo_bytes
        wire_per_chip = wire_bytes / chips
    return RooflineTerms(
        compute_s=flops_total / (chips * PEAK_FLOPS),
        memory_s=bytes_total / (chips * HBM_BW),
        collective_s=wire_per_chip / ICI_BW,
        model_flops=model_flops,
        hlo_flops_total=flops_total,
        hlo_bytes_total=bytes_total,
        wire_bytes_per_chip=wire_per_chip,
        chips=chips,
    )
