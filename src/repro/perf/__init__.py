from repro.perf.hlo import CollectiveStats, parse_collectives
from repro.perf.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, RooflineTerms, compute_terms

__all__ = ["CollectiveStats", "parse_collectives", "RooflineTerms",
           "compute_terms", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
