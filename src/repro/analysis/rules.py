"""The shipped rule set — each rule enforces one invariant the stack's
correctness or performance story rests on (see ``README.md`` for the
catalog with rationale and example diagnostics).

Rules yield ``(node, message)`` or ``(node, message, severity)`` tuples;
the engine attaches defaults, locations, and suppressions.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import LintContext, rule

# -- shared helpers -----------------------------------------------------------

_JIT_LIKE = ("jax.jit", "jax.pmap")

#: numpy.random module-level functions that mutate GLOBAL rng state; the
#: Generator API (np.random.default_rng(...)) is the sanctioned source
_LEGACY_NP_RANDOM = frozenset({
    "seed", "random", "rand", "randn", "randint", "random_sample", "ranf",
    "sample", "uniform", "normal", "lognormal", "standard_normal",
    "poisson", "binomial", "choice", "shuffle", "permutation",
    "exponential", "gamma", "beta", "dirichlet", "multinomial", "integers",
    "random_integers", "bytes", "get_state", "set_state",
})

#: jax.random consumers that *use up* a key (reusing a key across two of
#: these silently correlates the streams); split/fold_in derive fresh keys
_KEY_SAFE = frozenset({"split", "fold_in", "key_data", "wrap_key_data",
                       "PRNGKey", "key", "clone"})

#: methods that mutate their receiver in place (or publish to a registry)
_MUTATORS = frozenset({"append", "extend", "insert", "pop", "remove",
                       "clear", "update", "setdefault", "add", "discard",
                       "observe", "set", "inc", "write", "popitem",
                       "appendleft"})

#: repo methods whose result lives on device (jitted dispatch outputs)
_DEVICE_METHODS = frozenset({"score_grid", "score_batch", "score_pairs",
                             "latency", "objective", "edge_latencies",
                             "block_until_ready"})

#: sanctioned batched device→host transfers: their RESULTS are host values
_HOST_TRANSFERS = frozenset({"jax.device_get"})

#: jnp ops whose output shape depends on VALUES — incompatible with jit /
#: Pallas static shapes
_DYNAMIC_SHAPE_OPS = frozenset({"jax.numpy.nonzero", "jax.numpy.flatnonzero",
                                "jax.numpy.argwhere", "jax.numpy.unique"})

#: float64-producing dtype spellings (jax.numpy constructors silently
#: downcast or warn under the default x64-disabled config)
_F64_NAMES = frozenset({"numpy.float64", "jax.numpy.float64"})


def _target_names(t) -> set[str]:
    out: set[str] = set()
    for node in ast.walk(t):
        if isinstance(node, ast.Name):
            out.add(node.id)
    return out


def _bound_names(fn) -> set[str]:
    """Names bound anywhere inside a function node: params, assignments,
    loop/with/comprehension targets, nested defs."""
    a = fn.args
    names = {arg.arg for arg in (*getattr(a, "posonlyargs", ()), *a.args,
                                 *a.kwonlyargs)}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                names |= _target_names(t)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign, ast.NamedExpr)):
            names |= _target_names(node.target)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            names |= _target_names(node.target)
        elif isinstance(node, ast.comprehension):
            names |= _target_names(node.target)
        elif isinstance(node, ast.withitem) and node.optional_vars:
            names |= _target_names(node.optional_vars)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            names.add(node.name)
    return names


def _free_names(fn) -> set[str]:
    """Names a lambda/def loads but does not bind (its closure)."""
    bound = _bound_names(fn)
    loads = {n.id for n in ast.walk(fn)
             if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
    return loads - bound


def _jit_wrapper(ctx: LintContext, call: ast.Call) -> str | None:
    name = ctx.resolve(call.func)
    if name in _JIT_LIKE:
        return name
    if name in ("functools.partial", "partial") and call.args \
            and ctx.resolve(call.args[0]) in _JIT_LIKE:
        return ctx.resolve(call.args[0])
    return None


def _contains_device_call(ctx: LintContext, node,
                          device_names: set[str]) -> bool:
    """Does this expression (sub)tree produce a device value — a jax/jnp
    call, a known dispatch method, or a name assigned from one?"""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            name = ctx.resolve(n.func)
            if name in _HOST_TRANSFERS:
                continue
            if name and (name == "jax" or name.startswith("jax.")):
                return True
            if isinstance(n.func, ast.Attribute) \
                    and n.func.attr in _DEVICE_METHODS:
                return True
        elif isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                and n.id in device_names:
            return True
    return False


def _device_names_in_scope(ctx: LintContext, scope) -> set[str]:
    """Names assigned from jax/jnp calls or dispatch methods in a scope."""
    out: set[str] = set()
    for n in ast.walk(scope):
        if not (isinstance(n, ast.Assign) and isinstance(n.value, ast.Call)):
            continue
        name = ctx.resolve(n.value.func)
        devicey = (name and name.startswith("jax.")
                   and name not in _HOST_TRANSFERS) or (
            isinstance(n.value.func, ast.Attribute)
            and n.value.func.attr in _DEVICE_METHODS)
        if devicey:
            for t in n.targets:
                out |= _target_names(t)
    return out


# -- rule 1: no-silent-retrace ------------------------------------------------

@rule("no-silent-retrace", severity="error",
      summary="jit wrappers built per loop iteration or closing over "
              "call-varying Python scalars retrace/recompile silently")
def check_no_silent_retrace(ctx: LintContext):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        wrapper = _jit_wrapper(ctx, node)
        if wrapper is None:
            continue
        if ctx.in_traced(node):
            continue  # inside a trace everything is one compile unit
        fn_arg = node.args[0] if node.args else None

        # (a) jitted closure capturing an enclosing loop variable: every
        # distinct value compiles a fresh executable (constant-folded in)
        if isinstance(fn_arg, ast.Lambda):
            frees = _free_names(fn_arg)
            captured = set()
            for loop in ctx.enclosing_loops(node):
                if isinstance(loop, (ast.For, ast.AsyncFor)):
                    captured |= _target_names(loop.target) & frees
            if captured:
                yield (node, f"{wrapper} closes over loop variable(s) "
                             f"{sorted(captured)} — each value bakes in as "
                             f"a constant and compiles a fresh executable; "
                             f"pass them as traced arguments instead")
                continue

        # (b) wrapper constructed inside a loop
        if ctx.in_loop(node):
            invariant = isinstance(fn_arg, ast.Name) and not any(
                fn_arg.id in _bound_names_of_loop(loop)
                for loop in ctx.enclosing_loops(node))
            if invariant:
                yield (node, f"{wrapper}({fn_arg.id}) inside a loop re-wraps "
                             f"a loop-invariant function — every iteration "
                             f"gets a fresh callable with an empty compile "
                             f"cache; hoist the jit outside the loop")
            else:
                yield (node, f"{wrapper} inside a loop compiles once per "
                             f"iteration; hoist it if the function is "
                             f"loop-invariant, or suppress if per-iteration "
                             f"compilation is intended", "warning")


def _bound_names_of_loop(loop) -> set[str]:
    names = set()
    if isinstance(loop, (ast.For, ast.AsyncFor)):
        names |= _target_names(loop.target)
    for stmt in ast.walk(loop):
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                names |= _target_names(t)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            names |= _target_names(stmt.target)
    return names


# -- rule 2: dtype-discipline -------------------------------------------------

_ORACLE_SUFFIXES = ("core/costmodel.py",)


@rule("dtype-discipline", severity="error",
      summary="float64 leaks in jnp twins, np/jnp mixing in traced code, "
              "float32 inside the float64 scalar oracles")
def check_dtype_discipline(ctx: LintContext):
    path = ctx.path.replace("\\", "/")
    in_oracle = path.endswith(_ORACLE_SUFFIXES)
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute):
            name = ctx.resolve(node)
            if name == "jax.numpy.float64":
                yield (node, "jnp.float64 in a batched twin — the stack "
                             "runs x64-disabled, so this silently degrades "
                             "to float32 (or warns); the float64 contract "
                             "belongs to the numpy oracle only")
            elif in_oracle and name and name.endswith(".float32"):
                yield (node, "float32 inside a float64 scalar-oracle module "
                             "— the oracle is the precision reference the "
                             "batched twins are tested against")
        elif isinstance(node, ast.Constant) and node.value == "float32" \
                and in_oracle:
            yield (node, "float32 dtype string inside a float64 "
                         "scalar-oracle module")
        elif isinstance(node, (ast.Import, ast.ImportFrom)) and in_oracle:
            mods = [a.name for a in node.names] if isinstance(
                node, ast.Import) else [node.module or ""]
            if any(m == "jax" or m.startswith("jax.") for m in mods):
                yield (node, "jax import inside a scalar-oracle module — "
                             "oracles stay pure float64 numpy; put jnp "
                             "twins in their own module")
        elif isinstance(node, ast.Call):
            name = ctx.resolve(node.func)
            if name and name.startswith("jax.numpy."):
                for kw in node.keywords:
                    if kw.arg == "dtype" and _is_f64(ctx, kw.value):
                        yield (kw.value, f"{name.replace('jax.numpy.', 'jnp.')}"
                                         f"(dtype=float64) — x64 is disabled; "
                                         f"the twin must stay float32")
                if name in ("jax.numpy.asarray", "jax.numpy.array") \
                        and len(node.args) > 1 and _is_f64(ctx, node.args[1]):
                    yield (node.args[1], "float64 dtype passed to a jnp "
                                         "constructor — x64 is disabled; "
                                         "the twin must stay float32")
            elif name and name.startswith("numpy.") \
                    and not name.startswith("numpy.random.") \
                    and ctx.in_traced(node):
                yield (node, f"np call ({name.replace('numpy.', 'np.')}) "
                             f"inside traced code — numpy executes at trace "
                             f"time on tracers it cannot see (silent "
                             f"constant-folding or a concretization error); "
                             f"use the jnp twin")


def _is_f64(ctx: LintContext, node) -> bool:
    if isinstance(node, ast.Constant):
        return node.value == "float64"
    return ctx.resolve(node) in _F64_NAMES


# -- rule 3: jit-purity -------------------------------------------------------

@rule("jit-purity", severity="error",
      summary="Python side effects inside traced functions run at trace "
              "time only — prints, registry writes, attribute mutation")
def check_jit_purity(ctx: LintContext):
    # bound-name cache per traced scope chain
    bound_cache: dict = {}

    def locals_of(node) -> set[str]:
        """Union of names bound by every enclosing function up to (and
        including) the outermost traced one — values created during the
        trace, which are fair game to mutate."""
        chain = []
        for anc in ctx.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                chain.append(anc)
        key = tuple(id(f) for f in chain)
        if key not in bound_cache:
            names: set[str] = set()
            for f in chain:
                names |= _bound_names(f)
            bound_cache[key] = names
        return bound_cache[key]

    for node in ast.walk(ctx.tree):
        if not ctx.in_traced(node):
            continue
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "print":
            yield (node, "print() inside a traced function fires at trace "
                         "time only (once per compilation, not per call); "
                         "use jax.debug.print or hoist it")
        elif isinstance(node, (ast.Global, ast.Nonlocal)):
            kw = "global" if isinstance(node, ast.Global) else "nonlocal"
            yield (node, f"{kw} write inside a traced function mutates "
                         f"Python state at trace time only — the compiled "
                         f"executable never re-runs it")
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute) and \
                        isinstance(t.value, ast.Name):
                    base = t.value.id
                    if base in ("self", "cls") or base not in locals_of(node):
                        yield (t, f"attribute write `{base}.{t.attr} = ...` "
                                  f"inside a traced function mutates host "
                                  f"state at trace time — it will NOT "
                                  f"happen on later cached calls")
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATORS:
            recv = node.func.value
            # functional updates x.at[i].add(...) are pure — exempt
            if isinstance(recv, ast.Subscript) and \
                    isinstance(recv.value, ast.Attribute) and \
                    recv.value.attr == "at":
                continue
            if isinstance(recv, ast.Name):
                if recv.id not in locals_of(node):
                    yield (node, f"`.{node.func.attr}()` on closed-over "
                                 f"`{recv.id}` inside a traced function — "
                                 f"the mutation happens at trace time only; "
                                 f"thread state through function returns")
            elif isinstance(recv, ast.Call):
                yield (node, f"`.{node.func.attr}()` on a call result "
                             f"inside a traced function (registry/metric "
                             f"write?) — side effects are dropped on "
                             f"cached executions; record metrics outside "
                             f"the traced region (repro.obs pattern: guard "
                             f"at the dispatch site, not in the trace)")


# -- rule 4: hidden-host-sync -------------------------------------------------

@rule("hidden-host-sync", severity="error",
      summary=".item()/float()/np.asarray() on device values inside hot "
              "loops serializes every iteration on a device→host transfer")
def check_hidden_host_sync(ctx: LintContext):
    if not ctx.imports_module("jax"):
        return
    scope_cache: dict = {}

    def device_names(node) -> set[str]:
        scope = ctx.enclosing_function(node) or ctx.tree
        key = id(scope)
        if key not in scope_cache:
            scope_cache[key] = _device_names_in_scope(ctx, scope)
        return scope_cache[key]

    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and ctx.in_loop(node)):
            continue
        if ctx.in_traced(node):
            continue  # inside a trace there is no host to sync to
        # x.item() / x.block_until_ready() on a device-derived value
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("item", "block_until_ready"):
            if _contains_device_call(ctx, node.func.value,
                                     device_names(node)):
                yield (node, f"`.{node.func.attr}()` inside a loop forces a "
                             f"device→host sync every iteration — batch the "
                             f"values and transfer once after the loop")
            elif node.func.attr == "item":
                yield (node, "`.item()` inside a loop — if the receiver "
                             "lives on device this syncs every iteration",
                       "warning")
            continue
        name = ctx.resolve(node.func)
        is_cast = isinstance(node.func, ast.Name) \
            and node.func.id in ("float", "int", "bool")
        is_np_pull = name in ("numpy.asarray", "numpy.array")
        if not (is_cast or is_np_pull) or not node.args:
            continue
        if _contains_device_call(ctx, node.args[0], device_names(node)):
            what = node.func.id if is_cast else name.replace("numpy.", "np.")
            yield (node, f"`{what}(...)` on a device value inside a loop is "
                         f"a hidden host sync per iteration — keep the loop "
                         f"on device (vmap/lax) or transfer once afterwards")


# -- rule 5: rng-discipline ---------------------------------------------------

@rule("rng-discipline", severity="error",
      summary="global numpy/stdlib rng state and jax PRNG key reuse break "
              "seed-for-seed reproducibility")
def check_rng_discipline(ctx: LintContext):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = ctx.resolve(node.func)
        if not name:
            continue
        if name.startswith("numpy.random.") \
                and name.split(".")[-1] in _LEGACY_NP_RANDOM:
            yield (node, f"np.random.{name.split('.')[-1]}() draws from "
                         f"GLOBAL rng state — every generator takes an "
                         f"explicit np.random.Generator (rng=) so traces "
                         f"are seed-for-seed reproducible and rng-stream "
                         f"compatible")
        elif name.startswith("random.") and "random" in ctx.imports \
                and ctx.imports["random"] == "random":
            yield (node, f"stdlib {name}() draws from global rng state — "
                         f"pass an explicit np.random.Generator instead")

    # PRNG key reuse: a key consumed by two samplers without a split
    scopes = [n for n in ast.walk(ctx.tree)
              if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    scopes.append(ctx.tree)
    seen_fns: set[int] = set()
    for scope in scopes:
        yield from _check_key_reuse(ctx, scope, seen_fns)


def _walk_scope(scope):
    """Walk a scope WITHOUT descending into nested function definitions —
    each function gets its own key-reuse scan (no double reporting)."""
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def _check_key_reuse(ctx: LintContext, scope, seen_fns: set[int]):
    if id(scope) in seen_fns:
        return
    seen_fns.add(id(scope))
    events: list[tuple] = []  # (line, col, kind, name, node)
    for node in _walk_scope(scope):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            vname = ctx.resolve(node.value.func)
            for t in node.targets:
                if isinstance(t, ast.Name):
                    kind = "key" if vname in ("jax.random.PRNGKey",
                                              "jax.random.key") else "other"
                    events.append((node.lineno, node.col_offset, "assign",
                                   t.id, kind))
        elif isinstance(node, ast.Call):
            cname = ctx.resolve(node.func)
            if not (cname and cname.startswith("jax.random.")):
                continue
            if cname.split(".")[-1] in _KEY_SAFE:
                continue
            for arg in node.args[:1]:  # key is the first positional arg
                if isinstance(arg, ast.Name):
                    events.append((node.lineno, node.col_offset, "use",
                                   arg.id, node))
    events.sort(key=lambda e: (e[0], e[1]))
    used_once: dict[str, bool] = {}
    for line, col, kind, name, extra in events:
        if kind == "assign":
            used_once[name] = False if extra == "key" else None
        elif kind == "use" and used_once.get(name) is not None:
            if used_once.get(name):
                yield ((line, col + 1),
                       f"PRNG key `{name}` consumed by a second sampler "
                       f"without jax.random.split — reused keys emit "
                       f"IDENTICAL randomness across the two draws")
            elif name in used_once:
                used_once[name] = True


# -- rule 6: pallas-constraints ----------------------------------------------

@rule("pallas-constraints", severity="error",
      summary="Pallas grid/BlockSpec shape mismatches and dynamic-shape "
              "ops that cannot compile to a static kernel")
def check_pallas_constraints(ctx: LintContext):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = ctx.resolve(node.func)
        if name in _DYNAMIC_SHAPE_OPS and (ctx.in_traced(node)
                                           or ctx.in_kernel(node)):
            yield (node, f"{name.replace('jax.numpy.', 'jnp.')} has a "
                         f"value-dependent output shape — inside jit/Pallas "
                         f"this fails to trace (or forces host fallback); "
                         f"use masking (jnp.where with a fill value) with "
                         f"a static shape")
            continue
        if name == "jax.numpy.where" and len(node.args) == 1 \
                and (ctx.in_traced(node) or ctx.in_kernel(node)):
            yield (node, "single-argument jnp.where returns value-dependent "
                         "shapes — use the three-argument masking form "
                         "inside traced/kernel code")
            continue
        if not (name and name.endswith("pallas_call")):
            continue
        grid_len = None
        for kw in node.keywords:
            if kw.arg == "grid" and isinstance(kw.value, ast.Tuple):
                grid_len = len(kw.value.elts)
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.BinOp) and \
                            isinstance(el.op, ast.Div):
                        yield (el, "true division `/` inside a Pallas grid "
                                   "expression yields a float — grids are "
                                   "integer step counts; use `//` after "
                                   "padding the axis to a multiple of the "
                                   "block")
        for kw in node.keywords:
            if kw.arg not in ("in_specs", "out_specs"):
                continue
            for spec in ast.walk(kw.value):
                if not (isinstance(spec, ast.Call)
                        and isinstance(spec.func, (ast.Attribute, ast.Name))
                        and (spec.func.attr if isinstance(
                            spec.func, ast.Attribute) else
                            spec.func.id) == "BlockSpec"):
                    continue
                block_len = None
                if spec.args and isinstance(spec.args[0], ast.Tuple):
                    block_len = len(spec.args[0].elts)
                if len(spec.args) > 1 and isinstance(spec.args[1],
                                                     ast.Lambda):
                    lam = spec.args[1]
                    n_params = len(lam.args.args)
                    if grid_len is not None and n_params != grid_len:
                        yield (spec, f"BlockSpec index_map takes {n_params} "
                                     f"arg(s) but the grid has {grid_len} "
                                     f"dimension(s) — one index per grid "
                                     f"axis")
                    if block_len is not None and \
                            isinstance(lam.body, ast.Tuple) and \
                            len(lam.body.elts) != block_len:
                        yield (spec, f"BlockSpec block_shape has "
                                     f"{block_len} dim(s) but its index_map "
                                     f"returns {len(lam.body.elts)} — the "
                                     f"index tuple must match the block "
                                     f"rank")
