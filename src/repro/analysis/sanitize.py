"""Runtime sanitizer — the dynamic half of ``repro.analysis``.

The linter prevents invariant violations statically; this module catches
the ones only visible at runtime: NaN/Inf escaping a batched dispatch,
candidate batches whose dtype/shape would send XLA into an opaque retrace,
dq values outside the model's domain, and compile-cache misses beyond a
configured retrace budget (built on the same shape buckets
``search.bucket_first_dispatch`` already meters).

Same cost contract as ``repro.obs``: DISABLED by default, every
instrumented site guards on one attribute read (``sanitize.state().enabled``),
and the ENABLED overhead on the ``score_batch`` hot loop is gated <5% in
``benchmarks/bench_analysis.py`` — with bitwise-identical argmins, because
the checks only READ values the computation already produced.

    from repro.analysis import sanitize

    with sanitize.sanitized(retrace_budget=4):
        eng.score_batch(xs, dqs)      # raises AnalysisError on violation

The domain-check helpers (:func:`check_placements`, :func:`check_dq`,
:func:`check_finite`) are plain functions so always-on call sites — the
upfront validation in ``BatchedProblem.score_batch`` — reuse them without
enabling the sanitizer.
"""

from __future__ import annotations

import contextlib
import dataclasses

import numpy as np

from repro.analysis.errors import AnalysisError

__all__ = ["AnalysisError", "SanitizerState", "state", "enabled", "enable",
           "disable", "sanitized", "check_placements", "check_dq",
           "check_finite", "note_first_dispatch"]


@dataclasses.dataclass
class SanitizerState:
    """Process-local switchboard; ``enabled`` is the one-attribute-read
    hot-path guard (mirroring ``repro.obs.registry().enabled``)."""

    enabled: bool = False
    nan_check: bool = True
    domain_check: bool = True
    #: max number of distinct shape-bucket first-dispatches (compile-cache
    #: misses) tolerated since enable(); None = unmetered
    retrace_budget: int | None = None
    first_dispatches: int = 0


_state = SanitizerState()


def state() -> SanitizerState:
    return _state


def enabled() -> bool:
    return _state.enabled


def enable(retrace_budget: int | None = None, nan_check: bool = True,
           domain_check: bool = True) -> None:
    """Arm the sanitizer (resets the retrace-budget accounting)."""
    _state.enabled = True
    _state.nan_check = nan_check
    _state.domain_check = domain_check
    _state.retrace_budget = retrace_budget
    _state.first_dispatches = 0


def disable() -> None:
    _state.enabled = False
    _state.retrace_budget = None
    _state.first_dispatches = 0


@contextlib.contextmanager
def sanitized(retrace_budget: int | None = None, nan_check: bool = True,
              domain_check: bool = True):
    """Enable for the duration of a block; restores the prior state."""
    prior = dataclasses.replace(_state)
    enable(retrace_budget=retrace_budget, nan_check=nan_check,
           domain_check=domain_check)
    try:
        yield _state
    finally:
        _state.enabled = prior.enabled
        _state.nan_check = prior.nan_check
        _state.domain_check = prior.domain_check
        _state.retrace_budget = prior.retrace_budget
        _state.first_dispatches = prior.first_dispatches


# -- domain checks (plain functions: usable without enabling) -----------------

def check_placements(xs: np.ndarray, n_ops: int, n_devices: int, *,
                     bucket=None, finite: bool = False) -> None:
    """Validate a candidate batch BEFORE it reaches the jitted grid.

    Shape must be (..., n_ops, n_devices) and the dtype real-numeric —
    anything else would hand XLA a fresh abstract signature and surface as
    an opaque retrace (or a crash deep inside the dispatch).  ``finite=True``
    additionally rejects NaN/Inf entries (placement rows are probability
    masses; non-finite mass silently poisons every downstream objective).
    """
    xs = np.asarray(xs)
    if xs.dtype == object or not (np.issubdtype(xs.dtype, np.floating)
                                  or np.issubdtype(xs.dtype, np.integer)
                                  or np.issubdtype(xs.dtype, np.bool_)):
        raise AnalysisError(
            "score-batch-domain",
            f"candidate batch dtype {xs.dtype} is not real-numeric — XLA "
            f"would retrace (or fail) on an opaque abstract signature",
            bucket=bucket, dtype=str(xs.dtype))
    if xs.ndim < 2 or xs.shape[-2:] != (n_ops, n_devices):
        raise AnalysisError(
            "score-batch-domain",
            f"candidate batch shape {xs.shape} does not end in "
            f"(n_ops, n_devices) = ({n_ops}, {n_devices}) — a mis-shaped "
            f"batch dispatches into a fresh shape bucket and retraces",
            bucket=bucket, shape=tuple(xs.shape))
    if finite and not np.isfinite(xs).all():
        bad = int(np.size(xs) - np.isfinite(xs).sum())
        raise AnalysisError(
            "score-batch-domain",
            f"candidate batch carries {bad} non-finite entr(ies) — "
            f"placement mass must be finite",
            bucket=bucket, n_nonfinite=bad)


def check_dq(dq, *, bucket=None) -> None:
    """dq_fraction lives in [0, 1]: the fraction of rows degraded away."""
    if type(dq) is float or type(dq) is int:  # hot-path scalar fast path
        if 0.0 <= dq <= 1.0:
            return
    arr = np.asarray(dq, dtype=np.float64)
    # NaN propagates through min/max and fails both comparisons, so two
    # scalar reductions cover range AND the non-finite case without
    # allocating boolean temporaries (this runs on every score_batch)
    if arr.size and not (arr.min() >= 0.0 and arr.max() <= 1.0):
        raise AnalysisError(
            "dq-domain",
            f"dq_fraction outside [0, 1] (or non-finite): "
            f"min={float(arr.min()) if arr.size else 0}, "
            f"max={float(arr.max()) if arr.size else 0}",
            bucket=bucket)


def check_finite(name: str, arr, *, allow_inf: bool = True,
                 bucket=None) -> None:
    """NaN (and optionally Inf) guard on a dispatch output.  ``allow_inf``
    defaults True because +inf is the legitimate infeasible marker."""
    a = np.asarray(arr)
    # single-pass screen: any NaN poisons the sum, and Inf survives it,
    # so a finite sum proves the whole array clean (float dtypes cannot
    # overflow a float64 accumulation to Inf unless an Inf-scale value
    # is already present — which the precise pass below then finds)
    s = float(a.sum(dtype=np.float64)) if a.size else 0.0
    if s - s == 0.0 and allow_inf:
        return
    if np.isnan(a).any():
        raise AnalysisError(
            "nan-guard",
            f"{name} produced {int(np.isnan(a).sum())} NaN(s)",
            name=name, bucket=bucket)
    if not allow_inf and np.isinf(a).any():
        raise AnalysisError(
            "nan-guard",
            f"{name} produced {int(np.isinf(a).sum())} Inf(s)",
            name=name, bucket=bucket)


def note_first_dispatch(bucket) -> None:
    """Record a shape-bucket compile-cache miss; trips the retrace budget.

    Called by ``BatchedProblem`` exactly where the
    ``search.bucket_first_dispatch`` metric increments, so the static
    budget and the telemetry agree on what counts as a retrace.
    """
    if not _state.enabled or _state.retrace_budget is None:
        return
    _state.first_dispatches += 1
    if _state.first_dispatches > _state.retrace_budget:
        raise AnalysisError(
            "no-silent-retrace",
            f"retrace budget exceeded: {_state.first_dispatches} shape-"
            f"bucket first-dispatches > budget {_state.retrace_budget} — "
            f"candidate batches are leaking new padded shapes (warm the "
            f"buckets up front or fix the proposal source)",
            bucket=bucket, budget=_state.retrace_budget)
