"""Rule engine of the ``repro.analysis`` linter.

The linter is AST-based and repo-aware: a :class:`LintContext` parses one
file and precomputes the facts every rule needs — import aliases resolved
to canonical dotted names (``jnp`` → ``jax.numpy``), the set of TRACED
functions (decorated with / passed to ``jax.jit`` / ``vmap`` / ``lax.map``
/ ``pallas_call`` …), Pallas kernel bodies, parent links, and suppression
comments.  Rules (:mod:`repro.analysis.rules`) register themselves in
:data:`RULES` and yield ``(node, message)`` pairs; the engine attaches
severity, applies ``# repro: ignore[rule-id]`` suppressions, and renders
human or JSON output.

Suppressions:

  * same-line: ``expr  # repro: ignore[rule-id]`` (comma-separate several
    ids; bare ``# repro: ignore`` silences every rule on that line);
  * file-level: ``# repro: ignore-file[rule-id]`` anywhere in the file.

Exit policy: findings carry a per-rule severity (``error`` / ``warning``);
only errors fail the run (``--strict`` promotes warnings).
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Callable, Iterable, Iterator

__all__ = ["Finding", "Rule", "RULES", "rule", "LintContext", "lint_file",
           "lint_source", "lint_paths", "iter_python_files",
           "render_human", "render_json", "DEFAULT_EXCLUDED_DIRS"]

SEVERITIES = ("error", "warning")

# directories never linted by default: fixture trees deliberately contain
# rule violations, caches/VCS internals are noise
DEFAULT_EXCLUDED_DIRS = frozenset(
    {"fixtures", "__pycache__", ".git", ".venv", "node_modules"})

_IGNORE_RE = re.compile(
    r"#\s*repro:\s*ignore(?:-file)?(?:\[([A-Za-z0-9_,\- ]+)\])?")
_IGNORE_FILE_RE = re.compile(
    r"#\s*repro:\s*ignore-file(?:\[([A-Za-z0-9_,\- ]+)\])?")

# wrappers whose function arguments are traced by JAX (the closure body
# runs under tracing, so host-side Python inside it is suspect)
TRACE_WRAPPERS = frozenset({
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.map", "jax.lax.scan",
    "jax.lax.cond", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.switch", "jax.experimental.pallas.pallas_call",
})


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: where, which rule, how bad, and why."""

    rule: str
    severity: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.severity}[{self.rule}] {self.message}")

    def row(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered rule: id, default severity, one-line summary, and the
    check itself — ``check(ctx)`` yields ``(ast.AST | (line, col), msg)``."""

    id: str
    severity: str
    summary: str
    check: Callable[["LintContext"], Iterable[tuple]]


RULES: dict[str, Rule] = {}


def rule(rule_id: str, severity: str = "error", summary: str = ""):
    """Register a rule function under ``rule_id`` (kebab-case)."""
    if severity not in SEVERITIES:
        raise ValueError(f"severity must be one of {SEVERITIES}")

    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = Rule(rule_id, severity, summary or (fn.__doc__ or
                                                             "").strip(), fn)
        return fn

    return deco


def _parse_suppressions(source: str) -> tuple[dict[int, set], set]:
    """line → suppressed rule ids ({"*"} = all); plus file-level ids."""
    per_line: dict[int, set] = {}
    file_level: set = set()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _IGNORE_RE.search(tok.string)
            if not m:
                continue
            ids = ({i.strip() for i in m.group(1).split(",") if i.strip()}
                   if m.group(1) else {"*"})
            if _IGNORE_FILE_RE.search(tok.string):
                file_level |= ids
            else:
                per_line.setdefault(tok.start[0], set()).update(ids)
    except tokenize.TokenError:
        pass
    return per_line, file_level


class LintContext:
    """Parsed file + precomputed facts shared by every rule."""

    def __init__(self, path: str, source: str, tree: ast.AST):
        self.path = path
        self.source = source
        self.tree = tree
        self.suppress_lines, self.suppress_file = _parse_suppressions(source)
        self._parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self._parents[child] = node
        self.imports = self._collect_imports()
        self.traced, self.kernels = self._collect_traced()

    # -- imports / name resolution -------------------------------------------
    def _collect_imports(self) -> dict[str, str]:
        out: dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = \
                        a.name if a.asname else a.name.split(".")[0]
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
        return out

    def resolve(self, node) -> str | None:
        """Canonical dotted name of a Name/Attribute chain, through the
        file's import aliases (``jnp.max`` → ``jax.numpy.max``)."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(self.imports.get(node.id, node.id))
        return ".".join(reversed(parts))

    def imports_module(self, prefix: str) -> bool:
        return any(m == prefix or m.startswith(prefix + ".")
                   for m in self.imports.values())

    # -- traced-function discovery -------------------------------------------
    def _collect_traced(self) -> tuple[set, set]:
        traced: set = set()
        kernel_nodes: set = set()
        traced_names: set[str] = set()
        kernel_names: set[str] = set()

        def wrapper_of(call: ast.Call) -> str | None:
            name = self.resolve(call.func)
            if name in TRACE_WRAPPERS:
                return name
            # functools.partial(jax.jit, ...) used as wrapper or decorator
            if name in ("functools.partial", "partial") and call.args:
                inner = self.resolve(call.args[0])
                if inner in TRACE_WRAPPERS:
                    return inner
            return None

        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    name = self.resolve(dec) if not isinstance(dec, ast.Call) \
                        else wrapper_of(dec)
                    if name in TRACE_WRAPPERS:
                        traced.add(node)
            elif isinstance(node, ast.Call):
                wrapper = wrapper_of(node)
                if wrapper is None:
                    continue
                is_pallas = wrapper.endswith("pallas_call")
                for i, arg in enumerate(node.args):
                    if isinstance(arg, ast.Lambda):
                        traced.add(arg)
                    elif isinstance(arg, ast.Name):
                        traced_names.add(arg.id)
                        if is_pallas and i == 0:
                            kernel_names.add(arg.id)
                    elif isinstance(arg, ast.Attribute):
                        traced_names.add(arg.attr)
                        if is_pallas and i == 0:
                            kernel_names.add(arg.attr)

        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.name in traced_names:
                    traced.add(node)
                if node.name in kernel_names:
                    traced.add(node)
                    kernel_nodes.add(node)
        return traced, kernel_nodes

    # -- tree navigation ------------------------------------------------------
    def parent(self, node) -> ast.AST | None:
        return self._parents.get(node)

    def ancestors(self, node) -> Iterator[ast.AST]:
        node = self._parents.get(node)
        while node is not None:
            yield node
            node = self._parents.get(node)

    def enclosing_function(self, node):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return anc
        return None

    def in_traced(self, node) -> bool:
        """True when any enclosing function/lambda is traced by JAX."""
        for anc in self.ancestors(node):
            if anc in self.traced:
                return True
        return False

    def in_kernel(self, node) -> bool:
        for anc in self.ancestors(node):
            if anc in self.kernels:
                return True
        return False

    def in_loop(self, node) -> bool:
        """True when the node sits inside a for/while/comprehension body,
        stopping at the nearest enclosing function boundary."""
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return False
            if isinstance(anc, (ast.For, ast.AsyncFor, ast.While,
                                ast.ListComp, ast.SetComp, ast.DictComp,
                                ast.GeneratorExp)):
                return True
        return False

    def enclosing_loops(self, node) -> Iterator[ast.AST]:
        """Every for/while loop around the node, innermost first, crossing
        function boundaries (for closure-capture checks)."""
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.For, ast.AsyncFor, ast.While)):
                yield anc

    # -- suppression -----------------------------------------------------------
    def suppressed(self, rule_id: str, line: int) -> bool:
        if "*" in self.suppress_file or rule_id in self.suppress_file:
            return True
        ids = self.suppress_lines.get(line)
        return ids is not None and ("*" in ids or rule_id in ids)


def _loc(node) -> tuple[int, int]:
    if isinstance(node, tuple):
        return node
    return (getattr(node, "lineno", 1), getattr(node, "col_offset", 0) + 1)


def lint_source(path: str, source: str,
                select: set[str] | None = None) -> tuple[list[Finding], int]:
    """Lint one source string → (findings, n_suppressed).  ``select``
    restricts to a subset of rule ids (default: all registered)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("syntax", "error", path, e.lineno or 1,
                        (e.offset or 0) + 1, f"syntax error: {e.msg}")], 0
    ctx = LintContext(path, source, tree)
    findings: list[Finding] = []
    suppressed = 0
    for rid, r in sorted(RULES.items()):
        if select is not None and rid not in select:
            continue
        for item in r.check(ctx):
            node, message = item[0], item[1]
            severity = item[2] if len(item) > 2 else r.severity
            line, col = _loc(node)
            if ctx.suppressed(rid, line):
                suppressed += 1
                continue
            findings.append(Finding(rid, severity, path, line, col, message))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings, suppressed


def lint_file(path, select: set[str] | None = None
              ) -> tuple[list[Finding], int]:
    p = Path(path)
    return lint_source(str(p), p.read_text(encoding="utf-8"), select=select)


def iter_python_files(paths: Iterable,
                      excluded_dirs: frozenset = DEFAULT_EXCLUDED_DIRS
                      ) -> Iterator[Path]:
    seen = set()
    for raw in paths:
        p = Path(raw)
        files = [p] if p.is_file() else sorted(
            f for f in p.rglob("*.py")
            if not (set(f.parts) & excluded_dirs))
        for f in files:
            if f.suffix == ".py" and f not in seen:
                seen.add(f)
                yield f


def lint_paths(paths: Iterable, select: set[str] | None = None,
               excluded_dirs: frozenset = DEFAULT_EXCLUDED_DIRS) -> dict:
    """Lint every ``*.py`` under ``paths`` → report dict (see
    :func:`render_json` for the schema)."""
    findings: list[Finding] = []
    n_suppressed = 0
    n_files = 0
    for f in iter_python_files(paths, excluded_dirs):
        n_files += 1
        fs, sup = lint_file(f, select=select)
        findings.extend(fs)
        n_suppressed += sup
    return {
        "version": 1,
        "paths": [str(p) for p in paths],
        "files_checked": n_files,
        "counts": {
            "error": sum(f.severity == "error" for f in findings),
            "warning": sum(f.severity == "warning" for f in findings),
            "suppressed": n_suppressed,
        },
        "findings": [f.row() for f in findings],
    }


def render_human(report: dict) -> str:
    lines = [Finding(**row).render() for row in report["findings"]]
    c = report["counts"]
    lines.append(f"{c['error']} error(s), {c['warning']} warning(s), "
                 f"{c['suppressed']} suppressed — "
                 f"{report['files_checked']} file(s) checked")
    return "\n".join(lines)


def render_json(report: dict) -> str:
    return json.dumps(report, indent=2)
