"""repro.analysis — trace-safety & numerics static analysis (lint) plus a
runtime sanitizer for the whole stack.

The codebase rests on invariants nothing else enforces mechanically:
float64 scalar oracles vs float32 batched twins, one-dispatch-per-block
search, rng-stream compatibility of trace generators, telemetry that is
bitwise-invariant and free when off.  This package makes them checkable:

  * **lint** — ``python -m repro.analysis src/ tests/ benchmarks/`` runs an
    AST rule engine (per-rule severity, ``# repro: ignore[rule-id]``
    suppressions, JSON + human output) over the tree; CI keeps ``src/`` at
    zero errors.  Rule catalog: ``src/repro/analysis/README.md``.
  * **sanitize** — an opt-in runtime layer (same <5%-overhead contract as
    ``repro.obs``) that guards ``score_grid``/``score_batch`` with NaN/Inf
    checks, candidate dtype/shape/dq domain validation, and a retrace
    budget on the existing ``search.bucket_first_dispatch`` buckets;
    violations raise a typed :class:`AnalysisError` naming the offending
    shape bucket instead of an opaque XLA retrace.

    from repro import analysis
    report = analysis.lint_paths(["src"])          # static pass
    with analysis.sanitize.sanitized(retrace_budget=4):
        eng.score_batch(xs, dqs)                   # runtime guards armed
"""

from repro.analysis import rules as _rules  # noqa: F401 — registers rules
from repro.analysis import sanitize
from repro.analysis.engine import (RULES, Finding, Rule, lint_file,
                                   lint_paths, lint_source, render_human,
                                   render_json)
from repro.analysis.errors import AnalysisError

__all__ = [
    "AnalysisError", "Finding", "Rule", "RULES",
    "lint_file", "lint_paths", "lint_source",
    "render_human", "render_json", "sanitize",
]
