"""Typed errors for the analysis subsystem.

:class:`AnalysisError` is raised by the runtime sanitizer
(:mod:`repro.analysis.sanitize`) and by the always-on input validation in
``BatchedProblem.score_batch`` — it names the violated rule (same ids as the
static linter where one applies) and carries structured context (the
offending shape-bucket key, array name, ...) so a failure points at the
call site's data instead of an opaque XLA retrace or a NaN three layers
later.
"""

from __future__ import annotations

__all__ = ["AnalysisError"]


class AnalysisError(RuntimeError):
    """A violated trace-safety / numerics invariant, caught at runtime.

    Attributes:
        rule:    the rule id (kebab-case, e.g. ``"score-batch-domain"``,
                 ``"no-silent-retrace"`` — linter ids where one applies).
        context: structured details (``bucket=...``, ``name=...``) for
                 programmatic consumers; rendered into the message too.
    """

    def __init__(self, rule: str, message: str, **context):
        self.rule = rule
        self.context = dict(context)
        detail = ", ".join(f"{k}={v!r}" for k, v in self.context.items())
        super().__init__(f"[{rule}] {message}"
                         + (f" ({detail})" if detail else ""))
