"""CLI: ``python -m repro.analysis src/ tests/ benchmarks/``.

Exit code 1 when any error-severity finding survives suppression (or any
warning under ``--strict``); 0 on a clean tree.  ``--json`` emits the
machine-readable report (schema: version/paths/files_checked/counts/
findings) for CI artifacts.
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.engine import (DEFAULT_EXCLUDED_DIRS, RULES, lint_paths,
                                   render_human, render_json)
import repro.analysis.rules  # noqa: F401  — registers the rule set


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="trace-safety & numerics static analysis for the "
                    "repro stack")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", action="store_true",
                    help="emit the JSON report instead of human output")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--strict", action="store_true",
                    help="warnings also fail the run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--no-default-excludes", action="store_true",
                    help="also lint fixtures/ and cache directories")
    ns = ap.parse_args(argv)

    if ns.list_rules:
        for rid, r in sorted(RULES.items()):
            print(f"{rid} [{r.severity}] — {r.summary}")
        return 0

    select = {s.strip() for s in ns.select.split(",")} if ns.select else None
    if select is not None:
        unknown = select - set(RULES)
        if unknown:
            print(f"unknown rule id(s): {sorted(unknown)}", file=sys.stderr)
            return 2
    excluded = frozenset() if ns.no_default_excludes \
        else DEFAULT_EXCLUDED_DIRS
    report = lint_paths(ns.paths or ["src"], select=select,
                        excluded_dirs=excluded)
    print(render_json(report) if ns.json else render_human(report))
    failed = report["counts"]["error"] > 0 or (
        ns.strict and report["counts"]["warning"] > 0)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
