"""Regret accounting for the closed adaptive loop: was adapting worth it?

The controller's benefit claim is a *number*: cumulative objective F over
the trace of three policies on the SAME true world —

  * **static**   — the seed placement held fixed (remapped mechanically on
    device losses, never re-optimized),
  * **adaptive** — the controller's placement, PLUS the reconfiguration
    cost charged every time it switches (state-movement bytes priced by
    the com model — adaptation is not free),
  * **oracle**   — a placement re-optimized against the true fleet and the
    true (drift-included) operator graph whenever the world changes; the
    hindsight reference both regrets are measured against.

``regret = cumulative F − cumulative oracle F``; the closed loop earns its
keep when ``adaptive_regret < static_regret`` on drifting traces.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import OpGraph

__all__ = ["RegretReport", "reconfiguration_cost"]


def _greedy_transport(outflow: np.ndarray, inflow: np.ndarray,
                      com: np.ndarray) -> float:
    """Cheapest-pair greedy transport cost: route outflow mass to inflow
    destinations over the cheapest links first (a migration planner avoids
    degraded links; pricing every pair proportionally would bill a move
    AWAY from an outage as if the state crossed the outage twice).
    Deterministic: pairs scanned in (cost, u, v) order."""
    out = outflow.copy()
    inn = inflow.copy()
    u_idx, v_idx = np.nonzero(np.outer(out > 1e-12, inn > 1e-12))
    order = np.lexsort((v_idx, u_idx, com[u_idx, v_idx]))
    total = 0.0
    for k in order:
        u, v = int(u_idx[k]), int(v_idx[k])
        m = min(out[u], inn[v])
        if m <= 0.0:
            continue
        total += m * com[u, v]
        out[u] -= m
        inn[v] -= m
    return total


def reconfiguration_cost(x_old: np.ndarray, x_new: np.ndarray,
                         graph: OpGraph, fleet,
                         state_bytes_per_op: float = 1.0) -> float:
    """Price of switching placements: the operator state that must move,
    in the com model's own units.

    Operator i's state is ``state_bytes_per_op · out_bytes_i`` bytes per
    unit of placement mass; switching moves ``outflow = max(x_old − x_new,
    0)`` into ``inflow = max(x_new − x_old, 0)`` along a cheapest-links
    greedy transport plan priced by ``comCost`` — the same units as
    modeled latency, so the charge is directly comparable to the per-tick
    F it buys back."""
    x_old = np.asarray(x_old, dtype=np.float64)
    x_new = np.asarray(x_new, dtype=np.float64)
    if x_old.shape != x_new.shape:
        raise ValueError(f"placement shapes differ: {x_old.shape} vs "
                         f"{x_new.shape}")
    com = np.asarray(fleet.com_matrix(), dtype=np.float64)
    total = 0.0
    for i, op in enumerate(graph.operators):
        diff = x_new[i] - x_old[i]
        inflow = np.maximum(diff, 0.0)
        if float(inflow.sum()) <= 1e-12:
            continue
        outflow = np.maximum(-diff, 0.0)
        price = _greedy_transport(outflow, inflow, com)
        total += state_bytes_per_op * op.out_bytes * price
    return float(total)


@dataclasses.dataclass
class RegretReport:
    """Per-tick and cumulative F of {static, adaptive, oracle} on the true
    world, plus the controller's decision record.

    ``f_adaptive`` is the raw per-tick objective; the reconfiguration
    charges live separately in ``reconfig_costs`` (non-zero only at switch
    ticks) and are INCLUDED in ``cum_adaptive`` — the adaptive policy pays
    for its own moves.  ``controller_dispatches`` counts the jitted search
    dispatches the controller issued; the O(reconfigs)-not-O(ticks) claim
    is gated on it in ``benchmarks/bench_adaptive.py``.
    """

    scenario: str
    f_static: np.ndarray
    f_adaptive: np.ndarray
    f_oracle: np.ndarray
    reconfig_costs: np.ndarray
    drift: np.ndarray            # controller drift signal per tick (NaN warmup)
    reconfig_ticks: list[int]
    refit_ticks: list[int]
    n_refits: int
    n_reconfigs: int
    controller_dispatches: int
    oracle_dispatches: int
    final_com_scale: float

    @property
    def n_ticks(self) -> int:
        return int(self.f_static.size)

    @property
    def cum_static(self) -> float:
        return float(self.f_static.sum())

    @property
    def cum_adaptive(self) -> float:
        """Adaptive cumulative F including its reconfiguration charges."""
        return float(self.f_adaptive.sum() + self.reconfig_costs.sum())

    @property
    def cum_oracle(self) -> float:
        return float(self.f_oracle.sum())

    @property
    def static_regret(self) -> float:
        return self.cum_static - self.cum_oracle

    @property
    def adaptive_regret(self) -> float:
        return self.cum_adaptive - self.cum_oracle

    def summary(self) -> dict:
        return {
            "scenario": self.scenario,
            "n_ticks": self.n_ticks,
            "cum_static": self.cum_static,
            "cum_adaptive": self.cum_adaptive,
            "cum_oracle": self.cum_oracle,
            "static_regret": self.static_regret,
            "adaptive_regret": self.adaptive_regret,
            "reconfig_cost_total": float(self.reconfig_costs.sum()),
            "n_refits": self.n_refits,
            "n_reconfigs": self.n_reconfigs,
            "controller_dispatches": self.controller_dispatches,
            "oracle_dispatches": self.oracle_dispatches,
            "final_com_scale": self.final_com_scale,
        }
