"""The closed-loop adaptive controller (ROADMAP "close the loop").

``AdaptiveController`` drives a :class:`repro.streaming.engine.
StreamingEngine` through a trace tick by tick and closes the paper's
calibrate → optimize loop at runtime:

  observe ──► drift? ──► refit (repro.core.calibration.refit_from_replay)
     ▲                      │
     │                      ▼
  reconfig ◄── worth it? ◄── re-optimize (repro.search, batched, warm-start)

The controller's WORLD MODEL is a belief it maintains itself (the fleet it
was handed at start, recalibrated from observations); the engine's true
fleet drifts away through trace events (degrades, Markov region outages,
selectivity drift).  Every tick it compares the believed model's latency
against the observed latency and, when the normalized drift
(:func:`repro.core.calibration.normalized_drift`) crosses a threshold:

  1. re-fits per-device slowdowns and the global com scale from the
     window's busy/latency series (``refit_from_replay``), adopting the new
     belief only when it explains the window better;
  2. re-optimizes the placement — and, with ``co_optimize_dq``, the
     quality knob — through the batched search engine: ONE
     ``BatchedProblem.score_batch`` dispatch over
     :func:`repro.search.candidates.incumbent_candidates` (the incumbent
     always included, so re-optimization can never regress the belief
     score), crossed analytically with the dq grid;
  3. charges the reconfiguration cost (state-movement bytes priced by the
     believed com model — :func:`repro.adapt.regret.reconfiguration_cost`)
     and only switches when the modeled gain amortizes it.

Decisions are deterministic given (engine with ``observed="work"``, trace,
rng seed).  Dispatch count is O(reconfigurations), not O(ticks) — gated in
``benchmarks/bench_adaptive.py``.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro import obs
from repro.adapt.regret import RegretReport, reconfiguration_cost
from repro.core.calibration import (ReplayWindow, fit_work_unit,
                                    normalized_drift, refit_from_replay)
from repro.core.costmodel import CostConfig, latency, objective_F
from repro.sim.replay import apply_fleet_event
from repro.sim.scenarios import TraceEvent

__all__ = ["AdaptiveConfig", "AdaptiveController", "run_adaptive"]


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs of the closed loop.

    ``window`` ticks of observations feed each drift estimate / refit;
    adaptation triggers when the drift signal exceeds ``drift_threshold``
    (RMS of observed/modeled − 1, so 0.5 ≈ model off by 50%) and at least
    ``cooldown`` ticks have passed since the last adaptation.  A switch
    must buy back its reconfiguration charge within ``amortize_ticks``
    ticks of modeled improvement.  ``beta``/``dq`` are paper eq. 8's
    quality trade-off; ``co_optimize_dq`` searches the dq grid jointly
    with the placement in the same dispatch.

    ``use_belief`` maintains an explicit :class:`repro.belief.BeliefState`
    (refits write posterior updates into it; pass a ``prior`` to the
    controller for cold-start priors).  On its own it is passive
    bookkeeping — decisions and the rng stream are BITWISE identical to
    the legacy path (pinned in tests/test_adaptive.py).  The belief starts
    driving decisions through ``belief_sampling`` (robust scenarios are
    posterior samples instead of fixed ``robust_jitter`` noise) and
    ``probe_epsilon`` (probing candidates keep ε mass on high-uncertainty
    devices, adopted when the exploration bonus justifies the price);
    ``belief_decay`` ages observation counts per refit so stale evidence
    relaxes toward the prior."""

    window: int = 6
    drift_threshold: float = 0.5
    # emergency fast path: drift beyond fast_factor × drift_threshold
    # adapts with only 2 observed ticks instead of waiting for the full
    # window — catastrophic shifts (a region outage under the current
    # placement) are exactly when reaction delay is most expensive
    fast_factor: float = 6.0
    cooldown: int = 4
    n_candidates: int = 64
    jitter: float = 0.25
    # belief-robust re-optimization: the candidate batch is scored min–max
    # over `robust_scenarios` lognormal-jittered copies of the believed
    # fleet (the belief is an ESTIMATE — hedging against its error keeps
    # reconfigurations from over-concentrating on links the controller has
    # not observed recently).  1 ⇒ pure point-belief optimization.
    robust_scenarios: int = 4
    robust_jitter: float = 0.4
    oracle_candidates: int = 32
    beta: float = 0.0
    dq: float = 0.0
    co_optimize_dq: bool = False
    dq_steps: int = 5
    state_bytes_per_op: float = 0.25
    amortize_ticks: float = 20.0
    row_width: int = 4
    # belief layer (repro.belief) — all off by default: the legacy
    # controller path stays bitwise intact
    use_belief: bool = False
    belief_sampling: bool = False
    probe_epsilon: float = 0.0
    probe_top_k: int = 2
    prior_strength: float = 4.0
    belief_decay: float = 0.8

    def __post_init__(self):
        if self.window < 2:
            raise ValueError(f"window must be ≥ 2 ticks (a drift estimate "
                             f"needs two points), got {self.window}")


def _renorm(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(x.sum(axis=1, keepdims=True), 1e-9)


class AdaptiveController:
    """One controller per (engine, trace) run; see the module docstring for
    the loop it closes.  Use :func:`run_adaptive` for the one-call form."""

    def __init__(self, engine, cfg: AdaptiveConfig = AdaptiveConfig(),
                 name: str = "adaptive", prior=None):
        from repro.core.devices import ExplicitFleet
        from repro.sim.batched import BatchedEvaluator

        self.engine = engine
        self.cfg = cfg
        self.name = name
        self.graph = engine.graph.meta
        self.cost_cfg = CostConfig(alpha=engine.cfg.alpha)
        fleet = engine.fleet
        self.believed = ExplicitFleet(
            com_cost=np.asarray(fleet.com_matrix(), dtype=np.float64).copy(),
            speed=np.asarray(fleet.effective_speed(),
                             dtype=np.float64).copy(),
            available=None if fleet.available is None
            else np.asarray(fleet.available, dtype=bool).copy(),
            region=np.asarray(fleet.region).copy())
        self.believed_graph = self.graph  # selectivities re-fit over time
        self.com_scale = 1.0
        self.work_unit = float("nan")  # calibrated on the first full window
        self.dq = float(cfg.dq)
        # ONE evaluator for every re-optimization: the believed fleet is
        # data to the jitted grid, so recalibrations don't retrace (only a
        # material selectivity re-fit rebuilds it — the graph is structure)
        self._evaluator = BatchedEvaluator(self.graph, self.cost_cfg)
        self._evaluator_graph = self.graph
        self.controller_dispatches = 0
        self.oracle_dispatches = 0
        # explicit belief layer (None = legacy point-estimate controller)
        self.belief = None
        self._pending_prior_adapt = False
        if cfg.use_belief:
            from repro.belief import BeliefState, apply_degrade

            self.belief = BeliefState.from_fleet(
                self.believed, graph=self.graph, prior=prior,
                prior_strength=cfg.prior_strength)
            if prior is not None:
                # cold start: adopt the prior's predicted slowdowns as the
                # initial belief (a fresh fleet is no longer assumed
                # healthy) and re-optimize at the first observed tick
                d0 = self.belief.posterior_mean_degrade()
                if float(np.max(np.abs(np.log(d0)))) > 1e-9:
                    self.believed = apply_degrade(self.believed, d0)
                    self.belief.commit(d0)
                    self._pending_prior_adapt = True

    # -- belief-side scoring --------------------------------------------------
    def _believed_latency(self, x: np.ndarray) -> float:
        return latency(self.believed_graph, self.believed, x, self.cost_cfg)

    def _reoptimize(self, rng: np.random.Generator
                    ) -> tuple[np.ndarray, float, float, float]:
        """One-dispatch belief-robust re-optimization.

        The warm-start candidate batch (incumbent first, uniform fallback
        last) is scored against ``robust_scenarios`` jittered copies of the
        believed fleet in ONE ``score_grid`` dispatch; the dq axis expands
        analytically (the same ``/(1 + β·dq)`` trick the search layer
        uses) and the min–max candidate wins — a placement hedged against
        belief error, co-optimized with its quality knob.

        With the belief layer on, the scenario copies can be posterior
        samples (``belief_sampling`` — hedging follows the posterior
        variance instead of fixed jitter) and ``probe_epsilon`` rides
        probing variants of the incumbent in the SAME batch (zero extra
        dispatches), selected under an exploration bonus that discounts a
        candidate's score by the uncertainty mass it would observe.
        Returns (x_best, dq_best, score_best, score_incumbent)."""
        from repro.core.placement import uniform_placement
        from repro.search.candidates import (dq_grid, incumbent_candidates,
                                             probe_candidates)
        from repro.sim.batched import pack_fleets, pack_placements
        from repro.sim.scenarios import perturbed_fleet

        cfg = self.cfg
        if self._evaluator_graph is not self.believed_graph:
            from repro.sim.batched import BatchedEvaluator
            self._evaluator = BatchedEvaluator(self.believed_graph,
                                               self.cost_cfg)
            self._evaluator_graph = self.believed_graph
        avail = self.believed.availability(self.graph.n_ops)
        cands = incumbent_candidates(self.engine.x, avail, rng,
                                     cfg.n_candidates, jitter=cfg.jitter)
        n_base = cands.shape[0]
        std = None
        if self.belief is not None and cfg.probe_epsilon > 0.0:
            std = np.sqrt(self.belief.posterior_var())
            probes = probe_candidates(self.engine.x, avail, std,
                                      cfg.probe_epsilon, cfg.probe_top_k)
        else:
            probes = np.empty((0,) + self.engine.x.shape)
        cands = np.concatenate(
            [cands, probes,
             uniform_placement(self.graph.n_ops, avail)[None]])
        if cfg.co_optimize_dq and cfg.beta > 0.0:
            dqs = dq_grid(cfg.beta, steps=cfg.dq_steps, include=(self.dq,))
        else:
            dqs = np.array([self.dq])
        if self.belief is not None and cfg.belief_sampling:
            fleets = [self.believed] + self.belief.sample_fleets(
                self.believed, rng, max(cfg.robust_scenarios - 1, 0))
        else:
            fleets = [self.believed] + [
                perturbed_fleet(self.believed, rng, cfg.robust_jitter)
                for _ in range(max(cfg.robust_scenarios - 1, 0))]
        with obs.span("adapt.reoptimize", P=int(cands.shape[0]),
                      S=len(fleets), D=int(np.size(dqs))) as sp:
            lat = np.asarray(sp.sync(self._evaluator.score_grid(
                pack_placements(list(cands)), pack_fleets(fleets),
                dq=0.0, beta=0.0)), dtype=np.float64)     # (S, P)
        self.controller_dispatches += 1
        reg = obs.registry()
        if reg.enabled:
            reg.counter("adapt.reoptimize.dispatches").add(1)
        denom = 1.0 + cfg.beta * np.asarray(dqs, dtype=np.float64)
        worst = (lat[:, :, None] / denom[None, None, :]).max(axis=0)  # (P, D)
        sel = worst
        if std is not None and np.any(std > 0.0):
            # exploration bonus: candidate p's score shrinks by up to ε for
            # the fraction of posterior-std mass its placement would
            # observe (a device counts fully once it holds ≥ ε mean mass).
            # The bonus is the controller's price of information — it
            # participates in BOTH selection and the amortization gate, so
            # a probe is adopted exactly when the information is worth the
            # move.
            eps = float(cfg.probe_epsilon)
            mass = cands.mean(axis=1)                      # (P, V)
            cov = (std[None, :] * np.minimum(mass / eps, 1.0)).sum(axis=1) \
                / std.sum()
            sel = worst * (1.0 - eps * cov[:, None])
        i, d = divmod(int(np.argmin(sel)), sel.shape[1])
        if reg.enabled and n_base <= i < n_base + probes.shape[0]:
            reg.counter("belief.probes").add(1)
        inc_d = int(np.argmin(np.abs(np.asarray(dqs) - self.dq)))
        return (np.asarray(cands[i], dtype=np.float64), float(dqs[d]),
                float(sel[i, d]), float(sel[0, inc_d]))

    # -- truth-side scoring (regret accounting only) --------------------------
    def _true_F(self, true_graph, x: np.ndarray, dq: float) -> float:
        lat = latency(true_graph, self.engine.fleet, x, self.cost_cfg)
        return objective_F(lat, dq, self.cfg.beta)

    def _oracle_reoptimize(self, true_graph, oracle_x: np.ndarray,
                           oracle_dq: float, extra: list[np.ndarray],
                           rng: np.random.Generator
                           ) -> tuple[np.ndarray, float]:
        """Hindsight reference: scalar-oracle re-optimization against the
        TRUE fleet and TRUE (drift-included) graph.  Accounting only — the
        controller never sees this; scored with the float64 oracle, so it
        issues no jitted dispatches of its own."""
        from repro.search.candidates import dq_grid, incumbent_candidates

        cfg = self.cfg
        avail = self.engine.fleet.availability(self.graph.n_ops)
        cands = list(incumbent_candidates(oracle_x, avail, rng,
                                          cfg.oracle_candidates,
                                          jitter=cfg.jitter))
        cands += [np.asarray(x, dtype=np.float64) for x in extra]
        dqs = dq_grid(cfg.beta, steps=cfg.dq_steps, include=(oracle_dq,)) \
            if cfg.beta > 0.0 else np.array([oracle_dq])
        best = (math.inf, oracle_x, oracle_dq)
        for x in cands:
            lat = latency(true_graph, self.engine.fleet, x, self.cost_cfg)
            for dq in dqs:
                f = objective_F(lat, float(dq), cfg.beta)
                if f < best[0]:
                    best = (f, x, float(dq))
        return best[1], best[2]

    # -- the loop -------------------------------------------------------------
    def run(self, trace: list[TraceEvent],
            rng: np.random.Generator) -> RegretReport:
        cfg = self.cfg
        eng = self.engine
        alive = list(range(eng.fleet.n_devices))
        static_x = eng.x.copy()
        oracle_x, oracle_dq = eng.x.copy(), self.dq
        oracle_dirty = True
        # per-tick records
        f_static, f_adaptive, f_oracle = [], [], []
        charges, drift_series = [], []
        reconfig_ticks, refit_ticks = [], []
        # observation window (cleared on belief change / device-count change)
        w_rates, w_busy, w_obs, w_mod, w_xs = [], [], [], [], []
        w_rin, w_rout = [], []
        ticks_since_adapt = cfg.cooldown
        # a structural fleet event was applied and not yet adapted to: the
        # controller KNOWS the world changed (it applied the event), it just
        # doesn't know the magnitude — adapt as soon as a fresh window
        # fills, even if the drift signal stays quiet (a wrong belief can
        # look calibrated when the current placement avoids the links it is
        # wrong about)
        pending_structural = False

        def clear_window():
            w_rates.clear(); w_busy.clear(); w_obs.clear()
            w_mod.clear(); w_xs.clear(); w_rin.clear(); w_rout.clear()

        def make_window(tail):
            return ReplayWindow(
                rates=np.array(w_rates[tail]),
                busy=np.stack(w_busy[tail]),
                observed_latency=np.array(w_obs[tail]),
                xs=np.stack(w_xs[tail]),
                op_rows_in=np.stack(w_rin[tail]),
                op_rows_out=np.stack(w_rout[tail]))

        for ev in trace:
            if ev.kind not in ("rate", "burst"):
                idx = alive.index(ev.device) if ev.device in alive else None
                applied = apply_fleet_event(eng, ev, alive, beta=cfg.beta,
                                            reoptimize=False)
                if applied == "remove":
                    # device loss is OBSERVABLE — belief, baselines and the
                    # window all shrink with the world
                    keep = [u for u in range(self.believed.n_devices)
                            if u != idx]
                    self.believed, _ = self.believed.without_devices([idx])
                    if self.belief is not None:
                        self.belief = self.belief.without_devices(keep)
                    static_x = _renorm(static_x[:, keep])
                    oracle_x = _renorm(oracle_x[:, keep])
                if applied in ("degrade", "outage", "recover", "remove"):
                    # a structural world change: pre-event observations
                    # would make a refit fit an average of two worlds —
                    # start the window fresh (drift detection then needs
                    # `window` new ticks, a deliberate reaction delay).
                    # Gradual "drift" events deliberately do NOT reset it:
                    # chasing slow selectivity drift across a window is the
                    # controller's job, not noise.
                    clear_window()
                    pending_structural = True
                if applied is not None:
                    oracle_dirty = True
                continue

            # ---- tick: run the batch, observe ----------------------------
            rows = max(int(ev.rate), 1)
            rep = eng.run_batch(rng.normal(size=(rows, cfg.row_width)))
            observed = rep.true_latency         # the WORLD's true latency
            modeled = self.com_scale * self._believed_latency(eng.x)
            w_rates.append(ev.rate); w_busy.append(rep.device_busy.copy())
            w_obs.append(observed); w_mod.append(modeled)
            w_xs.append(eng.x.copy())
            w_rin.append(np.asarray(rep.op_rows_in, dtype=np.float64))
            w_rout.append(np.asarray(rep.op_rows_out, dtype=np.float64))
            ticks_since_adapt += 1
            if not np.isfinite(self.work_unit) \
                    and len(w_obs) >= cfg.window:
                # one-time unit calibration on the first full window, while
                # the belief is still trusted — later refits anchor their
                # slowdown estimates to this constant (fit_work_unit)
                self.work_unit = fit_work_unit(
                    self.believed_graph, self.believed,
                    make_window(slice(None)))

            # ---- regret accounting on the true world ---------------------
            true_g = eng.true_graph()
            if oracle_dirty:
                oracle_x, oracle_dq = self._oracle_reoptimize(
                    true_g, oracle_x, oracle_dq, [static_x, eng.x], rng)
                oracle_dirty = False
            charge = 0.0

            # ---- drift watch → refit → re-optimize -----------------------
            tail = slice(-cfg.window, None)
            drift = normalized_drift(np.array(w_obs[tail]),
                                     np.array(w_mod[tail]))
            drift_series.append(drift)
            if np.isfinite(drift):
                # Perfetto counter track: the controller's trigger signal
                obs.counter_sample("adapt.drift", drift)
            triggered = (np.isfinite(drift)
                         and drift > cfg.drift_threshold) \
                or pending_structural
            fast = (len(w_obs) >= 2 and np.isfinite(drift)
                    and drift > cfg.fast_factor * cfg.drift_threshold)
            do_adapt = (ticks_since_adapt >= cfg.cooldown
                        and ((len(w_obs) >= cfg.window and triggered)
                             or fast))
            # cold-start prior adaptation: the prior predicted a degraded
            # world, so re-optimize at the FIRST observed tick instead of
            # waiting a full drift window (no refit — there is nothing to
            # fit yet; one extra dispatch total)
            initial = self._pending_prior_adapt and len(w_obs) >= 1
            if do_adapt or initial:
                self._pending_prior_adapt = False
                if do_adapt:
                    pending_structural = False
                    if self.belief is not None:
                        # evidence ages one adaptation epoch before the new
                        # window lands: variance re-inflates, stale
                        # estimates relax toward the prior
                        self.belief.decay(cfg.belief_decay)
                    with obs.span("adapt.refit", ticks=len(w_obs)):
                        refit = refit_from_replay(
                            self.believed_graph, self.believed,
                            make_window(tail), self.cost_cfg,
                            work_unit=self.work_unit, belief=self.belief)
                    reg = obs.registry()
                    if reg.enabled and self.belief is not None:
                        reg.counter("belief.updates").add(1)
                        reg.gauge("belief.variance").set(
                            float(np.mean(self.belief.posterior_var())))
                    if not np.isfinite(refit.post_drift) \
                            or refit.post_drift <= refit.pre_drift:
                        self.believed = refit.fleet
                        self.com_scale = 1.0  # refit folded the scale in
                        if self.belief is not None:
                            self.belief.commit(refit.degrade)
                        if np.max(np.abs(refit.sel_scale - 1.0)) > 0.02:
                            # material selectivity drift: adopt the re-fit
                            # graph (the next re-optimization rebuilds its
                            # evaluator)
                            self.believed_graph = refit.graph
                        refit_ticks.append(ev.t)
                        if reg.enabled:
                            reg.counter("adapt.refits.adopted").add(1)
                    elif reg.enabled:
                        # refit explained the window WORSE — belief kept
                        reg.counter("adapt.refits.rejected").add(1)
                else:
                    reg = obs.registry()
                x_new, dq_new, score_new, score_inc = self._reoptimize(rng)
                # gate on the BELIEVED price (all the controller has); the
                # regret account below charges the TRUE price of the move
                cost = reconfiguration_cost(
                    eng.x, x_new, self.graph, self.believed,
                    cfg.state_bytes_per_op)
                if (score_inc - score_new) * cfg.amortize_ticks > cost:
                    if not np.array_equal(x_new, eng.x):
                        charge = reconfiguration_cost(
                            eng.x, x_new, self.graph, eng.fleet,
                            cfg.state_bytes_per_op)
                        reconfig_ticks.append(ev.t)
                        oracle_dirty = True
                        if reg.enabled:
                            reg.counter("adapt.reconfigs").add(1)
                    eng.x = x_new
                    self.dq = dq_new
                ticks_since_adapt = 0
                clear_window()

            f_static.append(self._true_F(true_g, static_x, cfg.dq))
            f_adaptive.append(self._true_F(true_g, eng.x, self.dq))
            f_oracle.append(self._true_F(true_g, oracle_x, oracle_dq))
            charges.append(charge)
            # regret timelines: one Perfetto counter track per policy
            # (main series = the adaptive policy under test)
            obs.counter_sample("adapt.F", f_adaptive[-1],
                               static=f_static[-1], oracle=f_oracle[-1])

        return RegretReport(
            scenario=self.name,
            f_static=np.array(f_static),
            f_adaptive=np.array(f_adaptive),
            f_oracle=np.array(f_oracle),
            reconfig_costs=np.array(charges),
            drift=np.array(drift_series),
            reconfig_ticks=reconfig_ticks,
            refit_ticks=refit_ticks,
            n_refits=len(refit_ticks),
            n_reconfigs=len(reconfig_ticks),
            controller_dispatches=self.controller_dispatches,
            oracle_dispatches=self.oracle_dispatches,
            final_com_scale=self.com_scale)


def run_adaptive(engine, trace: list[TraceEvent], rng: np.random.Generator,
                 cfg: AdaptiveConfig = AdaptiveConfig(),
                 name: str = "adaptive", prior=None) -> RegretReport:
    """Close the loop over one trace: observe → drift → refit → re-optimize
    → reconfigure, with regret accounting against the static seed placement
    and the per-world-change oracle.  One-call wrapper around
    :class:`AdaptiveController`.  ``prior`` (a :class:`repro.belief.
    LearnedPrior`) seeds the belief for cold starts when
    ``cfg.use_belief``."""
    return AdaptiveController(engine, cfg, name=name,
                              prior=prior).run(trace, rng)
