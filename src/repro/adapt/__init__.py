"""Closed-loop adaptive replay: run a trace tick-by-tick, watch
modeled-vs-observed drift, recalibrate the cost model from observations
(:func:`repro.core.calibration.refit_from_replay`), re-optimize placement
and dq through the batched search engine, charge reconfiguration costs,
and account regret against the static seed placement and a per-change
oracle (see ``src/repro/sim/README.md`` for the data-flow diagram)."""

from repro.adapt.controller import (AdaptiveConfig, AdaptiveController,
                                    run_adaptive)
from repro.adapt.regret import RegretReport, reconfiguration_cost

__all__ = ["AdaptiveConfig", "AdaptiveController", "RegretReport",
           "reconfiguration_cost", "run_adaptive"]
