"""Elastic scaling for the training path: survive pod/slice loss.

Strategy (checkpoint-restart based, the only sound one for synchronous
SPMD): on failure, rebuild a smaller mesh from the surviving devices,
restore the latest checkpoint host-side (runtime/checkpoint restores are
mesh-portable), rescale the global batch to keep per-device work constant
(or keep global batch and raise grad-accumulation), and continue.

``plan_rescale`` computes the new run configuration; the trainer driver
(launch/train.py) executes it.  tests/test_elastic.py exercises a full
kill→shrink→resume cycle on the host platform.
"""

from __future__ import annotations

import dataclasses

import jax

__all__ = ["RescalePlan", "plan_rescale", "rebuild_mesh"]


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_devices: int
    new_devices: int
    data_ways: int
    model_ways: int
    global_batch: int
    grad_accum: int
    note: str


def rebuild_mesh(n_devices: int, model_ways: int) -> jax.sharding.Mesh:
    if n_devices % model_ways:
        raise ValueError(f"{n_devices} devices not divisible by model={model_ways}")
    from repro.launch.mesh import make_mesh

    return make_mesh((n_devices // model_ways, model_ways), ("data", "model"))


def plan_rescale(old_devices: int, surviving: int, model_ways: int,
                 global_batch: int, keep_global_batch: bool = True) -> RescalePlan:
    """Largest usable device count = biggest multiple of model_ways ≤
    surviving (tensor-parallel groups must stay whole)."""
    usable = (surviving // model_ways) * model_ways
    if usable == 0:
        raise ValueError("not enough devices for one tensor-parallel group")
    data_ways = usable // model_ways
    if keep_global_batch:
        # keep optimization trajectory comparable: same global batch, more
        # grad accumulation when per-device batch would not divide
        accum = 1
        while global_batch % (data_ways * accum) or \
                (global_batch // (data_ways * accum)) > 4096:
            accum += 1
            if accum > global_batch:
                accum = 1
                break
        gb = global_batch
        note = f"kept global batch; grad_accum={accum}"
    else:
        gb = max((global_batch * usable) // old_devices, data_ways)
        gb -= gb % data_ways
        accum = 1
        note = "scaled global batch with device count"
    return RescalePlan(old_devices, usable, data_ways, model_ways, gb, accum,
                       note)
