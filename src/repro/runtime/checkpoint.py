"""Checkpoint/restart: atomic, step-tagged, keep-N, mesh-portable.

Layout: ``<dir>/step_<N>/``: ``manifest.json`` (treedef, shapes, dtypes,
pipeline cursor, extra metadata) + ``arrays.npz`` (flat leaves, host
gathered).  Writes go to ``step_<N>.tmp`` then ``os.rename`` — a crash mid-
write never corrupts the latest checkpoint (restart-safety is tested by
killing a trainer mid-run in tests/test_checkpoint.py).

Restore is *mesh-portable*: leaves are loaded host-side and ``device_put``
against the CURRENT mesh/sharding — so a job can restart on a different
device count (elastic down-scale after pod loss, runtime/elastic.py).
"""

from __future__ import annotations

import json
import os
import re
import shutil
from pathlib import Path

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "available_steps"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(ckpt_dir, step: int, state, extra: dict | None = None,
                    keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step}"
    tmp = ckpt_dir / f"step_{step}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat, treedef = _flatten_with_paths(state)
    # one batched device→host transfer for the whole pytree, not one sync
    # per leaf (flagged by repro.analysis hidden-host-sync)
    host = jax.device_get(list(flat))
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(host)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(flat),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(available_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def available_steps(ckpt_dir) -> list[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        m = _STEP_RE.match(p.name)
        if m and (p / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, step: int, target_state,
                       shardings=None) -> tuple:
    """Restore into the structure of ``target_state``; optionally place
    leaves with the given shardings (pytree of NamedSharding/None).

    Returns (state, extra_metadata)."""
    path = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    flat_t, treedef = jax.tree.flatten(target_state)
    if manifest["n_leaves"] != len(flat_t):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, target has "
            f"{len(flat_t)} — incompatible states")
    flat_sh = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None or not
               isinstance(x, (dict, list, tuple)))
               if shardings is not None else [None] * len(flat_t))
    out = []
    for i, (tgt, sh) in enumerate(zip(flat_t, flat_sh)):
        arr = data[f"a{i}"]
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != "
                             f"target {tgt.shape}")
        arr = arr.astype(tgt.dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest["extra"]
