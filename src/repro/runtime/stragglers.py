"""Straggler detection: EWMA per-device step-time monitor.

A device whose smoothed step time exceeds ``threshold ×`` the fleet median
is flagged; the caller (StreamingEngine / trainer) then degrades the
device's entry in the cost-model fleet and re-optimizes placement — the
paper's heterogeneous ``comCost`` / speed terms used as *live* state
(DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerMonitor:
    n_devices: int
    alpha: float = 0.3  # EWMA weight of the newest observation
    threshold: float = 1.8  # × median ⇒ straggler
    min_samples: int = 3

    def __post_init__(self):
        self.ewma = np.zeros(self.n_devices)
        self.samples = np.zeros(self.n_devices, dtype=int)

    def observe(self, step_times: np.ndarray):
        step_times = np.asarray(step_times, dtype=float)
        fresh = self.samples == 0
        self.ewma = np.where(fresh, step_times,
                             (1 - self.alpha) * self.ewma
                             + self.alpha * step_times)
        self.samples += 1

    def stragglers(self) -> list[tuple[int, float]]:
        """[(device, slowdown_factor)] for devices over threshold."""
        if (self.samples < self.min_samples).all():
            return []
        active = self.samples >= self.min_samples
        med = np.median(self.ewma[active]) if active.any() else 0.0
        if med <= 0:
            return []
        out = []
        for u in np.nonzero(active)[0]:
            ratio = self.ewma[u] / med
            if ratio > self.threshold:
                out.append((int(u), float(ratio)))
        return out

    def reset_device(self, u: int):
        self.ewma[u] = 0.0
        self.samples[u] = 0
