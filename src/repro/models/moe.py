"""Mixture-of-Experts FFN: top-k routing with capacity-bounded grouped
dispatch (GShard/Switch style, the TPU-native einsum formulation).

Tokens are processed in groups (``moe_group_size``) that stay aligned with
the data shards; per group we build a (g, E, C) dispatch one-hot and move
tokens to experts with einsums — GSPMD turns the expert-sharded einsums into
all-to-alls on the `model` axis (expert parallelism).  Tokens overflowing an
expert's capacity C = g·k/E·cf are dropped (residual passes through), the
standard trade at this scale.

Expert weights (E, d, f): experts shard over `model` when E divides the axis
(arctic: 128/16); otherwise the FFN dim shards instead (grok: 8 experts on a
16-way axis → f=32768 shards 2048/device).  Arctic's parallel dense-residual
MLP is included when ``moe_dense_residual`` is set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import ModelConfig
from repro.models.layers import dense, init_dense
from repro.models.sharding import logical_spec, param_spec, shard

__all__ = ["init_moe", "moe_ffn", "moe_specs"]


def init_moe(key, cfg: ModelConfig):
    E, d, f = cfg.moe_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    dt = cfg.pdtype
    p = {
        "router": init_dense(ks[0], d, E, jnp.float32),  # router stays f32
        "wi_gate": (jax.random.normal(ks[1], (E, d, f)) * d ** -0.5).astype(dt),
        "wi_up": (jax.random.normal(ks[2], (E, d, f)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, f, d)) * f ** -0.5).astype(dt),
    }
    if cfg.moe_dense_residual:
        from repro.models.layers import init_mlp
        p["dense_residual"] = init_mlp(ks[4], d, f, dt, kind="swiglu")
    return p


def _expert_axes(cfg: ModelConfig):
    """(expert_axis, shard_ff_too): where the expert dim shards.

    Default rules put experts on `model`.  The expert-parallel-over-data
    variant (rules["experts"]="data", §Perf iteration 6) makes expert
    weights stationary 256-way — E over `data`, d_ff over `model` — so
    *tokens* move (all-to-all) instead of weights (FSDP all-gather), and
    expert grads are born fully sharded."""
    from repro.models.sharding import _active_mesh, axis_rules
    mesh = _active_mesh()
    sizes = dict(mesh.shape) if mesh is not None else {}
    target = axis_rules().rules.get("experts")
    axes = (target,) if isinstance(target, str) else (target or ())
    axes = tuple(a for a in axes if a in sizes)
    ways = 1
    for a in axes:
        ways *= sizes[a]
    if axes and cfg.moe_experts % ways == 0:
        ff_axis = axis_rules().rules.get("ff")
        shard_ff = (ff_axis in sizes) and (ff_axis not in axes) \
            and cfg.d_ff % sizes.get(ff_axis, 1) == 0
        return axes, shard_ff
    return None, False


def moe_specs(cfg: ModelConfig, stacked: bool = True):
    """PartitionSpecs; resolve under an active mesh."""
    e_axes, shard_ff = _expert_axes(cfg)
    if e_axes is not None:
        e = e_axes if len(e_axes) > 1 else e_axes[0]
        f = "model" if shard_ff else None
        from jax.sharding import PartitionSpec
        w_spec = PartitionSpec(e, None, f)
        wo_spec = PartitionSpec(e, f, None)
    else:
        w_spec = param_spec((None, None, "ff"))
        wo_spec = param_spec((None, "ff", None))
    lead = (None,) if stacked else ()
    pad = lambda s: P(*(lead + tuple(s)))
    specs = {
        "router": pad(param_spec((None, None))),
        "wi_gate": pad(w_spec),
        "wi_up": pad(w_spec),
        "wo": pad(wo_spec),
    }
    if cfg.moe_dense_residual:
        specs["dense_residual"] = {
            "wi_gate": pad(param_spec((None, "ff"))),
            "wi_up": pad(param_spec((None, "ff"))),
            "wo": pad(param_spec(("ff", None))),
        }
    return specs


def moe_ffn(params, x: jnp.ndarray, cfg: ModelConfig):
    """x: (B, S, d) → (y, aux_loss).  Grouped top-k dispatch."""
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    tokens = B * S
    g = min(cfg.moe_group_size, tokens)
    n_groups = -(-tokens // g)
    pad = n_groups * g - tokens
    xt = x.reshape(tokens, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(n_groups, g, d)
    xg = shard(xg, "batch", None, None)  # groups follow the data shards

    C = max(int(g * k / E * cfg.moe_capacity_factor), 1)

    logits = jnp.einsum("Ggd,de->Gge", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (G, g, E)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert, FIFO per group
    sel = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # (G, g, k, E)
    flat = sel.reshape(n_groups, g * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # (G, g*k, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(n_groups, g, k)  # (G, g, k)
    keep = pos < C

    # dispatch/combine tensors: (G, g, E, C)
    pos_oh = jax.nn.one_hot(pos, C, dtype=xg.dtype) * keep[..., None].astype(xg.dtype)
    disp = jnp.einsum("GgkE,Ggkc->GgEc", sel.astype(xg.dtype), pos_oh)
    comb = jnp.einsum("Ggk,GgkE,Ggkc->GgEc",
                      gate_vals.astype(xg.dtype), sel.astype(xg.dtype), pos_oh)

    dt = xg.dtype  # bf16 wires/accumulators across the expert-parallel axis
    expert_in = jnp.einsum("GgEc,Ggd->GEcd", disp, xg,
                           preferred_element_type=dt)
    # when experts shard over a batch axis (expert-parallel-over-data), the
    # group dim must release that axis — the constraint below is the
    # all-to-all boundary where tokens move to their experts
    from repro.models.sharding import axis_rules
    e_rule = axis_rules().rules.get("experts")
    e_axes = {e_rule} if isinstance(e_rule, str) else set(e_rule or ())
    b_rule = axis_rules().rules.get("batch")
    b_axes = {b_rule} if isinstance(b_rule, str) else set(b_rule or ())
    if e_axes & b_axes:
        expert_in = shard(expert_in, None, "experts", None, None)
    else:
        expert_in = shard(expert_in, "batch", "experts", None, None)
    h = jax.nn.silu(jnp.einsum("GEcd,Edf->GEcf", expert_in,
                               params["wi_gate"].astype(dt),
                               preferred_element_type=dt)) \
        * jnp.einsum("GEcd,Edf->GEcf", expert_in,
                     params["wi_up"].astype(dt), preferred_element_type=dt)
    if e_axes & b_axes:
        h = shard(h, None, "experts", None, "ff")
    else:
        h = shard(h, "batch", "experts", None, None)
    out_e = jnp.einsum("GEcf,Efd->GEcd", h, params["wo"].astype(dt),
                       preferred_element_type=dt)
    y = jnp.einsum("GgEc,GEcd->Ggd", comb, out_e, preferred_element_type=dt)
    y = y.reshape(n_groups * g, d)[:tokens].reshape(B, S, d)

    if cfg.moe_dense_residual:
        from repro.models.layers import mlp
        y = y + mlp(params["dense_residual"], x, kind="swiglu")

    # Switch-style load-balance aux loss
    me = probs.mean(axis=1)  # (G, E) mean router prob
    ce = sel.astype(jnp.float32).sum(axis=2).mean(axis=1)  # (G, E) token frac·k
    aux = (E / k) * jnp.mean(jnp.sum(me * ce, axis=-1))
    return y.astype(x.dtype), aux
