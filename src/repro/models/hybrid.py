"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
(single parameter set) applied every ``shared_attn_every`` SSM layers
(arXiv:2411.15242).

Execution: python loop over attention sites (≤7 — HLO stays small), each
followed by a ``lax.scan`` over its group of mamba blocks.  The shared block
has one param set but per-site KV caches (its K/V differ per application).
Sub-quadratic end to end — runs the long_500k cells (attention sites see the
full context only through decode-time cache reads, O(S) per token).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import ModelConfig
from repro.models.layers import (
    KVCache, apply_norm, attention, init_attention, init_mlp, make_norm, mlp,
)
from repro.models.mamba2 import (
    SSMCache, init_mamba_block, mamba_block, mamba_block_specs,
)
from repro.models.sharding import param_spec, shard
from repro.models.transformer import remat_wrap, stack_layer_specs

__all__ = ["Zamba2LM", "HybridCache"]


@dataclasses.dataclass
class HybridCache:
    ssm: SSMCache  # stacked (L, …)
    attn: KVCache  # stacked (n_sites, …)


jax.tree_util.register_dataclass(HybridCache, data_fields=["ssm", "attn"],
                                 meta_fields=[])


class Zamba2LM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.shared_attn_every > 0
        self.cfg = cfg

    @property
    def n_sites(self) -> int:
        cfg = self.cfg
        return -(-cfg.n_layers // cfg.shared_attn_every)

    def _group(self, s: int) -> tuple[int, int]:
        cfg = self.cfg
        lo = s * cfg.shared_attn_every
        return lo, min(lo + cfg.shared_attn_every, cfg.n_layers)

    # ------------------------------------------------------------ params --
    def init_params(self, key):
        cfg = self.cfg
        ke, kb, ka, km, kh = jax.random.split(key, 5)
        blocks = jax.vmap(lambda k: init_mamba_block(k, cfg))(
            jax.random.split(kb, cfg.n_layers))
        shared = {
            "ln1": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "attn": init_attention(ka, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd, cfg.pdtype),
            "ln2": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.pdtype,
                            cfg.mlp_kind),
        }
        return {
            "embed": (jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model))
                      * cfg.d_model ** -0.5).astype(cfg.pdtype),
            "blocks": blocks,
            "shared_attn": shared,
            "final_norm": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded))
                     * cfg.d_model ** -0.5).astype(cfg.pdtype),
        }

    def param_specs(self):
        cfg = self.cfg
        from repro.models.layers import attn_specs
        shared = {
            "ln1": param_spec((None,)),
            "attn": attn_specs(),
            "ln2": param_spec((None,)),
            "mlp": {
                "wi_gate": param_spec((None, "ff")),
                "wi_up": param_spec((None, "ff")),
                "wo": param_spec(("ff", None)),
            },
        }
        return {
            "embed": param_spec(("vocab", None)),
            "blocks": stack_layer_specs(mamba_block_specs(cfg)),
            "shared_attn": shared,
            "final_norm": param_spec((None,)),
            "head": param_spec((None, "vocab")),
        }

    # ------------------------------------------------------------ pieces --
    def _shared_block(self, params, x, cache=None, cache_pos=None):
        cfg = self.cfg
        sp = params["shared_attn"]
        h = apply_norm(cfg.norm_type, x, sp["ln1"])
        a, new_cache = attention(
            sp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta, causal=True,
            cache=cache, cache_pos=cache_pos, impl=cfg.attention_impl,
            chunk=cfg.attn_chunk)
        x = x + a
        h = apply_norm(cfg.norm_type, x, sp["ln2"])
        x = x + mlp(sp["mlp"], h, cfg.mlp_kind)
        return shard(x, "batch", "seq", None), new_cache

    def _slice_blocks(self, blocks, lo, hi):
        return jax.tree.map(lambda a: a[lo:hi], blocks)

    def _run(self, params, x, caches=None, cache_pos=None, decode=False):
        """Shared driver for forward / prefill / decode."""
        cfg = self.cfg
        new_ssm, new_attn = [], []
        for s in range(self.n_sites):
            attn_cache = None
            if caches is not None:
                attn_cache = jax.tree.map(lambda a: a[s], caches.attn)
            if caches is None and cfg.remat != "none":
                # remat each attention site: without this the backward
                # keeps every site's attention internals live — ~15 GB for
                # zamba2 train_4k (§Perf notes)
                x, nc = jax.checkpoint(
                    lambda xx: self._shared_block(params, xx))(x)
            else:
                x, nc = self._shared_block(params, x, attn_cache, cache_pos)
            new_attn.append(nc)
            lo, hi = self._group(s)
            group = self._slice_blocks(params["blocks"], lo, hi)

            if caches is None:
                def body(carry, bp):
                    y, _ = mamba_block(bp, carry, cfg)
                    return y, None
                body = remat_wrap(body, cfg.remat)
                x, _ = jax.lax.scan(body, x, group)
            else:
                grp_cache = jax.tree.map(lambda a: a[lo:hi], caches.ssm)

                def body(carry, xs):
                    bp, cl = xs
                    y, nc = mamba_block(bp, carry, cfg, cl, decode=decode)
                    return y, nc
                if not decode:
                    body = remat_wrap(body, cfg.remat)
                x, grp_new = jax.lax.scan(body, x, (group, grp_cache))
                new_ssm.append(grp_new)
        if caches is None:
            return x, None
        ssm = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm)
        attn = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_attn)
        return x, HybridCache(ssm, attn)

    # -------------------------------------------------------------- API ---
    def embed_tokens(self, params, tokens):
        from repro.models.layers import embed_lookup
        x = embed_lookup(params["embed"], tokens, self.cfg.adtype)
        return shard(x, "batch", "seq", None)

    def logits(self, params, x):
        x = apply_norm(self.cfg.norm_type, x, params["final_norm"])
        out = jnp.einsum("bsd,dv->bsv", x, params["head"],
                         preferred_element_type=jnp.float32)
        return shard(out, "batch", None, "vocab")  # vocab-parallel logits (CE reduces over V)

    def forward(self, params, batch):
        x = self.embed_tokens(params, batch["tokens"])
        x, _ = self._run(params, x)
        from repro.models.layers import cotangent_cast
        x = cotangent_cast(x)  # keep the backward at activation dtype
        return self.logits(params, x), jnp.float32(0.0)

    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        L = cfg.n_layers
        ssm = SSMCache(
            jnp.zeros((L, batch_size, cfg.ssm_heads, cfg.ssm_state,
                       cfg.ssm_head_dim), jnp.float32),
            jnp.zeros((L, batch_size, cfg.ssm_conv - 1,
                       cfg.d_inner + 2 * cfg.ssm_state), cfg.adtype))
        z = jnp.zeros((self.n_sites, batch_size, max_seq,
                       cfg.n_kv_heads * cfg.hd), cfg.adtype)
        return HybridCache(ssm, KVCache(z, z))

    def cache_specs(self):
        return HybridCache(
            SSMCache(param_spec((None, "batch", "heads", None, None)),
                     param_spec((None, "batch", None, "inner"))),
            KVCache(param_spec((None, "batch", None, "kv_heads")),
                    param_spec((None, "batch", None, "kv_heads"))))

    def prefill(self, params, batch, cache):
        x = self.embed_tokens(params, batch["tokens"])
        x, new_cache = self._run(params, x, cache, jnp.int32(0))
        return self.logits(params, x[:, -1:, :]), new_cache

    def decode_step(self, params, cache, pos, tokens):
        x = self.embed_tokens(params, tokens)
        x, new_cache = self._run(params, x, cache, pos, decode=True)
        return self.logits(params, x), new_cache
