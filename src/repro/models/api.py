"""Unified model API: config, registry, analytic FLOP/param accounting.

``build_model(cfg)`` returns a family object exposing:

  init_params(key)                        -> params pytree
  param_specs()                           -> PartitionSpec pytree (same shape;
                                             resolve under an active mesh)
  forward(params, batch)                  -> (logits, aux_loss)
  init_cache(batch, max_seq)              -> cache pytree (zeros)
  prefill(params, batch, cache)           -> (logits_last, cache)
  decode_step(params, cache, pos, token, **extras) -> (logits, cache)

``batch`` is a dict: always ``tokens`` (B, S) int32; VLM adds
``image_embeds`` (B, n_img, d); whisper adds ``audio_frames`` (B, n_frames, d)
— modality frontends are stubs per the assignment: input_specs() provides
precomputed patch/frame embeddings.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["ModelConfig", "build_model", "count_params", "analytic_flops"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads
    norm_type: str = "rmsnorm"
    qk_norm: bool = False
    mlp_kind: str = "swiglu"
    rope_theta: float | None = 1e4
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 2
    moe_dense_residual: bool = False
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 4096
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared attention block applied every k ssm blocks
    shared_attn_every: int = 0
    # VLM: cross-attention to image embeddings every k self-attn layers
    cross_attn_every: int = 0
    n_image_tokens: int = 0
    # audio enc-dec
    encoder_layers: int = 0
    n_audio_frames: int = 0
    # numerics / implementation
    param_dtype: str = "float32"
    act_dtype: str = "bfloat16"
    attention_impl: str = "reference"  # reference | pallas | pallas_interpret
    attn_chunk: int = 256
    remat: str = "full"  # full | dots | none
    scan_layers: bool = True  # False: python-unrolled layers (giant-MoE FSDP:
    # per-layer weight gathers instead of one hoisted full-stack all-gather)
    sub_quadratic: bool = False  # supports long_500k (SSM/hybrid)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding/head tables pad the vocab to a multiple of 256 so the
        vocab axis shards evenly on any mesh (Megatron-style padding);
        analytics (count_params) use the true vocab."""
        return -(-self.vocab // 256) * 256

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.act_dtype)

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        from repro.models.transformer import DecoderLM
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        from repro.models.mamba2 import Mamba2LM
        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import Zamba2LM
        return Zamba2LM(cfg)
    if cfg.family == "vlm":
        from repro.models.vlm import VisionLM
        return VisionLM(cfg)
    if cfg.family == "audio":
        from repro.models.whisper import EncDecLM
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family}")


# ------------------------------------------------------- analytic counts ---

def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.hd
    p = cfg.d_model * cfg.n_heads * hd * 2  # wq, wo
    p += cfg.d_model * cfg.n_kv_heads * hd * 2  # wk, wv
    if cfg.qk_norm:
        p += 2 * hd
    return p


def _mlp_params(cfg: ModelConfig, d_ff: int | None = None) -> int:
    d_ff = d_ff or cfg.d_ff
    mult = 3 if cfg.mlp_kind == "swiglu" else 2
    return mult * cfg.d_model * d_ff


def count_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active-per-token) parameter counts from the config."""
    emb = cfg.vocab * cfg.d_model
    head = cfg.vocab * cfg.d_model
    norms = 2 * cfg.d_model if cfg.norm_type == "rmsnorm" else 0
    total = emb + head
    active = emb + head

    if cfg.family in ("dense", "moe", "vlm"):
        per_layer = _attn_params(cfg) + norms
        if cfg.moe_experts:
            router = cfg.d_model * cfg.moe_experts
            experts = cfg.moe_experts * _mlp_params(cfg)
            act_ffn = cfg.moe_top_k * _mlp_params(cfg)
            if cfg.moe_dense_residual:
                experts += _mlp_params(cfg)
                act_ffn += _mlp_params(cfg)
            total += cfg.n_layers * (per_layer + router + experts)
            active += cfg.n_layers * (per_layer + router + act_ffn)
        else:
            total += cfg.n_layers * (per_layer + _mlp_params(cfg))
            active += cfg.n_layers * (per_layer + _mlp_params(cfg))
        if cfg.family == "vlm" and cfg.cross_attn_every:
            n_cross = cfg.n_layers // cfg.cross_attn_every
            total += n_cross * (_attn_params(cfg) + norms)
            active += n_cross * (_attn_params(cfg) + norms)
    elif cfg.family == "ssm":
        per = _mamba2_params(cfg)
        total += cfg.n_layers * per
        active += cfg.n_layers * per
    elif cfg.family == "hybrid":
        per = _mamba2_params(cfg)
        total += cfg.n_layers * per
        active += cfg.n_layers * per
        shared = _attn_params(cfg) + _mlp_params(cfg) + norms
        total += shared  # one parameter set, reused
        n_apps = max(cfg.n_layers // max(cfg.shared_attn_every, 1), 1)
        active += n_apps * shared
    elif cfg.family == "audio":
        per_dec = _attn_params(cfg) * 2 + _mlp_params(cfg) + norms  # self+cross
        per_enc = _attn_params(cfg) + _mlp_params(cfg) + norms
        total += cfg.n_layers * per_dec + cfg.encoder_layers * per_enc
        active += cfg.n_layers * per_dec + cfg.encoder_layers * per_enc
    return int(total), int(active)


def _mamba2_params(cfg: ModelConfig) -> int:
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    in_proj = cfg.d_model * (2 * di + 2 * ns + nh)  # z, x, B, C, dt
    conv = cfg.ssm_conv * (di + 2 * ns)
    out_proj = di * cfg.d_model
    extras = nh * 3 + di  # A_log, D, dt_bias, gate-norm weight
    return in_proj + conv + out_proj + extras + cfg.d_model


def analytic_flops(cfg: ModelConfig, seq: int, batch: int,
                   mode: str = "train") -> float:
    """MODEL_FLOPS for one step: 6·N·D (train) / 2·N_active·D (inference)
    plus the attention O(S²) term; decode counts one new token per sequence
    attending over a cache of `seq`."""
    total, active = count_params(cfg)
    mult = 6.0 if mode == "train" else 2.0
    if mode == "decode":
        tokens = batch  # one token per sequence
        flops = 2.0 * active * tokens
        # attention over the cache
        attn_layers = _n_attn_applications(cfg)
        flops += tokens * attn_layers * 4.0 * cfg.n_heads * cfg.hd * seq
        return flops
    tokens = batch * seq
    flops = mult * active * tokens
    attn_layers = _n_attn_applications(cfg)
    flops += tokens * attn_layers * mult * 2.0 * cfg.n_heads * cfg.hd * seq * 0.5
    if cfg.family == "ssm" or cfg.family == "hybrid":
        # SSD scan term: per token per layer ~ 2·d_inner·ssm_state (state upd)
        flops += tokens * cfg.n_layers * mult * 2.0 * cfg.d_inner * cfg.ssm_state
    return flops


def _n_attn_applications(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "moe"):
        return cfg.n_layers
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every if cfg.cross_attn_every else 0
        return cfg.n_layers + n_cross
    if cfg.family == "hybrid":
        return max(cfg.n_layers // max(cfg.shared_attn_every, 1), 1)
    if cfg.family == "audio":
        return cfg.n_layers * 2 + cfg.encoder_layers
    return 0  # pure ssm
