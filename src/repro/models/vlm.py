"""Llama-3.2-Vision-style VLM: dense decoder backbone with gated
cross-attention layers to image patch embeddings every
``cross_attn_every`` self-attention layers.

The vision tower is a STUB per the assignment: ``batch["image_embeds"]``
carries precomputed (B, n_image_tokens, d_model) patch embeddings (the
dry-run's ``input_specs`` provides the ShapeDtypeStruct).  Cross-attn K/V are
computed once (prefill) and cached for decode.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.api import ModelConfig
from repro.models.layers import (
    KVCache, apply_norm, attention, init_attention, make_norm,
)
from repro.models.sharding import param_spec, shard
from repro.models.transformer import DecoderLM, remat_wrap, stack_layer_specs

__all__ = ["VisionLM", "VLMCache"]


@dataclasses.dataclass
class VLMCache:
    self_attn: KVCache  # (L, B, S, K, hd)
    cross: KVCache  # (n_cross, B, n_img, K, hd)


jax.tree_util.register_dataclass(VLMCache, data_fields=["self_attn", "cross"],
                                 meta_fields=[])


class VisionLM(DecoderLM):
    def __init__(self, cfg: ModelConfig):
        assert cfg.cross_attn_every > 0 and cfg.n_image_tokens > 0
        self.cfg = cfg  # (bypasses DecoderLM.__init__ family check)

    @property
    def n_cross(self) -> int:
        return -(-self.cfg.n_layers // self.cfg.cross_attn_every)

    def _group(self, s: int) -> tuple[int, int]:
        lo = s * self.cfg.cross_attn_every
        return lo, min(lo + self.cfg.cross_attn_every, self.cfg.n_layers)

    def _init_cross(self, key):
        cfg = self.cfg
        return {
            "ln": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "attn": init_attention(key, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd, cfg.pdtype),
            "gate": jnp.zeros((), cfg.pdtype),  # tanh-gated residual
        }

    def init_params(self, key):
        base = super().init_params(key)
        kc = jax.random.fold_in(key, 7)
        base["cross"] = jax.vmap(self._init_cross)(
            jax.random.split(kc, self.n_cross))
        return base

    def param_specs(self):
        specs = super().param_specs()
        from repro.models.layers import attn_specs
        specs["cross"] = stack_layer_specs({
            "ln": param_spec((None,)),
            "attn": attn_specs(),
            "gate": param_spec(()),
        })
        return specs

    def _cross_block(self, cp, x, image_embeds=None, cache=None):
        cfg = self.cfg
        h = apply_norm(cfg.norm_type, x, cp["ln"])
        a, new_cache = attention(
            cp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=None, causal=False,
            cache=cache, cache_pos=None, kv_source=image_embeds,
            impl="reference", chunk=cfg.attn_chunk)
        x = x + jnp.tanh(cp["gate"].astype(jnp.float32)).astype(x.dtype) * a
        return shard(x, "batch", "seq", None), new_cache

    def _run(self, params, x, image_embeds=None, caches=None, cache_pos=None):
        cfg = self.cfg
        new_self, new_cross = [], []
        for s in range(self.n_cross):
            cp = jax.tree.map(lambda a: a[s], params["cross"])
            cross_cache = None
            if caches is not None and image_embeds is None:
                cross_cache = jax.tree.map(lambda a: a[s], caches.cross)
            x, nc = self._cross_block(cp, x, image_embeds, cross_cache)
            if nc is None and image_embeds is not None and caches is not None:
                # prefill: cache the image K/V for decode (flat layout)
                k = (image_embeds @ cp["attn"]["wk"]).astype(cfg.adtype)
                v = (image_embeds @ cp["attn"]["wv"]).astype(cfg.adtype)
                nc = KVCache(k, v)
            new_cross.append(nc)
            lo, hi = self._group(s)
            group = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
            if caches is None:
                def body(carry, bp):
                    y, _, _ = self._block(bp, carry)
                    return y, None
                body = remat_wrap(body, cfg.remat)
                x, _ = jax.lax.scan(body, x, group)
            else:
                grp_cache = jax.tree.map(lambda a: a[lo:hi], caches.self_attn)

                def body(carry, xs):
                    bp, cl = xs
                    y, nc2, _ = self._block(bp, carry, cl, cache_pos)
                    return y, nc2
                if x.shape[1] > 1:
                    body = remat_wrap(body, cfg.remat)
                x, grp_new = jax.lax.scan(body, x, (group, grp_cache))
                new_self.append(grp_new)
        if caches is None:
            return x, None
        sa = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_self)
        cr = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_cross)
        return x, VLMCache(sa, cr)

    def forward(self, params, batch):
        x = self.embed_tokens(params, batch["tokens"])
        img = batch["image_embeds"].astype(self.cfg.adtype)
        x, _ = self._run(params, x, image_embeds=img)
        from repro.models.layers import cotangent_cast
        x = cotangent_cast(x)  # keep the backward at activation dtype
        return self.logits(params, x), jnp.float32(0.0)

    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        kvd = cfg.n_kv_heads * cfg.hd
        z = jnp.zeros((cfg.n_layers, batch_size, max_seq, kvd), cfg.adtype)
        zc = jnp.zeros((self.n_cross, batch_size, cfg.n_image_tokens, kvd),
                       cfg.adtype)
        return VLMCache(KVCache(z, z), KVCache(zc, zc))

    def cache_specs(self):
        s = param_spec((None, "batch", None, "kv_heads"))
        return VLMCache(KVCache(s, s), KVCache(s, s))

    def prefill(self, params, batch, cache):
        x = self.embed_tokens(params, batch["tokens"])
        img = batch["image_embeds"].astype(self.cfg.adtype)
        x, new_cache = self._run(params, x, image_embeds=img, caches=cache,
                                 cache_pos=jnp.int32(0))
        return self.logits(params, x[:, -1:, :]), new_cache

    def decode_step(self, params, cache, pos, tokens):
        x = self.embed_tokens(params, tokens)
        x, new_cache = self._run(params, x, image_embeds=None, caches=cache,
                                 cache_pos=pos)
        return self.logits(params, x), new_cache
