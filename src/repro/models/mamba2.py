"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

The SSD layer is computed chunk-wise: within a chunk of Q tokens the
quadratic "attention-like" form runs on the MXU; across chunks a sequential
``lax.scan`` passes the (H, N, P) state.  This is the TPU-native adaptation
of the paper's algorithm: per-chunk tensors are (B, Q, Q, H) — bounded
regardless of sequence length, so 500k-token contexts stream through with
constant memory (the long_500k cells).

Decode is the O(1) recurrence: S ← exp(A·dt)·S + dt·B⊗x, y = C·S + D·x,
plus a (k−1)-deep causal-conv ring buffer.  No KV cache — state size is
independent of context length.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import ModelConfig
from repro.models.layers import cross_entropy_loss, make_norm, apply_norm, rms_norm
from repro.models.sharding import param_spec, shard
from repro.models.transformer import remat_wrap, stack_layer_specs

__all__ = ["Mamba2LM", "SSMCache", "init_mamba_block", "mamba_block",
           "mamba_block_specs", "ssd_chunked", "ssd_decode_step"]


@dataclasses.dataclass
class SSMCache:
    """state: (B, H, N, P); conv: (B, k−1, Dc) ring of recent conv inputs."""

    state: jnp.ndarray
    conv: jnp.ndarray


jax.tree_util.register_dataclass(SSMCache, data_fields=["state", "conv"],
                                 meta_fields=[])


# ----------------------------------------------------------------- SSD -----

def ssd_chunked(x, B, C, dt, A, D, chunk: int):
    """Chunked SSD scan.

    x: (b, L, H, P); B, C: (b, L, N); dt: (b, L, H); A, D: (H,).
    Returns (y (b, L, H, P), final_state (b, H, N, P)).
    """
    b, L, H, Pd = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    n = -(-L // Q)
    pad = n * Q - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    def reshape_chunks(t):
        return t.reshape((b, n, Q) + t.shape[2:]).swapaxes(0, 1)

    xc, Bc, Cc, dtc = map(reshape_chunks, (x, B, C, dt))
    S0 = jnp.zeros((b, H, N, Pd), dtype=jnp.float32)

    def body(S, xs):
        x_c, B_c, C_c, dt_c = xs  # (b,Q,H,P), (b,Q,N), (b,Q,N), (b,Q,H)
        dtA = dt_c * A[None, None, :]  # (b,Q,H), negative
        cum = jnp.cumsum(dtA, axis=1)  # (b,Q,H)
        total = cum[:, -1, :]  # (b,H)
        # intra-chunk quadratic form
        CB = jnp.einsum("biN,bjN->bij", C_c, B_c,
                        preferred_element_type=jnp.float32)  # (b,Q,Q)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (b,i,j,H)
        mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))
        M = CB[..., None] * jnp.where(mask[None, :, :, None], decay, 0.0) \
            * dt_c[:, None, :, :]  # (b,i,j,H)
        y = jnp.einsum("bijh,bjhp->bihp", M, x_c.astype(jnp.float32))
        # contribution of carried-in state
        y += jnp.einsum("biN,bhNp->bihp", C_c.astype(jnp.float32), S) \
            * jnp.exp(cum)[..., None]
        # state update
        w = jnp.exp(total[:, None, :] - cum) * dt_c  # (b,Q,H)
        S_new = jnp.exp(total)[..., None, None] * S + jnp.einsum(
            "bjN,bjh,bjhp->bhNp", B_c.astype(jnp.float32), w,
            x_c.astype(jnp.float32))
        y += D[None, None, :, None] * x_c.astype(jnp.float32)
        return S_new, y.astype(x_c.dtype)

    S, ys = jax.lax.scan(body, S0, (xc, Bc, Cc, dtc))
    y = ys.swapaxes(0, 1).reshape(b, n * Q, H, Pd)[:, :L]
    return y, S


def ssd_decode_step(x, B, C, dt, A, D, state):
    """One-token recurrence.  x: (b,1,H,P); B,C: (b,1,N); dt: (b,1,H)."""
    dtA = jnp.exp(dt[:, 0] * A[None, :])  # (b,H)
    S = dtA[..., None, None] * state + jnp.einsum(
        "bN,bh,bhp->bhNp", B[:, 0].astype(jnp.float32), dt[:, 0],
        x[:, 0].astype(jnp.float32))
    y = jnp.einsum("bN,bhNp->bhp", C[:, 0].astype(jnp.float32), S) \
        + D[None, :, None] * x[:, 0].astype(jnp.float32)
    return y[:, None].astype(x.dtype), S


# --------------------------------------------------------------- block -----

def init_mamba_block(key, cfg: ModelConfig):
    d, di, N, H, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_conv)
    ks = jax.random.split(key, 8)
    dt = cfg.pdtype
    s = d ** -0.5
    Dc = di + 2 * N
    return {
        "norm": make_norm(cfg.norm_type, d, dt),
        "wz": (jax.random.normal(ks[0], (d, di)) * s).astype(dt),
        "wx": (jax.random.normal(ks[1], (d, di)) * s).astype(dt),
        "wB": (jax.random.normal(ks[2], (d, N)) * s).astype(dt),
        "wC": (jax.random.normal(ks[3], (d, N)) * s).astype(dt),
        "wdt": (jax.random.normal(ks[4], (d, H)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[5], (k, Dc)) * k ** -0.5).astype(dt),
        "conv_b": jnp.zeros((Dc,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "gate_norm": jnp.ones((di,), dt),
        "out_proj": (jax.random.normal(ks[6], (di, d)) * di ** -0.5).astype(dt),
    }


def mamba_block_specs(cfg: ModelConfig):
    return {
        "norm": param_spec((None,)),
        "wz": param_spec((None, "inner")),
        "wx": param_spec((None, "inner")),
        "wB": param_spec((None, None)),
        "wC": param_spec((None, None)),
        "wdt": param_spec((None, "heads")),
        "conv_w": param_spec((None, "inner")),
        "conv_b": param_spec(("inner",)),
        "A_log": param_spec(("heads",)),
        "D": param_spec(("heads",)),
        "dt_bias": param_spec(("heads",)),
        "gate_norm": param_spec(("inner",)),
        "out_proj": param_spec(("inner", None)),
    }


def _causal_conv(u, w, b, conv_cache=None):
    """Depthwise causal conv, kernel k.  u: (B, L, Dc); w: (k, Dc).

    With conv_cache (B, k−1, Dc) the history prepends u (decode/prefill
    continuation).  Returns (y (B, L, Dc), new_cache)."""
    k = w.shape[0]
    if conv_cache is None:
        hist = jnp.zeros((u.shape[0], k - 1, u.shape[2]), dtype=u.dtype)
    else:
        hist = conv_cache.astype(u.dtype)
    full = jnp.concatenate([hist, u], axis=1)  # (B, L+k−1, Dc)
    L = u.shape[1]
    y = sum(full[:, i:i + L] * w[i][None, None, :] for i in range(k))
    y = y + b[None, None, :]
    new_cache = full[:, -(k - 1):] if k > 1 else hist
    return y, new_cache


def mamba_block(bp, x, cfg: ModelConfig, cache: SSMCache | None = None,
                decode: bool = False):
    """Pre-norm residual Mamba2 block.  Returns (x, new_cache)."""
    b, L, d = x.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = apply_norm(cfg.norm_type, x, bp["norm"])
    dtp = x.dtype
    z = jnp.einsum("bld,di->bli", h, bp["wz"].astype(dtp),
                   preferred_element_type=dtp)
    xin = jnp.einsum("bld,di->bli", h, bp["wx"].astype(dtp),
                     preferred_element_type=dtp)
    Bin = jnp.einsum("bld,dn->bln", h, bp["wB"].astype(dtp),
                     preferred_element_type=dtp)
    Cin = jnp.einsum("bld,dn->bln", h, bp["wC"].astype(dtp),
                     preferred_element_type=dtp)
    dt_raw = jnp.einsum("bld,dh->blh", h, bp["wdt"]).astype(jnp.float32)

    conv_in = jnp.concatenate([xin, Bin, Cin], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, bp["conv_w"], bp["conv_b"],
                                      cache.conv if cache is not None else None)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xs, Bs, Cs = jnp.split(conv_out, [di, di + N], axis=-1)
    xs = xs.reshape(b, L, H, Pd)
    xs = shard(xs, "batch", None, "heads", None)
    dt = jax.nn.softplus(dt_raw + bp["dt_bias"][None, None, :])
    A = -jnp.exp(bp["A_log"])

    if decode:
        y, S = ssd_decode_step(xs, Bs, Cs, dt, A, bp["D"], cache.state)
    else:
        y, S = ssd_chunked(xs, Bs, Cs, dt, A, bp["D"], cfg.ssm_chunk)
    y = y.reshape(b, L, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 bp["gate_norm"])
    out = jnp.einsum("bli,id->bld", y, bp["out_proj"].astype(dtp),
                     preferred_element_type=dtp)
    new_cache = SSMCache(S, new_conv) if (cache is not None or decode) else None
    return x + out, new_cache


# ---------------------------------------------------------------- model ----

class Mamba2LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init_params(self, key):
        cfg = self.cfg
        ke, kb, kh = jax.random.split(key, 3)
        blocks = jax.vmap(lambda k: init_mamba_block(k, cfg))(
            jax.random.split(kb, cfg.n_layers))
        return {
            "embed": (jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model))
                      * cfg.d_model ** -0.5).astype(cfg.pdtype),
            "blocks": blocks,
            "final_norm": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded))
                     * cfg.d_model ** -0.5).astype(cfg.pdtype),
        }

    def param_specs(self):
        return {
            "embed": param_spec(("vocab", None)),
            "blocks": stack_layer_specs(mamba_block_specs(self.cfg)),
            "final_norm": param_spec((None,)),
            "head": param_spec((None, "vocab")),
        }

    def embed_tokens(self, params, tokens):
        from repro.models.layers import embed_lookup
        x = embed_lookup(params["embed"], tokens, self.cfg.adtype)
        return shard(x, "batch", "seq", None)

    def logits(self, params, x):
        x = apply_norm(self.cfg.norm_type, x, params["final_norm"])
        out = jnp.einsum("bsd,dv->bsv", x, params["head"],
                         preferred_element_type=jnp.float32)
        return shard(out, "batch", None, "vocab")  # vocab-parallel logits (CE reduces over V)

    def forward(self, params, batch):
        x = self.embed_tokens(params, batch["tokens"])

        def body(carry, bp):
            y, _ = mamba_block(bp, carry, self.cfg)
            return y, jnp.float32(0.0)

        body = remat_wrap(body, self.cfg.remat)
        x, _ = jax.lax.scan(body, x, params["blocks"])
        from repro.models.layers import cotangent_cast
        x = cotangent_cast(x)  # keep the backward at activation dtype
        return self.logits(params, x), jnp.float32(0.0)

    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        L = cfg.n_layers
        state = jnp.zeros((L, batch_size, cfg.ssm_heads, cfg.ssm_state,
                           cfg.ssm_head_dim), jnp.float32)
        conv = jnp.zeros((L, batch_size, cfg.ssm_conv - 1,
                          cfg.d_inner + 2 * cfg.ssm_state), cfg.adtype)
        return SSMCache(state, conv)

    def cache_specs(self):
        return SSMCache(param_spec((None, "batch", "heads", None, None)),
                        param_spec((None, "batch", None, "inner")))

    def prefill(self, params, batch, cache):
        x = self.embed_tokens(params, batch["tokens"])

        def body(carry, xs):
            bp, cache_l = xs
            y, new_cache = mamba_block(bp, carry, self.cfg, cache_l)
            return y, new_cache

        body = remat_wrap(body, self.cfg.remat)
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        return self.logits(params, x[:, -1:, :]), new_cache

    def decode_step(self, params, cache, pos, tokens):
        x = self.embed_tokens(params, tokens)

        def body(carry, xs):
            bp, cache_l = xs
            y, new_cache = mamba_block(bp, carry, self.cfg, cache_l,
                                       decode=True)
            return y, new_cache

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        return self.logits(params, x), new_cache
