"""Shared neural layers for the model zoo (pure JAX, functional style).

Every layer is a pair ``init_*(key, ...) -> params`` / ``apply(params, x)``;
params are plain pytrees (dicts of jnp arrays) so the whole model is a single
pytree that pjit shards by spec (see each family's ``param_specs``).

Attention weights keep an explicit head axis — (d, H, hd) — so tensor
parallelism shards *heads* over the `model` mesh axis; GSPMD pads when the
head count doesn't divide (56 q heads on a 16-way axis → padded to 64).
KV heads shard the same way and are repeated to H inside the computation
(GQA), which is also how the Pallas flash kernel consumes them.

Attention has three execution paths:
  * ``reference`` — chunked flash-style attention (scan over query chunks,
    f32 softmax rows): O(chunk·S) memory so 32k prefill fits HBM, and the
    path every backend can compile (the dry-run uses it).
  * ``pallas`` / ``pallas_interpret`` — kernels/flash_attention.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.sharding import shard

__all__ = [
    "rms_norm", "layer_norm", "make_norm", "apply_norm",
    "init_dense", "dense",
    "rotary_embedding", "apply_rotary",
    "init_attention", "attention",
    "init_mlp", "mlp",
    "cross_entropy_loss", "KVCache",
]


# ---------------------------------------------------------------- norms ----

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray | None, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    return x.astype(dt)


def layer_norm(x: jnp.ndarray, weight=None, bias=None, eps: float = 1e-5):
    """Non-parametric when weight/bias are None (OLMo-style)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def make_norm(norm_type: str, d: int, dtype):
    if norm_type == "rmsnorm":
        return jnp.ones((d,), dtype=dtype)
    if norm_type == "layernorm_nonparam":
        return jnp.zeros((0,), dtype=dtype)  # placeholder leaf (no params)
    raise ValueError(norm_type)


def apply_norm(norm_type: str, x, w, eps: float = 1e-6):
    if norm_type == "rmsnorm":
        return rms_norm(x, w, eps)
    return layer_norm(x, eps=1e-5)


# ---------------------------------------------------------------- dense ----

def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def dense(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    # mixed-precision weight streaming: matmuls read weights at activation
    # width (bf16) — halves HBM weight traffic vs streaming f32 masters
    # (§Perf iteration 1); master weights stay f32 in the optimizer.
    # preferred_element_type = activation dtype: otherwise jnp.einsum's
    # default f32 accumulation makes GSPMD all-reduce the tensor-parallel
    # partial sums at f32 width — 2× wire bytes (§Perf iteration 4).  The
    # per-chip MXU still accumulates in f32 internally.
    return jnp.einsum("...i,io->...o", x, w.astype(x.dtype),
                      preferred_element_type=x.dtype)


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray,
                 out_dtype, chunk: int = 512) -> jnp.ndarray:
    """Embedding as a chunked one-hot matmul (TPU-native).

    ``jnp.take``'s backward is a scatter-add, which XLA expands into a
    sequential per-token loop over the table shard — the dry-run analyzer
    measured 248 TB/device of traffic for qwen3's 152k tokens (§Perf
    iteration 1).  A one-hot einsum keeps both directions as MXU matmuls
    (bwd = one_hotᵀ @ dy); chunking the sequence bounds the one-hot to
    (B, chunk, V_shard)."""
    B, S = tokens.shape
    V, D = table.shape
    w = table.astype(out_dtype)

    def one(chunk_tokens):
        oh = jax.nn.one_hot(chunk_tokens, V, dtype=out_dtype)
        return jnp.einsum("bcv,vd->bcd", oh, w)

    if S <= chunk:
        return one(tokens)
    n = -(-S // chunk)
    pad = n * chunk - S
    tp = jnp.pad(tokens, ((0, 0), (0, pad)))
    ts = tp.reshape(B, n, chunk).transpose(1, 0, 2)
    _, outs = jax.lax.scan(lambda c, t: (None, one(t)), None, ts)
    return outs.transpose(1, 0, 2, 3).reshape(B, n * chunk, D)[:, :S]


# --------------------------------------------------------------- rotary ----

def rotary_embedding(positions: jnp.ndarray, head_dim: int, theta: float):
    """(P,) int positions → cos/sin (P, head_dim/2), f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (B, S, H, D); cos/sin: (S, D/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1).astype(dt)


# ------------------------------------------------------------ attention ----

@dataclasses.dataclass
class KVCache:
    """k/v: (B, S_max, K·D) per site (callers stack a layer axis in front).

    The head axis is stored FLAT so the cache shards on K·D over the model
    axis even when K alone doesn't divide it (same trick as the weights)."""

    k: jnp.ndarray
    v: jnp.ndarray


jax.tree_util.register_dataclass(KVCache, data_fields=["k", "v"], meta_fields=[])


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype=jnp.float32, qk_norm: bool = False):
    """Weights are stored FLAT — (d, H·hd) — so the tensor-parallel shard
    axis is the flattened head dim, which divides the 16-way model axis for
    every assigned arch even when the head count (56, 20…) does not.  The
    head axis is recovered by reshape inside the computation; GSPMD re-pads
    internally as needed."""
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": init_dense(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": init_dense(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": init_dense(ks[3], n_heads * head_dim, d_model, dtype,
                         scale=(n_heads * head_dim) ** -0.5),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype=dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype=dtype)
    return p


def attn_specs(qk_norm: bool = False):
    """PartitionSpecs for one attention site (flat-weight layout)."""
    from repro.models.sharding import param_spec
    s = {
        "wq": param_spec((None, "heads")),
        "wk": param_spec((None, "kv_heads")),
        "wv": param_spec((None, "kv_heads")),
        "wo": param_spec(("heads", None)),
    }
    if qk_norm:
        s["q_norm"] = param_spec((None,))
        s["k_norm"] = param_spec((None,))
    return s


def _sdpa_chunked(q, k, v, *, causal: bool, q_offset, chunk: int):
    """Flash-style reference: scan over query chunks, f32 softmax rows.

    q: (B, Sq, H, D); k, v: (B, Skv, H, D) (kv already repeated to H).
    Peak memory O(B·chunk·H·Skv), independent of Sq.
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    scale = D ** -0.5
    kv_pos = jnp.arange(Skv)

    def one_chunk(q_chunk, start):
        s = jnp.einsum("bchd,bshd->bchs", q_chunk, k,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = q_offset + start + jnp.arange(q_chunk.shape[1])
            mask = kv_pos[None, :] <= q_pos[:, None]  # (c, Skv)
            s = jnp.where(mask[None, :, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bchs,bshd->bchd", p.astype(v.dtype), v)

    if Sq <= chunk:
        return one_chunk(q, 0)
    n = -(-Sq // chunk)
    pad = n * chunk - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = qp.reshape(B, n, chunk, H, D).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(n) * chunk

    def body(_, xs):
        qc, st = xs
        return None, one_chunk(qc, st)

    # remat each q-chunk: otherwise ALL chunks' (c, Skv) score rows are
    # stacked as backward residuals — ~17 GB live at once for zamba2's
    # shared-attention sites (the Pallas kernel never materializes them)
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, (qs, starts))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, n * chunk, H, D)
    return out[:, :Sq]


def attention(
    params: dict,
    x: jnp.ndarray,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float | None = 1e4,
    causal: bool = True,
    cache: KVCache | None = None,
    cache_pos: jnp.ndarray | None = None,
    kv_source: jnp.ndarray | None = None,
    impl: str = "reference",
    chunk: int = 256,
    qk_norm: bool = False,
):
    """Self- or cross-attention with optional KV cache.

    Modes:
      * train:         cache=None — full-seq causal self-attention.
      * prefill:       cache=zeros buffer, cache_pos=0 — writes K/V.
      * decode:        x is (B,1,d); cache_pos = current length.
      * cross-attn:    kv_source (B,S_src,d) provides K/V, causal=False;
        decode-time, cache w/ cache_pos=None reads precomputed K/V.
    Returns (out, new_cache).
    """
    B, Sq, _ = x.shape
    G = n_heads // n_kv_heads
    q = dense(params["wq"], x).reshape(B, Sq, n_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, params["q_norm"])

    if cache is not None and cache_pos is None:
        # cross-attn decode: K/V precomputed at prefill, no rope
        S_c = cache.k.shape[1]
        k = cache.k.reshape(B, S_c, n_kv_heads, head_dim)
        v = cache.v.reshape(B, S_c, n_kv_heads, head_dim)
        new_cache = cache
        q_offset = 0
    else:
        src = x if kv_source is None else kv_source
        Skv_new = src.shape[1]
        k = dense(params["wk"], src).reshape(B, Skv_new, n_kv_heads, head_dim)
        v = dense(params["wv"], src).reshape(B, Skv_new, n_kv_heads, head_dim)
        if qk_norm:
            k = rms_norm(k, params["k_norm"])
        q_offset = 0
        if rope_theta is not None and kv_source is None:
            base = cache_pos if (cache is not None and cache_pos is not None) else 0
            cos_q, sin_q = rotary_embedding(base + jnp.arange(Sq), head_dim, rope_theta)
            cos_k, sin_k = rotary_embedding(base + jnp.arange(Skv_new), head_dim, rope_theta)
            q = apply_rotary(q, cos_q, sin_q)
            k = apply_rotary(k, cos_k, sin_k)
        if cache is not None and cache_pos is not None:
            # write new K/V (flat layout); unwritten future slots are
            # masked by q_offset
            kf = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.reshape(B, Skv_new, -1).astype(cache.k.dtype),
                cache_pos, axis=1)
            vf = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.reshape(B, Skv_new, -1).astype(cache.v.dtype),
                cache_pos, axis=1)
            new_cache = KVCache(kf, vf)
            S_c = kf.shape[1]
            k = kf.reshape(B, S_c, n_kv_heads, head_dim)
            v = vf.reshape(B, S_c, n_kv_heads, head_dim)
            q_offset = cache_pos
        else:
            new_cache = None

    # pin head-parallelism: under sequence-sharded activations GSPMD may
    # otherwise replicate heads and shard seq inside attention — 16×
    # redundant attention compute/memory (§Perf iteration 1, finding 3)
    from repro.models.sharding import shard_div
    q = shard_div(q, ("batch", None, "heads", None))
    k = shard_div(k, ("batch", None, "kv_heads", None))
    v = shard_div(v, ("batch", None, "kv_heads", None))

    if G > 1 and Sq == 1:
        # decode: grouped-GQA einsum — never materialize the G×-repeated
        # KV cache (7.5 GB/step for deepseek-33B; §Perf iteration 7).  The
        # (K, G) head split on a single-token q is a trivial reshard.
        q5 = q.reshape(B, Sq, n_kv_heads, G, head_dim)
        s = jnp.einsum("bqkgd,bskd->bqkgs", q5, k,
                       preferred_element_type=jnp.float32) * head_dim ** -0.5
        if causal:
            kv_pos = jnp.arange(k.shape[1])
            mask = kv_pos[None, :] <= q_offset + jnp.arange(Sq)[:, None]
            s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(v.dtype), v)
        out = out.reshape(B, Sq, n_heads, head_dim)
    else:
        # GQA: repeat kv heads to H (the flash kernel indexes instead on TPU)
        if G > 1:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        if impl in ("pallas", "pallas_interpret") and cache is None \
                and kv_source is None and causal:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True,
                                       interpret=(impl == "pallas_interpret"))
        else:
            out = _sdpa_chunked(q, k, v, causal=causal, q_offset=q_offset,
                                chunk=chunk)
    proj = dense(params["wo"], out.reshape(B, Sq, n_heads * head_dim))
    return proj, new_cache


# ---------------------------------------------------------------- MLPs -----

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32,
             kind: str = "swiglu"):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wi_gate": init_dense(ks[0], d_model, d_ff, dtype),
            "wi_up": init_dense(ks[1], d_model, d_ff, dtype),
            "wo": init_dense(ks[2], d_ff, d_model, dtype, scale=d_ff ** -0.5),
        }
    return {  # gelu
        "wi": init_dense(ks[0], d_model, d_ff, dtype),
        "wo": init_dense(ks[1], d_ff, d_model, dtype, scale=d_ff ** -0.5),
    }


def mlp(params, x, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jax.nn.silu(dense(params["wi_gate"], x)) * dense(params["wi_up"], x)
    else:
        h = jax.nn.gelu(dense(params["wi"], x))
    h = shard(h, "batch", None, "ff")  # inside MLP the shard axis is ff (SP re-shards at block end)
    return dense(params["wo"], h)


def cotangent_cast(x: jnp.ndarray) -> jnp.ndarray:
    """Identity fwd; casts the COTANGENT to x's dtype in bwd.

    Guard rail between the f32 cross-entropy head and the layer stack: if
    any head-path op promoted the backward to f32, residual adds would
    propagate it unchanged through every layer (2× backward wire/HBM).
    Measured on qwen3 train it is currently a no-op — the convert-transpose
    chain already downcasts (§Perf iteration 4a, refuted-as-win) — but it
    pins the invariant against future head changes."""

    @jax.custom_vjp
    def ident(v):
        return v

    def fwd(v):
        return v, None

    def bwd(_, g):
        return (g.astype(x.dtype),)

    ident.defvjp(fwd, bwd)
    return ident(x)


# ---------------------------------------------------------------- loss -----

def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Vocab-parallel-friendly CE: every reduction over V is a sum/max, so
    GSPMD keeps logits sharded on V and only all-reduces (B,S) scalars —
    no logits all-gather (the iota-compare form avoids a gather op)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    hit = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    loss = lse - ll
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
