"""Dense / MoE decoder-only LM (olmo, granite, deepseek, qwen3, arctic, grok).

Layers are stacked (leading L axis) and executed with ``lax.scan`` so HLO
size is depth-independent — essential for 62-layer models lowered against a
512-device mesh.  Remat wraps the scan body per ``cfg.remat``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.api import ModelConfig
from repro.models.layers import (
    KVCache,
    apply_norm,
    attention,
    init_attention,
    init_mlp,
    make_norm,
    mlp,
)
from repro.models.moe import init_moe, moe_ffn, moe_specs
from repro.models.sharding import param_spec, shard

__all__ = ["DecoderLM", "remat_wrap", "stack_layer_specs"]


def remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


def stack_layer_specs(spec_tree):
    """Prepend the stacked-layer axis (replicated) to every leaf spec."""
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        if cfg.family not in ("dense", "moe"):
            raise ValueError(cfg.family)
        self.cfg = cfg

    # ------------------------------------------------------------ params --
    def _init_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "attn": init_attention(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd, cfg.pdtype,
                                   cfg.qk_norm),
            "ln2": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
        }
        if cfg.moe_experts:
            p["moe"] = init_moe(k2, cfg)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.pdtype,
                                cfg.mlp_kind)
        return p

    def init_params(self, key):
        cfg = self.cfg
        ke, kb, kh = jax.random.split(key, 3)
        blocks = jax.vmap(self._init_block)(jax.random.split(kb, cfg.n_layers))
        return {
            "embed": (jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model))
                      * cfg.d_model ** -0.5).astype(cfg.pdtype),
            "blocks": blocks,
            "final_norm": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded))
                     * cfg.d_model ** -0.5).astype(cfg.pdtype),
        }

    def _block_specs(self):
        cfg = self.cfg
        from repro.models.layers import attn_specs
        s = {
            "ln1": param_spec((None,)),
            "attn": attn_specs(cfg.qk_norm),
            "ln2": param_spec((None,)),
        }
        if cfg.moe_experts:
            s["moe"] = moe_specs(cfg, stacked=False)
        else:
            s["mlp"] = {
                "wi_gate": param_spec((None, "ff")),
                "wi_up": param_spec((None, "ff")),
                "wo": param_spec(("ff", None)),
            } if cfg.mlp_kind == "swiglu" else {
                "wi": param_spec((None, "ff")),
                "wo": param_spec(("ff", None)),
            }
        return s

    def param_specs(self):
        return {
            "embed": param_spec(("vocab", None)),
            "blocks": stack_layer_specs(self._block_specs()),
            "final_norm": param_spec((None,)),
            "head": param_spec((None, "vocab")),
        }

    # ------------------------------------------------------------ blocks --
    def _block(self, bp, x, cache=None, cache_pos=None):
        cfg = self.cfg
        from repro.models.sharding import constrain_tree
        bp = constrain_tree(bp, self._block_specs())  # pin per-layer FSDP
        h = apply_norm(cfg.norm_type, x, bp["ln1"])
        a, new_cache = attention(
            bp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta, causal=True,
            cache=cache, cache_pos=cache_pos, impl=cfg.attention_impl,
            chunk=cfg.attn_chunk, qk_norm=cfg.qk_norm)
        x = x + a
        h = apply_norm(cfg.norm_type, x, bp["ln2"])
        if cfg.moe_experts:
            m, aux = moe_ffn(bp["moe"], h, cfg)
        else:
            m, aux = mlp(bp["mlp"], h, cfg.mlp_kind), jnp.float32(0.0)
        x = x + m
        x = shard(x, "batch", "seq", None)
        return x, new_cache, aux

    # ----------------------------------------------------------- forward --
    def embed_tokens(self, params, tokens):
        from repro.models.layers import embed_lookup
        x = embed_lookup(params["embed"], tokens, self.cfg.adtype)
        return shard(x, "batch", "seq", None)

    def logits(self, params, x):
        x = apply_norm(self.cfg.norm_type, x, params["final_norm"])
        out = jnp.einsum("bsd,dv->bsv", x, params["head"],
                         preferred_element_type=jnp.float32)
        return shard(out, "batch", None, "vocab")  # vocab-parallel logits (CE reduces over V)

    def forward(self, params, batch):
        """(logits, aux_loss) over the full sequence (training path)."""
        x = self.embed_tokens(params, batch["tokens"])

        def body(carry, bp):
            y, _, aux = self._block(bp, carry)
            return y, aux

        body = remat_wrap(body, self.cfg.remat)
        if self.cfg.scan_layers:
            x, auxes = jax.lax.scan(body, x, params["blocks"])
            aux = jnp.sum(auxes)
        else:
            aux = jnp.float32(0.0)
            for l in range(self.cfg.n_layers):
                bp = jax.tree.map(lambda a: a[l], params["blocks"])
                x, a = body(x, bp)
                aux = aux + a
        from repro.models.layers import cotangent_cast
        x = cotangent_cast(x)  # keep the backward at activation dtype
        return self.logits(params, x), aux

    # ------------------------------------------------------------- cache --
    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        shape = (cfg.n_layers, batch_size, max_seq, cfg.n_kv_heads * cfg.hd)
        z = jnp.zeros(shape, dtype=cfg.adtype)
        return KVCache(z, z)

    def cache_specs(self):
        spec = param_spec((None, "batch", None, "kv_heads"))
        return KVCache(spec, spec)

    def prefill(self, params, batch, cache):
        """Full-prompt pass writing the cache; returns (last_logits, cache)."""
        x = self.embed_tokens(params, batch["tokens"])
        pos = jnp.int32(0)

        def body(carry, xs):
            bp, cache_l = xs
            y, new_cache, _ = self._block(bp, carry, cache_l, pos)
            return y, new_cache

        body = remat_wrap(body, self.cfg.remat)
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        return self.logits(params, x[:, -1:, :]), new_cache

    def decode_step(self, params, cache, pos, tokens):
        """tokens: (B, 1) → (logits (B,1,V), new cache)."""
        x = self.embed_tokens(params, tokens)

        def body(carry, xs):
            bp, cache_l = xs
            y, new_cache, _ = self._block(bp, carry, cache_l, pos)
            return y, new_cache

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        return self.logits(params, x), new_cache
