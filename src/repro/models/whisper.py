"""Whisper-large-v3-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``batch["audio_frames"]``
carries precomputed (B, n_audio_frames, d_model) frame embeddings.  The
encoder is bidirectional self-attention (GELU MLPs, learned-free sinusoid-less
stub positions via rope=None + absolute embeddings omitted — backbone only);
the decoder interleaves causal self-attention and cross-attention to the
encoder output.  decode_32k exercises the decoder step with a 32k self-attn
KV cache per the assignment (the real model caps at 448 tokens — we lower
the backbone at the assigned shape).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.api import ModelConfig
from repro.models.layers import (
    KVCache, apply_norm, attention, init_attention, init_mlp, make_norm, mlp,
)
from repro.models.sharding import param_spec, shard
from repro.models.transformer import remat_wrap, stack_layer_specs

__all__ = ["EncDecLM", "EncDecCache"]


@dataclasses.dataclass
class EncDecCache:
    self_attn: KVCache  # (L, B, S_max, K, hd) decoder self-attn
    cross: KVCache  # (L, B, n_frames, K, hd) precomputed encoder K/V


jax.tree_util.register_dataclass(EncDecCache,
                                 data_fields=["self_attn", "cross"],
                                 meta_fields=[])


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder_layers > 0 and cfg.n_audio_frames > 0
        self.cfg = cfg

    # ------------------------------------------------------------ params --
    def _init_enc_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "attn": init_attention(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd, cfg.pdtype),
            "ln2": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.pdtype, "gelu"),
        }

    def _init_dec_block(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "self_attn": init_attention(k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd, cfg.pdtype),
            "ln_x": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "cross_attn": init_attention(k2, cfg.d_model, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.hd, cfg.pdtype),
            "ln2": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.pdtype, "gelu"),
        }

    def init_params(self, key):
        cfg = self.cfg
        ke, kenc, kdec, kh = jax.random.split(key, 4)
        return {
            "embed": (jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model))
                      * cfg.d_model ** -0.5).astype(cfg.pdtype),
            "encoder": jax.vmap(self._init_enc_block)(
                jax.random.split(kenc, cfg.encoder_layers)),
            "decoder": jax.vmap(self._init_dec_block)(
                jax.random.split(kdec, cfg.n_layers)),
            "enc_norm": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "final_norm": make_norm(cfg.norm_type, cfg.d_model, cfg.pdtype),
            "head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded))
                     * cfg.d_model ** -0.5).astype(cfg.pdtype),
        }

    def _attn_specs(self):
        from repro.models.layers import attn_specs
        return attn_specs()

    def param_specs(self):
        mlp_s = {"wi": param_spec((None, "ff")), "wo": param_spec(("ff", None))}
        enc = stack_layer_specs({
            "ln1": param_spec((None,)), "attn": self._attn_specs(),
            "ln2": param_spec((None,)), "mlp": mlp_s,
        })
        dec = stack_layer_specs({
            "ln1": param_spec((None,)), "self_attn": self._attn_specs(),
            "ln_x": param_spec((None,)), "cross_attn": self._attn_specs(),
            "ln2": param_spec((None,)), "mlp": mlp_s,
        })
        return {
            "embed": param_spec(("vocab", None)),
            "encoder": enc,
            "decoder": dec,
            "enc_norm": param_spec((None,)),
            "final_norm": param_spec((None,)),
            "head": param_spec((None, "vocab")),
        }

    # ------------------------------------------------------------ pieces --
    def encode(self, params, audio_frames):
        cfg = self.cfg
        x = audio_frames.astype(cfg.adtype)
        x = shard(x, "batch", "seq", None)

        def body(carry, bp):
            h = apply_norm(cfg.norm_type, carry, bp["ln1"])
            a, _ = attention(bp["attn"], h, n_heads=cfg.n_heads,
                             n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                             rope_theta=cfg.rope_theta, causal=False,
                             impl="reference", chunk=cfg.attn_chunk)
            y = carry + a
            h = apply_norm(cfg.norm_type, y, bp["ln2"])
            y = y + mlp(bp["mlp"], h, "gelu")
            return shard(y, "batch", "seq", None), None

        body = remat_wrap(body, cfg.remat)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return apply_norm(cfg.norm_type, x, params["enc_norm"])

    def _dec_block(self, bp, x, enc_out=None, self_cache=None, cache_pos=None,
                   cross_cache=None):
        cfg = self.cfg
        h = apply_norm(cfg.norm_type, x, bp["ln1"])
        a, new_self = attention(
            bp["self_attn"], h, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, causal=True, cache=self_cache,
            cache_pos=cache_pos, impl=cfg.attention_impl, chunk=cfg.attn_chunk)
        x = x + a
        h = apply_norm(cfg.norm_type, x, bp["ln_x"])
        a, _ = attention(
            bp["cross_attn"], h, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, rope_theta=None,
            causal=False, cache=cross_cache, cache_pos=None,
            kv_source=enc_out, impl="reference", chunk=cfg.attn_chunk)
        x = x + a
        h = apply_norm(cfg.norm_type, x, bp["ln2"])
        x = x + mlp(bp["mlp"], h, "gelu")
        return shard(x, "batch", "seq", None), new_self

    def embed_tokens(self, params, tokens):
        from repro.models.layers import embed_lookup
        x = embed_lookup(params["embed"], tokens, self.cfg.adtype)
        return shard(x, "batch", "seq", None)

    def logits(self, params, x):
        x = apply_norm(self.cfg.norm_type, x, params["final_norm"])
        out = jnp.einsum("bsd,dv->bsv", x, params["head"],
                         preferred_element_type=jnp.float32)
        return shard(out, "batch", None, "vocab")  # vocab-parallel logits (CE reduces over V)

    # -------------------------------------------------------------- API ---
    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio_frames"])
        x = self.embed_tokens(params, batch["tokens"])

        def body(carry, bp):
            y, _ = self._dec_block(bp, carry, enc_out=enc_out)
            return y, None

        body = remat_wrap(body, cfg.remat)
        x, _ = jax.lax.scan(body, x, params["decoder"])
        from repro.models.layers import cotangent_cast
        x = cotangent_cast(x)  # keep the backward at activation dtype
        return self.logits(params, x), jnp.float32(0.0)

    def init_cache(self, batch_size: int, max_seq: int):
        cfg = self.cfg
        kvd = cfg.n_kv_heads * cfg.hd
        z = jnp.zeros((cfg.n_layers, batch_size, max_seq, kvd), cfg.adtype)
        zc = jnp.zeros((cfg.n_layers, batch_size, cfg.n_audio_frames, kvd),
                       cfg.adtype)
        return EncDecCache(KVCache(z, z), KVCache(zc, zc))

    def cache_specs(self):
        s = param_spec((None, "batch", None, "kv_heads"))
        return EncDecCache(KVCache(s, s), KVCache(s, s))

    def prefill(self, params, batch, cache):
        """Encode audio, precompute cross K/V, prefill decoder self-cache."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio_frames"])

        def cross_kv(bp):
            # flat (B, S_frames, K·hd) layout, matching KVCache
            k = (enc_out @ bp["cross_attn"]["wk"]).astype(cfg.adtype)
            v = (enc_out @ bp["cross_attn"]["wv"]).astype(cfg.adtype)
            return KVCache(k, v)

        cross = jax.vmap(cross_kv)(params["decoder"])
        x = self.embed_tokens(params, batch["tokens"])
        pos = jnp.int32(0)

        def body(carry, xs):
            bp, self_l, cross_l = xs
            y, new_self = self._dec_block(bp, carry, enc_out=None,
                                          self_cache=self_l, cache_pos=pos,
                                          cross_cache=cross_l)
            return y, new_self

        body = remat_wrap(body, cfg.remat)
        x, new_self = jax.lax.scan(
            body, x, (params["decoder"], cache.self_attn, cross))
        return self.logits(params, x[:, -1:, :]), EncDecCache(new_self, cross)

    def decode_step(self, params, cache, pos, tokens):
        x = self.embed_tokens(params, tokens)

        def body(carry, xs):
            bp, self_l, cross_l = xs
            y, new_self = self._dec_block(bp, carry, enc_out=None,
                                          self_cache=self_l, cache_pos=pos,
                                          cross_cache=cross_l)
            return y, new_self

        x, new_self = jax.lax.scan(
            body, x, (params["decoder"], cache.self_attn, cache.cross))
        return self.logits(params, x), EncDecCache(new_self, cache.cross)
