"""Logical-axis sharding: model code names axes, the launcher maps them.

Model code annotates params/activations with *logical* axes ("batch",
"vocab", "heads", "ff", …).  An :class:`AxisRules` maps logical → mesh axes
and is swappable per experiment — this is the lever the §Perf hillclimbs
turn (e.g. "shard vocab over model" vs "replicate", sequence parallelism on
or off) without touching model code.

Outside a mesh context everything degrades to a no-op so the same model code
runs single-device in smoke tests.
"""

from __future__ import annotations

import dataclasses
import threading

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["AxisRules", "DEFAULT_RULES", "axis_rules", "set_axis_rules",
           "logical_spec", "shard", "param_spec", "constrain_tree",
           "fsdp_leaf_spec"]

MeshAxes = tuple[str, ...] | str | None


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical axis name → mesh axis (or tuple, or None=replicate)."""

    rules: dict[str, MeshAxes]

    def resolve(self, *logical: str | None, mesh: jax.sharding.Mesh | None = None) -> P:
        """PartitionSpec for the given logical axes, dropping mesh axes that
        don't exist on the active mesh (so ('pod','data') batch rules work on
        single-pod meshes too)."""
        mesh = mesh or _active_mesh()
        present = set(mesh.axis_names) if mesh is not None else set()
        out = []
        for name in logical:
            target = self.rules.get(name) if name else None
            if target is None:
                out.append(None)
                continue
            if isinstance(target, str):
                target = (target,)
            kept = tuple(a for a in target if a in present)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        return P(*out)


DEFAULT_RULES = AxisRules({
    # activations
    "batch": ("pod", "data"),
    "seq": None,          # flip to "model" for sequence parallelism
    "embed": None,
    # params
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",   # replicated automatically when not divisible
    "ff": "model",
    "experts": "model",
    "inner": "model",      # mamba2 d_inner / conv channels
    "state": None,
    "layers": None,
})

_local = threading.local()


def set_axis_rules(rules: AxisRules):
    _local.rules = rules


def axis_rules() -> AxisRules:
    return getattr(_local, "rules", DEFAULT_RULES)


def _active_mesh() -> jax.sharding.Mesh | None:
    # jax ≥ 0.5 exposes the context mesh as jax.sharding.get_abstract_mesh;
    # on older releases fall back to the thread-resources physical mesh that
    # `with mesh:` installs.
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        m = getter()
    else:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
    if m is None or m.empty:
        return None
    return m


def logical_spec(*logical: str | None) -> P:
    return axis_rules().resolve(*logical)


def shard(x, *logical: str | None):
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    spec = axis_rules().resolve(*logical, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def shard_div(x, logical: tuple[str | None, ...]):
    """Like :func:`shard` but SKIPS the whole constraint if any requested
    axis doesn't divide its dimension.  Pinning a non-divisible dim would
    constrain it to *replicated* — for 56-head attention that forces 16×
    redundant compute; leaving it unconstrained lets GSPMD pick a padded
    sharding instead (§Perf iteration 5)."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    requested = axis_rules().resolve(*logical, mesh=mesh)
    achieved = param_spec(logical, tuple(x.shape), mesh=mesh)
    if tuple(requested) != tuple(achieved):
        return x
    return jax.lax.with_sharding_constraint(x, achieved)


FSDP_AXIS = "data"
FSDP_MIN_ELEMS = 1 << 20


def fsdp_leaf_spec(spec: P, shape: tuple[int, ...],
                   mesh=None, axis: str = FSDP_AXIS,
                   min_elems: int = FSDP_MIN_ELEMS) -> P:
    """ZeRO-3 via GSPMD: add `axis` to the largest replicated, divisible dim
    of a big leaf (shared by launch.shardings.fsdp_specs and the in-body
    constraint below)."""
    mesh = mesh or _active_mesh()
    if mesh is None or axis not in mesh.axis_names:
        return spec
    ways = dict(mesh.shape)[axis]
    n = 1
    for s in shape:
        n *= s
    if n < min_elems:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for e in entries if e is not None
            for a in ((e,) if isinstance(e, str) else e)}
    if axis in used:
        return P(*entries)
    best, best_dim = -1, -1
    for d, e in enumerate(entries):
        if e is None and shape[d] % ways == 0 and shape[d] > best:
            best, best_dim = shape[d], d
    if best_dim < 0:
        return P(*entries)
    entries[best_dim] = axis
    return P(*entries)


def constrain_tree(params, spec_tree, fsdp: bool = True):
    """with_sharding_constraint over a params subtree (no-op without mesh).

    Applied at the TOP of every scanned block body: it pins the per-layer
    slice to its intended (FSDP) sharding so GSPMD's propagation cannot pull
    the body's gathered layout out onto the full stacked (L, …) tensor —
    without this, a 35-layer MoE stack all-gathers 3×19.5 GB per device
    (EXPERIMENTS.md §Dry-run notes)."""
    mesh = _active_mesh()
    if mesh is None:
        return params

    def leaf(x, spec):
        if not isinstance(spec, P) or not hasattr(x, "ndim"):
            return x
        if fsdp:
            spec = fsdp_leaf_spec(spec, x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, spec)

    return jax.tree.map(leaf, params, spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def param_spec(shape_logical: tuple[str | None, ...],
               divisibility: tuple[int, ...] | None = None,
               mesh: jax.sharding.Mesh | None = None) -> P:
    """Spec for a parameter; if ``divisibility`` is given, axes whose size
    does not divide by the mesh-axis size are replicated instead (e.g. 56
    query heads on model=16 still shard — GSPMD pads — but 8 kv heads on
    model=16 replicate, the Megatron kv-replication scheme)."""
    rules = axis_rules()
    mesh = mesh or _active_mesh()
    spec = list(rules.resolve(*shape_logical, mesh=mesh))
    if divisibility is not None and mesh is not None:
        sizes = dict(mesh.shape)
        for k, (target, dim) in enumerate(zip(spec, divisibility)):
            if target is None or dim <= 0:
                continue
            axes = (target,) if isinstance(target, str) else target
            total = 1
            for a in axes:
                total *= sizes.get(a, 1)
            if dim % total != 0:
                spec[k] = None
    return P(*spec)
