"""Batched-searcher parity: the repro.search implementations must return
the seed scalar-loop results (same argmin within ≤1e-5 relative objective)
while issuing O(dispatches) instead of O(candidates) evaluator calls, and
the old entry points must keep working as shims."""

import math

import numpy as np
import pytest

from repro.core import (CostConfig, DQCoupling, ExplicitFleet, ObjectiveSet,
                        PlacementProblem, RegionFleet, linear_graph)
from repro.core.optimizers import OptResult, _dq_grid
from repro.core.placement import random_placement, uniform_placement
from repro.search import BatchedProblem
from repro.search import exhaustive_search as b_exhaustive
from repro.search import greedy_transfer as b_greedy
from repro.search import random_search as b_random

COM = np.array([[0.0, 1.5, 2.0],
                [1.5, 0.0, 1.0],
                [2.0, 1.0, 0.0]])


def _problem(beta=1.0, coupling=True, objectives=None):
    g = linear_graph([1.0, 1.5, 1.0])
    fleet = ExplicitFleet(com_cost=COM)
    dq = DQCoupling(cap0=np.full(3, 1.2), load=np.full(3, 0.2)) \
        if coupling else None
    return PlacementProblem(g, fleet, beta=beta, dq=dq,
                            objectives=objectives)


# -- seed-faithful scalar reference loops (the pre-refactor algorithms) -------

def _scalar_exhaustive(prob, granularity=4):
    import itertools
    avail = prob.availability()
    n_ops, n_dev = avail.shape
    per_op = []
    for i in range(n_ops):
        idx = np.flatnonzero(avail[i])
        rows = []

        def comps(total, parts):
            if parts == 1:
                yield (total,)
                return
            for head in range(total + 1):
                for tail in comps(total - head, parts - 1):
                    yield (head,) + tail

        for comp in comps(granularity, idx.size):
            row = np.zeros(n_dev)
            row[idx] = np.asarray(comp) / granularity
            rows.append(row)
        per_op.append(rows)
    best_F, best_x, best_dq = math.inf, None, 0.0
    for rows in itertools.product(*per_op):
        x = np.stack(rows)
        for dq in _dq_grid(prob):
            f = prob.score(x, dq)
            if f < best_F:
                best_F, best_x, best_dq = f, x, dq
    return OptResult.of(prob, best_x, best_dq, [best_F], 0)


def _scalar_random(prob, rng, n_candidates=256):
    avail = prob.availability()
    n_ops, _ = avail.shape
    best_F, best_x, best_dq = math.inf, None, 0.0
    dqs = _dq_grid(prob)
    for x in [uniform_placement(n_ops, avail)] + [
            random_placement(n_ops, avail, rng, 0.5)
            for _ in range(n_candidates)]:
        for dq in dqs:
            f = prob.score(x, dq)
            if f < best_F:
                best_F, best_x, best_dq = f, x, dq
    return OptResult.of(prob, best_x, best_dq, [best_F], 0)


# -- argmin parity ------------------------------------------------------------

@pytest.mark.parametrize("beta,coupling", [(0.0, False), (1.0, True)])
def test_exhaustive_parity(beta, coupling):
    prob = _problem(beta=beta, coupling=coupling)
    want = _scalar_exhaustive(prob, granularity=3)
    got = b_exhaustive(prob, granularity=3)
    assert got.F == pytest.approx(want.F, rel=1e-5)
    assert got.dq_fraction == pytest.approx(want.dq_fraction, abs=1e-9)
    assert got.dispatches >= 1


def test_random_search_parity_same_rng_stream():
    """Same seed ⇒ same candidate stream ⇒ same winner (≤1e-5 rel)."""
    prob = _problem()
    want = _scalar_random(prob, np.random.default_rng(42), n_candidates=256)
    got = b_random(prob, np.random.default_rng(42), n_candidates=256)
    assert got.F == pytest.approx(want.F, rel=1e-5)
    np.testing.assert_allclose(got.x, want.x, atol=1e-12)


def test_greedy_parity_with_exact_rescoring():
    """The batched greedy follows the scalar loop's per-operator move scan
    (same neighborhoods, oracle-confirmed moves) — on a fixed instance it
    must land on the same descent result."""
    prob = _problem()
    res = b_greedy(prob)
    # the descent result is locally optimal for its own move set: no single
    # δ-transfer at the finest δ improves the exact score
    from repro.search import transfer_neighborhood
    avail = prob.availability()
    for i in range(prob.graph.n_ops):
        cands = transfer_neighborhood(res.x, avail, i, 0.05)
        for c in cands:
            assert prob.score(c, res.dq_fraction) >= res.F - 1e-9
    # and it matches the seed test expectations: beats uniform, feasible
    base = prob.score(uniform_placement(3, avail), 0.0)
    assert res.F <= base + 1e-9
    assert prob.feasible(res.x, res.dq_fraction)


# -- dispatch accounting: O(dispatches) ≪ O(candidates) -----------------------

def test_dispatch_collapse():
    prob = _problem()
    got = b_random(prob, np.random.default_rng(0), n_candidates=512,
                   batch=256)
    assert got.evals >= 512          # logical candidate × dq evaluations
    assert got.dispatches <= 4       # uniform seed + ⌈512/256⌉ chunks
    ex = b_exhaustive(prob, granularity=4)
    assert ex.evals > 20_000 and ex.dispatches <= 2


def test_engine_feasibility_matches_prob_score():
    prob = _problem(beta=1.0, coupling=True)
    eng = BatchedProblem(prob)
    rng = np.random.default_rng(5)
    xs = np.stack([random_placement(3, prob.availability(), rng)
                   for _ in range(16)])
    dqs = np.array([0.0, 0.5, 1.0])
    scores = eng.score_batch(xs, dqs)
    for i in range(16):
        for d, dq in enumerate(dqs):
            want = prob.score(xs[i], float(dq))
            if math.isinf(want):
                assert math.isinf(scores[i, d])
            else:
                assert scores[i, d] == pytest.approx(want, rel=1e-5)


def test_engine_multi_objective_matches_scalar_total():
    obj = ObjectiveSet.from_weights(latency_f=1.0, network_movement=0.01,
                                    occupancy_max=0.1)
    g = linear_graph([1.0, 1.5, 1.0], out_bytes=2.0, work=0.3)
    fleet = ExplicitFleet(com_cost=COM, speed=np.array([1.0, 0.5, 2.0]))
    prob = PlacementProblem(g, fleet, beta=0.8, objectives=obj)
    eng = BatchedProblem(prob)
    rng = np.random.default_rng(9)
    xs = np.stack([random_placement(3, prob.availability(), rng)
                   for _ in range(8)])
    scores = eng.score_batch(xs, np.array([0.0, 0.4]))
    for i in range(8):
        for d, dq in enumerate((0.0, 0.4)):
            assert scores[i, d] == pytest.approx(
                prob.score(xs[i], dq), rel=1e-4)


def test_engine_structured_fleet_path():
    """RegionFleet problems ride the structured S=1 family — scores match
    the oracle without materializing the com matrix inside the engine."""
    region = np.array([0, 0, 1, 1, 2, 2])
    inter = np.array([[0.1, 2.0, 3.0], [2.0, 0.1, 1.0], [3.0, 1.0, 0.1]])
    fleet = RegionFleet(region=region, inter=inter).degrade_device(1, 4.0)
    g = linear_graph([1.0, 0.7, 1.2])
    prob = PlacementProblem(g, fleet, beta=1.0)
    eng = BatchedProblem(prob)
    assert not eng.scalar_fallback
    rng = np.random.default_rng(2)
    xs = np.stack([random_placement(3, prob.availability(), rng)
                   for _ in range(4)])
    scores = eng.score_batch(xs, np.array([0.0, 1.0]))
    for i in range(4):
        for d, dq in enumerate((0.0, 1.0)):
            assert scores[i, d] == pytest.approx(
                prob.score(xs[i], dq), rel=1e-5)


def test_engine_scalar_fallback_for_compute_extension():
    """include_compute problems (e.g. the StreamingEngine's re-optimize
    path) fall back to the exact scalar loop — identical scores, zero
    dispatches."""
    prob = PlacementProblem(linear_graph([1.0, 1.0, 1.0], work=0.5),
                            ExplicitFleet(com_cost=COM),
                            CostConfig(include_compute=True))
    eng = BatchedProblem(prob)
    assert eng.scalar_fallback
    xs = uniform_placement(3, prob.availability())[None]
    scores = eng.score_batch(xs, np.array([0.0]))
    assert scores[0, 0] == pytest.approx(prob.score(xs[0], 0.0), rel=1e-12)
    assert eng.dispatches == 0


# -- shim surface -------------------------------------------------------------

def test_old_entry_points_are_shims():
    import repro.core.optimizers as co
    import repro.sim.replay as replay

    prob = _problem()
    res = co.random_search(prob, np.random.default_rng(1), n_candidates=64)
    assert res.dispatches >= 1      # proves the batched path is underneath
    res2 = co.greedy_transfer(prob)
    assert res2.dispatches >= 1
    assert callable(replay.robust_placement)
    assert callable(replay.scenario_robust_search)


def test_simulated_annealing_block_search_improves():
    from repro.search import simulated_annealing

    prob = _problem()
    res = simulated_annealing(prob, np.random.default_rng(0), steps=1500)
    avail = prob.availability()
    base = prob.score(uniform_placement(3, avail), 0.0)
    assert res.F <= base + 1e-9
    assert prob.feasible(res.x, res.dq_fraction)
    assert res.dispatches <= math.ceil(1500 / 64) + 1
