"""Process-wide executable cache (repro.sim.execache): LRU semantics, the
cross-instance recompile regression the cache exists to kill, and
fresh_cache isolation."""

import numpy as np
import pytest

from repro.core import CostConfig, ExplicitFleet, random_dag, \
    random_placement
from repro.obs import jaxhooks
from repro.sim import (BatchedEvaluator, ExecutableCache, executable_cache,
                       fresh_cache, graph_key, pack_fleets, pack_placements)


def test_lru_eviction_order_and_counters():
    c = ExecutableCache(capacity=2, name="t")
    builds = []
    get = lambda k: c.get_or_build((k,), lambda: builds.append(k) or k)
    get("a"), get("b")
    assert get("a") == "a" and c.stats()["hits"] == 1
    get("c")                      # evicts "b" (least recently used)
    assert ("b",) not in c and ("a",) in c and ("c",) in c
    get("b")                      # rebuild
    assert builds == ["a", "b", "c", "b"]
    st = c.stats()
    assert st["misses"] == 4 and st["evictions"] == 2 and len(c) == 2
    c.clear()
    assert len(c) == 0


def test_fresh_cache_isolates_and_restores():
    base = executable_cache()
    base_len = len(base)
    with fresh_cache() as tmp:
        assert executable_cache() is tmp and tmp is not base
        tmp.get_or_build(("x",), lambda: object())
        assert len(tmp) == 1
    assert executable_cache() is base and len(base) == base_len


def _problem(seed=0, n_ops=5, n_dev=4, n_fleets=3):
    rng = np.random.default_rng(seed)
    g = random_dag(n_ops, edge_prob=0.6, rng=rng)
    fleets = []
    for _ in range(n_fleets):
        com = rng.uniform(0.1, 3.0, (n_dev, n_dev))
        com = (com + com.T) / 2
        np.fill_diagonal(com, 0.0)
        fleets.append(ExplicitFleet(com_cost=com))
    xs = pack_placements([
        random_placement(n_ops, np.ones((n_ops, n_dev), bool), rng)
        for _ in range(6)])
    return g, pack_fleets(fleets), xs


def test_second_instance_never_recompiles():
    """THE regression this PR's cache hoist fixes: two BatchedEvaluators
    over identically-constructed graphs used to recompile everything,
    because jax's compilation cache keys on function identity and each
    instance owned fresh closures.  Now instance 2 resolves the SAME
    jitted callables through the process cache: zero compiles, bitwise
    identical grids."""
    g, coms, xs = _problem()
    with fresh_cache():
        ev1 = BatchedEvaluator(g, CostConfig())
        warm = np.asarray(ev1.score_grid(xs, coms, dq=0.2, beta=0.5))
        # an equal-content graph built independently (same dataclasses)
        g2 = random_dag(5, edge_prob=0.6, rng=np.random.default_rng(0))
        assert graph_key(g2) == graph_key(g)
        snap = jaxhooks.snapshot()
        ev2 = BatchedEvaluator(g2, CostConfig())
        again = np.asarray(ev2.score_grid(xs, coms, dq=0.2, beta=0.5))
        assert snap.delta() == (0, 0.0)
        np.testing.assert_array_equal(warm, again)
        assert ev1._jit_grid is ev2._jit_grid


def test_shared_returns_one_instance_per_content():
    g, _, _ = _problem()
    g2, _, _ = _problem()
    a = BatchedEvaluator.shared(g)
    assert BatchedEvaluator.shared(g2) is a
    assert BatchedEvaluator.shared(g, CostConfig(alpha=0.5)) is not a


def test_distinct_configs_do_not_collide():
    """Different CostConfigs must map to different executables — a cache
    hit across configs would silently score with the wrong alpha."""
    g, coms, xs = _problem()
    with fresh_cache():
        plain = np.asarray(
            BatchedEvaluator(g, CostConfig()).score_grid(xs, coms))
        alpha = np.asarray(
            BatchedEvaluator(g, CostConfig(alpha=1.0)).score_grid(xs, coms))
    assert not np.array_equal(plain, alpha)
