"""Property tests: the STRUCTURED batched path (RegionFleetFamily through
BatchedEvaluator) against the float64 numpy oracle, on both the vmap and
Pallas routes, including ``alpha > 0`` and the shared-family (S == 1)
broadcast case — plus the family pack/generator contracts."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container lacks hypothesis — use the shim
    from repro.testing.propcheck import given, settings, strategies as st

from repro.core import (
    CostConfig,
    RegionFleet,
    RegionFleetFamily,
    edge_latencies,
    latency,
    objective_F,
    random_dag,
    random_placement,
)
from repro.sim import (
    BatchedEvaluator,
    ScenarioConfig,
    pack_placements,
    pack_region_fleets,
    region_fleet_family,
    region_scenario_batch,
)

SETTINGS = dict(max_examples=20, deadline=None)
REL = 1e-5


def _random_region_fleets(rng, n_dev, n_fleets):
    """RegionFleets sharing one region layout, with random inter matrices
    and degrade multipliers (some healthy, some straggling)."""
    n_regions = int(rng.integers(1, n_dev + 1))
    region = rng.integers(0, n_regions, n_dev)
    fleets = []
    for k in range(n_fleets):
        inter = rng.uniform(0.1, 2.0, (n_regions, n_regions))
        inter = (inter + inter.T) / 2
        degrade = None if k == 0 else rng.uniform(0.5, 4.0, n_dev)
        fleets.append(RegionFleet(region=region, inter=inter,
                                  degrade=degrade))
    return fleets


@st.composite
def instances(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    alpha = draw(st.sampled_from([0.0, 0.25, 1.0]))
    use_pallas = draw(st.sampled_from([False, True]))
    rng = np.random.default_rng(seed)
    n_ops = int(rng.integers(2, 8))
    n_dev = int(rng.integers(2, 9))
    g = random_dag(n_ops, edge_prob=0.5, rng=rng)
    fleets = _random_region_fleets(rng, n_dev, int(rng.integers(1, 4)))
    xs = [random_placement(n_ops, np.ones((n_ops, n_dev), bool), rng,
                           sparsity=float(rng.uniform(0.0, 0.7)))
          for _ in range(int(rng.integers(1, 5)))]
    return g, fleets, xs, CostConfig(alpha=alpha), use_pallas


@given(instances())
@settings(**SETTINGS)
def test_structured_matches_oracle(inst):
    """score_grid / latency / edge_latencies over a RegionFleetFamily ==
    numpy oracle to ≤1e-5 relative, vmap AND Pallas routes, alpha 0/>0."""
    g, fleets, xs, cfg, use_pallas = inst
    fam = pack_region_fleets(fleets)
    ev = BatchedEvaluator(g, cfg, use_pallas=use_pallas, interpret=True)
    P = pack_placements(xs)
    beta, dq = 0.7, 0.3
    grid = np.asarray(ev.score_grid(P, fam, dq=dq, beta=beta))
    assert grid.shape == (len(fleets), len(xs))
    for si, fleet in enumerate(fleets):
        for pi, x in enumerate(xs):
            want = objective_F(latency(g, fleet, x, cfg), dq, beta)
            assert grid[si, pi] == pytest.approx(want, rel=REL, abs=1e-6)
    # per-edge + latency agreement on the first placement across every fleet
    b = len(fleets)
    xb = np.stack([xs[0]] * b)
    el = np.asarray(ev.edge_latencies(xb, fam))
    lat = np.asarray(ev.latency(xb, fam))
    for si, fleet in enumerate(fleets):
        np.testing.assert_allclose(
            el[si], edge_latencies(g, fleet, xs[0], cfg), rtol=REL, atol=1e-6)
        assert lat[si] == pytest.approx(latency(g, fleet, xs[0], cfg),
                                        rel=REL, abs=1e-6)


@given(instances())
@settings(**SETTINGS)
def test_structured_shared_family_broadcast(inst):
    """An S == 1 family broadcasts against a placement batch exactly like a
    (1, V, V) dense com — on both routes."""
    g, fleets, xs, cfg, use_pallas = inst
    fam1 = pack_region_fleets(fleets[:1])
    ev = BatchedEvaluator(g, cfg, use_pallas=use_pallas, interpret=True)
    lat = np.asarray(ev.latency(pack_placements(xs), fam1))
    assert lat.shape == (len(xs),)
    for pi, x in enumerate(xs):
        assert lat[pi] == pytest.approx(latency(g, fleets[0], x, cfg),
                                        rel=REL, abs=1e-6)


def test_structured_and_dense_paths_agree():
    """The SAME family scored structurally and via its materialized dense
    pack produces the same grid (the dispatch is an implementation detail)."""
    from repro.sim import pack_fleets

    rng = np.random.default_rng(3)
    g = random_dag(6, 0.5, rng)
    fleets = _random_region_fleets(rng, 7, 3)
    xs = [random_placement(6, np.ones((6, 7), bool), rng, 0.4)
          for _ in range(4)]
    ev = BatchedEvaluator(g, CostConfig(alpha=0.3))
    P = pack_placements(xs)
    a = np.asarray(ev.score_grid(P, pack_region_fleets(fleets), dq=0.2,
                                 beta=0.9))
    b = np.asarray(ev.score_grid(P, pack_fleets(fleets), dq=0.2, beta=0.9))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_structured_kernel_against_ref():
    """The raw structured Pallas kernel against a jnp reference."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import edge_latency_structured_max

    rng = np.random.default_rng(0)
    for B, E, V, R, Bc in [(1, 1, 2, 1, 1), (3, 7, 5, 2, 3),
                           (2, 130, 16, 4, 2), (4, 33, 12, 3, 1)]:
        xi = jnp.asarray(rng.random((B, E, V)), jnp.float32)
        xj = jnp.asarray(rng.random((B, E, V)), jnp.float32)
        mass = jnp.asarray(rng.random((B, E, R)), jnp.float32)
        a = jnp.asarray(rng.random((Bc, R, V)), jnp.float32)
        corr = jnp.asarray(rng.random((Bc, 1, V)), jnp.float32)
        out = edge_latency_structured_max(xi, xj, mass, a, corr,
                                          interpret=True)
        # one batched device→host transfer per shape, not one per operand
        out_h, xi_h, xj_h, mass_h, a_h, corr_h = jax.device_get(
            (out, xi, xj, mass, a, corr))
        t = np.einsum("ber,brv->bev", mass_h,
                      np.broadcast_to(a_h, (B, R, V)))
        t = t + np.broadcast_to(corr_h, (B, 1, V)) * xj_h
        want = (xi_h * t).max(axis=2)
        np.testing.assert_allclose(out_h, want, atol=1e-5, rtol=1e-5)


def test_pack_region_fleets_rejects_mismatched_layouts():
    rng = np.random.default_rng(1)
    a = _random_region_fleets(rng, 6, 1)[0]
    b = RegionFleet(region=(a.region + 1) % a.n_regions if a.n_regions > 1
                    else a.region, inter=a.inter * 2.0)
    if a.n_regions > 1:
        with pytest.raises(ValueError):
            pack_region_fleets([a, b])
    from repro.core import ExplicitFleet
    with pytest.raises(ValueError):
        pack_region_fleets([a, ExplicitFleet(com_cost=a.com_matrix())])
    # ValueError (not AttributeError) even when the FIRST element is dense
    with pytest.raises(ValueError):
        RegionFleetFamily.from_fleets([ExplicitFleet(com_cost=a.com_matrix()),
                                       a])


def test_region_fleet_family_generator_contract():
    """Generated families: shared layout, healthy region under outages,
    perturbations actually move link costs, and the pack round-trips."""
    rng = np.random.default_rng(7)
    cfg = ScenarioConfig(n_regions=(4, 4), devices_per_region=(3, 3),
                         outage_prob=0.5, straggler_prob=0.3,
                         outage_factor=100.0)
    fam = region_fleet_family(rng, 8, cfg)
    assert fam.inter.shape == (8, 4, 4)
    assert fam.degrade.shape == (8, fam.n_devices)
    assert (fam.degrade >= 1.0).all()
    for s in range(8):
        # at least one region fully healthy (no outage multiplier)
        healthy = [r for r in range(4)
                   if (fam.degrade[s][fam.region == r] < cfg.outage_factor).all()]
        assert healthy
    # scenarios differ
    assert not np.allclose(fam.inter[0], fam.inter[1])
    # round-trip: unpacking to fleets and re-packing preserves the family
    fam2 = pack_region_fleets(fam.fleets())
    np.testing.assert_allclose(fam2.inter, fam.inter)
    np.testing.assert_allclose(fam2.degrade, fam.degrade)


def test_region_scenario_batch_scores_structurally():
    """region_scenario_batch fleets share one layout, so robust_placement
    runs the structured path and still matches the scalar oracle."""
    from repro.sim import robust_placement

    rng = np.random.default_rng(9)
    cfg = ScenarioConfig(trace_len=4, n_regions=(3, 3),
                         devices_per_region=(2, 3))
    scens = region_scenario_batch(rng, 4, cfg)
    g = scens[0].graph
    assert all(isinstance(s.fleet, RegionFleet) for s in scens)
    assert all(np.array_equal(s.fleet.region, scens[0].fleet.region)
               for s in scens)
    x, worst, grid = robust_placement(g, scens, rng, n_candidates=32)
    assert grid.shape == (4, 32)
    k = int(grid.max(axis=0).argmin())
    for si, s in enumerate(scens):
        assert grid[si, k] == pytest.approx(
            latency(g, s.fleet, x), rel=2e-5, abs=1e-6)


def test_family_fleet_oracle_equivalence():
    """family.fleet(s) prices identically through the RegionFleet segment-sum
    oracle and the materialized ExplicitFleet — the degrade algebra check."""
    from repro.core import ExplicitFleet

    rng = np.random.default_rng(11)
    g = random_dag(5, 0.5, rng)
    fleets = _random_region_fleets(rng, 8, 3)
    fam = RegionFleetFamily.from_fleets(fleets)
    x = random_placement(5, np.ones((5, 8), bool), rng, 0.3)
    for s in range(fam.n_scenarios):
        rf = fam.fleet(s)
        ef = ExplicitFleet(com_cost=rf.com_matrix())
        assert latency(g, rf, x) == pytest.approx(latency(g, ef, x),
                                                  rel=1e-12)


def test_from_fleets_preserves_per_scenario_speed():
    """degrade_device keeps nominal speed and encodes the slowdown in
    ``degrade`` alone; packing and unpacking a family must round-trip each
    scenario's EFFECTIVE speed — the compute/occupancy objectives price the
    degraded fleet correctly without double-counting the multiplier."""
    rng = np.random.default_rng(13)
    base = _random_region_fleets(rng, 6, 1)[0]
    slow = base.degrade_device(2, 4.0)
    assert slow.speed[2] == pytest.approx(base.speed[2])  # nominal untouched
    assert slow.effective_speed()[2] == pytest.approx(base.speed[2] / 4.0)
    fam = RegionFleetFamily.from_fleets([base, slow])
    np.testing.assert_allclose(fam.fleet(0).effective_speed(),
                               base.effective_speed())
    np.testing.assert_allclose(fam.fleet(1).effective_speed(),
                               slow.effective_speed())
    np.testing.assert_allclose(fam.effective_speeds()[1],
                               slow.effective_speed())
    # shared nominal speeds stay a single (V,) vector
    assert fam.speed.ndim == 1
