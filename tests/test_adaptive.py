"""Closed-loop adaptive replay: the controller chases drift on a crafted
trace, survives the replay edge cases (zero/one-tick traces, removal floor,
back-to-back whole-region outages), keeps its dispatch count O(reconfigs),
and is deterministic under a fixed seed.

Belief handoff (PR 10): with uncertainty disabled and the prior set to the
base fleet, the belief-enabled controller reproduces the legacy
RegretReport BITWISE; with a learned prior it beats the blind controller on
a cold-start fixture."""

import dataclasses

import numpy as np
import pytest

from repro.adapt import (AdaptiveConfig, RegretReport, reconfiguration_cost,
                         run_adaptive)
from repro.core.placement import uniform_placement
from repro.sim import MIN_ALIVE_DEVICES, ScenarioConfig, replay_trace
from repro.sim.scenarios import TraceEvent, scenario_batch
from repro.streaming.engine import StreamingEngine
from repro.streaming.operators import StreamGraph, filter_op, map_op, source

CFG = ScenarioConfig(trace_len=8, base_rate=32.0, n_regions=(3, 3),
                     devices_per_region=(2, 2))
CTL = AdaptiveConfig(window=3, cooldown=2, drift_threshold=0.3,
                     amortize_ticks=8.0, n_candidates=32,
                     oracle_candidates=16)


def _stream_graph():
    ops = [
        source(),
        map_op("normalize", lambda r: (r - r.mean()) / (r.std() + 1e-9)),
        filter_op("threshold", lambda r: r[:, 0] > -0.5, selectivity=0.7),
    ]
    return StreamGraph(ops, [(0, 1), (1, 2)])


def _engine(seed: int = 0, cfg: ScenarioConfig = CFG):
    rng = np.random.default_rng(seed)
    sg = _stream_graph()
    s = scenario_batch(rng, 1, cfg, graph=sg.meta)[0]
    x = uniform_placement(sg.meta.n_ops,
                          np.ones((sg.meta.n_ops, s.n_devices), bool))
    return StreamingEngine(sg, s.fleet, x, observed="work"), s


def _rate_ticks(t0: int, n: int, rate: float = 32.0) -> list[TraceEvent]:
    return [TraceEvent(t=t0 + k, kind="rate", rate=rate) for k in range(n)]


def _outage_trace(region: int = 0, pre: int = 4, dwell: int = 14,
                  post: int = 4, factor: float = 32.0) -> list[TraceEvent]:
    """Healthy warmup, one long whole-region outage, recovery tail."""
    return (_rate_ticks(0, pre)
            + [TraceEvent(t=pre, kind="outage", rate=0.0, device=region,
                          factor=factor)]
            + _rate_ticks(pre, dwell)
            + [TraceEvent(t=pre + dwell, kind="recover", rate=0.0,
                          device=region, factor=factor)]
            + _rate_ticks(pre + dwell, post))


def test_adaptive_beats_static_on_drifting_trace():
    """One long whole-region outage: the controller refits, re-places away
    from the dead region, and ends with lower cumulative true F than the
    static seed placement — reconfiguration charges included."""
    eng, _ = _engine(0)
    trace = _outage_trace(region=int(np.asarray(eng.fleet.region)[0]))
    rep = run_adaptive(eng, trace, np.random.default_rng(1), CTL)
    assert rep.n_ticks == 22
    assert rep.n_reconfigs >= 1
    assert rep.cum_adaptive < rep.cum_static
    # the oracle is the hindsight floor of the three policies
    assert rep.cum_oracle <= rep.cum_adaptive + 1e-6
    assert rep.cum_oracle <= rep.cum_static + 1e-6
    # charges only appear on reconfiguration ticks
    assert (rep.reconfig_costs > 0).sum() <= rep.n_reconfigs


def test_adaptive_dispatches_scale_with_reconfigs_not_ticks():
    """Doubling the healthy tail adds ticks but no new drift: the dispatch
    count stays bounded by adaptations, far below the tick count."""
    eng, _ = _engine(0)
    region = int(np.asarray(eng.fleet.region)[0])
    rep_short = run_adaptive(eng, _outage_trace(region, post=4),
                             np.random.default_rng(1), CTL)
    eng2, _ = _engine(0)
    rep_long = run_adaptive(eng2, _outage_trace(region, post=24),
                            np.random.default_rng(1), CTL)
    for rep in (rep_short, rep_long):
        adaptations = rep.n_refits + rep.n_reconfigs
        assert rep.controller_dispatches <= 2 * max(adaptations, 1)
        assert rep.controller_dispatches <= rep.n_ticks / 2
    # +20 quiet ticks must not add +20 dispatches
    assert rep_long.controller_dispatches \
        <= rep_short.controller_dispatches + 2


def test_zero_length_trace_is_a_noop():
    eng, _ = _engine(2)
    rep = run_adaptive(eng, [], np.random.default_rng(0), CTL)
    assert isinstance(rep, RegretReport)
    assert rep.n_ticks == 0
    assert rep.cum_static == rep.cum_adaptive == rep.cum_oracle == 0.0
    assert rep.n_refits == rep.n_reconfigs == 0
    assert rep.controller_dispatches == 0


def test_one_tick_trace_no_refit_no_crash():
    eng, _ = _engine(2)
    rep = run_adaptive(eng, _rate_ticks(0, 1), np.random.default_rng(0), CTL)
    assert rep.n_ticks == 1
    assert rep.n_refits == 0 and rep.n_reconfigs == 0
    assert rep.controller_dispatches == 0
    assert np.isnan(rep.drift[0])  # one tick cannot carry a drift estimate


def test_trace_hits_min_alive_floor_mid_adaptation():
    """Removals interleaved with ticks drive a 3-device fleet to the
    MIN_ALIVE_DEVICES floor while the controller is running: exactly one
    removal lands, the rest are dropped, and the loop keeps going."""
    cfg = ScenarioConfig(trace_len=4, n_regions=(3, 3),
                         devices_per_region=(1, 1))
    eng, s = _engine(3, cfg)
    assert s.n_devices == 3
    trace = _rate_ticks(0, 4)
    for d in range(3):
        trace.append(TraceEvent(t=4 + d, kind="remove", rate=0.0, device=d))
        trace += _rate_ticks(5 + d, 2)
    rep = run_adaptive(eng, trace, np.random.default_rng(0), CTL)
    assert eng.fleet.n_devices == MIN_ALIVE_DEVICES == 2
    assert eng.x.shape[1] == 2
    assert rep.n_ticks == 10
    assert np.isfinite(rep.f_adaptive).all()


def test_back_to_back_region_outages_replay():
    """Two whole-region outages in consecutive events (different regions),
    then recoveries: replay applies and counts them, the engine's link
    state composes and returns to the original after both recover."""
    eng, _ = _engine(4)
    regions = np.asarray(eng.fleet.region)
    r0, r1 = int(regions[0]), int(regions[-1])
    assert r0 != r1
    com0 = np.asarray(eng.fleet.com_matrix()).copy()
    trace = (_rate_ticks(0, 2)
             + [TraceEvent(t=2, kind="outage", rate=0.0, device=r0,
                           factor=16.0),
                TraceEvent(t=2, kind="outage", rate=0.0, device=r1,
                           factor=16.0)]
             + _rate_ticks(2, 2)
             + [TraceEvent(t=4, kind="recover", rate=0.0, device=r0,
                           factor=16.0),
                TraceEvent(t=4, kind="recover", rate=0.0, device=r1,
                           factor=16.0)]
             + _rate_ticks(4, 2))
    rep = replay_trace(eng, trace, np.random.default_rng(0))
    assert rep.n_outages == 2
    assert len(rep.steps) == 6
    np.testing.assert_allclose(np.asarray(eng.fleet.com_matrix()), com0,
                               rtol=1e-9)


def test_back_to_back_region_outages_through_controller():
    """The same back-to-back outage pattern through the adaptive loop: no
    crash, finite regret series, and the belief-side machinery survives a
    window where BOTH outaged regions carry mass."""
    eng, _ = _engine(4)
    regions = np.asarray(eng.fleet.region)
    r0, r1 = int(regions[0]), int(regions[-1])
    trace = (_rate_ticks(0, 4)
             + [TraceEvent(t=4, kind="outage", rate=0.0, device=r0,
                           factor=16.0),
                TraceEvent(t=4, kind="outage", rate=0.0, device=r1,
                           factor=16.0)]
             + _rate_ticks(4, 8)
             + [TraceEvent(t=12, kind="recover", rate=0.0, device=r0,
                           factor=16.0),
                TraceEvent(t=12, kind="recover", rate=0.0, device=r1,
                           factor=16.0)]
             + _rate_ticks(12, 4))
    rep = run_adaptive(eng, trace, np.random.default_rng(0), CTL)
    assert rep.n_ticks == 16
    assert np.isfinite(rep.f_adaptive).all()
    assert np.isfinite(rep.f_static).all()
    assert rep.cum_oracle <= rep.cum_static + 1e-6


def test_controller_is_deterministic_under_fixed_seed():
    """Same engine seed + same controller rng seed ⇒ identical decisions
    and regret series across two runs (guards the observed='work' busy
    accounting and every random draw in the loop)."""
    reps = []
    for _ in range(2):
        eng, _ = _engine(5)
        trace = _outage_trace(region=int(np.asarray(eng.fleet.region)[0]))
        reps.append(run_adaptive(eng, trace, np.random.default_rng(9), CTL))
    a, b = reps
    assert a.reconfig_ticks == b.reconfig_ticks
    assert a.refit_ticks == b.refit_ticks
    np.testing.assert_array_equal(a.f_adaptive, b.f_adaptive)
    np.testing.assert_array_equal(a.f_static, b.f_static)
    np.testing.assert_array_equal(a.f_oracle, b.f_oracle)
    np.testing.assert_array_equal(a.reconfig_costs, b.reconfig_costs)
    assert a.controller_dispatches == b.controller_dispatches


def _bitwise_equal_reports(a: RegretReport, b: RegretReport) -> None:
    assert a.reconfig_ticks == b.reconfig_ticks
    assert a.refit_ticks == b.refit_ticks
    assert a.controller_dispatches == b.controller_dispatches
    assert a.final_com_scale == b.final_com_scale
    np.testing.assert_array_equal(a.f_adaptive, b.f_adaptive)
    np.testing.assert_array_equal(a.f_static, b.f_static)
    np.testing.assert_array_equal(a.f_oracle, b.f_oracle)
    np.testing.assert_array_equal(a.reconfig_costs, b.reconfig_costs)
    np.testing.assert_array_equal(a.drift, b.drift)


def test_belief_off_uncertainty_reproduces_legacy_bitwise():
    """use_belief=True with no prior, no posterior sampling and no probing
    is passive bookkeeping: the belief state updates alongside the run but
    touches neither the rng stream nor any decision — the RegretReport is
    BITWISE identical to the legacy controller on the crafted-outage
    fixture (the PR 5 differential guarantee)."""
    reps = []
    for cfg in (CTL, dataclasses.replace(CTL, use_belief=True)):
        eng, _ = _engine(0)
        trace = _outage_trace(region=int(np.asarray(eng.fleet.region)[0]))
        reps.append(run_adaptive(eng, trace, np.random.default_rng(1), cfg))
    _bitwise_equal_reports(*reps)


# -- cold start: learned prior vs blind controller -----------------------------

def _snapshot_fleet(fleet):
    from repro.core.devices import ExplicitFleet

    return ExplicitFleet(
        com_cost=np.asarray(fleet.com_matrix(), dtype=np.float64).copy(),
        speed=np.asarray(fleet.effective_speed(), dtype=np.float64).copy(),
        region=np.asarray(fleet.region).copy())


def _slow_tier_devices(fleet) -> np.ndarray:
    from repro.belief import speed_percentile

    pct = speed_percentile(np.asarray(fleet.effective_speed()))
    return np.flatnonzero(pct < 1.0 / 3.0)


def _slow_tier_trace(fleet, factor: float, n_ticks: int) -> list[TraceEvent]:
    """The cold-start world: the fleet's slow speed tier runs ``factor``×
    slower from tick 0 — a FEATURE-correlated truth a transferable prior
    can predict for devices it never observed."""
    events = [TraceEvent(t=0, kind="degrade", rate=0.0, device=int(u),
                         factor=factor)
              for u in _slow_tier_devices(fleet)]
    return events + _rate_ticks(0, n_ticks)


def _train_slow_tier_prior(factor: float, seeds=(10, 11, 12)):
    """Harvest training tuples from replay traces of OTHER fleets (the
    tuples replay generates for free) and fit the ridge prior on them."""
    from repro.core.calibration import ReplayWindow
    from repro.belief import fit_prior
    from repro.sim import merge_tuples, training_tuples

    parts = []
    for seed in seeds:
        eng, _ = _engine(seed)
        base = _snapshot_fleet(eng.fleet)
        trace = _slow_tier_trace(eng.fleet, factor, n_ticks=6)
        rep = replay_trace(eng, trace, np.random.default_rng(seed))
        window = ReplayWindow.from_report(rep, eng.x)
        parts.append(training_tuples(eng.graph.meta, base, window))
    corpus = merge_tuples(parts)
    return fit_prior(device_features=corpus.device_features,
                     device_log_degrade=corpus.device_log_degrade,
                     device_weights=corpus.device_weights)


def test_cold_start_belief_prior_beats_blind_adaptive():
    """Cold-start acceptance: a never-observed fleet whose slow tier is
    degraded from tick 0.  The blind controller must wait for a drift
    window before reacting; the belief controller's learned prior prices
    the slow tier up front and re-optimizes at the first tick — strictly
    lower cumulative true-F regret (vs its own oracle)."""
    factor = 8.0
    prior = _train_slow_tier_prior(factor)
    pred = prior.predict_degrade  # sanity: the prior actually learned tiers
    # both controllers amortize over the same (default) horizon — the CTL
    # fixture's tight 8-tick budget is for the outage tests above
    blind_cfg = dataclasses.replace(CTL, amortize_ticks=20.0)
    belief_cfg = dataclasses.replace(blind_cfg, use_belief=True,
                                     belief_sampling=True)
    reports = {}
    for name, cfg, pr in (("blind", blind_cfg, None),
                          ("belief", belief_cfg, prior)):
        eng, _ = _engine(6)
        if pr is not None:
            from repro.belief import device_features
            feats = device_features(eng.fleet)
            slow = _slow_tier_devices(eng.fleet)
            assert np.min(pred(feats)[slow]) > 2.0  # tier recognized
        trace = _slow_tier_trace(eng.fleet, factor, n_ticks=32)
        reports[name] = run_adaptive(eng, trace, np.random.default_rng(2),
                                     cfg, prior=pr)
    # regret against the best hindsight floor EITHER run found — the
    # per-run oracle consumes a different rng stream, so comparing each
    # policy to its own oracle would reward oracle luck, not the policy
    floor = min(r.cum_oracle for r in reports.values())
    regrets = {k: r.cum_adaptive - floor for k, r in reports.items()}
    assert regrets["belief"] < regrets["blind"]
    assert reports["belief"].cum_adaptive < reports["blind"].cum_adaptive


def test_reconfiguration_cost_properties():
    from repro.core.devices import ExplicitFleet
    from repro.core.graph import Operator, OpGraph

    g = OpGraph([Operator("a", out_bytes=2.0), Operator("b", out_bytes=4.0)],
                [(0, 1)])
    com = np.array([[0.0, 1.0, 5.0],
                    [1.0, 0.0, 2.0],
                    [5.0, 2.0, 0.0]])
    fleet = ExplicitFleet(com_cost=com)
    x = np.array([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0]])
    assert reconfiguration_cost(x, x, g, fleet) == 0.0
    # moving op a's mass 0→1 prices com[0,1]=1 × bytes 2
    x2 = np.array([[0.0, 1.0, 0.0], [0.0, 1.0, 0.0]])
    assert reconfiguration_cost(x, x2, g, fleet) == pytest.approx(2.0)
    # greedy routing prefers the cheap destination: half the mass must go
    # somewhere, and 0→1 (cost 1) is picked before 0→2 (cost 5)
    x3 = np.array([[0.0, 0.5, 0.5], [0.0, 1.0, 0.0]])
    assert reconfiguration_cost(x, x3, g, fleet) == \
        pytest.approx(2.0 * (0.5 * 1.0 + 0.5 * 5.0))
    with pytest.raises(ValueError):
        reconfiguration_cost(x, x[:, :2], g, fleet)
