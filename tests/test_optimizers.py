"""Placement-optimizer tests: all heuristics vs the exhaustive oracle on
tiny instances; feasibility; DQ co-optimization."""

import numpy as np
import pytest

from repro.core import (
    CostConfig,
    DQCoupling,
    ExplicitFleet,
    PlacementProblem,
    exhaustive_search,
    greedy_transfer,
    linear_graph,
    diamond_graph,
    projected_gradient,
    random_search,
    simulated_annealing,
    uniform_placement,
    validate_placement,
)

COM = np.array([[0.0, 1.5, 2.0],
                [1.5, 0.0, 1.0],
                [2.0, 1.0, 0.0]])


@pytest.fixture
def paper_problem():
    g = linear_graph([1.0, 1.5, 1.0])
    fleet = ExplicitFleet(com_cost=COM)
    # capacity 1.2 per device forces genuine spreading (otherwise the
    # trivial optimum is everything colocated at latency 0)
    dq = DQCoupling(cap0=np.full(3, 1.2), load=np.full(3, 0.2))
    return PlacementProblem(g, fleet, beta=1.0, dq=dq)


def test_all_optimizers_beat_uniform(paper_problem):
    prob = paper_problem
    avail = prob.availability()
    base = prob.score(
        np.full((3, 3), 1 / 3), 0.0)
    rng = np.random.default_rng(0)
    results = {
        "greedy": greedy_transfer(prob),
        "sa": simulated_annealing(prob, rng, steps=2500),
        "pg": projected_gradient(prob, steps=120),
        "rs": random_search(prob, rng, n_candidates=512),
    }
    for name, res in results.items():
        validate_placement(res.x, avail)
        assert prob.feasible(res.x, res.dq_fraction), name
        assert res.F <= base + 1e-9, f"{name}: {res.F} vs uniform {base}"


def test_heuristics_near_exhaustive(paper_problem):
    """Continuous heuristics should match or beat the granularity-4 grid
    oracle (they search a superset of the grid)."""
    prob = paper_problem
    oracle = exhaustive_search(prob, granularity=4)
    greedy = greedy_transfer(prob)
    pg = projected_gradient(prob, steps=150)
    assert min(greedy.F, pg.F) <= oracle.F * 1.10 + 1e-9


def test_exhaustive_is_grid_optimal():
    """On a 2-op/2-dev instance, brute force over a fine grid by hand."""
    g = linear_graph([1.0, 1.0])
    fleet = ExplicitFleet(com_cost=np.array([[0.0, 1.0], [1.0, 0.0]]))
    prob = PlacementProblem(g, fleet)
    res = exhaustive_search(prob, granularity=8)
    # colocation is optimal: latency 0
    assert res.F == pytest.approx(0.0, abs=1e-12)


def test_dq_pinned_to_one_when_free():
    """With no capacity coupling and β>0, more DQ strictly improves F, so
    every optimizer should end at dq=1 (paper eq. 8 logic)."""
    g = linear_graph([1.0, 1.5, 1.0])
    fleet = ExplicitFleet(com_cost=COM, available=np.array(
        [[1, 1, 0], [0, 1, 1], [1, 0, 1]], dtype=bool))
    prob = PlacementProblem(g, fleet, beta=2.0)
    res = greedy_transfer(prob)
    assert res.dq_fraction == pytest.approx(1.0)


def test_availability_respected():
    g = diamond_graph()
    avail = np.array([[1, 0, 0],
                      [0, 1, 1],
                      [1, 1, 0],
                      [0, 0, 1]], dtype=bool)
    fleet = ExplicitFleet(com_cost=COM, available=avail)
    prob = PlacementProblem(g, fleet)
    for res in (greedy_transfer(prob),
                simulated_annealing(prob, np.random.default_rng(1), steps=800),
                projected_gradient(prob, steps=80)):
        validate_placement(res.x, avail)


def test_degrade_device_shifts_mass():
    """Straggler mitigation: after degrading device 0 by 8×, re-optimizing
    moves mass off it."""
    g = linear_graph([1.0, 1.0, 1.0])
    fleet = ExplicitFleet(com_cost=COM)
    dq = DQCoupling(cap0=np.full(3, 1.2), load=np.zeros(3))
    prob = PlacementProblem(g, fleet, dq=dq)
    res0 = greedy_transfer(prob)
    mass0 = res0.x[:, 0].sum()
    degraded = fleet.degrade_device(0, 8.0)
    prob2 = PlacementProblem(g, degraded, dq=dq)
    res1 = greedy_transfer(prob2, x0=res0.x)
    assert res1.x[:, 0].sum() <= mass0 + 1e-9
    assert res1.F <= prob2.score(res0.x, res0.dq_fraction) + 1e-9


# -- dispatch accounting survives the core shims ------------------------------

def test_dispatch_counter_survives_shim_path(paper_problem):
    """Every core-level optimizer entry point reports its jitted dispatch
    count: the batched shims forward the engine's counter, and
    projected_gradient counts its grad_fn dispatches (regression: it used
    to silently report 0 while issuing steps x temps jitted calls)."""
    prob = paper_problem
    res = projected_gradient(prob, steps=25, temps=(0.1, 0.02))
    assert res.dispatches == 25 * 2
    for res in (random_search(prob, np.random.default_rng(0),
                              n_candidates=64),
                greedy_transfer(prob)):
        assert res.dispatches >= 1
        assert res.dispatches <= res.evals
