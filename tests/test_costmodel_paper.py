"""Paper-fidelity tests: the §3 worked example, digit for digit.

Tables 3–4 of the paper: 3-op linear DAG (s0=1, s1=1.5), 3 devices, α=0.
Every number the paper states is asserted here — this is the faithful
reproduction anchor (DESIGN.md §1)."""

import numpy as np
import pytest

from repro.core import (
    CostConfig,
    ExplicitFleet,
    edge_latency,
    latency,
    latency_via_paths,
    linear_graph,
    objective_F,
)

COM = np.array([[0.0, 1.5, 2.0],
                [1.5, 0.0, 1.0],
                [2.0, 1.0, 0.0]])
X_PAPER = np.array([[0.8, 0.2, 0.0],
                    [0.7, 0.0, 0.3],
                    [0.3, 0.4, 0.3]])
X_MODIFIED = np.array([[0.8, 0.2, 0.0],
                       [0.7, 0.0, 0.3],
                       [0.0, 0.4, 0.6]])


@pytest.fixture
def setup():
    return linear_graph([1.0, 1.5, 1.0]), ExplicitFleet(com_cost=COM)


def test_edge_0_to_1_is_048(setup):
    g, fleet = setup
    # paper: device0 0.48, device1 0.27, device2 0 → max 0.48
    lat = edge_latency(X_PAPER[0], X_PAPER[1], 1.0, fleet)
    assert lat == pytest.approx(0.48, abs=1e-12)


def test_edge_1_to_2_is_126(setup):
    g, fleet = setup
    # paper: max{1.26, 0, 0.45} = 1.26
    lat = edge_latency(X_PAPER[1], X_PAPER[2], 1.5, fleet)
    assert lat == pytest.approx(1.26, abs=1e-12)


def test_per_device_intermediates(setup):
    """The paper spells out 0.27 (device 1) and 0.45 (device 2)."""
    _, fleet = setup
    per_u_01 = X_PAPER[0] * 1.0 * (COM @ X_PAPER[1])
    assert per_u_01[1] == pytest.approx(0.27)
    assert per_u_01[2] == pytest.approx(0.0)
    per_u_12 = X_PAPER[1] * 1.5 * (COM @ X_PAPER[2])
    assert per_u_12[2] == pytest.approx(0.45)


def test_total_latency_174(setup):
    g, fleet = setup
    assert latency(g, fleet, X_PAPER) == pytest.approx(1.74, abs=1e-12)
    assert latency_via_paths(g, fleet, X_PAPER) == pytest.approx(1.74)


def test_F_beta1_dq05_is_116(setup):
    g, fleet = setup
    lat = latency(g, fleet, X_PAPER)
    assert objective_F(lat, 0.5, 1.0) == pytest.approx(1.16, abs=1e-12)


def test_modified_plan_latency_237(setup):
    g, fleet = setup
    # paper: edge 1→2 becomes max{1.89, 0, 0.18} = 1.89; total 2.37
    lat12 = edge_latency(X_MODIFIED[1], X_MODIFIED[2], 1.5, fleet)
    assert lat12 == pytest.approx(1.89, abs=1e-12)
    assert latency(g, fleet, X_MODIFIED) == pytest.approx(2.37, abs=1e-12)


def test_F_flip_with_beta(setup):
    """β=1: modified plan worse (1.185 > 1.16); β=2: better (0.79 < 0.87)."""
    g, fleet = setup
    lat0 = latency(g, fleet, X_PAPER)
    lat1 = latency(g, fleet, X_MODIFIED)
    assert objective_F(lat1, 1.0, 1.0) == pytest.approx(1.185, abs=1e-12)
    assert objective_F(lat1, 1.0, 1.0) > objective_F(lat0, 0.5, 1.0)
    f0 = objective_F(lat0, 0.5, 2.0)
    f1 = objective_F(lat1, 1.0, 2.0)
    assert f0 == pytest.approx(0.87, abs=1e-12)
    assert f1 == pytest.approx(0.79, abs=1e-12)
    assert f1 < f0  # the paper's trade-off flip


def test_beta_zero_removes_dq(setup):
    g, fleet = setup
    lat = latency(g, fleet, X_PAPER)
    assert objective_F(lat, 1.0, 0.0) == lat


def test_alpha_enabled_links(setup):
    """α>0 adds α·enabledLinks per edge; count for edge 0→1 with the paper
    placement: nz(x0)={0,1}, nz(x1)={0,2} → 2·2 − |{0}| = 3 links."""
    g, fleet = setup
    base = edge_latency(X_PAPER[0], X_PAPER[1], 1.0, fleet)
    with_alpha = edge_latency(X_PAPER[0], X_PAPER[1], 1.0, fleet,
                              CostConfig(alpha=0.1))
    assert with_alpha == pytest.approx(base + 0.1 * 3)
