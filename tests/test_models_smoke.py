"""Per-arch smoke tests: REDUCED same-family configs, one forward + one
train step on CPU, asserting output shapes and no NaNs (assignment
requirement).  Full configs are exercised only by the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, SHAPES
from repro.models.api import analytic_flops, build_model, count_params
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.steps import make_train_step


def _batch_for(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["audio_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)

    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))

    opt_cfg = AdamWConfig(lr=1e-3, bits8=False)
    opt_state = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(model, cfg, opt_cfg))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0.0
    # params actually changed (skip zero-size placeholder leaves)
    deltas = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()) if a.size else 0.0,
        params, params2)
    assert max(jax.tree.leaves(deltas)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_path(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 8
    batch = _batch_for(cfg, B, S)
    batch.pop("labels")
    cache = model.init_cache(B, S + 4)
    last, cache = jax.jit(model.prefill)(params, batch, cache)
    assert last.shape == (B, 1, cfg.vocab_padded)
    tok = jnp.argmax(last[:, 0, :cfg.vocab], -1).astype(jnp.int32)[:, None]
    lg, cache = jax.jit(model.decode_step)(params, cache, jnp.int32(S), tok)
    assert lg.shape == (B, 1, cfg.vocab_padded)
    assert not bool(jnp.isnan(lg).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_analytics(arch):
    """Full configs: param counts are in the published ballpark and the
    analytic flops are positive for every runnable shape."""
    cfg = get_config(arch)
    total, active = count_params(cfg)
    expected = {
        "olmo_1b": 1.3e9, "granite_8b": 8.2e9, "deepseek_coder_33b": 33e9,
        "qwen3_32b": 33e9, "mamba2_1_3b": 1.4e9, "arctic_480b": 477e9,
        "grok_1_314b": 316e9, "zamba2_1_2b": 1.2e9,
        "llama_3_2_vision_11b": 10e9, "whisper_large_v3": 1.6e9,
    }[arch]
    assert total == pytest.approx(expected, rel=0.12)
    assert active > 0
    if cfg.family != "hybrid":
        # hybrid (zamba2) REUSES its shared attention block ~7×, so
        # compute-active params legitimately exceed stored params
        assert active <= total
    for shape in SHAPES.values():
        f = analytic_flops(cfg, shape.seq_len, shape.global_batch, shape.kind)
        assert f > 0


def test_mamba_chunk_invariance():
    cfg = get_smoke_config("mamba2_1_3b").replace(act_dtype="float32")
    toks = jnp.arange(2 * 24, dtype=jnp.int32).reshape(2, 24) % cfg.vocab
    outs = []
    for chunk in (4, 8, 24):
        m = build_model(cfg.replace(ssm_chunk=chunk))
        p = m.init_params(jax.random.PRNGKey(0))
        # each chunk size builds a distinct model/program — recompiling per
        # iteration is the point of the invariance check
        lg, _ = jax.jit(m.forward)(p, {"tokens": toks})  # repro: ignore[no-silent-retrace]
        outs.append(np.asarray(lg))
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=2e-4)


@pytest.mark.parametrize("arch", ["granite_8b", "zamba2_1_2b",
                                  "llama_3_2_vision_11b", "whisper_large_v3"])
def test_decode_matches_forward(arch):
    """Greedy decode step == forward on the extended sequence (exactness of
    KV caches / SSM state across all cache layouts)."""
    cfg = get_smoke_config(arch).replace(act_dtype="float32")
    if cfg.moe_experts:
        cfg = cfg.replace(moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch_for(cfg, B, S)
    batch.pop("labels")
    cache = model.init_cache(B, S + 2)
    last, cache = jax.jit(model.prefill)(params, batch, cache)
    lg_full, _ = jax.jit(model.forward)(params, batch)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(lg_full[:, -1]), atol=1e-4)
    tok = jnp.argmax(last[:, 0, :cfg.vocab], -1).astype(jnp.int32)[:, None]
    lg, _ = jax.jit(model.decode_step)(params, cache, jnp.int32(S), tok)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    full2, _ = jax.jit(model.forward)(params, b2)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full2[:, -1]), atol=5e-3)
