"""The repro.analysis linter: every rule fires on its trigger fixture and
stays silent on its negative twin, suppressions are honored, the JSON
report keeps its schema, and — the regression that matters — the shipped
``src/`` tree lints clean through the real CLI."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import RULES, lint_file, lint_paths, lint_source
from repro.analysis.engine import DEFAULT_EXCLUDED_DIRS, iter_python_files
from repro.analysis.__main__ import main as cli_main

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analysis"

#: rule id → (trigger fixture, minimum error count)
RULE_FIXTURES = {
    "no-silent-retrace": ("retrace", 2),
    "dtype-discipline": ("dtype", 3),
    "jit-purity": ("purity", 3),
    "hidden-host-sync": ("hostsync", 3),
    "rng-discipline": ("rng", 3),
    "pallas-constraints": ("pallas", 4),
}


# -- rule catalog --------------------------------------------------------------

def test_all_six_rules_registered():
    assert set(RULE_FIXTURES) <= set(RULES)
    for r in RULES.values():
        assert r.severity in ("error", "warning")
        assert r.summary  # every rule documents itself


# -- per-rule trigger + negative fixtures --------------------------------------

@pytest.mark.parametrize("rule_id", sorted(RULE_FIXTURES))
def test_rule_triggers_on_bad_fixture(rule_id):
    stem, min_errors = RULE_FIXTURES[rule_id]
    findings, _ = lint_file(FIXTURES / f"{stem}_bad.py")
    hits = [f for f in findings if f.rule == rule_id]
    errors = [f for f in hits if f.severity == "error"]
    assert len(errors) >= min_errors, [f.render() for f in findings]
    # the fixture triggers ONLY its own rule — rules don't bleed into
    # each other's fixtures
    assert {f.rule for f in findings} == {rule_id}, \
        [f.render() for f in findings]


@pytest.mark.parametrize("rule_id", sorted(RULE_FIXTURES))
def test_rule_silent_on_good_fixture(rule_id):
    stem, _ = RULE_FIXTURES[rule_id]
    findings, _ = lint_file(FIXTURES / f"{stem}_good.py")
    assert findings == [], [f.render() for f in findings]


def test_retrace_severities():
    """Loop-invariant re-wrap is an error; a per-iteration program is only
    a warning (sometimes intended — suppressible)."""
    findings, _ = lint_file(FIXTURES / "retrace_bad.py")
    sev = {f.severity for f in findings}
    assert sev == {"error", "warning"}


# -- suppressions --------------------------------------------------------------

_TRIGGER = "import numpy as np\nx = np.random.rand(3){}\n"


def test_inline_suppression_honored():
    findings, sup = lint_source("t.py", _TRIGGER.format(""))
    assert [f.rule for f in findings] == ["rng-discipline"]
    findings, sup = lint_source(
        "t.py", _TRIGGER.format("  # repro: ignore[rng-discipline]"))
    assert findings == [] and sup == 1


def test_bare_and_file_level_suppression():
    findings, sup = lint_source("t.py", _TRIGGER.format("  # repro: ignore"))
    assert findings == [] and sup == 1
    src = "# repro: ignore-file[rng-discipline]\n" + _TRIGGER.format("")
    findings, sup = lint_source("t.py", src)
    assert findings == [] and sup == 1


def test_suppressing_one_rule_keeps_others():
    src = ("import numpy as np\n"
           "import jax\n"
           "x = np.random.rand(3)  # repro: ignore[no-silent-retrace]\n")
    findings, sup = lint_source("t.py", src)
    # the suppression names a DIFFERENT rule: the rng finding survives
    assert [f.rule for f in findings] == ["rng-discipline"] and sup == 0


def test_syntax_error_is_a_finding():
    findings, _ = lint_source("t.py", "def broken(:\n")
    assert findings[0].rule == "syntax" and findings[0].severity == "error"


# -- JSON report schema --------------------------------------------------------

def test_json_report_schema():
    report = lint_paths([FIXTURES / "rng_bad.py"])
    blob = json.loads(json.dumps(report))
    assert blob["version"] == 1
    assert blob["files_checked"] == 1
    assert set(blob["counts"]) == {"error", "warning", "suppressed"}
    assert blob["counts"]["error"] >= 3
    for row in blob["findings"]:
        assert set(row) == {"rule", "severity", "path", "line", "col",
                            "message"}
        assert row["rule"] in RULES and row["line"] >= 1


def test_fixture_dirs_excluded_by_default():
    """`fixtures/` is skipped on directory walks (its violations are
    deliberate) but still lintable when named as an explicit file."""
    walked = list(iter_python_files([REPO / "tests"]))
    assert not any("fixtures" in f.parts for f in walked)
    assert "fixtures" in DEFAULT_EXCLUDED_DIRS
    report = lint_paths([FIXTURES])  # directory walk: everything excluded
    assert report["files_checked"] == 0


# -- the CLI and the clean-tree regression -------------------------------------

def test_cli_select_unknown_rule_exits_2(capsys):
    assert cli_main(["--select", "not-a-rule", str(FIXTURES)]) == 2


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in RULE_FIXTURES:
        assert rid in out


def test_cli_bad_fixture_fails_json(capsys):
    rc = cli_main(["--json", str(FIXTURES / "purity_bad.py")])
    assert rc == 1
    blob = json.loads(capsys.readouterr().out)
    assert blob["counts"]["error"] >= 3


def test_src_tree_lints_clean_via_module_invocation():
    """Acceptance: ``python -m repro.analysis src/`` exits 0 — the shipped
    tree satisfies its own invariants (CI keeps it that way)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 error(s)" in proc.stdout
