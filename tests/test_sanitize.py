"""The runtime sanitizer: typed AnalysisError on NaN/shape/dtype/domain
violations and retrace-budget trips, state save/restore, and — the cost
contract — bitwise-identical score_batch results with the sanitizer on."""

import numpy as np
import pytest

from repro.analysis import AnalysisError, sanitize
from repro.core import (CostConfig, ExplicitFleet, PlacementProblem,
                        random_dag, random_placement)
from repro.search.engine import BatchedProblem
from repro.sim.batched import BatchedEvaluator, pack_placements


@pytest.fixture
def prob():
    rng = np.random.default_rng(3)
    g = random_dag(6, 0.5, rng)
    lat = rng.random((5, 5))
    com = (lat + lat.T) / 2
    np.fill_diagonal(com, 0.0)
    return PlacementProblem(g, ExplicitFleet(com_cost=com), beta=1.0)


@pytest.fixture
def xs(prob):
    rng = np.random.default_rng(4)
    avail = np.ones((6, 5), bool)
    return np.stack([random_placement(6, avail, rng, 0.4)
                     for _ in range(8)])


DQS = np.linspace(0.0, 0.8, 4)


# -- state machine -------------------------------------------------------------

def test_disabled_by_default_and_context_restores():
    assert not sanitize.enabled()
    with sanitize.sanitized(retrace_budget=2) as st:
        assert sanitize.enabled() and st.retrace_budget == 2
        with sanitize.sanitized(retrace_budget=9):
            assert sanitize.state().retrace_budget == 9
        assert sanitize.state().retrace_budget == 2
    assert not sanitize.enabled()
    assert sanitize.state().retrace_budget is None


def test_analysis_error_carries_rule_and_context():
    err = AnalysisError("nan-guard", "boom", bucket=16, name="lat")
    assert err.rule == "nan-guard"
    assert err.context == {"bucket": 16, "name": "lat"}
    assert "[nan-guard]" in str(err) and "bucket=16" in str(err)


# -- domain-check helpers ------------------------------------------------------

def test_check_placements_dtype_shape_nan():
    ok = np.zeros((3, 6, 5))
    sanitize.check_placements(ok, 6, 5)
    with pytest.raises(AnalysisError) as ei:
        sanitize.check_placements(np.empty((3, 4, 5)), 6, 5, bucket=4)
    assert ei.value.rule == "score-batch-domain"
    assert ei.value.context["bucket"] == 4
    with pytest.raises(AnalysisError):
        sanitize.check_placements(np.array([object()], dtype=object), 6, 5)
    bad = ok.copy()
    bad[0, 0, 0] = np.nan
    sanitize.check_placements(bad, 6, 5)  # finite off: NaN passes
    with pytest.raises(AnalysisError):
        sanitize.check_placements(bad, 6, 5, finite=True)


def test_check_dq_and_finite():
    sanitize.check_dq([0.0, 0.5, 1.0])
    for bad in ([1.5], [-0.1], [np.nan]):
        with pytest.raises(AnalysisError) as ei:
            sanitize.check_dq(bad)
        assert ei.value.rule == "dq-domain"
    sanitize.check_finite("x", [1.0, np.inf])  # inf = infeasible marker: ok
    with pytest.raises(AnalysisError):
        sanitize.check_finite("x", [1.0, np.inf], allow_inf=False)
    with pytest.raises(AnalysisError) as ei:
        sanitize.check_finite("x", [np.nan], bucket=8)
    assert ei.value.context["bucket"] == 8


# -- score_batch integration ---------------------------------------------------

def test_score_batch_upfront_shape_validation(prob, xs):
    bp = BatchedProblem(prob, chunk=64)
    with pytest.raises(AnalysisError) as ei:
        bp.score_batch(xs[:, :4, :], DQS)  # wrong n_ops
    assert ei.value.rule == "score-batch-domain"
    assert "bucket" in ei.value.context  # names the offending bucket
    with pytest.raises(AnalysisError):
        bp.score_batch(np.zeros(3), DQS)  # not even a placement batch
    assert bp.dispatches == 0  # rejected BEFORE any dispatch


def test_score_batch_dq_domain_when_enabled(prob, xs):
    bp = BatchedProblem(prob, chunk=64)
    bp.score_batch(xs, np.array([0.2, 2.0]))  # disabled: unchecked
    with sanitize.sanitized():
        with pytest.raises(AnalysisError) as ei:
            bp.score_batch(xs, np.array([0.2, 2.0]))
    assert ei.value.rule == "dq-domain"


def test_score_batch_nan_candidates_when_enabled(prob, xs):
    bad = xs.copy()
    bad[0, 0, 0] = np.nan
    bp = BatchedProblem(prob, chunk=64)
    with sanitize.sanitized():
        with pytest.raises(AnalysisError) as ei:
            bp.score_batch(bad, DQS)
    # NaN mass propagates through the dispatch and trips the output
    # nan-guard (cheaper than scanning every candidate batch up front)
    assert ei.value.rule == "nan-guard"
    assert "bucket" in ei.value.context


def test_retrace_budget_trips(prob, xs):
    with sanitize.sanitized(retrace_budget=0):
        with pytest.raises(AnalysisError) as ei:
            BatchedProblem(prob, chunk=64).score_batch(xs, DQS)
    assert ei.value.rule == "no-silent-retrace"
    assert ei.value.context["budget"] == 0
    # budget >= the actual bucket count: clean
    with sanitize.sanitized(retrace_budget=4):
        BatchedProblem(prob, chunk=64).score_batch(xs, DQS)


def test_sanitized_scores_bitwise_identical(prob, xs):
    base = BatchedProblem(prob, chunk=64).score_batch(xs, DQS)
    with sanitize.sanitized(retrace_budget=8):
        san = BatchedProblem(prob, chunk=64).score_batch(xs, DQS)
    assert np.array_equal(base, san)  # checks only READ, never rewrite
    assert np.argmin(base) == np.argmin(san)


def test_score_pairs_validated(prob, xs):
    bp = BatchedProblem(prob, chunk=64)
    with pytest.raises(AnalysisError):
        bp.score_pairs(xs[:, :, :3], np.full(8, 0.2))
    out = bp.score_pairs(xs, np.full(8, 0.2))
    assert out.shape == (8,)


# -- score_grid integration ----------------------------------------------------

def test_score_grid_dq_guard(prob, xs):
    ev = BatchedEvaluator(prob.graph, CostConfig())
    P = pack_placements(list(xs))
    coms = np.asarray([prob.fleet.com_matrix()], dtype=np.float32)
    ev.score_grid(P, coms, dq=1.7)  # disabled: analytic domain unchecked
    with sanitize.sanitized():
        with pytest.raises(AnalysisError) as ei:
            ev.score_grid(P, coms, dq=1.7)
        assert ei.value.rule == "dq-domain"
        out = ev.score_grid(P, coms, dq=0.3)  # in-domain passes NaN guard
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ev.score_grid(P, coms, dq=0.3)))
