"""Replay + robustness: generated traces drive the real StreamingEngine and
min–max placement selection behaves like a min–max."""

import numpy as np
import pytest

from repro.core import (latency, objective_F, scenario_robust_search,
                        uniform_placement)
from repro.sim import (
    MIN_ALIVE_DEVICES,
    ScenarioConfig,
    TraceEvent,
    replay_trace,
    robust_placement,
    scenario_batch,
)
from repro.streaming.engine import StreamingEngine
from repro.streaming.operators import StreamGraph, filter_op, map_op, source

CFG = ScenarioConfig(trace_len=8, base_rate=32.0,
                     n_regions=(2, 3), devices_per_region=(2, 3))


def _stream_graph():
    ops = [
        source(),
        map_op("normalize", lambda r: (r - r.mean()) / (r.std() + 1e-9)),
        filter_op("threshold", lambda r: r[:, 0] > -0.5, selectivity=0.7),
    ]
    return StreamGraph(ops, [(0, 1), (1, 2)])


def _engine(scenario, graph):
    x = uniform_placement(
        graph.meta.n_ops,
        np.ones((graph.meta.n_ops, scenario.n_devices), bool))
    return StreamingEngine(graph, scenario.fleet, x)


def test_replay_runs_trace_and_reports_drift():
    rng = np.random.default_rng(0)
    sg = _stream_graph()
    s = scenario_batch(rng, 1, CFG, graph=sg.meta)[0]
    rep = replay_trace(_engine(s, sg), s.trace, rng)
    ticks = [e for e in s.trace if e.kind in ("rate", "burst")]
    assert len(rep.steps) == len(ticks)
    assert all(st.modeled_latency >= 0 for st in rep.steps)
    d = rep.drift()
    assert d["n_ticks"] == len(ticks)
    assert np.isfinite(d["ratio_mean"])


def test_replay_applies_degrade_and_remove():
    rng = np.random.default_rng(1)
    sg = _stream_graph()
    s = scenario_batch(rng, 1, CFG, graph=sg.meta)[0]
    v = s.n_devices
    trace = [
        TraceEvent(t=0, kind="rate", rate=32.0),
        TraceEvent(t=1, kind="degrade", rate=0.0, device=0, factor=4.0),
        TraceEvent(t=2, kind="rate", rate=32.0),
        TraceEvent(t=3, kind="remove", rate=0.0, device=1),
        TraceEvent(t=4, kind="burst", rate=128.0),
        TraceEvent(t=5, kind="remove", rate=0.0, device=1),  # dead: dropped
    ]
    eng = _engine(s, sg)
    rep = replay_trace(eng, trace, rng)
    assert rep.n_degrades == 1 and rep.n_removes == 1
    assert eng.fleet.n_devices == v - 1
    assert rep.steps[-1].n_devices == v - 1
    assert eng.x.shape == (sg.meta.n_ops, v - 1)


def test_replay_rejects_unknown_event():
    rng = np.random.default_rng(2)
    sg = _stream_graph()
    s = scenario_batch(rng, 1, CFG, graph=sg.meta)[0]
    with pytest.raises(ValueError):
        replay_trace(_engine(s, sg),
                     [TraceEvent(t=0, kind="comet", rate=1.0)], rng)


def test_replay_never_removes_below_floor():
    """Removal floor at replay time: a trace that tries to strip a 3-device
    fleet bare only gets ONE removal through — the engine keeps
    MIN_ALIVE_DEVICES (= 2) devices, matching random_trace's generation-time
    invariant."""
    rng = np.random.default_rng(5)
    sg = _stream_graph()
    cfg = ScenarioConfig(trace_len=4, n_regions=(2, 2),
                         devices_per_region=(1, 2))
    s = scenario_batch(rng, 1, cfg, n_devices=3)[0]
    assert s.n_devices == 3
    trace = [TraceEvent(t=t, kind="remove", rate=0.0, device=t)
             for t in range(3)]
    eng = _engine(s, sg)
    rep = replay_trace(eng, trace, rng)
    assert rep.n_removes == 1
    assert eng.fleet.n_devices == MIN_ALIVE_DEVICES == 2


def test_robust_search_per_scenario_dq():
    """dq as an (S,) array: scenario s's quality knob divides its grid row,
    and the reported worst case is the scenario maximizing F — which with
    per-scenario dq need NOT be the max-latency scenario."""
    rng = np.random.default_rng(6)
    scens = scenario_batch(rng, 3, CFG)
    g = scens[0].graph
    beta = 4.0
    # find the max-latency scenario for the uniform placement, then hand it
    # a big dq so its (1 + β·dq) denominator pushes another scenario to the
    # top of the F ranking
    uni = uniform_placement(g.n_ops, np.ones((g.n_ops, scens[0].n_devices),
                                             bool))
    lats_uni = [latency(g, s.fleet, uni) for s in scens]
    dq = np.zeros(3)
    dq[int(np.argmax(lats_uni))] = 1.0
    x, worst, grid = robust_placement(g, scens, rng, n_candidates=32,
                                      dq=dq, beta=beta,
                                      extra_candidates=[uni])
    # grid rows carry their own denominators
    k = int(grid.max(axis=0).argmin())
    for si, s in enumerate(scens):
        want = objective_F(latency(g, s.fleet, x), float(dq[si]), beta)
        assert grid[si, k] == pytest.approx(want, rel=2e-5, abs=1e-6)
    # search end-to-end: F / latency / dq_fraction describe the argmax-F
    # scenario, not the argmax-latency one
    res = scenario_robust_search(g, scens, rng, n_candidates=32, dq=dq,
                                 beta=beta)
    lats = [latency(g, s.fleet, res.x) for s in scens]
    fs = [objective_F(lat, float(d), beta) for lat, d in zip(lats, dq)]
    j = int(np.argmax(fs))
    assert res.F == pytest.approx(fs[j], rel=1e-12)
    assert res.latency == pytest.approx(lats[j], rel=1e-12)
    assert res.dq_fraction == float(dq[j])
    # the engineered case really exercises the fix: max F ≠ max latency
    if j != int(np.argmax(lats)):
        assert res.F < max(lats)


def test_robust_placement_is_minmax():
    """The returned placement's worst case equals the grid's min–max, and
    beats the uniform placement's worst case (uniform is candidate 0)."""
    rng = np.random.default_rng(3)
    scens = scenario_batch(rng, 4, CFG)
    g = scens[0].graph
    x, worst, grid = robust_placement(g, scens, rng, n_candidates=64)
    assert grid.shape == (4, 64)
    assert worst == pytest.approx(grid.max(axis=0).min())
    assert worst <= grid[:, 0].max() + 1e-9  # no worse than uniform
    # cross-check the winning column against the scalar oracle
    k = int(grid.max(axis=0).argmin())
    for si, s in enumerate(scens):
        assert grid[si, k] == pytest.approx(
            latency(g, s.fleet, x), rel=2e-5, abs=1e-6)


def test_scenario_robust_search_entry_point():
    rng = np.random.default_rng(4)
    scens = scenario_batch(rng, 3, CFG)
    g = scens[0].graph
    res = scenario_robust_search(g, scens, rng, n_candidates=48)
    assert res.x.shape == (g.n_ops, scens[0].n_devices)
    np.testing.assert_allclose(res.x.sum(axis=1), 1.0, atol=1e-6)
    # reported F is the true worst case of the returned placement
    worst = max(latency(g, s.fleet, res.x) for s in scens)
    assert res.F == pytest.approx(worst, rel=2e-5, abs=1e-6)
    # warm starts only help: the robust F is ≤ uniform's worst case
    uni = uniform_placement(g.n_ops, np.ones((g.n_ops, scens[0].n_devices),
                                             bool))
    worst_uni = max(latency(g, s.fleet, uni) for s in scens)
    assert res.F <= worst_uni + 1e-9
