"""Generator validity: random fleets / graphs / traces satisfy the
structural contracts the batched evaluator and replay depend on."""

import math

import numpy as np
import pytest

from repro.core.devices import ExplicitFleet, RegionFleet
from repro.sim import (
    Scenario,
    ScenarioConfig,
    diurnal_rate,
    perturbed_fleet,
    random_fleet,
    random_graph,
    random_trace,
    scenario_batch,
)
from repro.sim.scenarios import GRAPH_FAMILIES


def test_random_fleet_structure():
    rng = np.random.default_rng(0)
    for _ in range(20):
        fleet = random_fleet(rng)
        com = fleet.com_matrix()
        assert com.shape == (fleet.n_devices, fleet.n_devices)
        assert (com >= 0).all()
        np.testing.assert_allclose(com, com.T)          # symmetric links
        np.testing.assert_array_equal(np.diag(com), 0)  # local stays free
        assert (fleet.speed > 0).all()


def test_random_fleet_pinned_device_count():
    rng = np.random.default_rng(1)
    for n in (2, 5, 17):
        fleet = random_fleet(rng, n_devices=n)
        assert fleet.n_devices == n


def test_region_fleet_variant():
    rng = np.random.default_rng(2)
    cfg = ScenarioConfig(explicit_fleet=False)
    fleet = random_fleet(rng, cfg)
    assert isinstance(fleet, RegionFleet)
    assert fleet.inter.shape == (fleet.n_regions, fleet.n_regions)


def test_perturbed_fleet_is_nearby_and_valid():
    rng = np.random.default_rng(3)
    base = random_fleet(rng, n_devices=6)
    pert = perturbed_fleet(base, rng, jitter=0.2)
    assert isinstance(pert, ExplicitFleet)
    com0, com1 = base.com_matrix(), pert.com_matrix()
    np.testing.assert_allclose(com1, com1.T)
    np.testing.assert_array_equal(np.diag(com1), np.diag(com0))
    off = ~np.eye(6, dtype=bool)
    assert not np.allclose(com0[off], com1[off])  # actually perturbed
    assert (com1[off] > 0).all()


@pytest.mark.parametrize("family", GRAPH_FAMILIES)
def test_random_graph_families(family):
    rng = np.random.default_rng(4)
    for _ in range(10):
        g = random_graph(rng, family=family)
        assert g.n_ops >= 2 and g.n_edges >= 1
        assert g.sources and g.sinks        # toposort succeeded ⇒ DAG
        if family == "fan_out":
            assert len(g.sinks) == g.n_ops - 1
        if family == "fan_in":
            assert len(g.sources) == g.n_ops - 1


def test_random_graph_unknown_family():
    with pytest.raises(ValueError):
        random_graph(np.random.default_rng(0), family="torus")


def test_diurnal_rate_cycles():
    cfg = ScenarioConfig(base_rate=100.0, diurnal_amplitude=0.5,
                         diurnal_period=24)
    rates = [diurnal_rate(t, cfg) for t in range(48)]
    assert max(rates) == pytest.approx(150.0, rel=0.01)
    assert min(rates) == pytest.approx(50.0, rel=0.01)
    assert rates[0] == pytest.approx(rates[24], rel=1e-9)  # periodic


def test_random_trace_contract():
    rng = np.random.default_rng(5)
    cfg = ScenarioConfig(trace_len=200, loss_prob=0.2, degrade_prob=0.2)
    n_dev = 6
    trace = random_trace(rng, n_dev, cfg)
    removed = set()
    ticks = [e for e in trace if e.kind in ("rate", "burst")]
    assert len(ticks) == cfg.trace_len
    for ev in trace:
        if ev.kind in ("rate", "burst"):
            assert ev.rate > 0.0 and math.isfinite(ev.rate)
        elif ev.kind == "degrade":
            assert 0 <= ev.device < n_dev and ev.device not in removed
            assert ev.factor > 1.0
        elif ev.kind == "remove":
            assert 0 <= ev.device < n_dev and ev.device not in removed
            removed.add(ev.device)
    assert n_dev - len(removed) >= 2  # engine always has somewhere to place


def test_random_trace_removal_floor_boundary():
    """Regression for the generation/replay floor mismatch: a 3-device fleet
    under certain loss (loss_prob=1) loses exactly ONE device — the trace
    never removes below MIN_ALIVE_DEVICES (= 2), however long it runs."""
    from repro.sim import MIN_ALIVE_DEVICES

    rng = np.random.default_rng(12)
    cfg = ScenarioConfig(trace_len=50, loss_prob=1.0, degrade_prob=0.0)
    removes = [e for e in random_trace(rng, 3, cfg) if e.kind == "remove"]
    assert len(removes) == 3 - MIN_ALIVE_DEVICES == 1
    # at the floor itself nothing is ever removed
    assert not [e for e in random_trace(rng, MIN_ALIVE_DEVICES, cfg)
                if e.kind == "remove"]


def test_scenario_batch_stacks():
    rng = np.random.default_rng(6)
    batch = scenario_batch(rng, 5)
    assert len(batch) == 5
    g = batch[0].graph
    v = batch[0].n_devices
    for s in batch:
        assert isinstance(s, Scenario)
        assert s.graph is g            # shared job graph
        assert s.n_devices == v        # stackable fleets
    # fleets actually differ across the family
    assert not np.allclose(batch[0].fleet.com_matrix(),
                           batch[1].fleet.com_matrix())


# -- time-correlated realism: Markov outages + selectivity drift --------------

REALISM = ScenarioConfig(trace_len=60, outage_on_prob=0.1,
                         outage_off_prob=0.25, selectivity_drift_std=0.2,
                         loss_prob=0.05, degrade_prob=0.05)


def test_random_trace_markov_outage_structure():
    """Outages are a region-level Markov chain: every outage eventually
    recovers (trace ends healthy), at most one outage is open per region,
    and at least one region stays healthy at all times."""
    rng = np.random.default_rng(7)
    n_regions = 3
    trace = random_trace(rng, 8, REALISM, n_regions=n_regions, n_ops=4)
    open_out = set()
    saw_outage = False
    for ev in trace:
        if ev.kind == "outage":
            saw_outage = True
            assert ev.device not in open_out
            assert 0 <= ev.device < n_regions
            open_out.add(ev.device)
            assert len(open_out) < n_regions  # ≥1 healthy region always
            assert ev.factor == REALISM.trace_outage_factor
        elif ev.kind == "recover":
            assert ev.device in open_out
            open_out.discard(ev.device)
    assert saw_outage  # the knobs above make one overwhelmingly likely
    assert not open_out  # every outage closed by trace end


def test_random_trace_selectivity_drift_bounded():
    """Drift steps are per-op multiplicative random walks whose cumulative
    product stays within the configured bounds."""
    rng = np.random.default_rng(8)
    n_ops = 3
    trace = random_trace(rng, 6, REALISM, n_regions=2, n_ops=n_ops)
    drifts = [e for e in trace if e.kind == "drift"]
    assert drifts
    cum = np.ones(n_ops)
    lo, hi = REALISM.selectivity_drift_bounds
    for ev in drifts:
        assert 0 <= ev.device < n_ops
        cum[ev.device] *= ev.factor
        assert lo - 1e-9 <= cum[ev.device] <= hi + 1e-9


def test_random_trace_deterministic_same_seed():
    """Same seed ⇒ byte-identical traces, with every realism layer on
    (guards the Markov-outage and selectivity-drift generators)."""
    t1 = random_trace(np.random.default_rng(11), 8, REALISM,
                      n_regions=3, n_ops=4)
    t2 = random_trace(np.random.default_rng(11), 8, REALISM,
                      n_regions=3, n_ops=4)
    assert t1 == t2  # TraceEvent is a frozen dataclass — exact equality


def test_random_trace_defaults_leave_rng_stream_unchanged():
    """The realism layers are opt-in: with default (0.0) knobs the trace —
    and therefore everything drawn after it from the same rng — matches
    what the pre-Markov generator produced."""
    cfg = ScenarioConfig(trace_len=30)
    r1, r2 = np.random.default_rng(13), np.random.default_rng(13)
    base = random_trace(r1, 6, cfg)
    with_args = random_trace(r2, 6, cfg, n_regions=4, n_ops=5)
    assert base == with_args
    assert r1.random() == r2.random()  # identical stream positions


def test_region_scenario_batch_deterministic_same_seed():
    from repro.sim import region_scenario_batch

    cfg = ScenarioConfig(trace_len=12, outage_on_prob=0.1,
                         selectivity_drift_std=0.2, explicit_fleet=False)
    b1 = region_scenario_batch(np.random.default_rng(17), 3, cfg)
    b2 = region_scenario_batch(np.random.default_rng(17), 3, cfg)
    for s1, s2 in zip(b1, b2):
        np.testing.assert_array_equal(s1.fleet.inter, s2.fleet.inter)
        np.testing.assert_array_equal(s1.fleet.degrade_or_ones(),
                                      s2.fleet.degrade_or_ones())
        assert s1.trace == s2.trace
